"""Offline weight transformation (paper §3.1 stage (i), Fig. 5 left):
QAT/dense checkpoint → ternary quantize → flexible sub-2-bit trit packing →
serve-ready parameter tree. Reports per-arch bits/weight.

    PYTHONPATH=src python examples/convert_and_pack.py [--arch mamba2-1.3b]
"""
import argparse

import jax

from repro.configs import get_config
from repro.models import (
    encdec_init,
    init_lm,
    pack_params,
    packed_param_bytes,
    param_count,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    init = encdec_init if cfg.family == "encdec" else init_lm
    dense = init(jax.random.PRNGKey(0), cfg)
    packed = pack_params(dense, cfg)

    n = param_count(dense)
    db, pb = packed_param_bytes(dense), packed_param_bytes(packed)
    print(f"arch={cfg.name}")
    print(f"params:            {n:,}")
    print(f"dense bytes:       {db:,} ({8 * db / n:.2f} bits/param)")
    print(f"packed bytes:      {pb:,} ({8 * pb / n:.2f} bits/param incl. "
          f"embeddings+norms kept high-precision)")
    print(f"compression:       {db / pb:.2f}x")


if __name__ == "__main__":
    main()
