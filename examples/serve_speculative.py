"""Speculative serving quickstart: the decode path as a Vec-LUT parallel
workload. Train-free — packs random ternary weights, then serves the same
request stream several ways and prints the accept/throughput accounting:

  plain     one token per slot per tick (the M=1 decode the paper critiques)
  ngram     prompt-lookup drafting (no extra weights) + (B, K+1) verification
  adaptive  the same drafter with per-slot adaptive draft lengths: each
            slot's k_eff tracks its acceptance EWMA, and cold slots (here:
            the random half of the workload, which prompt-lookup can't
            draft for) skip drafting entirely — watch the mean_k / skip
            columns split the warm and cold halves
  tree      tree-structured verification (--tree B1,B2,...): the drafter
            proposes top-B candidates at each of the first depths and ONE
            flattened verify pass scores the whole tree — each slot's row
            carries n_nodes > K+1 candidates (the nodes/step column), the
            deepest multi-token regime the Vec-LUT kernels see
  oracle    self-drafting with the target's own weights — acceptance is 1.0
            by construction, showing the verification-side ceiling (K+1
            tokens per step)

    PYTHONPATH=src python examples/serve_speculative.py [--arch smollm-360m] [--k 4]

Greedy speculative output is token-for-token identical to plain decoding —
adaptive K included — and the script asserts it.

With --temperature T (T>0) the script instead demos stochastic drafting:
a ModelDrafter samples its proposals at the serving temperature and
rejection sampling consumes the proposal distributions (draft_probs), so
emitted tokens are exact target-model samples; the printed acceptance gap
vs greedy drafting is the probability mass greedy proposals throw away.
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_lm, pack_params
from repro.serve import ContinuousBatchingScheduler, Engine, Request
from repro.spec import SpecConfig


def serve(params, cfg, prompts, args, spec=None, temperature=0.0):
    eng = Engine(params, cfg, max_slots=args.slots, max_len=256, spec=spec,
                 temperature=temperature)
    sched = ContinuousBatchingScheduler(eng)
    reqs = [Request(rid=i, prompt=p.copy(), max_new_tokens=args.max_new)
            for i, p in enumerate(prompts)]
    sched.submit(reqs)
    stats = sched.run_to_completion()
    return [r.generated for r in reqs], stats


def fmt(stats):
    return (f"{stats.decode_tok_s:7.1f} decode tok/s   "
            f"{stats.decode_tokens_per_step:.2f} tok/step   "
            f"accept {stats.acceptance_rate:.2f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--k", type=int, default=4, help="draft tokens per step")
    ap.add_argument("--tree", default="2,2",
                    help="draft-tree branching factors for the tree arm "
                         "('' skips it)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help=">0 switches to the stochastic-drafting demo")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = pack_params(init_lm(jax.random.PRNGKey(0), cfg), cfg)
    rng = np.random.default_rng(0)
    # half repetitive prompts (the regime prompt-lookup drafting feeds on),
    # half random (adversarial for drafting — the adaptive policy's prey)
    pat = rng.integers(0, cfg.vocab, size=4)
    warm = [np.tile(pat, 6).astype(np.int32) for _ in range(args.requests // 2)]
    cold = [rng.integers(0, cfg.vocab, size=24).astype(np.int32)
            for _ in range(args.requests - args.requests // 2)]
    prompts = warm + cold

    if args.temperature > 0:
        # stochastic-drafting demo: self-draft so the arm isolates the
        # proposal mode (q == p under stochastic → acceptance 1.0 ceiling)
        common = dict(k=args.k, drafter="model",
                      draft_params=params, draft_cfg=cfg)
        _, st = serve(params, cfg, prompts, args,
                      spec=SpecConfig(**common), temperature=args.temperature)
        print(f"greedy-draft     @T={args.temperature}: {fmt(st)}")
        _, st = serve(params, cfg, prompts, args,
                      spec=SpecConfig(stochastic=True, **common),
                      temperature=args.temperature)
        print(f"stochastic-draft @T={args.temperature}: {fmt(st)}")
        print("both emit exact target-model samples; the acceptance gap is "
              "the draft mass greedy (one-hot) proposals discard")
        return

    plain, base = serve(params, cfg, prompts, args)
    print(f"plain   : {base.decode_tok_s:7.1f} decode tok/s   1.00 tok/step")

    ngram, st = serve(params, cfg, prompts, args, spec=SpecConfig(k=args.k))
    print(f"ngram   : {fmt(st)}")
    assert ngram == plain, "greedy speculative decode must be exact"

    adaptive, st = serve(
        params, cfg, prompts, args,
        spec=SpecConfig(k=args.k, adaptive_k=True, skip_below=0.25,
                        probe_every=4),
    )
    print(f"adaptive: {fmt(st)}   mean_k {st.mean_draft_k:.2f}   "
          f"skip {st.skip_rate:.2f}")
    assert adaptive == plain, "adaptive-K greedy decode must stay exact"

    if args.tree:
        branching = tuple(int(x) for x in args.tree.split(","))
        treed, st = serve(params, cfg, prompts, args,
                          spec=SpecConfig(k=args.k, tree=branching))
        print(f"tree    : {fmt(st)}   nodes/step {st.nodes_per_step:.1f}")
        assert treed == plain, "greedy tree decode must stay exact"

    oracle_spec = SpecConfig(k=args.k, drafter="model",
                             draft_params=params, draft_cfg=cfg)
    oracle, st = serve(params, cfg, prompts, args, spec=oracle_spec)
    print(f"oracle  : {fmt(st)}")
    assert oracle == plain
    print("exactness: speculative output == plain greedy output ✓")


if __name__ == "__main__":
    main()
