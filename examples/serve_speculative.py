"""Speculative serving quickstart: the decode path as a Vec-LUT parallel
workload. Train-free — packs random ternary weights, then serves the same
request stream three ways and prints the accept/throughput accounting:

  plain    one token per slot per tick (the M=1 decode the paper critiques)
  ngram    prompt-lookup drafting (no extra weights) + (B, K+1) verification
  oracle   self-drafting with the target's own weights — acceptance is 1.0
           by construction, showing the verification-side ceiling (K+1
           tokens per step)

    PYTHONPATH=src python examples/serve_speculative.py [--arch smollm-360m] [--k 4]

Greedy speculative output is token-for-token identical to plain decoding —
the script asserts it.
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_lm, pack_params
from repro.serve import ContinuousBatchingScheduler, Engine, Request
from repro.spec import SpecConfig


def serve(params, cfg, prompts, args, spec=None):
    eng = Engine(params, cfg, max_slots=args.slots, max_len=256, spec=spec)
    sched = ContinuousBatchingScheduler(eng)
    reqs = [Request(rid=i, prompt=p.copy(), max_new_tokens=args.max_new)
            for i, p in enumerate(prompts)]
    sched.submit(reqs)
    stats = sched.run_to_completion()
    return [r.generated for r in reqs], stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--k", type=int, default=4, help="draft tokens per step")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = pack_params(init_lm(jax.random.PRNGKey(0), cfg), cfg)
    rng = np.random.default_rng(0)
    # repetitive prompts — the regime prompt-lookup drafting feeds on
    pat = rng.integers(0, cfg.vocab, size=4)
    prompts = [np.tile(pat, 6).astype(np.int32) for _ in range(args.requests)]

    plain, base = serve(params, cfg, prompts, args)
    print(f"plain : {base.decode_tok_s:7.1f} decode tok/s   1.00 tok/step")

    ngram, st = serve(params, cfg, prompts, args, spec=SpecConfig(k=args.k))
    print(f"ngram : {st.decode_tok_s:7.1f} decode tok/s   "
          f"{st.decode_tokens_per_step:.2f} tok/step   "
          f"accept {st.acceptance_rate:.2f}")
    assert ngram == plain, "greedy speculative decode must be exact"

    oracle_spec = SpecConfig(k=args.k, drafter="model",
                             draft_params=params, draft_cfg=cfg)
    oracle, st = serve(params, cfg, prompts, args, spec=oracle_spec)
    print(f"oracle: {st.decode_tok_s:7.1f} decode tok/s   "
          f"{st.decode_tokens_per_step:.2f} tok/step   "
          f"accept {st.acceptance_rate:.2f}")
    assert oracle == plain
    print("exactness: speculative output == plain greedy output ✓")


if __name__ == "__main__":
    main()
