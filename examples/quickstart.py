"""Quickstart: the Vec-LUT mpGeMM public API in 40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pack_weight, ternary_quantize, vlut_gemm, mad_gemm_int8
from repro.kernels import vlut_mpgemm, ref_mpgemm

# 1. Quantize a weight matrix to ternary (BitNet b1.58 absmean recipe) and
#    pack it at 1.6 bits/weight (g=5 trit groups → one uint8 index each).
rng = np.random.default_rng(0)
W = jnp.asarray(rng.standard_normal((1024, 4096)), jnp.float32)   # (M, K)
tern = ternary_quantize(W)
packed = pack_weight(tern.values, tern.scale, mode="auto")  # K=4096 → 816 g=5 + 4 g=4 groups
print(f"packed: {packed.bits_per_weight:.3f} bits/weight "
      f"({packed.M}x{packed.K} -> {packed.packed5.nbytes + packed.packed4.nbytes} bytes)")

# 2. Multiply against N parallel tokens with the vector-LUT algorithm
#    (paper Alg. 1: one unified table, one 1→N lookup per weight byte).
A = jnp.asarray(rng.standard_normal((4096, 64)), jnp.float32)     # (K, N)
out = vlut_gemm(packed, A)                                        # (M, N) f32
print("vlut_gemm:", out.shape, out.dtype)

# 3. Same thing through the TPU kernel wrappers (Pallas on TPU, shardable
#    XLA decode path elsewhere) — bit-identical integer results.
out_kernel = vlut_mpgemm(packed, A, impl="decode", interpret=True)
ref = ref_mpgemm(packed, A)
print("kernel max |err| vs oracle:", float(jnp.max(jnp.abs(out_kernel - ref))))

# 4. Baseline comparison (MAD int8 à la bitnet.cpp I2_S).
print("mad max |err| vs oracle:", float(jnp.max(jnp.abs(mad_gemm_int8(packed, A) - ref))))
