"""Continuous-batching serving demo (paper §5.3.2): train-free — packs random
ternary weights, then serves a mixed prefill/decode request stream.

    PYTHONPATH=src python examples/serve_batched.py [--arch gemma3-1b]

--prefill-chunk N turns on chunked prefill: admission claims a slot and the
prompt streams in N tokens per tick through one batched mixed step that also
carries the decode rows — the Vec-LUT kernels see parallel tokens every tick
and queued requests stop stalling behind whole-prompt admissions.
--token-budget caps the real tokens scheduled per tick.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_lm, pack_params, packed_param_bytes
from repro.serve import ContinuousBatchingScheduler, Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill size (0 = whole-prompt admission)")
    ap.add_argument("--token-budget", type=int, default=0,
                    help="real tokens scheduled per chunked tick (0 = all)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    dense = init_lm(jax.random.PRNGKey(0), cfg)
    params = pack_params(dense, cfg)
    print(f"{args.arch}: packed weights "
          f"{packed_param_bytes(params) / 2**20:.1f} MiB "
          f"(dense {packed_param_bytes(dense) / 2**20:.1f} MiB)")

    engine = Engine(params, cfg, max_slots=args.slots, max_len=256,
                    prefill_chunk=args.prefill_chunk,
                    token_budget=args.token_budget)
    sched = ContinuousBatchingScheduler(engine)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab, rng.integers(8, 48)).astype(np.int32),
                max_new_tokens=args.max_new)
        for i in range(args.requests)
    ]
    sched.submit(reqs)
    stats = sched.run_to_completion()
    ttft = (f" | median TTFT {1e3 * float(np.median(stats.ttft_s)):.0f} ms"
            if stats.ttft_s else "")
    chunked = (f" | {stats.chunk_steps} mixed chunk steps "
               f"({stats.prefill_pad_tokens} pad tokens)"
               if args.prefill_chunk else "")
    print(f"completed {stats.completed}/{args.requests} | "
          f"{stats.throughput_tok_s:.1f} tok/s total "
          f"({stats.prefill_tok_s:.1f} prefill / {stats.decode_tok_s:.1f} decode)"
          f"{ttft}{chunked}")


if __name__ == "__main__":
    main()
