"""End-to-end driver: train a ~100M-param ternary (QAT) LM for a few hundred
steps on the synthetic bigram corpus, with checkpoints + fault tolerance.

    PYTHONPATH=src python examples/train_ternary_lm.py \
        [--steps 300] [--d-model 512] [--layers 8] [--full-100m]

`--full-100m` uses a ~100M-parameter model (slow on 1 CPU core); the default
is a scaled-down config with identical code paths.
"""
import argparse

from repro.configs.base import ModelConfig, uniform_layers
from repro.data import DataConfig
from repro.dist.fault_tolerance import run_with_restarts
from repro.optim import AdamWConfig
from repro.train import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ternary_lm")
    args = ap.parse_args()

    if args.full_100m:  # ~100M params
        args.d_model, args.layers = 768, 12

    cfg = ModelConfig(
        name="ternary-lm-example",
        n_layers=args.layers, d_model=args.d_model,
        n_heads=args.d_model // 64, n_kv_heads=max(args.d_model // 128, 1),
        head_dim=64, d_ff=args.d_model * 4, vocab=8192,
        layers=uniform_layers(args.layers),
        loss_chunk=128, attn_dense_max=4096,
    )
    tc = TrainConfig(
        total_steps=args.steps, checkpoint_every=100,
        checkpoint_dir=args.ckpt_dir, log_every=20, grad_compression=True,
    )
    opt = AdamWConfig(lr=3e-3, warmup_steps=args.steps // 10,
                      total_steps=args.steps, int8_state=True)
    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)

    def attempt(i):
        t = Trainer(cfg, opt, tc, dc, install_signals=True)
        log = t.run()
        print(f"final loss: {log[-1]['loss']:.4f} "
              f"(bigram entropy floor ≈ 1.386)")

    run_with_restarts(attempt, max_restarts=2)


if __name__ == "__main__":
    main()
