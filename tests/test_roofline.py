"""Roofline analysis: HLO collective parser + three-term model."""
import pytest

from repro.roofline.analysis import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    Roofline,
    _shape_bytes,
    collective_bytes,
)

HLO = """
HloModule jit_step
ENTRY %main {
  %p0 = f32[8,128]{1,0} parameter(0)
  %ar = f32[8,128]{1,0} all-reduce(%p0), replica_groups={{0,1}}
  %ag.1 = bf16[16,256]{1,0} all-gather(%p0), dimensions={0}
  %rs = f32[4,128]{1,0} reduce-scatter(%ar), dimensions={0}
  %a2a = (s8[2,64]{1,0}, s8[2,64]{1,0}) all-to-all(%p0, %p0)
  %cp = u8[32]{0} collective-permute(%p0), source_target_pairs={{0,1}}
  %add = f32[8,128]{1,0} add(%p0, %ar)
}
"""


class TestParser:
    def test_shape_bytes(self):
        assert _shape_bytes("f32[8,128]") == 8 * 128 * 4
        assert _shape_bytes("bf16[16,256]") == 16 * 256 * 2
        assert _shape_bytes("u8[32]") == 32
        assert _shape_bytes("f32[]") == 4

    def test_collective_bytes(self):
        got = collective_bytes(HLO)
        assert got["all-reduce"]["bytes"] == 8 * 128 * 4
        assert got["all-gather"]["bytes"] == 16 * 256 * 2
        assert got["reduce-scatter"]["bytes"] == 4 * 128 * 4
        assert got["all-to-all"]["bytes"] == 2 * (2 * 64)
        assert got["collective-permute"]["bytes"] == 32
        assert got["all-reduce"]["count"] == 1

    def test_non_collectives_ignored(self):
        got = collective_bytes("%x = f32[8,8]{1,0} add(%a, %b)")
        assert sum(v["bytes"] for v in got.values()) == 0


class TestModel:
    def test_terms_and_dominant(self):
        rl = Roofline(
            arch="a", shape="s", mesh="m", chips=256,
            flops_per_device=197e12 * 0.5,       # 0.5 s compute
            bytes_per_device=819e9 * 0.1,        # 0.1 s memory
            coll_bytes_per_device=50e9 * 0.2,    # 0.2 s collective
            model_flops_total=197e12 * 256 * 0.25,
        )
        assert rl.compute_s == pytest.approx(0.5)
        assert rl.memory_s == pytest.approx(0.1)
        assert rl.collective_s == pytest.approx(0.2)
        assert rl.dominant == "compute"
        assert rl.roofline_fraction == pytest.approx(0.5)
        assert rl.useful_flops_ratio == pytest.approx(0.5)

    def test_memory_efficiency(self):
        rl = Roofline(
            arch="a", shape="s", mesh="m", chips=1,
            flops_per_device=0, bytes_per_device=100.0,
            coll_bytes_per_device=0, min_bytes_per_device=40.0,
        )
        assert rl.memory_efficiency == pytest.approx(0.4)
        assert rl.dominant == "memory"
