"""Mamba2 SSD: the chunked scan must equal the exact token-by-token
recurrence, and prefill→decode must be consistent."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.ssm import _causal_conv, _ssd_chunked


def _ssd_reference(x, dt, a, b_mat, c_mat, h0):
    """Exact sequential recurrence: h_t = exp(dt_t a) h_{t-1} + dt_t B_t x_t."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    hst = np.array(h0, np.float64)
    ys = np.zeros((bsz, s, h, p))
    for t in range(s):
        da = np.exp(dt[:, t] * a)                       # (B,H)
        upd = np.einsum("bhn,bhp,bh->bhpn", b_mat[:, t], x[:, t], dt[:, t])
        hst = hst * da[:, :, None, None] + upd
        ys[:, t] = np.einsum("bhn,bhpn->bhp", c_mat[:, t], hst)
    return ys, hst


@pytest.mark.parametrize("s,chunk", [(16, 4), (32, 8), (17, 8), (8, 16)])
def test_chunked_equals_recurrence(s, chunk, rng):
    bsz, h, p, n = 2, 3, 4, 5
    x = rng.standard_normal((bsz, s, h, p)).astype(np.float32)
    dt = rng.uniform(0.01, 0.2, (bsz, s, h)).astype(np.float32)
    a = -rng.uniform(0.5, 2.0, (h,)).astype(np.float32)
    b_mat = rng.standard_normal((bsz, s, h, n)).astype(np.float32)
    c_mat = rng.standard_normal((bsz, s, h, n)).astype(np.float32)
    h0 = rng.standard_normal((bsz, h, p, n)).astype(np.float32)
    y, hf = _ssd_chunked(
        jnp.asarray(x), jnp.asarray(dt), jnp.asarray(a),
        jnp.asarray(b_mat), jnp.asarray(c_mat), chunk, jnp.asarray(h0),
    )
    y_ref, h_ref = _ssd_reference(x, dt, a, b_mat, c_mat, h0)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(hf), h_ref, rtol=2e-3, atol=2e-3)


def test_causal_conv_is_causal(rng):
    x = rng.standard_normal((1, 10, 3)).astype(np.float32)
    w = rng.standard_normal((4, 3)).astype(np.float32)
    b = np.zeros(3, np.float32)
    y1, _ = _causal_conv(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), None)
    x2 = x.copy()
    x2[:, 7:] = 99.0  # future change
    y2, _ = _causal_conv(jnp.asarray(x2), jnp.asarray(w), jnp.asarray(b), None)
    np.testing.assert_array_equal(np.asarray(y1)[:, :7], np.asarray(y2)[:, :7])


def test_conv_history_streaming(rng):
    """conv(x) == conv applied in two chunks with carried history."""
    x = rng.standard_normal((2, 12, 3)).astype(np.float32)
    w = rng.standard_normal((4, 3)).astype(np.float32)
    b = rng.standard_normal(3).astype(np.float32)
    full, _ = _causal_conv(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), None)
    y1, h = _causal_conv(jnp.asarray(x[:, :7]), jnp.asarray(w), jnp.asarray(b), None)
    y2, _ = _causal_conv(jnp.asarray(x[:, 7:]), jnp.asarray(w), jnp.asarray(b), h)
    got = np.concatenate([np.asarray(y1), np.asarray(y2)], axis=1)
    np.testing.assert_allclose(got, np.asarray(full), rtol=1e-5, atol=1e-5)


def test_mamba2_prefill_decode_consistency():
    """Covered end-to-end in test_models_smoke, but assert the SSM state path
    specifically: decode continues exactly from the prefill state."""
    from repro.models import decode_step, init_cache, init_lm, lm_hidden, prefill
    from repro.models.decoder import _head_matmul

    cfg = get_config("mamba2-1.3b", smoke=True)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 21), 0, cfg.vocab)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    h, _, _ = lm_hidden(params, tok, cfg, mode="eval")
    want = np.asarray(_head_matmul(params, h[:, -1:, :], cfg)[:, 0])
    cache = init_cache(cfg, 2, max_len=32)
    _, cache = prefill(params, tok[:, :20], cache, cfg, mode="eval")
    got, _ = decode_step(params, tok[:, 20:21], cache, cfg, mode="eval")
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-2, atol=2e-2)
