"""HLO static analyzer: validated exact on known scan-of-matmul workloads
(the roofline's flops/bytes source — see EXPERIMENTS.md §3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_stats import parse_hlo_stats


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


class TestDotFlops:
    def test_single_matmul(self):
        a = jnp.ones((128, 64))
        b = jnp.ones((64, 32))
        hlo = _compile(lambda a, b: a @ b, a, b)
        st = parse_hlo_stats(hlo)
        assert st.dot_flops == 2 * 128 * 64 * 32

    def test_scan_multiplies_by_trip_count(self):
        w = jnp.ones((64, 64))
        x = jnp.ones((128, 64))

        def fn(x):
            return jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=7)[0]

        st = parse_hlo_stats(_compile(fn, x))
        assert st.dot_flops == 7 * 2 * 128 * 64 * 64

    def test_nested_scans_multiply(self):
        w = jnp.ones((32, 32))
        x = jnp.ones((16, 32))

        def inner(c):
            return jax.lax.scan(lambda ci, _: (ci @ w, None), c, None, length=3)[0]

        def fn(x):
            return jax.lax.scan(
                lambda c, _: (inner(c) @ w, None), x, None, length=5
            )[0]

        st = parse_hlo_stats(_compile(fn, x))
        want = 5 * (3 + 1) * 2 * 16 * 32 * 32
        assert st.dot_flops == want
        assert st.n_whiles == 2
        assert st.unknown_trip_whiles == 0

    def test_batched_dot_contraction(self):
        a = jnp.ones((4, 16, 8))
        b = jnp.ones((4, 8, 32))
        hlo = _compile(lambda a, b: jnp.einsum("bik,bkj->bij", a, b), a, b)
        st = parse_hlo_stats(hlo)
        assert st.dot_flops == 2 * 4 * 16 * 8 * 32


class TestTraffic:
    def test_slice_counts_slice_not_operand(self):
        big = jnp.ones((4096, 1024))

        def fn(x, i):
            return jax.lax.dynamic_slice(x, (i, 0), (8, 1024))

        st = parse_hlo_stats(_compile(fn, big, jnp.asarray(0)))
        # must NOT charge the 16MB operand for a 32KB slice
        assert st.traffic_bytes < 1e6

    def test_fused_slice_is_bounded(self):
        # when XLA fuses arithmetic around the slice, the fusion operand is
        # charged conservatively — bounded by a small multiple of the buffer
        big = jnp.ones((4096, 1024))

        def fn(x, i):
            return jax.lax.dynamic_slice(x, (i, 0), (8, 1024)) * 2.0

        st = parse_hlo_stats(_compile(fn, big, jnp.asarray(0)))
        assert st.traffic_bytes <= big.size * 4 * 3

    def test_elementwise_fusion_counts_boundaries(self):
        x = jnp.ones((1024, 1024))
        st = parse_hlo_stats(_compile(lambda x: jnp.tanh(x * 2 + 1) * x, x))
        nbytes = 1024 * 1024 * 4
        # one fused op: read x (+ maybe twice), write result
        assert nbytes * 2 <= st.traffic_bytes <= nbytes * 6


class TestXlaCostAnalysisIsWrong:
    """Documents WHY the analyzer exists: XLA counts scan bodies once."""

    def test_cost_analysis_undercounts_scans(self):
        w = jnp.ones((64, 64))
        x = jnp.ones((128, 64))

        def fn(x):
            return jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=10)[0]

        compiled = jax.jit(fn).lower(x).compile()
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):  # older jaxlib: list per device
            cost = cost[0] if cost else {}
        xla_flops = cost.get("flops", 0)
        ours = parse_hlo_stats(compiled.as_text()).dot_flops
        want = 10 * 2 * 128 * 64 * 64
        assert ours == want
        assert xla_flops < want  # the undercount this module fixes
