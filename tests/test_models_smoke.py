"""Per-architecture smoke tests: REDUCED same-family configs, one forward +
one train grad step on CPU — output shapes right, loss/grads finite.
(Deliverable (f): every assigned arch as a selectable config.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_archs, cell_is_applicable
from repro.models import (
    decode_step,
    encdec_init,
    encdec_loss,
    init_cache,
    init_lm,
    lm_loss,
    pack_params,
    prefill,
)

ARCHS = list_archs()

# the deepest/widest smoke configs dominate CPU compile time — the fast lane
# (`pytest -m "not slow"`) keeps one arch per family instead
_HEAVY_ARCHS = {
    "jamba-1.5-large-398b", "gemma3-1b", "deepseek-v3-671b",
    "llama4-scout-17b-a16e", "whisper-medium",
}


def _arch_params(archs):
    return [
        pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY_ARCHS else a
        for a in archs
    ]


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10
    for a in ARCHS:
        cfg = get_config(a)
        smoke = get_config(a, smoke=True)
        assert cfg.name == a
        assert smoke.n_layers <= 8


def test_full_configs_match_assignment():
    """Exact published dims (spot-check the assignment table)."""
    c = get_config("deepseek-v3-671b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab) == (61, 7168, 128, 129_280)
    assert c.moe.n_experts == 256 and c.moe.top_k == 8
    c = get_config("llama4-scout-17b-a16e")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (48, 5120, 8192, 202_048)
    assert c.moe.n_experts == 16 and c.moe.top_k == 1
    c = get_config("jamba-1.5-large-398b")
    assert (c.n_layers, c.d_model, c.d_ff) == (72, 8192, 24_576)
    assert sum(s.mixer == "attn" for s in c.layers) * 8 == c.n_layers  # 1:7
    c = get_config("gemma3-1b")
    assert sum(s.window == 0 for s in c.layers) * 6 >= c.n_layers - 2  # 5:1
    c = get_config("mamba2-1.3b")
    assert all(s.mixer == "ssm" for s in c.layers) and c.ssm.d_state == 128
    c = get_config("command-r-35b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (40, 8192, 22_528, 256_000)
    c = get_config("smollm-360m")
    assert (c.n_heads, c.n_kv_heads, c.d_ff) == (15, 5, 2560)
    c = get_config("whisper-medium")
    assert c.family == "encdec" and c.enc_layers == 24
    c = get_config("chameleon-34b")
    assert c.qk_norm and c.vocab == 65_536
    c = get_config("internlm2-1.8b")
    assert (c.d_model, c.n_heads, c.n_kv_heads) == (2048, 16, 8)


@pytest.mark.parametrize("arch", _arch_params(ARCHS))
def test_smoke_forward_and_grad(arch):
    cfg = get_config(arch, smoke=True)
    B, S = 2, 32
    rng = jax.random.PRNGKey(0)
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    lab = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    if cfg.family == "encdec":
        params = encdec_init(rng, cfg)
        frames = jax.random.normal(
            jax.random.PRNGKey(3), (B, S // cfg.enc_frame_ratio, cfg.d_model)
        )
        loss_fn = lambda p: encdec_loss(p, frames, tok, lab, cfg)[0]
    else:
        params = init_lm(rng, cfg)
        loss_fn = lambda p: lm_loss(p, tok, lab, cfg)[0]
    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    assert 1.0 < float(loss) < 20.0  # ~ln(vocab) at init
    gn = sum(float(jnp.sum(g.astype(jnp.float32) ** 2)) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize(
    "arch",
    _arch_params(
        ["smollm-360m", "gemma3-1b", "chameleon-34b", "llama4-scout-17b-a16e"]
    ),
)
def test_smoke_packed_serve(arch):
    """Packed (Vec-LUT serving) params produce finite decode logits that
    agree in top-1 with the QAT eval path for most positions."""
    cfg = get_config(arch, smoke=True)
    B, S = 2, 24
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    sp = pack_params(params, cfg)
    cache = init_cache(cfg, B, max_len=S + 8)
    _, cache = prefill(sp, tok[:, :S], cache, cfg, mode="serve")
    logits, _ = decode_step(sp, tok[:, S : S + 1], cache, cfg, mode="serve")
    assert np.all(np.isfinite(np.asarray(logits)))
    assert logits.shape == (B, cfg.vocab)


def test_applicability_matrix():
    """40 cells: long_500k runs only for sub-quadratic archs (DESIGN.md §4)."""
    runs = {
        (a, s) for a in ARCHS for s in SHAPES if cell_is_applicable(a, s)
    }
    assert len(runs) == 40 - 7
    assert ("mamba2-1.3b", "long_500k") in runs
    assert ("jamba-1.5-large-398b", "long_500k") in runs
    assert ("gemma3-1b", "long_500k") in runs
    assert ("command-r-35b", "long_500k") not in runs
