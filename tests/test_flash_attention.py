"""Flash-attention Pallas kernel: interpret-mode allclose vs the sdpa oracle
over shape/dtype/mask sweeps (deliverable c: per-kernel shape/dtype sweeps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention_bsnd
from repro.models.attention import sdpa


def _case(rng, b, s, h, kv, d, dtype):
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, kv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, kv, d)), dtype)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return q, k, v, pos


@pytest.mark.parametrize("b,s,h,kv,d", [
    (1, 32, 2, 2, 8), (2, 64, 4, 2, 16), (1, 100, 4, 1, 32), (2, 17, 3, 1, 8),
])
@pytest.mark.parametrize("causal", [True, False])
def test_matches_sdpa(b, s, h, kv, d, causal, rng):
    q, k, v, pos = _case(rng, b, s, h, kv, d, jnp.float32)
    want = np.asarray(sdpa(q, k, v, pos, pos, causal=causal, dense_max=10**6))
    got = np.asarray(flash_attention_bsnd(
        q, k, v, causal=causal, bq=32, bk=32, interpret=True))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [8, 24])
def test_sliding_window(window, rng):
    q, k, v, pos = _case(rng, 1, 96, 4, 2, 16, jnp.float32)
    want = np.asarray(sdpa(q, k, v, pos, pos, causal=True, window=window,
                           dense_max=10**6))
    got = np.asarray(flash_attention_bsnd(
        q, k, v, causal=True, window=window, bq=16, bk=16, interpret=True))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_bf16(rng):
    q, k, v, pos = _case(rng, 2, 64, 4, 4, 16, jnp.bfloat16)
    want = np.asarray(sdpa(q, k, v, pos, pos, causal=True, dense_max=10**6),
                      np.float32)
    got = np.asarray(flash_attention_bsnd(
        q, k, v, causal=True, bq=32, bk=32, interpret=True), np.float32)
    np.testing.assert_allclose(got, want, rtol=3e-2, atol=3e-2)


def test_softcap(rng):
    q, k, v, pos = _case(rng, 1, 48, 2, 2, 16, jnp.float32)
    want = np.asarray(sdpa(q, k, v, pos, pos, causal=True, softcap=20.0,
                           dense_max=10**6))
    got = np.asarray(flash_attention_bsnd(
        q, k, v, causal=True, softcap=20.0, bq=16, bk=16, interpret=True))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_model_integration_flash_flag(rng):
    """cfg.attn_impl='flash' end-to-end equals the chunked/dense path."""
    from repro.configs import get_config
    from repro.models import init_lm, lm_hidden

    cfg = get_config("smollm-360m", smoke=True)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 48), 0, cfg.vocab)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    h_ref, _, _ = lm_hidden(params, tok, cfg, mode="eval")
    cfg2 = cfg.with_(attn_impl="flash")
    h_fl, _, _ = lm_hidden(params, tok, cfg2, mode="eval")
    # bf16 + QAT act-quant rounding flips cascade through layers; compare
    # with an absolute tolerance sized to the hidden-state scale.
    a, b = np.asarray(h_fl, np.float32), np.asarray(h_ref, np.float32)
    scale = np.abs(b).mean()
    assert np.abs(a - b).max() < 0.15 * scale + 0.1
    assert np.corrcoef(a.ravel(), b.ravel())[0, 1] > 0.999
