"""Observability layer: metrics registry, tracer, null path, engine wiring."""
import json
import math

import jax
import numpy as np
import pytest

from repro import obs as obs_mod
from repro.obs import NULL_OBS, Obs, ObsConfig
from repro.obs.metrics import (
    M_BUCKETS,
    TTFT_BUCKETS,
    Counter,
    Histogram,
    MetricsRegistry,
)
from repro.obs.trace import _NULL_SPAN, Tracer


@pytest.fixture(autouse=True)
def _detach_obs():
    """Tests that construct enabled Obs instances must not leak them into the
    module-global kernel hook (ops/autotune read obs_mod.current())."""
    yield
    obs_mod.install(None)


class TestHistogram:
    def test_bucket_assignment(self):
        h = Histogram("h", buckets=(1.0, 2.0, 5.0))
        for v in (0.5, 1.0, 1.5, 2.0, 4.0, 100.0):
            h.observe(v)
        # edges are upper bounds (bisect_left: v == edge lands in its bucket)
        assert h.counts == [2, 2, 1, 1]
        assert h.cumulative() == [2, 4, 5, 6]
        assert h.count == 6 and h.sum == pytest.approx(109.0)

    def test_percentile_interpolation(self):
        h = Histogram("h", buckets=(1.0, 2.0, 4.0))
        for v in np.linspace(0.05, 0.95, 10):   # all mass in [0, 1)
            h.observe(float(v))
        # uniform mass assumption → p50 is mid-bucket
        assert h.percentile(0.5) == pytest.approx(0.5)
        assert h.percentile(1.0) == pytest.approx(1.0)

    def test_percentile_tail_and_empty(self):
        h = Histogram("h", buckets=(1.0, 2.0))
        assert h.percentile(0.5) == 0.0          # no observations yet
        h.observe(50.0)                          # +Inf tail
        # the histogram cannot see past its last edge — report it, not a lie
        assert h.percentile(0.99) == 2.0
        with pytest.raises(ValueError):
            h.percentile(1.5)

    def test_ladders_sorted(self):
        for ladder in (TTFT_BUCKETS, M_BUCKETS):
            assert list(ladder) == sorted(ladder)


class TestCounter:
    def test_monotone(self):
        c = Counter("c")
        c.inc()
        c.inc(2.5)
        assert c.value == 3.5
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_sync_to_never_decreases(self):
        c = Counter("c")
        c.sync_to(10)
        c.sync_to(7)    # stale snapshot must not roll the export back
        assert c.value == 10
        c.sync_to(12)
        assert c.value == 12


class TestRegistry:
    def test_get_or_create_keyed_on_labels(self):
        r = MetricsRegistry()
        a = r.counter("x", labels={"impl": "vlut"})
        b = r.counter("x", labels={"impl": "vlut"})
        c = r.counter("x", labels={"impl": "xla"})
        assert a is b and a is not c
        assert r.find("x", {"impl": "xla"}) is c
        assert r.find("nope") is None

    def test_kind_conflict_raises(self):
        r = MetricsRegistry()
        r.counter("x")
        with pytest.raises(TypeError):
            r.gauge("x")

    def test_prometheus_exposition(self):
        r = MetricsRegistry()
        r.counter("repro:req_total", "requests", {"kind": "a"}).inc(3)
        h = r.histogram("repro:lat_seconds", "latency", buckets=(0.1, 1.0))
        h.observe(0.05)
        h.observe(0.5)
        h.observe(5.0)
        text = r.to_prometheus()
        assert "# TYPE repro:req_total counter" in text
        assert 'repro:req_total{kind="a"} 3' in text
        # cumulative bucket semantics + the implicit +Inf bucket
        assert 'repro:lat_seconds_bucket{le="0.1"} 1' in text
        assert 'repro:lat_seconds_bucket{le="1"} 2' in text
        assert 'repro:lat_seconds_bucket{le="+Inf"} 3' in text
        assert "repro:lat_seconds_count 3" in text

    def test_json_roundtrip(self):
        r = MetricsRegistry()
        r.gauge("g").set(2.0)
        s = r.series("s", capacity=2)
        for v in (1.0, 2.0, 3.0):
            s.record(v)
        blob = json.loads(json.dumps(r.to_json()))
        by_name = {m["name"]: m for m in blob["metrics"]}
        assert by_name["g"]["value"] == 2.0
        # ring keeps the newest `capacity` samples; lifetime count is total
        assert by_name["s"]["samples"] == [2.0, 3.0]
        assert by_name["s"]["count"] == 3 and by_name["s"]["mean"] == 2.0


class TestTracer:
    def test_span_records_complete_event(self):
        tr = Tracer()
        with tr.span("work", m=4) as sp:
            sp.args["extra"] = 1
        ev = tr.events[-1]
        assert ev["name"] == "work" and ev["ph"] == "X"
        assert ev["args"] == {"m": 4, "extra": 1}
        assert ev["dur"] >= 0.0 and ev["ts"] >= 0.0

    def test_ring_drops_oldest(self):
        tr = Tracer(capacity=3)
        for i in range(5):
            tr.instant(f"e{i}")
        assert [e["name"] for e in tr.events] == ["e2", "e3", "e4"]
        assert tr.dropped == 2
        assert tr.to_json()["otherData"]["dropped_events"] == 2

    def test_trace_event_json_shape(self, tmp_path):
        """The export must be the trace_event object format Perfetto loads."""
        tr = Tracer()
        with tr.span("a"):
            pass
        tr.complete("b", tr._t0, tr._t0 + 1e-3, args={"k": 1})
        path = tr.write(str(tmp_path / "trace.json"))
        blob = json.loads(open(path).read())
        assert blob["displayTimeUnit"] == "ms"
        for ev in blob["traceEvents"]:
            assert {"name", "ph", "ts", "pid", "tid"} <= set(ev)
            assert ev["ph"] in ("X", "i")
            if ev["ph"] == "X":
                assert ev["dur"] >= 0.0
        assert blob["traceEvents"][1]["dur"] == pytest.approx(1000.0)

    def test_disabled_records_nothing(self):
        tr = Tracer(enabled=False)
        assert tr.span("x") is _NULL_SPAN
        tr.complete("x", 0.0)
        tr.instant("x")
        assert not tr.events and tr.emitted == 0


class TestNullPath:
    """obs=None / enabled=False must be free: no metric objects, no events,
    shared singletons on every span path."""

    def test_null_obs_is_inert(self):
        assert not NULL_OBS.enabled
        assert NULL_OBS.span("x") is _NULL_SPAN
        assert NULL_OBS.mpgemm_span(1, 2, 3, "xla", "fused") is _NULL_SPAN
        NULL_OBS.step_event("decode", 0.0, m_real=1, m_padded=1)
        NULL_OBS.observe_ttft(1.0)
        NULL_OBS.on_tick(None, queue_depth=0, completed=0, rejected=0)
        NULL_OBS.record_kernel_sample(
            g=4, impl="lut", m=8, kg=2, n=1, fused=True, seconds=1e-3)
        assert not NULL_OBS.registry.all()
        assert not NULL_OBS.tracer.events
        assert NULL_OBS.stats_line() == "obs disabled"
        assert NULL_OBS.finalize() == []

    def test_null_span_args_discarded(self):
        sp = _NULL_SPAN
        with sp:
            sp.args["k"] = "v"      # legal, discarded
        assert sp.args == {}

    def test_install_ignores_disabled(self):
        obs_mod.install(NULL_OBS)
        assert obs_mod.current() is None
        live = Obs(ObsConfig())
        obs_mod.install(live)
        assert obs_mod.current() is live
        obs_mod.install(None)
        assert obs_mod.current() is None


class TestObsFacade:
    def test_step_event(self):
        o = Obs(ObsConfig())
        t0 = o.now()
        o.step_event("chunk", t0, m_real=24, m_padded=32, prefills=3)
        h = o.registry.find("repro:engine_step_seconds", {"kind": "chunk"})
        assert h.count == 1
        assert list(o.s_eff_m.samples) == [24.0]
        assert o.h_eff_m.count == 1
        ev = o.tracer.events[-1]
        assert ev["name"] == "engine_step/chunk"
        assert ev["args"] == {"m_real": 24, "m_padded": 32, "prefills": 3}

    def test_mpgemm_span(self):
        o = Obs(ObsConfig())
        with o.mpgemm_span(16, 2048, 512, impl="xla", fusion="fused"):
            pass
        c = o.registry.find(
            "repro:mpgemm_dispatch_total", {"impl": "xla", "fusion": "fused"})
        assert c.value == 1
        ev = o.tracer.events[-1]
        assert ev["name"] == "mpgemm_dispatch"
        assert (ev["args"]["m"], ev["args"]["k"], ev["args"]["n"]) == (
            16, 2048, 512)

    def test_record_kernel_sample_gauges(self):
        o = Obs(ObsConfig())
        o.record_kernel_sample(
            g=4, impl="lut", m=512, kg=512, n=16, fused=True, seconds=1e-3)
        labels = {"impl": "lut", "g": "4", "shape": "512x2048",
                  "m_tokens": "16"}
        gf = o.registry.find("repro:mpgemm_achieved_gflops", labels)
        gb = o.registry.find("repro:mpgemm_achieved_gbps", labels)
        assert gf.value > 0 and gb.value > 0
        assert math.isfinite(gf.value)

    def test_finalize_writes_exports(self, tmp_path):
        o = Obs(ObsConfig(
            metrics_out=str(tmp_path / "m.json"),
            trace_out=str(tmp_path / "t.json"),
        ))
        o.observe_ttft(0.02)
        with o.span("x"):
            pass
        paths = o.finalize()
        assert len(paths) == 2
        m = json.loads(open(paths[0]).read())
        names = {x["name"] for x in m["metrics"]}
        assert "repro:time_to_first_token_seconds" in names
        t = json.loads(open(paths[1]).read())
        assert t["traceEvents"][0]["name"] == "x"


@pytest.mark.slow
class TestEngineIntegration:
    @pytest.fixture(scope="class")
    def served(self):
        from repro.configs import get_config
        from repro.models import init_lm, pack_params

        cfg = get_config("smollm-360m", smoke=True)
        params = pack_params(init_lm(jax.random.PRNGKey(0), cfg), cfg)
        return cfg, params

    def _requests(self, cfg, n, rng, max_new=6):
        from repro.serve import Request

        return [
            Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab, size=rng.integers(4, 20))
                .astype(np.int32),
                max_new_tokens=max_new,
            )
            for i in range(n)
        ]

    def test_gauges_track_engine_tick_by_tick(self, served, rng):
        from repro.serve import ContinuousBatchingScheduler, Engine

        cfg, params = served
        eng = Engine(params, cfg, max_slots=2, max_len=64,
                     obs=ObsConfig(), prefill_chunk=4)
        try:
            sched = ContinuousBatchingScheduler(eng)
            sched.submit(self._requests(cfg, 5, rng))
            o = eng.obs
            while sched.queue or eng.has_work:
                sched.tick()
                # on_tick runs at the END of tick(): gauges must equal the
                # engine's live state right now, every tick
                assert o.g_waiting.value == len(sched.queue)
                assert o.g_running.value == int(eng.active.sum())
                assert o.g_prefilling.value == len(eng.prefilling)
                assert o.g_slots_free.value == sum(eng.slot_free)
                assert o.c_completed.value == len(sched.completed)
            assert o.g_slots_free.value == eng.max_slots
        finally:
            obs_mod.install(None)

    def test_latency_and_trace_surface(self, served, rng):
        from repro.serve import ContinuousBatchingScheduler, Engine

        cfg, params = served
        n_req, max_new = 6, 6
        eng = Engine(params, cfg, max_slots=3, max_len=64, obs=ObsConfig())
        try:
            sched = ContinuousBatchingScheduler(eng)
            sched.submit(self._requests(cfg, n_req, rng, max_new=max_new))
            stats = sched.run_to_completion()
            o = eng.obs
            assert stats.completed == n_req
            # one TTFT per completed request; one TPOT per request that
            # produced >= 2 tokens (all of them here)
            assert o.h_ttft.count == n_req
            assert o.h_tpot.count == n_req
            assert o.h_ttft.percentile(0.95) >= o.h_ttft.percentile(0.5) > 0
            # counters mirrored from the engine's source-of-truth attributes
            assert o.c_prompt_tok.value == eng.prefill_tokens
            assert o.c_gen_tok.value == eng.decode_tokens
            # every decode step recorded its real parallel-token count
            assert o.s_eff_m.count > 0
            assert all(1 <= m <= eng.max_slots for m in o.s_eff_m.samples)
            names = {e["name"] for e in o.tracer.events}
            assert "scheduler_tick" in names
            assert "engine_step/decode" in names
            # mpGeMM dispatch spans fire at trace time with shape+impl args
            mp = [e for e in o.tracer.events if e["name"] == "mpgemm_dispatch"]
            assert mp
            assert {"m", "k", "n", "impl", "fusion"} <= set(mp[0]["args"])
        finally:
            obs_mod.install(None)

    def test_disabled_engine_records_nothing(self, served, rng):
        from repro.serve import ContinuousBatchingScheduler, Engine

        cfg, params = served
        eng = Engine(params, cfg, max_slots=2, max_len=64)   # obs=None
        assert eng.obs is NULL_OBS
        sched = ContinuousBatchingScheduler(eng)
        sched.submit(self._requests(cfg, 3, rng, max_new=3))
        stats = sched.run_to_completion()
        assert stats.completed == 3
        assert not eng.obs.registry.all()
        assert not eng.obs.tracer.events
        assert obs_mod.current() is None
