"""Data pipeline: determinism, replay, host sharding, learnability."""
import numpy as np

from repro.data import DataConfig, SyntheticLM


def _cfg(**kw):
    return DataConfig(**{**dict(vocab=64, seq_len=32, global_batch=8), **kw})


def test_batch_is_pure_function_of_step():
    d1 = SyntheticLM(_cfg())
    d2 = SyntheticLM(_cfg())
    for step in (0, 3, 17):
        b1, b2 = d1.batch_at(step), d2.batch_at(step)
        assert np.array_equal(b1["tokens"], b2["tokens"])
        assert np.array_equal(b1["labels"], b2["labels"])


def test_labels_are_shifted_tokens():
    b = SyntheticLM(_cfg()).batch_at(0)
    assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_steps_differ():
    d = SyntheticLM(_cfg())
    assert not np.array_equal(d.batch_at(0)["tokens"], d.batch_at(1)["tokens"])


def test_state_roundtrip_replays_exactly():
    d = SyntheticLM(_cfg())
    for _ in range(5):
        next(d)
    saved = d.state_dict()
    want = next(d)
    d2 = SyntheticLM(_cfg())
    d2.load_state_dict(saved)
    got = next(d2)
    assert np.array_equal(want["tokens"], got["tokens"])


def test_host_sharding_partitions_batch():
    full = SyntheticLM(_cfg(global_batch=8), 0, 1)
    h0 = SyntheticLM(_cfg(global_batch=8), 0, 2)
    h1 = SyntheticLM(_cfg(global_batch=8), 1, 2)
    assert h0.host_batch == h1.host_batch == 4
    # different hosts draw independent (disjoint-seeded) rows
    assert not np.array_equal(h0.batch_at(0)["tokens"], h1.batch_at(0)["tokens"])


def test_bigram_structure_is_learnable():
    """Next token is always one of `branching` successors — entropy ln(b),
    far below uniform ln(vocab). Sanity for the training examples."""
    cfg = _cfg(vocab=128, branching=4)
    d = SyntheticLM(cfg)
    b = d.batch_at(0)
    succ = d._succ
    tok, lab = b["tokens"], b["labels"]
    ok = np.isin(lab.ravel(), succ[tok.ravel()].reshape(-1, cfg.branching))
    # vectorized check: each label must be in its token's successor row
    rows = succ[tok.ravel()]
    assert np.all((rows == lab.ravel()[:, None]).any(axis=1))
