"""Speculative decoding subsystem: drafters, acceptance rules, multi-token
verification, KV rollback, and engine-level greedy exactness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import (
    decode_step,
    init_cache,
    init_lm,
    pack_params,
    prefill,
    rollback_cache,
    verify_step,
)
from repro.serve import (
    ContinuousBatchingScheduler,
    Engine,
    Request,
    accept_speculative,
    greedy_accept,
)
from repro.spec import ModelDrafter, NgramDrafter, SpecConfig


@pytest.fixture(scope="module")
def served():
    cfg = get_config("smollm-360m", smoke=True)
    params = pack_params(init_lm(jax.random.PRNGKey(0), cfg), cfg)
    return cfg, params


# --------------------------------------------------------------------------
# Drafters
# --------------------------------------------------------------------------
class TestNgramDrafter:
    def test_prompt_lookup_continuation(self):
        d = NgramDrafter(max_n=3, min_n=1)
        ctx = np.array([1, 2, 3, 4, 9, 1, 2, 3])
        # trailing trigram [1,2,3] recurred at 0 → continuation [4, 9]
        np.testing.assert_array_equal(d.propose([ctx], 2)[0], [4, 9])

    def test_most_recent_match_wins(self):
        d = NgramDrafter(max_n=2, min_n=1)
        ctx = np.array([7, 1, 7, 2, 7])
        # suffix [7] matches at 0 and 2; most recent (2) → continuation [2, 7]
        np.testing.assert_array_equal(d.propose([ctx], 2)[0], [2, 7])

    def test_fallback_repeats_last_token(self):
        d = NgramDrafter()
        np.testing.assert_array_equal(d.propose([np.array([5])], 3)[0], [5, 5, 5])
        np.testing.assert_array_equal(
            d.propose([np.array([1, 2, 3, 4])], 2)[0], [4, 4]
        )

    def test_short_continuation_padded(self):
        d = NgramDrafter(max_n=1, min_n=1)
        ctx = np.array([8, 3, 8])   # match at 0; only [3] follows before suffix
        out = d.propose([ctx], 4)[0]
        np.testing.assert_array_equal(out, [3, 8, 8, 8])

    def test_free_slots_skipped(self):
        d = NgramDrafter()
        out = d.propose([None, np.array([4, 4, 4])], 2)
        assert out.shape == (2, 2)
        np.testing.assert_array_equal(out[1], [4, 4])


# --------------------------------------------------------------------------
# Acceptance rules
# --------------------------------------------------------------------------
class TestAcceptance:
    def test_greedy_accept_prefix_lengths(self):
        draft = jnp.asarray([[1, 2, 3], [1, 9, 3], [9, 2, 3], [1, 2, 9]])
        tgt = jnp.asarray([[1, 2, 3, 4]] * 4)
        np.testing.assert_array_equal(
            np.asarray(greedy_accept(draft, tgt)), [3, 1, 0, 2]
        )

    def test_greedy_mode_returns_argmax_tokens(self):
        rng = jax.random.PRNGKey(0)
        logits = jax.random.normal(rng, (2, 4, 16))
        draft = jnp.argmax(logits, axis=-1)[:, :3].astype(jnp.int32)
        n_acc, out = accept_speculative(draft, logits, rng, temperature=0.0)
        np.testing.assert_array_equal(np.asarray(n_acc), [3, 3])
        np.testing.assert_array_equal(np.asarray(out), np.argmax(logits, axis=-1))

    def test_stochastic_accepts_certain_tokens(self):
        # p(draft token) == 1 at every position → always accepted; bonus from
        # the last position's point mass.
        v, k = 8, 3
        draft = jnp.asarray([[2, 5, 1]], dtype=jnp.int32)
        onehot = jax.nn.one_hot(jnp.asarray([[2, 5, 1, 7]]), v)
        logits = jnp.log(onehot * (1 - 1e-6) + 1e-9)
        n_acc, out = accept_speculative(draft, logits, jax.random.PRNGKey(1),
                                        temperature=1.0)
        assert int(n_acc[0]) == k
        np.testing.assert_array_equal(np.asarray(out[0]), [2, 5, 1, 7])

    def test_stochastic_rejects_impossible_tokens(self):
        # p(draft token) == 0 → rejected at position 0; the resampled
        # correction must come from the target distribution's support.
        v = 8
        draft = jnp.asarray([[3, 3, 3]], dtype=jnp.int32)
        support = jax.nn.one_hot(jnp.asarray([[5, 5, 5, 5]]), v)
        logits = jnp.log(support * (1 - 1e-6) + 1e-9)
        n_acc, out = accept_speculative(draft, logits, jax.random.PRNGKey(2),
                                        temperature=1.0)
        assert int(n_acc[0]) == 0
        assert int(out[0, 0]) == 5


# --------------------------------------------------------------------------
# Multi-token verification + rollback (model level)
# --------------------------------------------------------------------------
@pytest.mark.slow
class TestVerifyStep:
    K = 3

    def _prefilled(self, served, rng, max_len=64):
        cfg, params = served
        prompt = jnp.asarray(rng.integers(0, cfg.vocab, (1, 12)), jnp.int32)
        cache = init_cache(cfg, 1, max_len)
        logits, cache = jax.jit(
            lambda p, c, t: prefill(p, t, c, cfg, mode="serve")
        )(params, cache, prompt)
        return cfg, params, cache, int(jnp.argmax(logits[0]))

    def _check_matches_sequential(self, cfg, params, cache, toks):
        seq_logits = []
        seq_cache = cache
        for t in toks:
            l, seq_cache = decode_step(
                params, jnp.asarray([[t]], jnp.int32), seq_cache, cfg, mode="serve"
            )
            seq_logits.append(np.asarray(l[0]))
        ver_logits, ver_cache = verify_step(
            params, jnp.asarray([toks], jnp.int32), cache, cfg, mode="serve"
        )
        np.testing.assert_allclose(
            np.asarray(ver_logits[0]), np.stack(seq_logits), rtol=2e-4, atol=2e-4
        )
        # both caches advanced identically
        def idx_leaves(cache):
            flat, _ = jax.tree_util.tree_flatten_with_path(cache)
            return [l for p, l in flat if getattr(p[-1], "key", None) == "idx"]

        for s, v in zip(idx_leaves(seq_cache), idx_leaves(ver_cache)):
            np.testing.assert_array_equal(np.asarray(s), np.asarray(v))

    def test_matches_sequential_decode(self, served, rng):
        """verify_step logits over (1, K+1) tokens == K+1 sequential
        decode_step calls — the exactness property acceptance rides on."""
        cfg, params, cache, t0 = self._prefilled(served, rng)
        self._check_matches_sequential(cfg, params, cache, [t0, 17, 401, 3])

    def test_matches_sequential_decode_mla(self, rng):
        """Same parity on an MLA arch: the absorbed-latent verify path and
        its multi-query causal mask (mla.py) must match sequential decode."""
        cfg = get_config("deepseek-v3-671b", smoke=True)
        params = pack_params(init_lm(jax.random.PRNGKey(0), cfg), cfg)
        prompt = jnp.asarray(rng.integers(0, cfg.vocab, (1, 12)), jnp.int32)
        cache = init_cache(cfg, 1, 64)
        logits, cache = prefill(params, prompt, cache, cfg, mode="serve")
        t0 = int(jnp.argmax(logits[0]))
        toks = [t0] + [int(t) for t in rng.integers(0, cfg.vocab, 3)]
        self._check_matches_sequential(cfg, params, cache, toks)

    def test_rollback_then_decode_is_exact(self, served, rng):
        """A rejected speculative excursion + rollback must leave the cache
        behaving exactly like the cache that never speculated."""
        cfg, params, cache, t0 = self._prefilled(served, rng)
        # clean continuation from the untouched cache
        clean_logits, _ = decode_step(
            params, jnp.asarray([[t0]], jnp.int32), cache, cfg, mode="serve"
        )
        # speculative excursion: verify K+1 (wrong) tokens, then roll back
        wrong = jnp.asarray([[t0, 7, 7, 7]], jnp.int32)
        _, dirty = verify_step(params, wrong, cache, cfg, mode="serve")
        idx0 = 12  # prompt length — every accepted token rolled back
        restored = rollback_cache(dirty, jnp.asarray([idx0]))
        redo_logits, _ = decode_step(
            params, jnp.asarray([[t0]], jnp.int32), restored, cfg, mode="serve"
        )
        np.testing.assert_allclose(
            np.asarray(clean_logits), np.asarray(redo_logits), rtol=1e-5, atol=1e-5
        )

    def test_verify_rejects_ssm(self, served):
        cfg = get_config("mamba2-1.3b", smoke=True)
        with pytest.raises(ValueError, match="ssm"):
            params = pack_params(init_lm(jax.random.PRNGKey(0), cfg), cfg)
            cache = init_cache(cfg, 1, 32)
            verify_step(params, jnp.zeros((1, 3), jnp.int32), cache, cfg)

    def test_verify_rejects_windowed(self):
        """Ring caches lose in-window history on rollback — the model layer
        itself must refuse, not just the engine constructor."""
        cfg = get_config("gemma3-1b", smoke=True)
        assert any(s.window for s in cfg.layer_specs())
        with pytest.raises(ValueError, match="window"):
            params = pack_params(init_lm(jax.random.PRNGKey(0), cfg), cfg)
            cache = init_cache(cfg, 1, 32)
            verify_step(params, jnp.zeros((1, 3), jnp.int32), cache, cfg)


# --------------------------------------------------------------------------
# Engine integration
# --------------------------------------------------------------------------
def _run_engine(cfg, params, prompts, *, spec=None, max_new=8, max_len=64,
                slots=2, temperature=0.0, seed=0):
    eng = Engine(params, cfg, max_slots=slots, max_len=max_len,
                 temperature=temperature, seed=seed, spec=spec)
    sched = ContinuousBatchingScheduler(eng)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    sched.submit(reqs)
    stats = sched.run_to_completion()
    return [r.generated for r in reqs], stats, eng


@pytest.mark.slow
class TestSpecEngine:
    def test_greedy_exactness_ngram(self, served, rng):
        """Acceptance criterion: Engine(spec=...) greedy output is token-for-
        token identical to the plain engine on the same prompts."""
        cfg, params = served
        prompts = [
            rng.integers(0, cfg.vocab, size=rng.integers(4, 20)).astype(np.int32)
            for _ in range(5)
        ]
        base, _, _ = _run_engine(cfg, params, prompts)
        spec, _, eng = _run_engine(
            cfg, params, prompts, spec=SpecConfig(k=3, drafter="ngram")
        )
        assert base == spec
        assert eng.spec_steps > 0 and eng.drafted_tokens > 0

    def test_greedy_exactness_model_drafter(self, served, rng):
        cfg, params = served
        prompts = [rng.integers(0, cfg.vocab, size=9).astype(np.int32)
                   for _ in range(3)]
        base, _, _ = _run_engine(cfg, params, prompts)
        spec_cfg = SpecConfig(k=2, drafter="model",
                              draft_params=params, draft_cfg=cfg)
        spec, _, _ = _run_engine(cfg, params, prompts, spec=spec_cfg)
        assert base == spec

    def test_oracle_drafter_accepts_everything(self, served, rng):
        """Self-drafting with the target's own params: every draft token
        matches the target's greedy pick → acceptance rate 1, and every
        uncapped step emits k+1 tokens."""
        cfg, params = served
        prompts = [rng.integers(0, cfg.vocab, size=8).astype(np.int32)]
        k = 3
        spec_cfg = SpecConfig(k=k, drafter="model",
                              draft_params=params, draft_cfg=cfg)
        # max_new − 1 (prefill token) divisible by k+1 → no step is capped
        out, stats, eng = _run_engine(
            cfg, params, prompts, spec=spec_cfg, max_new=2 * (k + 1) + 1, slots=1
        )
        assert eng.acceptance_rate == 1.0
        assert eng.decode_tokens_per_step == k + 1
        assert stats.accepted_tokens == stats.spec_steps * k

    def test_repetitive_prompt_accepts_drafts(self, served):
        """Prompt-lookup on a repetition-collapsed stream: the engine must
        average >1 token per verify step (≥1 accepted draft per step)."""
        cfg, params = served
        prompt = np.tile([11, 23], 8).astype(np.int32)
        out, stats, eng = _run_engine(
            cfg, params, [prompt], spec=SpecConfig(k=3), max_new=16, slots=1
        )
        assert eng.accepted_tokens >= eng.spec_steps  # ≥1 accepted per step
        assert eng.decode_tokens_per_step > 1.0

    def test_temperature_spec_completes(self, served, rng):
        """Stochastic path: rejection sampling emits only valid tokens and
        requests complete."""
        cfg, params = served
        prompts = [rng.integers(0, cfg.vocab, size=8).astype(np.int32)
                   for _ in range(2)]
        out, stats, _ = _run_engine(
            cfg, params, prompts, spec=SpecConfig(k=2), temperature=1.0, seed=3
        )
        assert stats.completed == 2
        assert all(len(g) == 8 for g in out)
        assert all(0 <= t < cfg.vocab for g in out for t in g)

    def test_spec_refuses_ssm_and_windowed(self, served):
        cfg_ssm = get_config("mamba2-1.3b", smoke=True)
        with pytest.raises(ValueError, match="ssm"):
            Engine({}, cfg_ssm, spec=SpecConfig(k=2))
        cfg_win = get_config("gemma3-1b", smoke=True)
        if any(s.window for s in cfg_win.layer_specs()):
            with pytest.raises(ValueError, match="window"):
                Engine({}, cfg_win, spec=SpecConfig(k=2))
            # a windowed DRAFT config must fail at construction too, not
            # deep inside jit tracing of the first propose()
            with pytest.raises(ValueError, match="window"):
                ModelDrafter({}, cfg_win, max_slots=1, max_len=32)

    def test_stats_flow_through_scheduler(self, served, rng):
        cfg, params = served
        prompts = [rng.integers(0, cfg.vocab, size=6).astype(np.int32)]
        _, stats, eng = _run_engine(cfg, params, prompts, spec=SpecConfig(k=2))
        assert stats.spec_steps == eng.spec_steps > 0
        assert stats.drafted_tokens == eng.drafted_tokens
        assert stats.decode_tokens_per_step == eng.decode_tokens_per_step
