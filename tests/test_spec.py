"""Speculative decoding subsystem: drafters, acceptance rules, multi-token
verification, KV rollback, and engine-level greedy exactness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import (
    compact_tree_cache,
    decode_step,
    init_cache,
    init_lm,
    pack_params,
    prefill,
    rollback_cache,
    verify_step,
)
from repro.serve import (
    ContinuousBatchingScheduler,
    Engine,
    Request,
    accept_speculative,
    accept_tree,
    greedy_accept,
)
from repro.spec import (
    Drafter,
    ModelDrafter,
    NgramDrafter,
    SpecConfig,
    build_tree,
)


@pytest.fixture(scope="module")
def served():
    cfg = get_config("smollm-360m", smoke=True)
    params = pack_params(init_lm(jax.random.PRNGKey(0), cfg), cfg)
    return cfg, params


# --------------------------------------------------------------------------
# Drafters
# --------------------------------------------------------------------------
class TestNgramDrafter:
    def test_prompt_lookup_continuation(self):
        d = NgramDrafter(max_n=3, min_n=1)
        ctx = np.array([1, 2, 3, 4, 9, 1, 2, 3])
        # trailing trigram [1,2,3] recurred at 0 → continuation [4, 9]
        np.testing.assert_array_equal(d.propose([ctx], 2)[0], [4, 9])

    def test_most_recent_match_wins(self):
        d = NgramDrafter(max_n=2, min_n=1)
        ctx = np.array([7, 1, 7, 2, 7])
        # suffix [7] matches at 0 and 2; most recent (2) → continuation [2, 7]
        np.testing.assert_array_equal(d.propose([ctx], 2)[0], [2, 7])

    def test_fallback_repeats_last_token(self):
        d = NgramDrafter()
        np.testing.assert_array_equal(d.propose([np.array([5])], 3)[0], [5, 5, 5])
        np.testing.assert_array_equal(
            d.propose([np.array([1, 2, 3, 4])], 2)[0], [4, 4]
        )

    def test_short_continuation_padded(self):
        d = NgramDrafter(max_n=1, min_n=1)
        ctx = np.array([8, 3, 8])   # match at 0; only [3] follows before suffix
        out = d.propose([ctx], 4)[0]
        np.testing.assert_array_equal(out, [3, 8, 8, 8])

    def test_free_slots_skipped(self):
        d = NgramDrafter()
        out = d.propose([None, np.array([4, 4, 4])], 2)
        assert out.shape == (2, 2)
        np.testing.assert_array_equal(out[1], [4, 4])


# --------------------------------------------------------------------------
# Acceptance rules
# --------------------------------------------------------------------------
class TestAcceptance:
    def test_greedy_accept_prefix_lengths(self):
        draft = jnp.asarray([[1, 2, 3], [1, 9, 3], [9, 2, 3], [1, 2, 9]])
        tgt = jnp.asarray([[1, 2, 3, 4]] * 4)
        np.testing.assert_array_equal(
            np.asarray(greedy_accept(draft, tgt)), [3, 1, 0, 2]
        )

    def test_greedy_mode_returns_argmax_tokens(self):
        rng = jax.random.PRNGKey(0)
        logits = jax.random.normal(rng, (2, 4, 16))
        draft = jnp.argmax(logits, axis=-1)[:, :3].astype(jnp.int32)
        n_acc, out = accept_speculative(draft, logits, rng, temperature=0.0)
        np.testing.assert_array_equal(np.asarray(n_acc), [3, 3])
        np.testing.assert_array_equal(np.asarray(out), np.argmax(logits, axis=-1))

    def test_stochastic_accepts_certain_tokens(self):
        # p(draft token) == 1 at every position → always accepted; bonus from
        # the last position's point mass.
        v, k = 8, 3
        draft = jnp.asarray([[2, 5, 1]], dtype=jnp.int32)
        onehot = jax.nn.one_hot(jnp.asarray([[2, 5, 1, 7]]), v)
        logits = jnp.log(onehot * (1 - 1e-6) + 1e-9)
        n_acc, out = accept_speculative(draft, logits, jax.random.PRNGKey(1),
                                        temperature=1.0)
        assert int(n_acc[0]) == k
        np.testing.assert_array_equal(np.asarray(out[0]), [2, 5, 1, 7])

    def test_stochastic_rejects_impossible_tokens(self):
        # p(draft token) == 0 → rejected at position 0; the resampled
        # correction must come from the target distribution's support.
        v = 8
        draft = jnp.asarray([[3, 3, 3]], dtype=jnp.int32)
        support = jax.nn.one_hot(jnp.asarray([[5, 5, 5, 5]]), v)
        logits = jnp.log(support * (1 - 1e-6) + 1e-9)
        n_acc, out = accept_speculative(draft, logits, jax.random.PRNGKey(2),
                                        temperature=1.0)
        assert int(n_acc[0]) == 0
        assert int(out[0, 0]) == 5

    def test_masked_greedy_accept_caps_prefix(self):
        draft = jnp.asarray([[1, 2, 3], [1, 2, 3]])
        tgt = jnp.asarray([[1, 2, 3, 4]] * 2)       # every draft matches …
        mask = jnp.asarray([[True, True, False], [False, False, False]])
        # … but acceptance may not run past a row's real (unmasked) drafts
        np.testing.assert_array_equal(
            np.asarray(greedy_accept(draft, tgt, mask)), [2, 0]
        )

    def test_masked_greedy_out_is_plain_argmax(self):
        """A k_eff=0 row under greedy masking is a plain decode row: n_acc 0
        and out[:, 0] the position-0 argmax; partially masked rows emit the
        argmax continuation at the first padded position."""
        rng = jax.random.PRNGKey(0)
        logits = jax.random.normal(rng, (2, 4, 16))
        draft = jnp.argmax(logits, axis=-1)[:, :3].astype(jnp.int32)  # perfect
        mask = jnp.asarray([[True, True, False], [False, False, False]])
        n_acc, out = accept_speculative(draft, logits, rng, temperature=0.0,
                                        draft_mask=mask)
        np.testing.assert_array_equal(np.asarray(n_acc), [2, 0])
        np.testing.assert_array_equal(np.asarray(out), np.argmax(logits, -1))

    def test_masked_stochastic_never_accepts_padding(self):
        # point-mass target on the draft tokens → unmasked drafts always
        # accepted; the mask must still stop acceptance at k_eff
        v = 8
        draft = jnp.asarray([[2, 5, 1]], dtype=jnp.int32)
        onehot = jax.nn.one_hot(jnp.asarray([[2, 5, 1, 7]]), v)
        logits = jnp.log(onehot * (1 - 1e-6) + 1e-9)
        mask = jnp.asarray([[True, False, False]])
        for seed in range(8):
            n_acc, out = accept_speculative(
                draft, logits, jax.random.PRNGKey(seed), temperature=1.0,
                draft_mask=mask,
            )
            assert int(n_acc[0]) == 1
            # correction at the first padded position: a full target sample,
            # here the point mass at token 5
            np.testing.assert_array_equal(np.asarray(out[0, :2]), [2, 5])

    def test_rejected_token_never_resampled_on_vanishing_residual(self):
        """Leviathan guarantee hardening: when the residual (p-q)+ sums to
        zero (float round-off or an inconsistent proposal q >= p everywhere)
        the fallback must never re-emit the token just rejected (regression:
        the old fallback resampled from full p)."""
        v, k = 8, 2
        draft = jnp.asarray([[3, 3]], dtype=jnp.int32)
        logits = jnp.zeros((1, k + 1, v)).at[:, :, 3].set(2.0)  # p(3) ≈ 0.51
        q = jnp.full((1, k, v), 1e6)    # q >= p everywhere → residual ≡ 0,
        for seed in range(64):          # accept prob p/q ≈ 0 → always reject
            n_acc, out = accept_speculative(
                draft, logits, jax.random.PRNGKey(seed), temperature=1.0,
                draft_probs=q,
            )
            assert int(n_acc[0]) == 0
            assert int(out[0, 0]) != 3

    def test_stochastic_draft_probs_exact_distribution(self):
        """With stochastic proposals q fed in as draft_probs, the emitted
        token at position 0 must be distributed exactly as the target's
        softmax — the Leviathan exactness property the engine's
        temperature>0 ModelDrafter path rides on."""
        v, k, n = 12, 2, 4000
        tl = jax.random.normal(jax.random.PRNGKey(0), (1, k + 1, v)) * 1.5
        q = jax.nn.softmax(
            jax.random.normal(jax.random.PRNGKey(1), (1, k, v)) * 1.5, axis=-1
        )

        def one(key):
            kd, ka = jax.random.split(key)
            draft = jax.random.categorical(kd, jnp.log(q), axis=-1)
            n_acc, out = accept_speculative(
                draft.astype(jnp.int32), tl, ka, temperature=1.0, draft_probs=q
            )
            return out[0, 0]

        toks = jax.vmap(one)(jax.random.split(jax.random.PRNGKey(2), n))
        counts = np.bincount(np.asarray(toks), minlength=v) / n
        p0 = np.asarray(jax.nn.softmax(tl[0, 0]))
        assert np.abs(counts - p0).sum() < 0.08   # TV; E[TV] ≈ 0.02 at n=4000

    def test_masked_correction_samples_full_target(self):
        """When every real draft is accepted, the token emitted at the first
        padded position must be a FULL target sample for that position (it
        was never proposed, so nothing was rejected there) — not a residual
        resample."""
        v, n = 12, 4000
        tl = jax.random.normal(jax.random.PRNGKey(3), (1, 3, v)) * 1.5
        # q(pos 0) == p(pos 0) → position 0 always accepted; position 1 padded
        q = jnp.stack([jax.nn.softmax(tl[:, 0]), jnp.full((1, v), 1.0 / v)], axis=1)
        mask = jnp.asarray([[True, False]])

        def one(key):
            kd, ka = jax.random.split(key)
            draft = jax.random.categorical(kd, jnp.log(q), axis=-1)
            n_acc, out = accept_speculative(
                draft.astype(jnp.int32), tl, ka, temperature=1.0,
                draft_probs=q, draft_mask=mask,
            )
            return n_acc[0], out[0, 1]

        n_accs, toks = jax.vmap(one)(jax.random.split(jax.random.PRNGKey(4), n))
        np.testing.assert_array_equal(np.asarray(n_accs), np.ones(n))
        counts = np.bincount(np.asarray(toks), minlength=v) / n
        p1 = np.asarray(jax.nn.softmax(tl[0, 1]))
        assert np.abs(counts - p1).sum() < 0.08


# --------------------------------------------------------------------------
# Draft trees (pure structure + acceptance, no model)
# --------------------------------------------------------------------------
class TestDraftTree:
    def test_structure_chain_after_branching(self):
        t = build_tree(4, (2, 2))
        # 1 root + 2 + 4 + 4 + 4: depths past len(tree) chain per leaf
        assert t.n_nodes == 15 and t.n_draft == 14
        assert t.branching == (2, 2, 1, 1)
        np.testing.assert_array_equal(np.bincount(t.depths), [1, 2, 4, 4, 4])
        assert t.leaf_paths.shape == (4, 5)
        # every path starts at the root and descends parent→child
        for path in t.leaf_paths:
            assert path[0] == 0
            for d in range(1, 5):
                assert t.parents[path[d]] == path[d - 1]

    def test_ancestor_matrix(self):
        t = build_tree(2, (2,))
        # nodes: 0 root; 1,2 depth-1; 3=chain(1), 4=chain(2)
        np.testing.assert_array_equal(t.parents, [0, 0, 0, 1, 2])
        assert t.ancestors[3].tolist() == [True, True, False, True, False]
        assert t.ancestors[4].tolist() == [True, False, True, False, True]
        assert t.ancestors[0].tolist() == [True, False, False, False, False]

    def test_rank0_path_is_the_chain(self):
        t = build_tree(3, (3, 2))
        # the all-rank-0 leaf is leaf 0 by flattening order
        path = t.leaf_paths[0]
        assert all(t.ranks[n] == 0 for n in path)

    def test_validation(self):
        with pytest.raises(ValueError, match="at most k deep"):
            build_tree(2, (2, 2, 2))
        with pytest.raises(ValueError, match=">= 1"):
            build_tree(2, (0,))
        with pytest.raises(ValueError, match="nodes"):
            build_tree(4, (8, 8, 8))
        with pytest.raises(ValueError, match="adaptive_k"):
            SpecConfig(k=2, tree=(2,), adaptive_k=True)
        with pytest.raises(ValueError, match="stochastic"):
            SpecConfig(k=2, tree=(2,), drafter="model", stochastic=True,
                       draft_params={}, draft_cfg={})
        with pytest.raises(ValueError, match="at most k deep"):
            SpecConfig(k=1, tree=(2, 2))
        assert SpecConfig(k=3, tree=(2,)).tree_struct().n_nodes == 7
        assert SpecConfig(k=3).tree_struct() is None


class TestAcceptTree:
    def _onehot_logits(self, picks, v=16):
        """(B, N, V) logits whose argmax at node j is picks[b][j]."""
        oh = jax.nn.one_hot(jnp.asarray(picks), v)
        return jnp.log(oh * (1 - 1e-6) + 1e-9)

    def test_longest_path_wins(self):
        t = build_tree(2, (2,))           # paths [0,1,3] and [0,2,4]
        tokens = jnp.asarray([[5, 7, 9, 7, 8]], jnp.int32)
        # target picks: after root → 9 (rejects node 1, accepts node 2),
        # after node 2 → 8 (accepts node 4), after node 4 → 3 (correction)
        logits = self._onehot_logits([[9, 0, 8, 0, 3]])
        n_acc, out, path = accept_tree(tokens, logits, t, jax.random.PRNGKey(0))
        assert int(n_acc[0]) == 2
        np.testing.assert_array_equal(np.asarray(out[0]), [9, 8, 3])
        np.testing.assert_array_equal(np.asarray(path[0]), [0, 2, 4])

    def test_no_match_emits_correction_only(self):
        t = build_tree(2, (2,))
        tokens = jnp.asarray([[5, 7, 9, 7, 8]], jnp.int32)
        logits = self._onehot_logits([[1, 0, 0, 0, 0]])   # root pick misses all
        n_acc, out, _ = accept_tree(tokens, logits, t, jax.random.PRNGKey(0))
        assert int(n_acc[0]) == 0
        assert int(out[0, 0]) == 1        # the target's own pick

    def test_tie_resolves_to_lowest_rank_branch(self):
        t = build_tree(2, (2,))
        # both depth-1 siblings carry the accepted token 7; deeper nodes miss
        tokens = jnp.asarray([[5, 7, 7, 1, 2]], jnp.int32)
        logits = self._onehot_logits([[7, 9, 9, 0, 0]])
        n_acc, out, path = accept_tree(tokens, logits, t, jax.random.PRNGKey(0))
        assert int(n_acc[0]) == 1
        np.testing.assert_array_equal(np.asarray(path[0]), [0, 1, 3])
        np.testing.assert_array_equal(np.asarray(out[0, :2]), [7, 9])

    def test_temperature_correction_sampled_from_last_accepted_node(self):
        # greedy path matching at temperature>0, correction sampled from the
        # last accepted node's next-token distribution (here a point mass)
        t = build_tree(1, (2,))           # root + 2 leaves
        tokens = jnp.asarray([[5, 7, 9]], jnp.int32)
        logits = self._onehot_logits([[9, 0, 4]])
        for seed in range(8):
            n_acc, out, _ = accept_tree(
                tokens, logits, t, jax.random.PRNGKey(seed), temperature=1.0
            )
            assert int(n_acc[0]) == 1
            np.testing.assert_array_equal(np.asarray(out[0]), [9, 4])


class TestCompactTreeCache:
    def test_moves_path_entries_and_invalidates_losers(self):
        """Window slots d < take must receive the accepted path node's entry
        (slot == position restored — slot_pos is *gathered* from the source
        node, whose tree write recorded position pos + depth == dst); later
        window slots get slot_pos = -1 so a stale sibling's small position
        can never satisfy a future query's position mask."""
        b, L, n = 2, 12, 5
        line = np.tile(np.arange(L, dtype=np.float32)[None, None, :], (1, b, 1))
        # slot_pos as a real tree verify step leaves it: node j sits at slot
        # pos+j but records position pos+depth(j) — tree (2,2) depths are
        # [0, 1, 1, 2, 2] — identity (chain writes) outside the window
        depths = np.array([0, 1, 1, 2, 2])
        sp = np.tile(np.arange(L, dtype=np.int32)[None], (b, 1))
        sp[0, 3:8] = 3 + depths
        sp[1, 0:5] = 0 + depths
        cache = {
            "k": jnp.asarray(line[..., None, None]),          # (1, B, L, 1, 1)
            "slot_pos": jnp.asarray(sp[None]),
            "idx": jnp.zeros((1, b), jnp.int32),
        }
        pos = jnp.asarray([3, 0])
        # row 0: accepted path nodes 2 (depth 1) and 4 (depth 2), take=3;
        # row 1: nothing accepted beyond the root, take=1
        sel = jnp.asarray([[0, 2, 4, 3, 4], [0, 1, 2, 3, 4]])
        take = jnp.asarray([3, 1])
        out = compact_tree_cache(cache, pos, sel, take)
        k0 = np.asarray(out["k"])[0, 0, :, 0, 0]
        np.testing.assert_array_equal(k0[:3], [0, 1, 2])      # prefix intact
        np.testing.assert_array_equal(k0[3:8], [3, 5, 7, 6, 7])
        sp0 = np.asarray(out["slot_pos"])[0, 0]
        np.testing.assert_array_equal(sp0[3:8], [3, 4, 5, -1, -1])
        sp1 = np.asarray(out["slot_pos"])[0, 1]
        np.testing.assert_array_equal(sp1[:5], [0, -1, -1, -1, -1])
        np.testing.assert_array_equal(sp1[5:], np.arange(5, L))
        np.testing.assert_array_equal(np.asarray(out["idx"]), 0)  # rollback's

    def test_identity_window_is_noop(self):
        """A slot that took no part in the verify step (free, or mid-chunked-
        prefill) is passed sel=identity and take=n: its window — live data,
        unwritten -1 slot_pos entries included — must come back byte-for-
        byte unchanged (regression: take=0 used to stamp slot_pos=-1 over a
        prefilling slot's live prefix)."""
        b, L, n = 1, 10, 4
        rng = np.random.default_rng(3)
        sp = np.where(np.arange(L) < 6, np.arange(L), -1).astype(np.int32)
        cache = {
            "k": jnp.asarray(rng.normal(size=(1, b, L, 1, 1)).astype(np.float32)),
            "slot_pos": jnp.asarray(sp[None, None]),
            "idx": jnp.full((1, b), 6, jnp.int32),
        }
        out = compact_tree_cache(
            cache,
            jnp.asarray([0]),
            jnp.arange(n, dtype=jnp.int32)[None],
            jnp.asarray([n]),
        )
        for key in ("k", "slot_pos", "idx"):
            np.testing.assert_array_equal(
                np.asarray(out[key]), np.asarray(cache[key])
            )

    @staticmethod
    def _boundary_cache(rng, b, L):
        return {
            "k": jnp.asarray(rng.normal(size=(1, b, L, 1, 1)).astype(np.float32)),
            "slot_pos": jnp.asarray(
                np.arange(L, dtype=np.int32)[None, None].repeat(b, axis=1)
            ),
            "idx": jnp.full((1, b), L, jnp.int32),
        }

    def test_identity_window_crossing_buffer_end_is_noop(self):
        """Identity window whose dst columns run past max_len (a full buffer
        plus a non-participating slot): with mode="drop" the out-of-range
        columns vanish and the in-range ones gather themselves — byte-exact
        no-op. (Boundary regression for the R1 fix: the old implicit clamp
        was load-bearing here only because src clamped identically.)"""
        b, L, n = 1, 10, 4
        rng = np.random.default_rng(5)
        cache = self._boundary_cache(rng, b, L)
        out = compact_tree_cache(
            cache,
            jnp.asarray([L - 2]),                       # window = [8..11] > L
            jnp.arange(n, dtype=jnp.int32)[None],
            jnp.asarray([n]),
        )
        for key in ("k", "slot_pos", "idx"):
            np.testing.assert_array_equal(
                np.asarray(out[key]), np.asarray(cache[key])
            )

    def test_oob_window_columns_never_clobber_last_entry(self):
        """Non-identity window at the buffer frontier: the columns whose dst
        lands past max_len must be DROPPED, not clamped onto the last valid
        slot (under the old clamp, the dead col-3 write — gathered k[6],
        slot_pos -1 — landed on slot 7 and clobbered the live entry)."""
        b, L = 1, 8
        rng = np.random.default_rng(7)
        cache = self._boundary_cache(rng, b, L)
        k_old = np.asarray(cache["k"]).copy()
        out = compact_tree_cache(
            cache,
            jnp.asarray([L - 2]),                        # dst = [6, 7, 8, 9]
            jnp.asarray([[1, 0, 2, 0]], jnp.int32),      # src = [7, 6, 8, 6]
            jnp.asarray([2]),                            # live cols: 0, 1
        )
        k = np.asarray(out["k"])[0, 0, :, 0, 0]
        sp = np.asarray(out["slot_pos"])[0, 0]
        # accepted path: slot 6 ← old 7, slot 7 ← old 6 (gathers clamp src 8
        # to 7, but those columns' writes are dropped, never visible)
        assert k[6] == k_old[0, 0, 7, 0, 0]
        assert k[7] == k_old[0, 0, 6, 0, 0]
        assert sp[6] == 7 and sp[7] == 6
        # untouched prefix
        np.testing.assert_array_equal(k[:6], k_old[0, 0, :6, 0, 0])
        np.testing.assert_array_equal(sp[:6], np.arange(6))


# --------------------------------------------------------------------------
# Adaptive-K policy (pure config logic, no model)
# --------------------------------------------------------------------------
class TestKPolicy:
    def test_fixed_when_adaptive_disabled(self):
        assert SpecConfig(k=4).k_policy(0.0) == 4
        assert SpecConfig(k=4).k_policy(1.0) == 4

    def test_scales_with_acceptance_ewma(self):
        c = SpecConfig(k=4, adaptive_k=True, k_min=1, skip_below=0.2)
        assert c.k_policy(1.0) == 4
        assert c.k_policy(0.5) == 2
        assert c.k_policy(0.25) == 1     # floored at k_min
        assert c.k_policy(0.05) == 0     # cold → skip drafting

    def test_cold_slot_probes_after_streak(self):
        c = SpecConfig(k=4, adaptive_k=True, probe_every=3)
        assert c.k_policy(0.0, skip_streak=0) == 0
        assert c.k_policy(0.0, skip_streak=2) == 0
        assert c.k_policy(0.0, skip_streak=3) == c.k_min  # probe

    def test_knob_validation(self):
        with pytest.raises(ValueError, match="accept_ewma"):
            SpecConfig(k=2, accept_ewma=1.0)
        with pytest.raises(ValueError, match="k_min"):
            SpecConfig(k=2, k_min=0)
        with pytest.raises(ValueError, match="k_min"):
            SpecConfig(k=2, k_min=3)
        with pytest.raises(ValueError, match="skip_below"):
            SpecConfig(k=2, skip_below=1.5)
        with pytest.raises(ValueError, match="probe_every"):
            SpecConfig(k=2, probe_every=0)
        with pytest.raises(ValueError, match="stochastic"):
            SpecConfig(k=2, drafter="ngram", stochastic=True)

    def test_ngram_drafter_skips_slot_k_zero(self):
        d = NgramDrafter()
        out = d.propose([np.array([4, 4, 4]), np.array([7, 7, 7])], 2,
                        slot_k=np.array([0, 2]))
        np.testing.assert_array_equal(out[0], [0, 0])   # untouched padding
        np.testing.assert_array_equal(out[1], [7, 7])
        draft, probs = d.propose([np.array([4, 4])], 2, return_probs=True)
        assert probs is None                             # deterministic


class TestNgramTreeProposal:
    def test_branches_are_distinct_continuations(self):
        d = NgramDrafter(max_n=1, min_n=1)
        # token 5 was followed by 8 (twice) and by 3 (once, most recent)
        ctx = np.array([5, 8, 5, 8, 5, 3, 5])
        t = build_tree(2, (2,))
        out = d.propose([ctx], 2, tree=t)[0]
        # depth-1 candidates: 8 (count 2) ranked above 3 (count 1)
        assert out[0] == 8 and out[1] == 3
        # chain continuations track each branch's own hypothesis: after
        # [... 5, 8] the bigram fallback sees 8 → 5; after [... 5, 3] 3 → 5
        assert out.shape == (t.n_draft,)

    def test_fewer_matches_than_branches_pads(self):
        d = NgramDrafter(max_n=1, min_n=1)
        ctx = np.array([5, 8, 5])                        # one continuation
        t = build_tree(1, (3,))
        out = d.propose([ctx], 1, tree=t)[0]
        np.testing.assert_array_equal(out, [8, 8, 8])    # padded with best

    def test_free_slots_skipped(self):
        d = NgramDrafter()
        t = build_tree(2, (2,))
        out = d.propose([None, np.array([4, 4, 4])], 2, tree=t)
        assert out.shape == (2, t.n_draft)
        np.testing.assert_array_equal(out[0], 0)


# --------------------------------------------------------------------------
# Multi-token verification + rollback (model level)
# --------------------------------------------------------------------------
@pytest.mark.slow
class TestVerifyStep:
    K = 3

    def _prefilled(self, served, rng, max_len=64):
        cfg, params = served
        prompt = jnp.asarray(rng.integers(0, cfg.vocab, (1, 12)), jnp.int32)
        cache = init_cache(cfg, 1, max_len)
        prefill_fn = jax.jit(lambda p, c, t: prefill(p, t, c, cfg, mode="serve"))
        logits, cache = prefill_fn(params, cache, prompt)
        return cfg, params, cache, int(jnp.argmax(logits[0]))

    def _check_matches_sequential(self, cfg, params, cache, toks):
        seq_logits = []
        seq_cache = cache
        for t in toks:
            l, seq_cache = decode_step(
                params, jnp.asarray([[t]], jnp.int32), seq_cache, cfg, mode="serve"
            )
            seq_logits.append(np.asarray(l[0]))
        ver_logits, ver_cache = verify_step(
            params, jnp.asarray([toks], jnp.int32), cache, cfg, mode="serve"
        )
        np.testing.assert_allclose(
            np.asarray(ver_logits[0]), np.stack(seq_logits), rtol=2e-4, atol=2e-4
        )
        # both caches advanced identically
        def idx_leaves(cache):
            flat, _ = jax.tree_util.tree_flatten_with_path(cache)
            return [l for p, l in flat if getattr(p[-1], "key", None) == "idx"]

        for s, v in zip(idx_leaves(seq_cache), idx_leaves(ver_cache)):
            np.testing.assert_array_equal(np.asarray(s), np.asarray(v))

    def test_matches_sequential_decode(self, served, rng):
        """verify_step logits over (1, K+1) tokens == K+1 sequential
        decode_step calls — the exactness property acceptance rides on."""
        cfg, params, cache, t0 = self._prefilled(served, rng)
        self._check_matches_sequential(cfg, params, cache, [t0, 17, 401, 3])

    def test_matches_sequential_decode_mla(self, rng):
        """Same parity on an MLA arch: the absorbed-latent verify path and
        its multi-query causal mask (mla.py) must match sequential decode."""
        cfg = get_config("deepseek-v3-671b", smoke=True)
        params = pack_params(init_lm(jax.random.PRNGKey(0), cfg), cfg)
        prompt = jnp.asarray(rng.integers(0, cfg.vocab, (1, 12)), jnp.int32)
        cache = init_cache(cfg, 1, 64)
        logits, cache = prefill(params, prompt, cache, cfg, mode="serve")
        t0 = int(jnp.argmax(logits[0]))
        toks = [t0] + [int(t) for t in rng.integers(0, cfg.vocab, 3)]
        self._check_matches_sequential(cfg, params, cache, toks)

    def test_rollback_then_decode_is_exact(self, served, rng):
        """A rejected speculative excursion + rollback must leave the cache
        behaving exactly like the cache that never speculated."""
        cfg, params, cache, t0 = self._prefilled(served, rng)
        # clean continuation from the untouched cache
        clean_logits, _ = decode_step(
            params, jnp.asarray([[t0]], jnp.int32), cache, cfg, mode="serve"
        )
        # speculative excursion: verify K+1 (wrong) tokens, then roll back
        wrong = jnp.asarray([[t0, 7, 7, 7]], jnp.int32)
        _, dirty = verify_step(params, wrong, cache, cfg, mode="serve")
        idx0 = 12  # prompt length — every accepted token rolled back
        restored = rollback_cache(dirty, jnp.asarray([idx0]))
        redo_logits, _ = decode_step(
            params, jnp.asarray([[t0]], jnp.int32), restored, cfg, mode="serve"
        )
        np.testing.assert_allclose(
            np.asarray(clean_logits), np.asarray(redo_logits), rtol=1e-5, atol=1e-5
        )

    def test_verify_rejects_ssm(self, served):
        cfg = get_config("mamba2-1.3b", smoke=True)
        with pytest.raises(ValueError, match="ssm"):
            params = pack_params(init_lm(jax.random.PRNGKey(0), cfg), cfg)
            cache = init_cache(cfg, 1, 32)
            verify_step(params, jnp.zeros((1, 3), jnp.int32), cache, cfg)

    def test_verify_rejects_windowed(self):
        """Ring caches lose in-window history on rollback — the model layer
        itself must refuse, not just the engine constructor."""
        cfg = get_config("gemma3-1b", smoke=True)
        assert any(s.window for s in cfg.layer_specs())
        with pytest.raises(ValueError, match="window"):
            params = pack_params(init_lm(jax.random.PRNGKey(0), cfg), cfg)
            cache = init_cache(cfg, 1, 32)
            verify_step(params, jnp.zeros((1, 3), jnp.int32), cache, cfg)


# --------------------------------------------------------------------------
# Engine integration
# --------------------------------------------------------------------------
def _run_engine(cfg, params, prompts, *, spec=None, max_new=8, max_len=64,
                slots=2, temperature=0.0, seed=0):
    eng = Engine(params, cfg, max_slots=slots, max_len=max_len,
                 temperature=temperature, seed=seed, spec=spec)
    sched = ContinuousBatchingScheduler(eng)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    sched.submit(reqs)
    stats = sched.run_to_completion()
    return [r.generated for r in reqs], stats, eng


@pytest.mark.slow
class TestSpecEngine:
    def test_greedy_exactness_ngram(self, served, rng):
        """Acceptance criterion: Engine(spec=...) greedy output is token-for-
        token identical to the plain engine on the same prompts — with fixed
        K and with per-slot adaptive K (mask/sentinel padding must never
        leak a token)."""
        cfg, params = served
        prompts = [
            rng.integers(0, cfg.vocab, size=rng.integers(4, 20)).astype(np.int32)
            for _ in range(5)
        ]
        base, _, _ = _run_engine(cfg, params, prompts)
        spec, _, eng = _run_engine(
            cfg, params, prompts, spec=SpecConfig(k=3, drafter="ngram")
        )
        assert base == spec
        assert eng.spec_steps > 0 and eng.drafted_tokens > 0
        adapt, _, eng_a = _run_engine(
            cfg, params, prompts,
            spec=SpecConfig(k=3, drafter="ngram", adaptive_k=True,
                            accept_ewma=0.5, skip_below=0.3, probe_every=2),
        )
        assert base == adapt
        assert eng_a.spec_steps > 0

    def test_greedy_exactness_model_drafter(self, served, rng):
        cfg, params = served
        prompts = [rng.integers(0, cfg.vocab, size=9).astype(np.int32)
                   for _ in range(3)]
        base, _, _ = _run_engine(cfg, params, prompts)
        spec_cfg = SpecConfig(k=2, drafter="model",
                              draft_params=params, draft_cfg=cfg)
        spec, _, _ = _run_engine(cfg, params, prompts, spec=spec_cfg)
        assert base == spec

    def test_oracle_drafter_accepts_everything(self, served, rng):
        """Self-drafting with the target's own params: every draft token
        matches the target's greedy pick → acceptance rate 1, and every
        uncapped step emits k+1 tokens."""
        cfg, params = served
        prompts = [rng.integers(0, cfg.vocab, size=8).astype(np.int32)]
        k = 3
        spec_cfg = SpecConfig(k=k, drafter="model",
                              draft_params=params, draft_cfg=cfg)
        # max_new − 1 (prefill token) divisible by k+1 → no step is capped
        out, stats, eng = _run_engine(
            cfg, params, prompts, spec=spec_cfg, max_new=2 * (k + 1) + 1, slots=1
        )
        assert eng.acceptance_rate == 1.0
        assert eng.decode_tokens_per_step == k + 1
        assert stats.accepted_tokens == stats.spec_steps * k

    def test_repetitive_prompt_accepts_drafts(self, served):
        """Prompt-lookup on a repetition-collapsed stream: the engine must
        average >1 token per verify step (≥1 accepted draft per step)."""
        cfg, params = served
        prompt = np.tile([11, 23], 8).astype(np.int32)
        out, stats, eng = _run_engine(
            cfg, params, [prompt], spec=SpecConfig(k=3), max_new=16, slots=1
        )
        assert eng.accepted_tokens >= eng.spec_steps  # ≥1 accepted per step
        assert eng.decode_tokens_per_step > 1.0

    def test_temperature_spec_completes(self, served, rng):
        """Stochastic path: rejection sampling emits only valid tokens and
        requests complete."""
        cfg, params = served
        prompts = [rng.integers(0, cfg.vocab, size=8).astype(np.int32)
                   for _ in range(2)]
        out, stats, _ = _run_engine(
            cfg, params, prompts, spec=SpecConfig(k=2), temperature=1.0, seed=3
        )
        assert stats.completed == 2
        assert all(len(g) == 8 for g in out)
        assert all(0 <= t < cfg.vocab for g in out for t in g)

    def test_adaptive_cold_slot_skips_drafting(self, served, rng):
        """A slot whose drafts keep getting rejected must fall to k_eff=0
        (plain decode rows), periodically re-probe, and still emit exactly
        the plain greedy output."""

        class WrongDrafter(Drafter):
            # proposes last_token+1 — (almost) never the target's greedy pick
            def __init__(self, vocab):
                self.vocab = vocab

            def propose(self, contexts, k, *, slot_k=None, rng=None,
                        temperature=0.0, return_probs=False):
                out = np.zeros((len(contexts), k), np.int32)
                for i, ctx in enumerate(contexts):
                    if ctx is not None:
                        out[i] = (int(ctx[-1]) + 1) % self.vocab
                return (out, None) if return_probs else out

        cfg, params = served
        prompts = [rng.integers(0, cfg.vocab, size=8).astype(np.int32)]
        base, _, _ = _run_engine(cfg, params, prompts, max_new=14, slots=1)
        spec_cfg = SpecConfig(k=4, adaptive_k=True, accept_ewma=0.5,
                              skip_below=0.3, probe_every=3)
        eng = Engine(params, cfg, max_slots=1, max_len=64, spec=spec_cfg)
        eng.drafter = WrongDrafter(cfg.vocab)
        req = Request(rid=0, prompt=prompts[0], max_new_tokens=14)
        assert eng.add(req)
        seen_k = set()
        for _ in range(32):
            if req.done:
                break
            eng.decode_once()
            seen_k.add(int(eng.slot_k_eff[0]))   # live per-slot k observable
        assert req.done
        assert req.generated == base[0]          # exactness survives skipping
        assert eng.spec_skipped_steps > 0        # the policy did go cold
        assert 0.0 < eng.skip_rate <= 1.0
        assert eng.drafted_tokens < eng.spec_slot_steps * spec_cfg.k
        assert spec_cfg.k in seen_k              # optimistic full-k start …
        assert 0 in seen_k                       # … decayed to a skip

    def test_stochastic_model_drafter_high_acceptance(self, served, rng):
        """Self-drafting stochastically at the serving temperature: q ≈ p at
        every position, so rejection sampling accepts (almost) everything —
        the acceptance win greedy one-hot proposals throw away."""
        cfg, params = served
        prompts = [rng.integers(0, cfg.vocab, size=8).astype(np.int32)
                   for _ in range(2)]
        spec_cfg = SpecConfig(k=2, drafter="model", stochastic=True,
                              draft_params=params, draft_cfg=cfg)
        out, stats, eng = _run_engine(
            cfg, params, prompts, spec=spec_cfg, temperature=1.0, seed=9
        )
        assert stats.completed == 2
        assert all(len(g) == 8 for g in out)
        assert all(0 <= t < cfg.vocab for g in out for t in g)
        assert eng.acceptance_rate > 0.9      # q == p up to float round-off

    def test_spec_refuses_ssm_and_windowed(self, served):
        cfg_ssm = get_config("mamba2-1.3b", smoke=True)
        with pytest.raises(ValueError, match="ssm"):
            Engine({}, cfg_ssm, spec=SpecConfig(k=2))
        cfg_win = get_config("gemma3-1b", smoke=True)
        if any(s.window for s in cfg_win.layer_specs()):
            with pytest.raises(ValueError, match="window"):
                Engine({}, cfg_win, spec=SpecConfig(k=2))
            # a windowed DRAFT config must fail at construction too, not
            # deep inside jit tracing of the first propose()
            with pytest.raises(ValueError, match="window"):
                ModelDrafter({}, cfg_win, max_slots=1, max_len=32)

    def test_stats_flow_through_scheduler(self, served, rng):
        cfg, params = served
        prompts = [rng.integers(0, cfg.vocab, size=6).astype(np.int32)]
        _, stats, eng = _run_engine(cfg, params, prompts, spec=SpecConfig(k=2))
        assert stats.spec_steps == eng.spec_steps > 0
        assert stats.drafted_tokens == eng.drafted_tokens
        assert stats.decode_tokens_per_step == eng.decode_tokens_per_step
        assert stats.spec_skipped_steps == eng.spec_skipped_steps == 0
        assert stats.skip_rate == eng.skip_rate == 0.0
        assert stats.mean_draft_k == eng.mean_draft_k == 2.0


@pytest.mark.slow
class TestTreeSpecEngine:
    """Tree-structured multi-candidate verification: greedy output must be
    token-identical to plain decode, chain mode must be untouched, and the
    verify pass must carry tree-many nodes per slot step."""

    def _mixed_prompts(self, cfg, rng, n=4):
        """Half repetitive (n-gram tree drafting feeds), half random."""
        pat = rng.integers(0, cfg.vocab, size=3)
        warm = [np.tile(pat, 5).astype(np.int32) for _ in range(n - n // 2)]
        cold = [rng.integers(0, cfg.vocab, size=rng.integers(4, 16)).astype(np.int32)
                for _ in range(n // 2)]
        return warm + cold

    def test_greedy_tree_exactness_mixed_batch(self, served, rng):
        """Acceptance criterion: greedy tree-speculative serving emits
        token-for-token the plain-decode output on a mixed warm/cold batch,
        while each slot's verify row carries n_nodes > k+1 candidates."""
        cfg, params = served
        prompts = self._mixed_prompts(cfg, rng)
        base, _, _ = _run_engine(cfg, params, prompts, max_new=10)
        spec = SpecConfig(k=4, drafter="ngram", tree=(2, 2))
        treed, stats, eng = _run_engine(cfg, params, prompts, spec=spec,
                                        max_new=10)
        assert base == treed
        n_nodes = spec.tree_struct().n_nodes
        assert n_nodes > spec.k + 1
        assert eng.nodes_per_step == stats.nodes_per_step == n_nodes
        assert eng.spec_steps > 0 and eng.verified_nodes > 0

    def test_greedy_tree_exactness_model_drafter(self, served, rng):
        cfg, params = served
        prompts = [rng.integers(0, cfg.vocab, size=9).astype(np.int32)
                   for _ in range(2)]
        base, _, _ = _run_engine(cfg, params, prompts)
        spec = SpecConfig(k=3, drafter="model", tree=(2,),
                          draft_params=params, draft_cfg=cfg)
        treed, _, eng = _run_engine(cfg, params, prompts, spec=spec)
        assert base == treed
        # the rank-0 path is the self-draft argmax chain → fully accepted
        # whenever a step isn't capped by max_new_tokens
        assert eng.decode_tokens_per_step > 1.0

    def test_greedy_tree_exactness_mla(self, rng):
        """The absorbed-latent MLA verify path under tree masks + window
        compaction must stay exact too."""
        cfg = get_config("deepseek-v3-671b", smoke=True)
        params = pack_params(init_lm(jax.random.PRNGKey(0), cfg), cfg)
        prompts = [np.tile([7, 3, 9], 4).astype(np.int32),
                   rng.integers(0, cfg.vocab, size=8).astype(np.int32)]
        base, _, _ = _run_engine(cfg, params, prompts)
        treed, _, _ = _run_engine(
            cfg, params, prompts, spec=SpecConfig(k=3, drafter="ngram", tree=(2, 2))
        )
        assert base == treed

    def test_chain_mode_is_unchanged(self, served, rng):
        """tree=None must run the pre-tree chain path: same output as plain
        decode, k+1 verified nodes per slot step, no tree state."""
        cfg, params = served
        prompts = self._mixed_prompts(cfg, rng)
        base, _, _ = _run_engine(cfg, params, prompts)
        chain, _, eng = _run_engine(cfg, params, prompts,
                                    spec=SpecConfig(k=3, drafter="ngram"))
        assert base == chain
        assert eng._tree is None
        assert eng.nodes_per_step == eng.spec.k + 1

    def test_tree_temperature_serving_completes(self, served, rng):
        """temperature>0 tree serving (greedy path matching + sampled
        correction — see accept_tree's TODO) warns about the approximation,
        emits valid tokens, and completes."""
        cfg, params = served
        prompts = [rng.integers(0, cfg.vocab, size=8).astype(np.int32)
                   for _ in range(2)]
        with pytest.warns(UserWarning, match="greedy-filtered"):
            out, stats, _ = _run_engine(
                cfg, params, prompts, spec=SpecConfig(k=2, tree=(2,)),
                temperature=1.0, seed=5,
            )
        assert stats.completed == 2
        assert all(len(g) == 8 for g in out)
        assert all(0 <= t < cfg.vocab for g in out for t in g)

    def test_tree_draft_window_budget(self, served, rng):
        """Admission must budget the tree's slot window (n_nodes-1 slots
        past the root), not just k."""
        cfg, params = served
        spec = SpecConfig(k=4, drafter="ngram", tree=(2, 2))   # 15 nodes
        eng = Engine(params, cfg, max_slots=1, max_len=32, spec=spec)
        assert eng._draft_window == 14
        prompt = rng.integers(0, cfg.vocab, size=10).astype(np.int32)
        with pytest.raises(ValueError, match="draft window"):
            eng.add(Request(rid=0, prompt=prompt, max_new_tokens=10))


@pytest.mark.slow
class TestModelDrafterSlotK:
    def test_decode_loop_capped_and_free_slots_untouched(self, served):
        """Regression: propose() used to run all k-1 draft decode steps even
        when every active slot's k_eff was smaller, and scribbled
        synced[free]=1 on free slots."""
        cfg, params = served
        d = ModelDrafter(params, cfg, max_slots=2, max_len=32)
        prompt = (np.arange(5) + 7).astype(np.int32)
        d.on_admit(0, prompt)
        assert int(d.synced[1]) == 0                    # free slot, untouched
        calls = []
        real_decode = d._decode
        d._decode = lambda *a: (calls.append(1), real_decode(*a))[1]
        k = 4
        ctx = np.concatenate([prompt, [3]]).astype(np.int32)
        out = d.propose([ctx, None], k, slot_k=np.array([2, 0]))
        assert out.shape == (2, k)
        # deepest active k_eff = 2 → exactly 1 decode step (not k-1 = 3)
        assert len(calls) == 1
        # free slot's synced must never be written
        assert int(d.synced[1]) == 0
        assert int(d.synced[0]) == 6

    def test_all_slots_skipping_runs_no_decode_steps(self, served):
        cfg, params = served
        d = ModelDrafter(params, cfg, max_slots=1, max_len=32)
        prompt = (np.arange(5) + 7).astype(np.int32)
        d.on_admit(0, prompt)
        calls = []
        real_decode = d._decode
        d._decode = lambda *a: (calls.append(1), real_decode(*a))[1]
        ctx = np.concatenate([prompt, [3]]).astype(np.int32)
        out = d.propose([ctx], 3, slot_k=np.array([0]))
        assert out.shape == (1, 3)
        assert len(calls) == 0              # nothing to draft anywhere


@pytest.mark.slow
def test_stochastic_spec_matches_plain_sampling_distribution(served):
    """Acceptance criterion: temperature>0 serving with a stochastic
    ModelDrafter is *distributionally* identical to plain temperature
    sampling. On a small vocab, the marginal distribution of the first
    verify-emitted token over many independent runs must match the plain
    engine's (total variation below a seeded statistical bound)."""
    import dataclasses as dc

    cfg0, _ = served
    cfg = dc.replace(cfg0, vocab=16)
    params = pack_params(init_lm(jax.random.PRNGKey(1), cfg), cfg)
    prompt = np.asarray([3, 11, 7, 2, 9, 14], np.int32)
    n, v = 600, cfg.vocab

    def collect(spec):
        # one engine reused across trials: jit caches stay warm and the
        # engine rng advances, giving i.i.d. samples per request
        eng = Engine(params, cfg, max_slots=1, max_len=32,
                     temperature=1.5, seed=11, spec=spec)
        sched = ContinuousBatchingScheduler(eng)
        toks = []
        for i in range(n):
            req = Request(rid=i, prompt=prompt.copy(), max_new_tokens=3)
            sched.submit([req])
            sched.run_to_completion()
            assert len(req.generated) == 3
            toks.append(req.generated[1])    # first decode/verify-step token
        return np.bincount(toks, minlength=v) / n

    plain = collect(None)
    spec = collect(SpecConfig(k=2, drafter="model", stochastic=True,
                              draft_params=params, draft_cfg=cfg))
    tv = 0.5 * np.abs(plain - spec).sum()
    assert tv < 0.15, f"TV(plain, speculative) = {tv:.3f}"
