"""results/check_regression.py: the nightly bench gate must fail loudly —
not silently skip — when a tracked metric disappears from the current run."""
import importlib.util
import json
import pathlib

import pytest

_PATH = (
    pathlib.Path(__file__).resolve().parent.parent
    / "results" / "check_regression.py"
)
_spec = importlib.util.spec_from_file_location("check_regression", _PATH)
cr = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(cr)


def _row(name, us=None, winner=None):
    r = {"name": name}
    if us is not None:
        r["us_per_call"] = us
    if winner is not None:
        r["winner"] = winner
    return r


def _rows(*rows):
    return {r["name"]: r for r in rows}


def compare(base, cur, **kw):
    kw.setdefault("threshold", 0.15)
    kw.setdefault("pattern", "")
    kw.setdefault("strict_winners", False)
    return cr.compare_suite(base, cur, **kw)


class TestCompareSuite:
    def test_clean_within_threshold(self):
        f, w = compare(_rows(_row("a", 100.0)), _rows(_row("a", 110.0)))
        assert f == [] and w == []

    def test_timing_regression_fails(self):
        f, _ = compare(_rows(_row("a", 100.0)), _rows(_row("a", 120.0)))
        assert len(f) == 1 and "a" in f[0]

    def test_missing_tracked_metric_fails(self):
        # the row survives but its us_per_call vanished (e.g. the bench now
        # emits only a winner field) — previously a silent skip
        f, _ = compare(_rows(_row("a", 100.0)), _rows(_row("a")))
        assert len(f) == 1
        assert "missing" in f[0] and "100.0" in f[0]

    def test_missing_baseline_row_fails(self):
        f, _ = compare(
            _rows(_row("a", 100.0), _row("b", 50.0)), _rows(_row("a", 100.0))
        )
        assert len(f) == 1 and f[0].startswith("b:")

    def test_missing_row_respects_pattern(self):
        f, _ = compare(
            _rows(_row("crossover/a", 1.0), _row("decode/b", 1.0)),
            _rows(_row("crossover/a", 1.0)),
            pattern="crossover/",
        )
        assert f == []      # decode/b is outside the gated pattern

    def test_untracked_baseline_row_still_skipped(self):
        # baseline itself never had a time → nothing to gate
        f, w = compare(_rows(_row("a")), _rows(_row("a")))
        assert f == [] and w == []

    def test_new_row_without_baseline_only_warns(self):
        f, w = compare(
            _rows(_row("a", 1.0)), _rows(_row("a", 1.0), _row("new", 2.0))
        )
        assert f == [] and len(w) == 1 and "new" in w[0]

    def test_winner_flip_warns_or_fails(self):
        base = _rows(_row("m/winner", winner="vlut"))
        cur = _rows(_row("m/winner", winner="gemm"))
        f, w = compare(base, cur)
        assert f == [] and len(w) == 1
        f, w = compare(base, cur, strict_winners=True)
        assert len(f) == 1 and w == []


class TestMainExit:
    def _write(self, d, rows):
        (d / "BENCH_t.json").write_text(json.dumps({"rows": rows}))

    @pytest.fixture
    def dirs(self, tmp_path):
        b, c = tmp_path / "base", tmp_path / "cur"
        b.mkdir(), c.mkdir()
        return b, c

    def _argv(self, b, c):
        return ["--baseline-dir", str(b), "--current-dir", str(c)]

    def test_exit_zero_when_clean(self, dirs):
        b, c = dirs
        self._write(b, [_row("a", 100.0)])
        self._write(c, [_row("a", 101.0)])
        assert cr.main(self._argv(b, c)) == 0

    def test_exit_one_on_missing_tracked_key(self, dirs, capsys):
        b, c = dirs
        self._write(b, [_row("a", 100.0)])
        self._write(c, [])
        assert cr.main(self._argv(b, c)) == 1
        assert "missing from current" in capsys.readouterr().out

    def test_exit_two_when_no_common_files(self, dirs):
        b, c = dirs
        self._write(b, [_row("a", 100.0)])
        assert cr.main(self._argv(b, c)) == 2
