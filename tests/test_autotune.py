"""Tile autotuner: legal-candidate enumeration under the §4 VMEM budget,
persistent on-disk cache round-trips, cache reuse instead of re-timing, and
ops.py dispatch actually honoring the tuned cache."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pack_weight, ternary_quantize
from repro.kernels import autotune, ref_mpgemm, select_tiles, vlut_mpgemm


@pytest.fixture
def tmp_cache(tmp_path):
    """Point the process-default cache at a throwaway file; restore after."""
    cache = autotune.reset_default_cache(str(tmp_path / "tiles.json"))
    yield cache
    autotune.reset_default_cache()


class TestCandidates:
    @pytest.mark.parametrize("g", [4, 5])
    @pytest.mark.parametrize("impl", ["lookup", "decode"])
    @pytest.mark.parametrize("fused", [True, False])
    def test_all_candidates_respect_vmem_budget(self, g, impl, fused):
        cands = autotune.candidate_tiles(
            g, impl, 4096, 1024, 512, fused=fused
        )
        assert cands
        for t in cands:
            b = autotune.tile_vmem_bytes(
                g, impl, t["bm"], t["bn"], t["bkg"], fused=fused
            )
            assert b <= autotune.VMEM_BUDGET_BYTES, (t, b)
            assert t["bn"] % 128 == 0          # N_tile: multiple of lane width
            assert t["bm"] % 8 == 0            # sublane alignment

    def test_lookup_g5_is_table_constrained(self):
        """3^5·bkg·bn·2B dominates: no g=5 lookup candidate may pair large
        bkg with large bn (the §4 K_tile rule with VMEM as the cache)."""
        for t in autotune.candidate_tiles(5, "lookup", 4096, 1024, 512):
            assert 3 ** 5 * t["bkg"] * t["bn"] * 2 <= autotune.VMEM_BUDGET_BYTES

    def test_clamped_to_problem(self):
        cands = autotune.candidate_tiles(4, "decode", 16, 4, 8)
        for t in cands:
            assert t["bkg"] <= 4

    def test_heuristic_matches_select_tiles(self):
        for g in (4, 5):
            for impl in ("lookup", "decode"):
                assert select_tiles(g, impl) == autotune.heuristic_tiles(g, impl)


class TestCacheRoundTrip:
    def test_disk_round_trip(self, tmp_path):
        path = str(tmp_path / "tiles.json")
        c1 = autotune.TileCache(path)
        key = autotune.cache_key(5, "lookup", 320, 64, 32, backend="cpu", fused=True)
        c1.put(key, dict(bm=64, bn=128, bkg=16), seconds=1.25e-3)
        # a fresh instance (fresh process analogue) reads the same winner
        c2 = autotune.TileCache(path)
        assert c2.get(key) == dict(bm=64, bn=128, bkg=16)
        raw = json.load(open(path))
        assert raw[key]["seconds"] == pytest.approx(1.25e-3)

    def test_missing_and_corrupt_cache_are_empty(self, tmp_path):
        assert autotune.TileCache(str(tmp_path / "nope.json")).get("k") is None
        bad = tmp_path / "bad.json"
        bad.write_text("{not json")
        assert autotune.TileCache(str(bad)).get("k") is None


class TestTuneAndReuse:
    def test_cached_entries_reused_instead_of_retimed(self, tmp_path):
        cache = autotune.TileCache(str(tmp_path / "tiles.json"))
        calls = []

        def fake_bench(tiles):
            calls.append(dict(tiles))
            return float(tiles["bkg"])  # smallest bkg "wins"

        args = dict(fused=True, backend="test", cache=cache, benchmark=fake_bench,
                    tune_if_missing=True)
        t1 = autotune.get_tiles(4, "decode", 64, 16, 32, **args)
        assert calls, "cold cache must time candidates"
        n_timed = len(calls)
        assert t1["bkg"] == min(c["bkg"] for c in calls)
        # warm cache: no further timing, identical answer
        t2 = autotune.get_tiles(4, "decode", 64, 16, 32, **args)
        assert t2 == t1
        assert len(calls) == n_timed

    def test_env_tuning_skips_interpret_backend(self, tmp_path, monkeypatch):
        """REPRO_VLUT_AUTOTUNE=1 must not time candidates through the
        interpreter (minutes per candidate, meaningless numbers): interpret
        dispatch gets the heuristic unless tuning is requested explicitly."""
        monkeypatch.setenv(autotune.TUNE_ENV, "1")
        cache = autotune.TileCache(str(tmp_path / "tiles.json"))
        calls = []
        t = autotune.get_tiles(
            4, "decode", 64, 16, 32,
            fused=True, backend="interpret", cache=cache,
            benchmark=lambda tiles: calls.append(tiles) or 1.0,
        )
        assert not calls
        assert t == autotune.heuristic_tiles(4, "decode", fused=True)

    def test_cold_cache_falls_back_to_heuristic(self, tmp_path):
        cache = autotune.TileCache(str(tmp_path / "tiles.json"))
        t = autotune.get_tiles(
            5, "lookup", 64, 16, 32,
            fused=True, backend="test", cache=cache, tune_if_missing=False,
        )
        assert t == autotune.heuristic_tiles(5, "lookup", fused=True)

    def test_fused_heuristic_respects_budget(self):
        """The cold-cache fallback must fit the *fused* working set (f32 A
        tile + int32 scratch), not just the unfused int8 one."""
        for g in (4, 5):
            for impl in ("lookup", "decode"):
                t = autotune.heuristic_tiles(g, impl, fused=True)
                assert (
                    autotune.tile_vmem_bytes(g, impl, **t, fused=True)
                    <= autotune.VMEM_BUDGET_BYTES
                ), (g, impl, t)

    def test_tune_times_real_kernel_and_persists(self, tmp_path):
        """End-to-end: tune() on a tiny problem with the real (interpreted)
        kernel benchmark writes a winner that get_tiles then serves."""
        cache = autotune.TileCache(str(tmp_path / "tiles.json"))
        cands = [dict(bm=8, bn=128, bkg=4), dict(bm=8, bn=128, bkg=8)]
        res = autotune.tune(
            4, "decode", 8, 8, 4,
            fused=True, interpret=True, cache=cache, candidates=cands,
        )
        assert res.tiles in cands
        assert len(res.trials) == len(cands)
        assert all(s > 0 for _, s in res.trials)
        hit = autotune.get_tiles(
            4, "decode", 8, 8, 4,
            fused=True, backend="interpret", cache=cache, tune_if_missing=False,
        )
        assert hit == res.tiles


class TestDispatchIntegration:
    def test_ops_dispatch_uses_cached_tiles(self, tmp_cache):
        """Seed the process cache with odd-but-legal tiles for the exact
        segment the fused dispatch will ask about; the kernel must run with
        them (observable: result still exact vs the oracle, and the cache is
        the only place those tiles exist)."""
        m, k, n = 16, 40, 8   # single g=5 segment of 8 groups
        key = autotune.cache_key(
            5, "decode", m, 8, n, backend="interpret", fused=True
        )
        tmp_cache.put(key, dict(bm=8, bn=128, bkg=2))
        assert autotune.get_tiles(
            5, "decode", m, 8, n, fused=True, backend="interpret",
            tune_if_missing=False,
        ) == dict(bm=8, bn=128, bkg=2)

        rng = np.random.default_rng(0)
        w = rng.standard_normal((m, k)).astype(np.float32)
        a = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
        tw = ternary_quantize(jnp.asarray(w))
        pw = pack_weight(tw.values, tw.scale, "i1")
        out = np.asarray(vlut_mpgemm(pw, a, impl="decode", interpret=True))
        np.testing.assert_allclose(
            out, np.asarray(ref_mpgemm(pw, a)), rtol=1e-6, atol=1e-6
        )
