"""Packing unit + property tests (paper §3.3: lossless flexible sub-2-bit)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    pack_group_sizes,
    pack_ternary,
    pack_weight,
    sign_matrix,
    ternary_quantize,
    unpack_ternary,
)

GOOD_K = st.integers(4, 400).filter(lambda k: k not in (6, 7, 11))


class TestSignMatrix:
    def test_shape_and_range(self):
        for g in (4, 5):
            s = sign_matrix(g)
            assert s.shape == (3**g, g)
            assert set(np.unique(s)) <= {-1, 0, 1}

    def test_row_encodes_index(self):
        """Row i must be the ternary expansion of i (paper Fig. 6)."""
        for g in (4, 5):
            s = sign_matrix(g).astype(np.int64)
            idx = ((s + 1) * (3 ** np.arange(g))).sum(axis=1)
            assert np.array_equal(idx, np.arange(3**g))

    def test_all_rows_distinct(self):
        s = sign_matrix(5)
        assert len({tuple(r) for r in s}) == 3**5

    def test_zero_row_is_center(self):
        for g in (4, 5):
            zc = (3**g - 1) // 2
            assert np.all(sign_matrix(g)[zc] == 0)


class TestPackRoundtrip:
    @given(
        st.integers(1, 7),
        st.sampled_from([4, 5]),
        st.integers(1, 30),
        st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=20, deadline=None)
    def test_roundtrip_property(self, m, g, kg, seed):
        rng = np.random.default_rng(seed)
        w = rng.integers(-1, 2, size=(m, kg * g)).astype(np.int8)
        packed = pack_ternary(jnp.asarray(w), g)
        assert packed.dtype == jnp.uint8
        assert int(jnp.max(packed)) < 3**g
        back = unpack_ternary(packed, g)
        assert np.array_equal(np.asarray(back), w)

    def test_nondivisible_raises(self):
        with pytest.raises(ValueError):
            pack_ternary(jnp.zeros((2, 7), jnp.int8), 4)


class TestFlexiblePacking:
    @given(GOOD_K)
    @settings(max_examples=60, deadline=None)
    def test_group_sizes_cover_k(self, k):
        n5, n4 = pack_group_sizes(k)
        assert 5 * n5 + 4 * n4 == k

    @given(GOOD_K)
    @settings(max_examples=30, deadline=None)
    def test_bpw_near_1_6(self, k):
        """Paper: flexible packing always near-1.6 bpw; never above 2.0."""
        n5, n4 = pack_group_sizes(k)
        bpw = 8.0 * (n5 + n4) / k
        assert 1.6 <= bpw <= 2.0

    def test_impossible_k(self):
        for k in (1, 2, 3, 6, 7, 11):
            with pytest.raises(ValueError):
                pack_group_sizes(k)

    # slow: each drawn (m, k) is a fresh pack/unpack jit compile
    @pytest.mark.slow
    @given(st.integers(1, 6), GOOD_K, st.integers(0, 2**31 - 1))
    @settings(max_examples=16, deadline=None)
    def test_packed_weight_roundtrip(self, m, k, seed):
        rng = np.random.default_rng(seed)
        w = rng.standard_normal((m, k)).astype(np.float32)
        tw = ternary_quantize(jnp.asarray(w))
        pw = pack_weight(tw.values, tw.scale, mode="auto")
        assert np.array_equal(np.asarray(pw.unpack()), np.asarray(tw.values))
        assert pw.bits_per_weight <= 2.0

    def test_modes(self):
        w = jnp.asarray(np.random.default_rng(0).integers(-1, 2, (4, 40)), jnp.int8)
        s = jnp.ones((4,))
        assert pack_weight(w, s, "i1").bits_per_weight == pytest.approx(1.6)
        assert pack_weight(w, s, "i2").bits_per_weight == pytest.approx(2.0)
        with pytest.raises(ValueError):
            pack_weight(jnp.zeros((2, 21), jnp.int8), jnp.ones(2), "i1")
