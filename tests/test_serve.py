"""Serving engine + continuous-batching scheduler."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_cache, init_lm, lm_hidden, pack_params, prefill
from repro.models.decoder import _head_matmul
from repro.serve import ContinuousBatchingScheduler, Engine, Request


@pytest.fixture(scope="module")
def served():
    cfg = get_config("smollm-360m", smoke=True)
    params = pack_params(init_lm(jax.random.PRNGKey(0), cfg), cfg)
    return cfg, params


def _requests(cfg, n, rng, max_new=6):
    return [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, size=rng.integers(4, 20)).astype(np.int32),
            max_new_tokens=max_new,
        )
        for i in range(n)
    ]


@pytest.mark.slow
class TestEngine:
    def test_all_requests_complete(self, served, rng):
        cfg, params = served
        eng = Engine(params, cfg, max_slots=3, max_len=64)
        sched = ContinuousBatchingScheduler(eng)
        reqs = _requests(cfg, 8, rng)
        sched.submit(reqs)
        stats = sched.run_to_completion()
        assert stats.completed == 8
        assert all(len(r.generated) == 6 for r in reqs)
        assert stats.decode_tokens > 0 and stats.prefill_tokens > 0

    def test_greedy_determinism(self, served, rng):
        cfg, params = served
        prompts = [r.prompt for r in _requests(cfg, 5, rng)]
        gens = []
        for _ in range(2):
            eng = Engine(params, cfg, max_slots=2, max_len=64)
            sched = ContinuousBatchingScheduler(eng)
            reqs = [Request(rid=i, prompt=p, max_new_tokens=5)
                    for i, p in enumerate(prompts)]
            sched.submit(reqs)
            sched.run_to_completion()
            gens.append([r.generated for r in reqs])
        assert gens[0] == gens[1]

    def test_bucketed_prefill_matches_full_forward(self, served, rng):
        """Left-padded bucket prefill must not change the next-token logits."""
        cfg, params = served
        n = 13  # not a bucket multiple
        prompt = rng.integers(0, cfg.vocab, size=n).astype(np.int32)
        eng = Engine(params, cfg, max_slots=1, max_len=64)
        req = Request(rid=0, prompt=prompt, max_new_tokens=1)
        assert eng.add(req)
        # reference: unpadded forward
        import jax.numpy as jnp
        h, _, _ = lm_hidden(params, jnp.asarray(prompt)[None, :], cfg, mode="serve")
        want = int(np.argmax(np.asarray(_head_matmul(params, h[:, -1:, :], cfg)[:, 0])))
        assert req.generated[0] == want

    def test_slot_reuse(self, served, rng):
        cfg, params = served
        eng = Engine(params, cfg, max_slots=1, max_len=64)
        sched = ContinuousBatchingScheduler(eng)
        sched.submit(_requests(cfg, 3, rng, max_new=3))
        stats = sched.run_to_completion()
        assert stats.completed == 3  # one slot serviced all three

    def test_backpressure(self, served, rng):
        cfg, params = served
        eng = Engine(params, cfg, max_slots=2, max_len=64)
        reqs = _requests(cfg, 4, rng)
        assert eng.add(reqs[0]) and eng.add(reqs[1])
        assert not eng.add(reqs[2])  # no free slot


@pytest.mark.slow
def test_temperature_sampling_varies(served, rng):
    cfg, params = served
    prompt = rng.integers(0, cfg.vocab, size=8).astype(np.int32)
    outs = set()
    for seed in range(3):
        eng = Engine(params, cfg, max_slots=1, max_len=64,
                     temperature=1.0, seed=seed)
        sched = ContinuousBatchingScheduler(eng)
        reqs = [Request(rid=0, prompt=prompt, max_new_tokens=8)]
        sched.submit(reqs)
        sched.run_to_completion()
        outs.add(tuple(reqs[0].generated))
    assert len(outs) > 1  # different seeds → different samples
