"""Serving engine + continuous-batching scheduler."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_cache, init_lm, lm_hidden, pack_params, prefill
from repro.models.decoder import _head_matmul
from repro.serve import ContinuousBatchingScheduler, Engine, Request


@pytest.fixture(scope="module")
def served():
    cfg = get_config("smollm-360m", smoke=True)
    params = pack_params(init_lm(jax.random.PRNGKey(0), cfg), cfg)
    return cfg, params


def _requests(cfg, n, rng, max_new=6):
    return [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, size=rng.integers(4, 20)).astype(np.int32),
            max_new_tokens=max_new,
        )
        for i in range(n)
    ]


@pytest.mark.slow
class TestEngine:
    def test_all_requests_complete(self, served, rng):
        cfg, params = served
        eng = Engine(params, cfg, max_slots=3, max_len=64)
        sched = ContinuousBatchingScheduler(eng)
        reqs = _requests(cfg, 8, rng)
        sched.submit(reqs)
        stats = sched.run_to_completion()
        assert stats.completed == 8
        assert all(len(r.generated) == 6 for r in reqs)
        assert stats.decode_tokens > 0 and stats.prefill_tokens > 0

    def test_greedy_determinism(self, served, rng):
        cfg, params = served
        prompts = [r.prompt for r in _requests(cfg, 5, rng)]
        gens = []
        for _ in range(2):
            eng = Engine(params, cfg, max_slots=2, max_len=64)
            sched = ContinuousBatchingScheduler(eng)
            reqs = [Request(rid=i, prompt=p, max_new_tokens=5)
                    for i, p in enumerate(prompts)]
            sched.submit(reqs)
            sched.run_to_completion()
            gens.append([r.generated for r in reqs])
        assert gens[0] == gens[1]

    def test_bucketed_prefill_matches_full_forward(self, served, rng):
        """Left-padded bucket prefill must not change the next-token logits."""
        cfg, params = served
        n = 13  # not a bucket multiple
        prompt = rng.integers(0, cfg.vocab, size=n).astype(np.int32)
        eng = Engine(params, cfg, max_slots=1, max_len=64)
        req = Request(rid=0, prompt=prompt, max_new_tokens=1)
        assert eng.add(req)
        # reference: unpadded forward
        import jax.numpy as jnp
        h, _, _ = lm_hidden(params, jnp.asarray(prompt)[None, :], cfg, mode="serve")
        want = int(np.argmax(np.asarray(_head_matmul(params, h[:, -1:, :], cfg)[:, 0])))
        assert req.generated[0] == want

    def test_slot_reuse(self, served, rng):
        cfg, params = served
        eng = Engine(params, cfg, max_slots=1, max_len=64)
        sched = ContinuousBatchingScheduler(eng)
        sched.submit(_requests(cfg, 3, rng, max_new=3))
        stats = sched.run_to_completion()
        assert stats.completed == 3  # one slot serviced all three

    def test_backpressure(self, served, rng):
        cfg, params = served
        eng = Engine(params, cfg, max_slots=2, max_len=64)
        reqs = _requests(cfg, 4, rng)
        assert eng.add(reqs[0]) and eng.add(reqs[1])
        assert not eng.add(reqs[2])  # no free slot

    def test_engine_full_requeue(self, served, rng):
        """Requests rejected while the engine is full stay queued (FCFS) and
        are admitted as slots free up — nothing is lost or reordered."""
        cfg, params = served
        eng = Engine(params, cfg, max_slots=2, max_len=64)
        sched = ContinuousBatchingScheduler(eng)
        reqs = _requests(cfg, 6, rng, max_new=6)  # long enough to span the ticks
        sched.submit(reqs)
        sched.tick()  # one admission per tick → 4 still queued, engine full
        assert len(sched.queue) == 5 and eng.n_active == 1
        sched.tick()
        assert len(sched.queue) == 4 and eng.n_active == 2
        sched.tick()  # engine full: queue head must be retained, not dropped
        assert len(sched.queue) == 4 and sched.queue[0].rid == reqs[2].rid
        stats = sched.run_to_completion()
        assert stats.completed == 6
        assert all(r.done for r in reqs)

    def test_slot_reuse_after_completion(self, served, rng):
        """A freed slot is reused by a later request and its stale cache
        content never leaks: the recycled request's output equals the same
        request run on a fresh engine."""
        cfg, params = served
        prompts = [p.prompt for p in _requests(cfg, 3, rng)]
        # fresh-engine reference for the LAST request
        ref_eng = Engine(params, cfg, max_slots=1, max_len=64)
        ref = Request(rid=99, prompt=prompts[-1], max_new_tokens=4)
        ref_eng.add(ref)
        while not ref.done:
            ref_eng.decode_once()
        # one slot services all three sequentially → slot 0 reused twice
        eng = Engine(params, cfg, max_slots=1, max_len=64)
        sched = ContinuousBatchingScheduler(eng)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(prompts)]
        sched.submit(reqs)
        stats = sched.run_to_completion()
        assert stats.completed == 3
        assert all(r.slot == 0 for r in reqs)
        assert reqs[-1].generated == ref.generated

    def test_mixed_lengths_share_one_jit_entry(self, served, rng):
        """Prompts of different lengths inside one 16-bucket must share a
        single prefill jit cache entry (left-padding, not recompilation)."""
        cfg, params = served
        eng = Engine(params, cfg, max_slots=4, max_len=64)
        for i, n in enumerate([3, 9, 13, 16]):     # all bucket to 16
            prompt = rng.integers(0, cfg.vocab, size=n).astype(np.int32)
            assert eng.add(Request(rid=i, prompt=prompt, max_new_tokens=2))
        assert eng._prefill1._cache_size() == 1
        # a second bucket adds exactly one more entry
        eng2 = Engine(params, cfg, max_slots=4, max_len=64)
        for i, n in enumerate([13, 21]):           # buckets 16 and 32
            prompt = rng.integers(0, cfg.vocab, size=n).astype(np.int32)
            assert eng2.add(Request(rid=i, prompt=prompt, max_new_tokens=2))
        assert eng2._prefill1._cache_size() == 2


@pytest.mark.slow
class TestAdmissionLimits:
    def test_overflowing_request_rejected(self, served, rng):
        """prompt + max_new_tokens > max_len must be refused on admission
        with a clear error instead of silently wrapping the KV ring."""
        cfg, params = served
        eng = Engine(params, cfg, max_slots=1, max_len=32)
        prompt = rng.integers(0, cfg.vocab, size=20).astype(np.int32)
        with pytest.raises(ValueError, match="max_len"):
            eng.add(Request(rid=0, prompt=prompt, max_new_tokens=20))

    def test_spec_budget_counts_draft_window(self, served, rng):
        """With speculation the verify step writes up to k positions past the
        last kept token — admission must reserve that headroom too."""
        from repro.spec import SpecConfig

        cfg, params = served
        eng = Engine(params, cfg, max_slots=1, max_len=32, spec=SpecConfig(k=4))
        prompt = rng.integers(0, cfg.vocab, size=20).astype(np.int32)
        with pytest.raises(ValueError, match="draft window"):
            eng.add(Request(rid=0, prompt=prompt, max_new_tokens=10))
        # same request fits without speculation
        eng2 = Engine(params, cfg, max_slots=1, max_len=32)
        assert eng2.add(Request(rid=0, prompt=prompt.copy(), max_new_tokens=10))

    def test_rejection_does_not_consume_admission(self, served, rng):
        """A rejected queue head must not waste the tick's one admission:
        the next queued request is admitted in the SAME tick (regression:
        the scheduler used to stop after the rejection, idling a free
        slot for a full tick)."""
        cfg, params = served
        eng = Engine(params, cfg, max_slots=1, max_len=32)
        sched = ContinuousBatchingScheduler(eng)
        bad = Request(
            rid=0, prompt=rng.integers(0, cfg.vocab, size=30).astype(np.int32),
            max_new_tokens=30)                       # can never fit
        fits = Request(
            rid=1, prompt=rng.integers(0, cfg.vocab, size=8).astype(np.int32),
            max_new_tokens=4)
        sched.submit([bad, fits])
        sched.tick()
        assert sched.rejected == [bad] and "max_len" in bad.error
        assert fits.generated          # prefilled on the first tick
        assert eng.n_active == 1 and fits.slot == 0
        stats = sched.run_to_completion()
        assert stats.completed == 1 and stats.rejected == 1

    def test_scheduler_rejects_oversized_in_place(self, served, rng):
        """One impossible request must not abort the batch: the scheduler
        marks it rejected (error set, no output) and keeps serving."""
        cfg, params = served
        eng = Engine(params, cfg, max_slots=1, max_len=32)
        sched = ContinuousBatchingScheduler(eng)
        mk = lambda rid, n, new: Request(
            rid=rid, prompt=rng.integers(0, cfg.vocab, size=n).astype(np.int32),
            max_new_tokens=new)
        good, bad, good2 = mk(0, 8, 4), mk(1, 30, 30), mk(2, 6, 4)
        sched.submit([good, bad, good2])
        stats = sched.run_to_completion()
        assert stats.completed == 2 and stats.rejected == 1
        assert good.done and good2.done
        assert not bad.done and not bad.generated
        assert "max_len" in bad.error and sched.rejected == [bad]

    def test_fitting_request_completes_at_boundary(self, served, rng):
        """A request that exactly fills max_len completes cleanly."""
        cfg, params = served
        eng = Engine(params, cfg, max_slots=1, max_len=32)
        prompt = rng.integers(0, cfg.vocab, size=24).astype(np.int32)
        req = Request(rid=0, prompt=prompt, max_new_tokens=8)  # 24 + 8 == 32
        assert eng.add(req)
        for _ in range(16):
            if req.done:
                break
            eng.decode_once()
        assert req.done and len(req.generated) == 8

    def test_exact_fit_boundary_admitted(self, served, rng):
        """The final generated token is sampled but never written back, so
        prompt + max_new_tokens - 1 == max_len must be ADMITTED and emit all
        max_new tokens (regression: the old bound budgeted a phantom cache
        position for it and wrongly rejected this request)."""
        cfg, params = served
        eng = Engine(params, cfg, max_slots=1, max_len=32)
        prompt = rng.integers(0, cfg.vocab, size=25).astype(np.int32)
        req = Request(rid=0, prompt=prompt, max_new_tokens=8)  # 25+8-1 == 32
        assert eng.add(req)
        for _ in range(16):
            if req.done:
                break
            eng.decode_once()
        assert req.done and len(req.generated) == 8
        # one more token genuinely overflows and must still be refused
        eng2 = Engine(params, cfg, max_slots=1, max_len=32)
        with pytest.raises(ValueError, match="max_len"):
            eng2.add(Request(rid=1, prompt=prompt.copy(), max_new_tokens=9))

    def test_spec_exact_fit_boundary_admitted(self, served, rng):
        """Same boundary with speculation: prompt + max_new - 1 + draft_k ==
        max_len fits (the verify window is budgeted past the last *written*
        position) and the request completes with every token."""
        from repro.spec import SpecConfig

        cfg, params = served
        k = 3
        eng = Engine(params, cfg, max_slots=1, max_len=32, spec=SpecConfig(k=k))
        prompt = rng.integers(0, cfg.vocab, size=22).astype(np.int32)
        req = Request(rid=0, prompt=prompt, max_new_tokens=8)  # 22+8-1+3 == 32
        assert eng.add(req)
        for _ in range(16):
            if req.done:
                break
            eng.decode_once()
        assert req.done and len(req.generated) == 8
        eng2 = Engine(params, cfg, max_slots=1, max_len=32, spec=SpecConfig(k=k))
        with pytest.raises(ValueError, match="draft window"):
            eng2.add(Request(rid=1, prompt=prompt.copy(), max_new_tokens=9))


class TestSampleTopK:
    def test_top_k_at_and_past_vocab(self, rng):
        """top_k >= V must behave like unrestricted sampling instead of
        indexing `sort(logits)[:, -top_k]` out of bounds (regression: the
        unclamped index raises IndexError on jax versions that bounds-check
        static indices, and silently relies on gather clipping on those
        that don't)."""
        import jax.numpy as jnp

        from repro.serve import sample

        v = 8
        logits = jnp.asarray(rng.normal(size=(3, v)), jnp.float32)
        for top_k in (v, v + 1, v + 5):
            toks = np.asarray(sample(logits, jax.random.PRNGKey(0),
                                     temperature=1.0, top_k=top_k))
            assert toks.shape == (3,)
            assert ((0 <= toks) & (toks < v)).all()
        # clamped top_k keeps the full support → identical to plain sampling
        full = np.asarray(sample(logits, jax.random.PRNGKey(7), temperature=1.0))
        clamped = np.asarray(sample(logits, jax.random.PRNGKey(7),
                                    temperature=1.0, top_k=v + 3))
        np.testing.assert_array_equal(full, clamped)

    def test_top_k_one_is_greedy(self, rng):
        import jax.numpy as jnp

        from repro.serve import sample

        logits = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
        toks = sample(logits, jax.random.PRNGKey(1), temperature=1.0, top_k=1)
        np.testing.assert_array_equal(
            np.asarray(toks), np.asarray(jnp.argmax(logits, axis=-1))
        )

    def test_top_k_keeps_exactly_k_on_ties(self):
        """Regression: `logits < kth` kept every tie of the k-th logit, so
        three tied logits survived top_k=2 and token 2 could be emitted.
        Exactly top_k candidates must survive (ties break toward lower
        token ids)."""
        import jax.numpy as jnp

        from repro.serve import sample

        logits = jnp.asarray([[1.0, 1.0, 1.0, 0.0, 0.0, 0.0]])
        seen = {
            int(sample(logits, jax.random.PRNGKey(s), temperature=1.0,
                       top_k=2)[0])
            for s in range(64)
        }
        assert seen == {0, 1}

    def test_negative_top_k_raises(self, rng):
        """Regression: top_k=-1 was silently accepted (min(-1, V) = -1 then
        `sort[:, 1]` — a nonsense threshold)."""
        import jax.numpy as jnp

        from repro.serve import sample

        logits = jnp.asarray(rng.normal(size=(2, 8)), jnp.float32)
        with pytest.raises(ValueError, match="top_k"):
            sample(logits, jax.random.PRNGKey(0), temperature=1.0, top_k=-1)


@pytest.mark.slow
class TestSchedulerRunIsolation:
    def test_second_run_reports_only_its_own_work(self, served, rng):
        """Regression: run_to_completion accumulated — a second call
        re-counted the first run's completions and tokens against only the
        new wall clock, inflating throughput and acceptance."""
        cfg, params = served
        eng = Engine(params, cfg, max_slots=2, max_len=64)
        sched = ContinuousBatchingScheduler(eng)
        prompt = rng.integers(0, cfg.vocab, size=6).astype(np.int32)
        sched.submit([Request(rid=0, prompt=prompt.copy(), max_new_tokens=5)])
        s1 = sched.run_to_completion()
        sched.submit([Request(rid=1, prompt=prompt.copy(), max_new_tokens=5)])
        s2 = sched.run_to_completion()
        # identical workloads → identical per-run deltas, not 2x totals
        assert s1.completed == s2.completed == 1
        assert s2.decode_tokens == s1.decode_tokens > 0
        assert s2.prefill_tokens == s1.prefill_tokens > 0
        assert len(s1.ttft_s) == len(s2.ttft_s) == 1

    def test_second_run_spec_counters_are_deltas(self, served, rng):
        from repro.spec import SpecConfig

        cfg, params = served
        eng = Engine(params, cfg, max_slots=1, max_len=64,
                     spec=SpecConfig(k=2, drafter="ngram"))
        sched = ContinuousBatchingScheduler(eng)
        prompt = np.tile([9, 4], 6).astype(np.int32)
        sched.submit([Request(rid=0, prompt=prompt.copy(), max_new_tokens=6)])
        s1 = sched.run_to_completion()
        sched.submit([Request(rid=1, prompt=prompt.copy(), max_new_tokens=6)])
        s2 = sched.run_to_completion()
        assert s1.spec_steps == s2.spec_steps > 0
        assert s1.drafted_tokens == s2.drafted_tokens > 0
        assert s1.accepted_tokens == s2.accepted_tokens
        assert s1.verified_nodes == s2.verified_nodes > 0
        # derived rates survive the reuse unchanged
        assert s1.acceptance_rate == s2.acceptance_rate
        assert s1.decode_tokens_per_step == s2.decode_tokens_per_step


@pytest.mark.slow
def test_temperature_sampling_varies(served, rng):
    cfg, params = served
    prompt = rng.integers(0, cfg.vocab, size=8).astype(np.int32)
    outs = set()
    for seed in range(3):
        eng = Engine(params, cfg, max_slots=1, max_len=64,
                     temperature=1.0, seed=seed)
        sched = ContinuousBatchingScheduler(eng)
        reqs = [Request(rid=0, prompt=prompt, max_new_tokens=8)]
        sched.submit(reqs)
        sched.run_to_completion()
        outs.add(tuple(reqs[0].generated))
    assert len(outs) > 1  # different seeds → different samples


@pytest.mark.slow
class TestCompileStability:
    """Dynamic complement of lint rule R2: after a warmup pass has traced
    every jitted entry point, steady-state scheduler ticks over a mixed
    chunked-prefill + decode + speculative workload must not add a single
    compile-cache entry — a traced-value branch or unstable static arg
    anywhere on the tick path would."""

    def test_zero_recompiles_after_warmup(self, served, rng):
        from repro.lint import CompileGuard
        from repro.spec import SpecConfig

        cfg, params = served
        eng = Engine(params, cfg, max_slots=3, max_len=64,
                     prefill_chunk=4, spec=SpecConfig(k=2, drafter="ngram"))
        sched = ContinuousBatchingScheduler(eng)
        # warmup: a full mixed workload traces each entry at its one shape
        # (chunk-only ticks, mixed chunk+decode ticks, pure spec decode)
        sched.submit(_requests(cfg, 6, rng, max_new=8))
        sched.run_to_completion()
        guard = CompileGuard(eng.jit_entries())
        base = guard.arm()
        assert sum(base.values()) > 0, "no compile activity seen in warmup"
        # steady state: fresh requests, same shapes — 20 ticks, zero misses
        sched.submit(_requests(cfg, 10, rng, max_new=8))
        for _ in range(20):
            sched.tick()
        guard.assert_steady("20 steady-state mixed prefill/decode/spec ticks")
