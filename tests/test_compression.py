"""Gradient compression: error feedback accounting + collective pattern."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.compression import (
    compress_tree,
    compressed_psum,
    decompress_tree,
    ef_init,
)
from repro.optim import QTensor


def test_small_leaves_pass_through(rng):
    g = {"small": jnp.asarray(rng.standard_normal(10), jnp.float32)}
    ef = ef_init(g)
    comp, _ = compress_tree(g, ef)
    assert not isinstance(comp["small"], QTensor)
    np.testing.assert_array_equal(np.asarray(comp["small"]), np.asarray(g["small"]))


def test_error_feedback_accounting(rng):
    """decompress(compress(g + ef)) + new_ef == g + ef exactly."""
    g = {"w": jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)}
    ef = ef_init(g)
    comp, new_ef = compress_tree(g, ef)
    dec = decompress_tree(comp)
    np.testing.assert_allclose(
        np.asarray(dec["w"] + new_ef["w"]), np.asarray(g["w"]), rtol=1e-5, atol=1e-6
    )


def test_error_feedback_preserves_signal_over_steps(rng):
    """Sum of decompressed grads ≈ sum of true grads (EF drains the error)."""
    true = [rng.standard_normal((64, 128)).astype(np.float32) * 0.1 for _ in range(20)]
    ef = ef_init({"w": jnp.zeros((64, 128))})
    acc = np.zeros((64, 128), np.float32)
    for g in true:
        comp, ef = compress_tree({"w": jnp.asarray(g)}, ef)
        acc += np.asarray(decompress_tree(comp)["w"])
    want = np.sum(true, axis=0)
    resid = np.abs(acc - want).max()
    assert resid <= np.abs(np.asarray(ef["w"])).max() + 1e-5


def test_compressed_psum_close_to_exact(rng):
    """Under a vmapped axis, int8-compressed psum ≈ exact psum."""
    g = rng.standard_normal((4, 16, 256)).astype(np.float32)

    out = jax.vmap(lambda x: compressed_psum(x, "dp"), axis_name="dp")(jnp.asarray(g))
    want = g.sum(axis=0, keepdims=True)
    rowmax = np.abs(g).max(axis=(0, 2), keepdims=True)
    err = np.abs(np.asarray(out)[0] - want[0]).max()
    assert err <= 4 * float(rowmax.max()) / 127 + 1e-6
