"""Paged KV cache: block tables, radix prefix sharing, host-RAM offload.

The exactness contract: a paged engine's greedy serving output is token-
identical to the dense slot-cache engine — across GQA and MLA archs, the
whole-prompt / chunked-prefill admission paths, and speculation in chain,
adaptive-K, and tree modes — including the block-boundary edges (prompt
exactly on a page edge, rollback across a page edge, copy-on-write forks
mid-page) and through page recycling, prefix sharing, and the offload tier.

Admission semantics (the out-of-pages satellite): pool exhaustion is a
TRANSIENT deferral — `Engine.add` returns False with a queue-for-pages
error string and the scheduler keeps the request queued — while a request
that can never fit raises the permanent exceeds-model-context ValueError.
The two must stay distinguishable.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_lm, pack_params
from repro.serve import (
    ContinuousBatchingScheduler,
    Engine,
    OutOfPages,
    PagedKVConfig,
    Request,
)
from repro.serve.paging import PagePool, Pager, RadixPrefixIndex
from repro.spec import SpecConfig


@pytest.fixture(scope="module")
def served():
    cfg = get_config("smollm-360m", smoke=True)
    params = pack_params(init_lm(jax.random.PRNGKey(0), cfg), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def served_mla():
    cfg = get_config("deepseek-v3-671b", smoke=True)
    params = pack_params(init_lm(jax.random.PRNGKey(0), cfg), cfg)
    return cfg, params


def _run(cfg, params, prompts, *, max_new=6, slots=3, max_len=96, **kw):
    eng = Engine(params, cfg, max_slots=slots, max_len=max_len, **kw)
    sched = ContinuousBatchingScheduler(eng)
    reqs = [Request(rid=i, prompt=p.copy(), max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    sched.submit(reqs)
    stats = sched.run_to_completion()
    return [r.generated for r in reqs], stats, eng


def _prompts(cfg, rng, lens):
    return [rng.integers(0, cfg.vocab, size=n).astype(np.int32) for n in lens]


# --------------------------------------------------------------------------
# Host-side pager unit tests (no device, no model → fast lane)
# --------------------------------------------------------------------------
class TestPagePool:
    def test_null_page_never_allocated(self):
        pool = PagePool(4)
        got = {pool.alloc() for _ in range(3)}
        assert got == {1, 2, 3}
        assert pool.alloc() is None

    def test_refcounted_release(self):
        pool = PagePool(3)
        p = pool.alloc()
        pool.retain(p)
        assert not pool.release(p)      # one ref left → not freed
        assert pool.release(p)          # last ref → back on the free list
        assert pool.free_pages == 2


class TestPagerAdmission:
    def _pager(self, n_pages=9, ps=4, **kw):
        cfg = PagedKVConfig(page_size=ps, n_pages=n_pages, **kw)
        return Pager(cfg, max_slots=2, max_len=32, n_pages=n_pages)

    def test_reserves_full_budget(self):
        pager = self._pager()
        pager.admit(0, np.arange(6), need_tokens=10)   # ceil(10/4) = 3 pages
        assert pager.free_pages == 8 - 3
        assert len(pager.slot_pages[0]) == 3

    def test_out_of_pages_rolls_back(self):
        pager = self._pager(n_pages=3)                 # 2 allocatable
        with pytest.raises(OutOfPages, match="page pool exhausted"):
            pager.admit(0, np.arange(8), need_tokens=12)  # needs 3
        assert pager.free_pages == 2                   # nothing leaked
        assert pager.slot_pages[0] == []

    def test_release_feeds_prefix_index_and_rehit(self):
        pager = self._pager()
        prompt = np.arange(11)                         # 2 full pages + 3
        pager.admit(0, prompt, need_tokens=14)         # 4 pages
        pager.release(0, prompt)
        # 2 full-page prefix chunks live in the index; the rest freed
        assert pager.shared_pages == 2
        assert pager.free_pages == 8 - 2
        matched = pager.admit(1, prompt, need_tokens=14)
        assert matched == 8                            # 2 pages x ps=4
        assert pager.prefix_hit_tokens == 8
        assert pager.prefix_hit_requests == 1
        assert pager.slot_shared[1] == 2

    def test_match_capped_below_full_prompt(self):
        """At least one prompt token must run through the model (first-token
        logits), so a fully indexed prompt still leaves a fresh page."""
        pager = self._pager()
        prompt = np.arange(8)                          # exactly 2 pages
        pager.admit(0, prompt, need_tokens=8)
        pager.release(0, prompt)
        matched = pager.admit(1, prompt, need_tokens=8)
        assert matched == 4                            # page 2 NOT matched
        assert len(pager.slot_pages[1]) == 2           # 1 shared + 1 fresh

    def test_prefix_sharing_off(self):
        pager = self._pager(prefix_sharing=False)
        prompt = np.arange(11)
        pager.admit(0, prompt, need_tokens=12)
        pager.release(0, prompt)
        assert pager.shared_pages == 0
        assert pager.free_pages == 8
        assert pager.admit(1, prompt, need_tokens=12) == 0

    def test_cow_fork_shares_only_full_common_pages(self):
        pager = self._pager()
        p1 = np.arange(12)
        p2 = np.concatenate([np.arange(6), 90 + np.arange(6)])  # forks mid-page 1
        pager.admit(0, p1, need_tokens=12)
        pager.release(0, p1)
        matched = pager.admit(1, p2, need_tokens=12)
        assert matched == 4                            # only page 0 shared
        # shared page is refcounted, not copied: index ref + slot ref
        shared = pager.slot_pages[1][0]
        assert pager.pool.refs[shared] == 2

    def test_eviction_drops_cold_leaves_first(self):
        pager = self._pager(n_pages=5)                 # 4 allocatable
        prompt = np.arange(11)
        pager.admit(0, prompt, need_tokens=14)         # all 4 pages
        pager.release(0, prompt)                       # 2 → index, 2 freed
        # a disjoint request needs 4 pages → both index pages get dropped
        pager.admit(1, 50 + np.arange(8), need_tokens=14)
        assert pager.pages_dropped == 2
        assert pager.shared_pages == 0

    def test_offload_tier_pages_out_and_back_in(self):
        store = {}
        calls = {"out": 0, "in": 0}

        def page_out(page):
            calls["out"] += 1
            return f"kv@{page}"

        def page_in(page, data):
            calls["in"] += 1
            store[page] = data

        cfg = PagedKVConfig(page_size=4, n_pages=5, host_offload_pages=8)
        pager = Pager(cfg, max_slots=2, max_len=32, n_pages=5,
                      page_out=page_out, page_in=page_in)
        prompt = np.arange(11)
        pager.admit(0, prompt, need_tokens=14)
        pager.release(0, prompt)
        pager.admit(1, 50 + np.arange(8), need_tokens=14)  # evicts → host
        assert calls["out"] == 2 and pager.offloaded_pages == 2
        assert pager.pages_paged_out == 2
        pager.release(1, 50 + np.arange(8))
        # the original prefix pages come back from the host tier on a hit —
        # and paging them in squeezes the *other* prompt's cold prefix out
        # (the pool still only holds 4 pages), so the tier keeps 2 resident
        matched = pager.admit(0, prompt, need_tokens=14)
        assert matched == 8 and calls["in"] == 2
        assert pager.pages_paged_in == 2 and pager.offloaded_pages == 2
        assert pager.pages_paged_out == 4

    def test_radix_walk_stops_at_first_miss(self):
        idx = RadixPrefixIndex(4)
        n1 = idx.insert(idx.root, (0, 1, 2, 3))
        n1.page = 1
        n2 = idx.insert(n1, (4, 5, 6, 7))
        n2.page = 2
        hits = list(idx.walk(np.array([0, 1, 2, 3, 9, 9, 9, 9]), 8))
        assert [n.page for n in hits] == [1]
        hits = list(idx.walk(np.arange(8), 8))
        assert [n.page for n in hits] == [1, 2]
        assert list(idx.walk(np.arange(8), 7)) == [n1]  # partial page cut


# --------------------------------------------------------------------------
# Engine admission semantics (chunked claims → no forward pass → fast lane)
# --------------------------------------------------------------------------
class TestPagedEngineAdmission:
    def test_out_of_pages_defers_exceeds_context_rejects(self):
        """The two admission failures must stay distinguishable: transient
        pool exhaustion queues (False + queue-for-pages error), a request
        that can never fit raises (exceeds-model-context ValueError)."""
        cfg = get_config("smollm-360m", smoke=True)
        eng = Engine(None, cfg, max_slots=2, max_len=64, prefill_chunk=16,
                     paged_kv=PagedKVConfig(page_size=16, n_pages=3,
                                            prefix_sharing=False))
        ok = Request(rid=0, prompt=np.arange(20, dtype=np.int32),
                     max_new_tokens=8)
        assert eng.add(ok) and ok.error == ""
        starved = Request(rid=1, prompt=np.arange(20, dtype=np.int32),
                          max_new_tokens=8)
        assert not eng.add(starved)
        assert "waiting for free KV pages" in starved.error
        assert "exhausted" in starved.error
        too_big = Request(rid=2, prompt=np.arange(60, dtype=np.int32),
                          max_new_tokens=8)
        with pytest.raises(ValueError, match="model context"):
            eng.add(too_big)
        # fits max_len (47 ≤ 64) but needs 3 pages against a 2-page pool:
        # permanent too — waiting can never produce pages the pool lacks
        pool_big = Request(rid=3, prompt=np.arange(40, dtype=np.int32),
                           max_new_tokens=8)
        with pytest.raises(ValueError, match="allocatable pages"):
            eng.add(pool_big)

    def test_queue_for_pages_clears_error_on_retry(self):
        cfg = get_config("smollm-360m", smoke=True)
        eng = Engine(None, cfg, max_slots=2, max_len=64, prefill_chunk=16,
                     paged_kv=PagedKVConfig(page_size=16, n_pages=4,
                                            prefix_sharing=False))
        a = Request(rid=0, prompt=np.arange(20, dtype=np.int32),
                    max_new_tokens=8)
        b = Request(rid=1, prompt=np.arange(20, dtype=np.int32),
                    max_new_tokens=8)
        assert eng.add(a) and not eng.add(b)
        assert "waiting for free KV pages" in b.error
        # slot release frees the reservation; the retry must admit cleanly
        del eng.prefilling[a.slot]
        eng.slot_free[a.slot] = True
        eng.pager.release(a.slot, a.prompt)
        assert eng.add(b) and b.error == ""

    def test_reservation_prevents_mid_decode_exhaustion(self):
        """Admission reserves prompt + max_new - 1 (+ draft window) worth of
        pages up front — after admit, the slot can decode to its token
        budget without ever touching the allocator again."""
        cfg = get_config("smollm-360m", smoke=True)
        eng = Engine(None, cfg, max_slots=1, max_len=64, prefill_chunk=16,
                     paged_kv=PagedKVConfig(page_size=16,
                                            prefix_sharing=False))
        req = Request(rid=0, prompt=np.arange(17, dtype=np.int32),
                      max_new_tokens=16)
        assert eng.add(req)
        # 17 + 16 - 1 = 32 positions → 2 pages of 16
        assert len(eng.pager.slot_pages[0]) == 2

    def test_rejects_non_pageable_archs(self):
        """Ring-buffer (windowed) and SSM layers are genuinely non-pageable;
        the refusal must say so (not just name the dense fallback)."""
        paged = PagedKVConfig(page_size=16)
        with pytest.raises(ValueError, match="window"):
            Engine(None, get_config("gemma3-1b", smoke=True),
                   max_slots=1, max_len=64, paged_kv=paged)
        with pytest.raises(ValueError, match="ssm"):
            Engine(None, get_config("mamba2-1.3b", smoke=True),
                   max_slots=1, max_len=64, paged_kv=paged)

    def test_knob_validation(self):
        cfg = get_config("smollm-360m", smoke=True)
        with pytest.raises(ValueError, match="multiple of page_size"):
            Engine(None, cfg, max_len=60,
                   paged_kv=PagedKVConfig(page_size=16))
        with pytest.raises(ValueError, match="n_pages"):
            Engine(None, cfg, max_len=64,
                   paged_kv=PagedKVConfig(page_size=16, n_pages=1))


# --------------------------------------------------------------------------
# Greedy token identity: paged == dense
# --------------------------------------------------------------------------
@pytest.mark.slow
class TestPagedExactness:
    LENS = (7, 19, 34, 4, 25)
    PAGED = PagedKVConfig(page_size=8)

    def test_gqa_whole_prompt(self, served, rng):
        cfg, params = served
        prompts = _prompts(cfg, rng, self.LENS)
        base, bstats, _ = _run(cfg, params, prompts)
        got, pstats, _ = _run(cfg, params, prompts, paged_kv=self.PAGED)
        assert got == base
        assert pstats.prefill_tokens == bstats.prefill_tokens

    def test_gqa_chunked(self, served, rng):
        cfg, params = served
        prompts = _prompts(cfg, rng, self.LENS)
        base, _, _ = _run(cfg, params, prompts)
        got, stats, _ = _run(cfg, params, prompts, prefill_chunk=16,
                             paged_kv=self.PAGED)
        assert got == base and stats.chunk_steps > 0

    def test_mla_whole_prompt(self, served_mla, rng):
        cfg, params = served_mla
        prompts = _prompts(cfg, rng, self.LENS)
        base, _, _ = _run(cfg, params, prompts)
        got, _, _ = _run(cfg, params, prompts, paged_kv=self.PAGED)
        assert got == base

    def test_mla_chunked(self, served_mla, rng):
        cfg, params = served_mla
        prompts = _prompts(cfg, rng, (7, 19, 34))
        base, _, _ = _run(cfg, params, prompts)
        got, _, _ = _run(cfg, params, prompts, prefill_chunk=16,
                         paged_kv=self.PAGED)
        assert got == base

    @pytest.mark.parametrize("spec", [
        SpecConfig(k=3, drafter="ngram"),
        SpecConfig(k=3, drafter="ngram", adaptive_k=True),
        SpecConfig(k=3, drafter="ngram", tree=(2, 2)),
    ], ids=["chain", "adaptive", "tree"])
    def test_gqa_spec_modes(self, served, rng, spec):
        cfg, params = served
        prompts = _prompts(cfg, rng, self.LENS)
        base, _, _ = _run(cfg, params, prompts, spec=spec)
        got, stats, _ = _run(cfg, params, prompts, spec=spec,
                             paged_kv=self.PAGED)
        assert got == base and stats.spec_steps > 0

    @pytest.mark.parametrize("spec", [
        SpecConfig(k=3, drafter="ngram"),
        SpecConfig(k=2, drafter="ngram", tree=(2, 2)),
    ], ids=["chain", "tree"])
    def test_mla_spec_modes(self, served_mla, rng, spec):
        cfg, params = served_mla
        prompts = _prompts(cfg, rng, (7, 19, 34))
        base, _, _ = _run(cfg, params, prompts, spec=spec)
        got, _, _ = _run(cfg, params, prompts, spec=spec,
                         paged_kv=self.PAGED)
        assert got == base

    def test_page_recycling_stays_exact(self, served, rng):
        """slots=1 with a minimal pool: every admission reuses the previous
        request's recycled (garbage-holding) pages, so the scrub-on-alloc
        discipline is what keeps outputs exact."""
        cfg, params = served
        prompts = _prompts(cfg, rng, (19, 25, 7, 34))
        base, _, _ = _run(cfg, params, prompts, slots=1)
        paged = PagedKVConfig(page_size=8, n_pages=96 // 8 + 1,
                              prefix_sharing=False)
        got, _, eng = _run(cfg, params, prompts, slots=1, paged_kv=paged)
        assert got == base
        assert eng.pager.free_pages == eng.pager.total_pages  # all returned


# --------------------------------------------------------------------------
# Block-boundary edges
# --------------------------------------------------------------------------
@pytest.mark.slow
class TestBlockBoundaries:
    def test_prompt_exactly_on_page_edge(self, served, rng):
        """Prompts of exactly 1, 2, 3 pages: the write frontier lands on a
        page boundary, so the first decode allocates nothing mid-page."""
        cfg, params = served
        prompts = _prompts(cfg, rng, (8, 16, 24))
        base, _, _ = _run(cfg, params, prompts)
        got, _, _ = _run(cfg, params, prompts,
                         paged_kv=PagedKVConfig(page_size=8))
        assert got == base

    def test_rollback_across_page_edge(self, served, rng):
        """Speculative rollback must restore a frontier that crosses page
        boundaries: page_size=4 < k+1=5 guarantees every verify window spans
        at least one page edge."""
        cfg, params = served
        prompts = _prompts(cfg, rng, (7, 14, 21))
        spec = SpecConfig(k=4, drafter="ngram")
        base, _, _ = _run(cfg, params, prompts, spec=spec, max_new=10)
        got, _, _ = _run(cfg, params, prompts, spec=spec, max_new=10,
                         paged_kv=PagedKVConfig(page_size=4))
        assert got == base

    def test_tree_compaction_across_page_edge(self, served, rng):
        """Tree verify writes n_nodes candidate slots, compaction gathers the
        winners — with page_size=4 < n_nodes=7 the window always straddles a
        page edge, exercising the block-table gather/scatter compaction."""
        cfg, params = served
        prompts = _prompts(cfg, rng, (7, 14, 21))
        spec = SpecConfig(k=2, drafter="ngram", tree=(3, 2))
        base, _, _ = _run(cfg, params, prompts, spec=spec, max_new=10)
        got, _, _ = _run(cfg, params, prompts, spec=spec, max_new=10,
                         paged_kv=PagedKVConfig(page_size=4))
        assert got == base

    def test_cow_fork_mid_page(self, served, rng):
        """Two prompts sharing a prefix that ends mid-page: only the full
        common pages are shared, the partial page is recomputed privately —
        and both outputs match the dense engine's."""
        cfg, params = served
        base_p = rng.integers(0, cfg.vocab, size=20).astype(np.int32)
        fork = base_p.copy()
        fork[12:] = rng.integers(0, cfg.vocab, size=8).astype(np.int32)
        prompts = [base_p, fork, base_p]
        dense, _, _ = _run(cfg, params, prompts, slots=1)
        got, stats, _ = _run(cfg, params, prompts, slots=1,
                             paged_kv=PagedKVConfig(page_size=8))
        assert got == dense
        # req1 shares only page 0 (8 tok), req2 rehits base_p's full prefix
        assert stats.prefix_hit_requests == 2
        assert stats.prefix_hit_tokens == 8 + 16


# --------------------------------------------------------------------------
# Prefix sharing + offload end-to-end
# --------------------------------------------------------------------------
@pytest.mark.slow
class TestPrefixSharingServing:
    def test_shared_system_prompt_identity_and_hits(self, served, rng):
        cfg, params = served
        shared = rng.integers(0, cfg.vocab, size=24).astype(np.int32)
        prompts = [
            np.concatenate([shared,
                            rng.integers(0, cfg.vocab, size=4).astype(np.int32)])
            for _ in range(4)
        ]
        dense, _, _ = _run(cfg, params, prompts, slots=1)
        got, stats, eng = _run(cfg, params, prompts, slots=1,
                               paged_kv=PagedKVConfig(page_size=8))
        assert got == dense
        # requests 2..4 each reuse the 24-token (3-page) shared prefix
        assert stats.prefix_hit_requests == 3
        assert stats.prefix_hit_tokens == 3 * 24
        # shared prefill work was actually skipped, not just recounted
        assert stats.prefill_tokens == sum(map(len, prompts)) - 3 * 24

    def test_offload_tier_round_trip_stays_exact(self, served, rng):
        """A pool too small to keep cold prefixes resident offloads them to
        host RAM and pages them back in on the next hit — output identical
        to dense, with the paged-out/in counters moving."""
        cfg, params = served
        p1 = rng.integers(0, cfg.vocab, size=20).astype(np.int32)
        p2 = rng.integers(0, cfg.vocab, size=20).astype(np.int32)
        prompts = [p1, p2, p1]          # p1's prefix must survive p2
        dense, _, _ = _run(cfg, params, prompts, slots=1, max_len=32,
                           max_new=4)
        # 3 allocatable pages; each request reserves ceil(23/8) = 3, so p2's
        # admission must evict p1's 2 index pages into the host tier, and
        # re-admitting p1 pages them back in (evicting p2's in turn)
        paged = PagedKVConfig(page_size=8, n_pages=4, host_offload_pages=8)
        got, _, eng = _run(cfg, params, prompts, slots=1, max_len=32,
                           max_new=4, paged_kv=paged)
        assert got == dense
        assert eng.pager.pages_paged_out >= 2
        assert eng.pager.pages_paged_in >= 2

    def test_out_of_pages_drains_fcfs(self, served, rng):
        """A pool that fits one request at a time: later requests wait for
        pages (never rejected) and the queue drains FCFS."""
        cfg, params = served
        prompts = _prompts(cfg, rng, (19, 21, 23))
        dense, _, _ = _run(cfg, params, prompts, slots=3, max_len=32,
                           max_new=4)
        # 4 allocatable pages: enough for the largest request alone
        # (23 + 3 positions → 4 pages), never for two at once
        paged = PagedKVConfig(page_size=8, n_pages=5, prefix_sharing=False)
        got, stats, _ = _run(cfg, params, prompts, slots=3, max_len=32,
                             max_new=4, paged_kv=paged)
        assert got == dense
        assert stats.completed == 3 and stats.rejected == 0


# --------------------------------------------------------------------------
# Observability: page-pool and prefix gauges ride the existing on_tick sync
# --------------------------------------------------------------------------
@pytest.mark.slow
class TestPagedObs:
    def test_gauges_and_counters_exported(self, served, rng):
        from repro.obs import ObsConfig

        cfg, params = served
        shared = rng.integers(0, cfg.vocab, size=16).astype(np.int32)
        prompts = [
            np.concatenate([shared,
                            rng.integers(0, cfg.vocab, size=4).astype(np.int32)])
            for _ in range(3)
        ]
        _, _, eng = _run(cfg, params, prompts, slots=1,
                         paged_kv=PagedKVConfig(page_size=8),
                         obs=ObsConfig(trace=False))
        obs = eng.obs
        assert obs.g_pages_total.value == eng.pager.total_pages
        assert obs.g_pages_free.value == eng.pager.free_pages
        assert obs.c_prefix_hit_tok.value == eng.prefix_hit_tokens > 0
        assert obs.c_prefix_hit_req.value == eng.prefix_hit_requests == 2
        assert "pages=" in obs.stats_line()
        assert "prefix_hit=" in obs.stats_line()
