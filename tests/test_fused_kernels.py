"""Fused single-pass kernel validation (interpret=True on CPU).

The fused pipeline (quantize + de-interleave prologue, int32 VMEM
accumulation, scale epilogue) must reproduce the reference oracle across
padding edges (odd M/K/N), both kernels, mixed g=5/g=4 segments, and must
match the unfused three-pass pipeline bit-for-bit on single-segment weights
(same quantizer, same int path, same f32 scale application order)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import act_quant_tokens, pack_ternary, pack_weight, ternary_quantize
from repro.kernels import (
    ref_mpgemm,
    ref_segment_gemm_int,
    ternary_decode_gemm_fused,
    ternary_matmul,
    vlut_lookup_gemm_fused,
    vlut_mpgemm,
)
from repro.kernels import ops as kernel_ops


def _mk(m, k, n, seed=0):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((m, k)).astype(np.float32)
    a = rng.standard_normal((k, n)).astype(np.float32)
    tw = ternary_quantize(jnp.asarray(w))
    return tw, jnp.asarray(a)


# odd M/K/N on purpose: every axis exercises the padding edge
ODD_SHAPES = [(8, 13, 3), (5, 20, 1), (33, 45, 17), (64, 97, 130), (127, 24, 7)]


class TestFusedKernelsDirect:
    """Direct fused-kernel calls against the dense int oracle + exact scales."""

    @pytest.mark.parametrize("g", [4, 5])
    @pytest.mark.parametrize("impl", ["decode", "lookup"])
    def test_single_segment_exact(self, g, impl, rng):
        m, kg, n = 16, 8, 32
        k = kg * g
        w = rng.integers(-1, 2, (m, k)).astype(np.int8)
        a = rng.standard_normal((k, n)).astype(np.float32)
        packed = pack_ternary(jnp.asarray(w), g)
        a_j = jnp.asarray(a)
        a_q, a_scale = act_quant_tokens(a_j)
        want_int = np.asarray(ref_segment_gemm_int(packed, a_q, g))
        want = want_int.astype(np.float32) * np.asarray(a_scale)[None, :]

        fn = ternary_decode_gemm_fused if impl == "decode" else vlut_lookup_gemm_fused
        out = fn(
            packed,
            a_j.reshape(kg, g, n),
            a_scale[None, :],
            jnp.ones((m, 1), jnp.float32),
            g=g, bm=8, bn=32, bkg=4, interpret=True,
        )
        np.testing.assert_allclose(np.asarray(out), want, rtol=1e-6, atol=1e-6)

    @pytest.mark.parametrize("impl", ["decode", "lookup"])
    def test_padded_groups_contribute_zero(self, impl, rng):
        """ops-style padding: zero-code weight columns + zero activations +
        unit scales on padded tokens change nothing."""
        g, m, kg, n = 5, 8, 6, 16
        k = kg * g
        w = rng.integers(-1, 2, (m, k)).astype(np.int8)
        a = rng.standard_normal((k, n)).astype(np.float32)
        packed = pack_ternary(jnp.asarray(w), g)
        a_j = jnp.asarray(a)
        a_q, a_scale = act_quant_tokens(a_j)
        want = (
            np.asarray(ref_segment_gemm_int(packed, a_q, g)).astype(np.float32)
            * np.asarray(a_scale)[None, :]
        )
        zero_code = (3 ** g - 1) // 2
        packed_p = jnp.pad(packed, ((0, 0), (0, 2)), constant_values=zero_code)
        a3_p = jnp.pad(a_j.reshape(kg, g, n), ((0, 2), (0, 0), (0, 8)))
        as_p = jnp.pad(a_scale[None, :], ((0, 0), (0, 8)), constant_values=1.0)
        fn = ternary_decode_gemm_fused if impl == "decode" else vlut_lookup_gemm_fused
        out = fn(
            packed_p, a3_p, as_p, jnp.ones((m, 1), jnp.float32),
            g=g, bm=8, bn=8, bkg=4, interpret=True,
        )
        np.testing.assert_allclose(
            np.asarray(out)[:, :n], want, rtol=1e-6, atol=1e-6
        )
        # padded token columns: activations are 0 → exactly 0 out
        assert np.all(np.asarray(out)[:, n:] == 0)


class TestFusedPipeline:
    """vlut_mpgemm(fusion='fused') — the single-pass hot path."""

    @pytest.mark.parametrize("impl", ["decode", "lookup"])
    @pytest.mark.parametrize("m,k,n", ODD_SHAPES)
    def test_matches_oracle_odd_shapes(self, impl, m, k, n):
        tw, a = _mk(m, k, n, seed=m * 1000 + n)
        pw = pack_weight(tw.values, tw.scale, "auto")  # mixed g=5/g=4 for most K
        out = np.asarray(
            vlut_mpgemm(pw, a, impl=impl, interpret=True, fusion="fused")
        )
        want = np.asarray(ref_mpgemm(pw, a))
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("impl", ["decode", "lookup"])
    @pytest.mark.parametrize("mode", ["i1", "i2"])
    def test_single_segment_bit_identical_to_unfused(self, impl, mode):
        """Same quantizer + same int path + same scale-mult order → the fused
        kernel's f32 output is bit-identical to the unfused pipeline when
        only one segment exists."""
        k = 40  # 5|40 and 4|40
        tw, a = _mk(24, k, 9, seed=3)
        pw = pack_weight(tw.values, tw.scale, mode)
        fused = np.asarray(
            vlut_mpgemm(pw, a, impl=impl, interpret=True, fusion="fused")
        )
        unfused = np.asarray(
            vlut_mpgemm(pw, a, impl=impl, interpret=True, fusion="unfused")
        )
        np.testing.assert_array_equal(fused, unfused)

    @pytest.mark.parametrize("impl", ["decode", "lookup"])
    def test_mixed_segments_match_unfused(self, impl):
        """g=5 + g=4 mixed packing: fused sums two f32 partials (vs int32 sum
        then scale) — equal within f32 rounding."""
        tw, a = _mk(32, 57, 21, seed=11)  # 57 = 5*9 + 4*3 → both segments
        pw = pack_weight(tw.values, tw.scale, "auto")
        assert pw.packed5.shape[-1] and pw.packed4.shape[-1]
        fused = np.asarray(
            vlut_mpgemm(pw, a, impl=impl, interpret=True, fusion="fused")
        )
        unfused = np.asarray(
            vlut_mpgemm(pw, a, impl=impl, interpret=True, fusion="unfused")
        )
        np.testing.assert_allclose(fused, unfused, rtol=1e-6, atol=1e-6)

    def test_scale_epilogue_per_channel(self):
        """Non-trivial per-channel w_scale must be applied inside the kernel
        epilogue exactly as the unfused dequant pass applies it."""
        rng = np.random.default_rng(7)
        m, k, n = 16, 40, 8
        w = rng.standard_normal((m, k)).astype(np.float32) * np.linspace(
            0.1, 4.0, m
        )[:, None]
        a = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
        tw = ternary_quantize(jnp.asarray(w))
        assert np.asarray(tw.scale).std() > 0.1  # genuinely per-channel
        pw = pack_weight(tw.values, tw.scale, "i1")
        fused = np.asarray(vlut_mpgemm(pw, a, impl="decode", interpret=True))
        want = np.asarray(ref_mpgemm(pw, a))
        np.testing.assert_allclose(fused, want, rtol=1e-6, atol=1e-6)

    def test_bf16_output_dtype(self):
        """The epilogue emits the requested dtype directly from the kernel."""
        tw, a = _mk(16, 40, 8, seed=5)
        pw = pack_weight(tw.values, tw.scale, "i1")
        out = vlut_mpgemm(
            pw, a, impl="decode", interpret=True, out_dtype=jnp.bfloat16
        )
        assert out.dtype == jnp.bfloat16
        want = np.asarray(ref_mpgemm(pw, a))
        np.testing.assert_allclose(
            np.asarray(out, np.float32), want, rtol=2e-2, atol=2e-2
        )


class TestFusedServeRouting:
    """ternary_matmul routes serve-shaped calls through the fused path."""

    def test_leading_dims_fused_interpret(self):
        rng = np.random.default_rng(3)
        k, m = 45, 32
        w = rng.standard_normal((m, k)).astype(np.float32)
        tw = ternary_quantize(jnp.asarray(w))
        pw = pack_weight(tw.values, tw.scale, "auto")
        x = rng.standard_normal((2, 3, 4, k)).astype(np.float32)
        with kernel_ops.dispatch_override(impl="decode", fusion="fused",
                                          interpret=True):
            y = np.asarray(ternary_matmul(pw, jnp.asarray(x)))
        assert y.shape == (2, 3, 4, m)
        want = np.asarray(
            ref_mpgemm(pw, jnp.asarray(x.reshape(-1, k).T))
        ).T.reshape(2, 3, 4, m)
        np.testing.assert_allclose(y, want, rtol=1e-4, atol=1e-4)

    def test_dispatch_override_restores(self):
        base = kernel_ops.dispatch_config()
        before = (base.impl, base.fusion, base.interpret)
        with kernel_ops.dispatch_override(impl="lookup", interpret=True):
            assert kernel_ops.dispatch_config().impl == "lookup"
        assert (base.impl, base.fusion, base.interpret) == before


@pytest.mark.slow
def test_engine_prefill_decode_fused_end_to_end():
    """serve/engine.py prefill + decode on the fused interpreted Pallas path
    produce the same greedy tokens as the default (XLA) path."""
    from repro.configs import get_config
    from repro.models import init_lm, pack_params
    from repro.serve import Engine, Request

    cfg = get_config("smollm-360m", smoke=True)
    params = pack_params(init_lm(jax.random.PRNGKey(0), cfg), cfg)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, size=9).astype(np.int32)

    def gen(**mpgemm_kw):
        eng = Engine(params, cfg, max_slots=1, max_len=32, **mpgemm_kw)
        req = Request(rid=0, prompt=prompt, max_new_tokens=4)
        assert eng.add(req)
        while eng.n_active:
            eng.decode_once()
        return req.generated

    want = gen()  # default routing (XLA on CPU)
    got = gen(mpgemm_impl="decode", mpgemm_fusion="fused", mpgemm_interpret=True)
    assert got == want
