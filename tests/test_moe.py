"""MoE routing/dispatch properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import MoEConfig
from repro.models.moe import moe_apply, moe_init


def _cfg(**kw):
    base = get_config("llama4-scout-17b-a16e", smoke=True)
    return base.with_(moe=MoEConfig(**{**dict(
        n_experts=4, top_k=2, d_ff_expert=32, capacity_factor=1.25
    ), **kw}))


def test_output_shape_and_finite(rng):
    cfg = _cfg()
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)), jnp.float32)
    out, aux = moe_apply(p, x, cfg, "train")
    assert out.shape == x.shape
    assert np.all(np.isfinite(np.asarray(out)))
    assert float(aux) > 0  # load-balance + z losses active in train


def test_aux_free_routing_has_zero_aux(rng):
    cfg = _cfg(router_aux_free=True)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)), jnp.float32)
    _, aux = moe_apply(p, x, cfg, "train")
    assert float(aux) == 0.0
    assert "router_bias" in p


def test_small_batch_is_dropless(rng):
    """Decode-sized batches must not drop tokens (engine correctness)."""
    cfg = _cfg(capacity_factor=0.01)  # hostile factor; floor must protect
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((2, 1, cfg.d_model)), jnp.float32)
    out, _ = moe_apply(p, x, cfg, "serve")
    # dropless ⇒ output differs from zero for every token
    assert np.all(np.abs(np.asarray(out)).sum(-1) > 0)


def test_capacity_drops_under_pressure(rng):
    """With capacity_factor ≪ 1 on a big batch, some tokens must drop to the
    residual stream (GShard semantics) — outputs for dropped tokens are the
    shared-expert-only / zero contribution."""
    cfg = _cfg(n_shared=0, capacity_factor=0.25)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((4, 32, cfg.d_model)), jnp.float32)
    out, _ = moe_apply(p, x, cfg, "train")
    zero_rows = np.abs(np.asarray(out)).sum(-1) < 1e-7
    assert zero_rows.any()


def test_shared_expert_always_contributes(rng):
    cfg = _cfg(n_shared=1, capacity_factor=0.25)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((4, 32, cfg.d_model)), jnp.float32)
    out, _ = moe_apply(p, x, cfg, "train")
    assert np.all(np.abs(np.asarray(out)).sum(-1) > 0)


def test_top1_selects_argmax(rng):
    cfg = _cfg(top_k=1, capacity_factor=8.0)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((1, 4, cfg.d_model)), jnp.float32)
    out, _ = moe_apply(p, x, cfg, "eval")
    # manual: dispatch every token to its argmax expert and compare
    xt = np.asarray(x).reshape(-1, cfg.d_model)
    logits = xt @ np.asarray(p["router"]["w"])
    eidx = logits.argmax(-1)
    want = np.zeros_like(xt)
    from repro.models.common import linear_apply
    for i, e in enumerate(eidx):
        h1 = np.asarray(linear_apply(
            {"qw": p["experts"]["w1"]["qw"][e]}, jnp.asarray(xt[i]), cfg, "eval"))
        h3 = np.asarray(linear_apply(
            {"qw": p["experts"]["w3"]["qw"][e]}, jnp.asarray(xt[i]), cfg, "eval"))
        h = h1 * (1 / (1 + np.exp(-h1))) * h3
        want[i] = np.asarray(linear_apply(
            {"qw": p["experts"]["w2"]["qw"][e]}, jnp.asarray(h), cfg, "eval"))
    got = np.asarray(out).reshape(-1, cfg.d_model)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_block_local_dispatch_matches_global(rng):
    """§Perf 4.2: block-local positions must not change routing semantics
    (identical outputs when capacity is not binding)."""
    cfg = _cfg(capacity_factor=8.0)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((4, 16, cfg.d_model)), jnp.float32)
    o1, _ = moe_apply(p, x, cfg, "eval", n_blocks=1)
    o4, _ = moe_apply(p, x, cfg, "eval", n_blocks=4)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o4), rtol=1e-5, atol=1e-6)


def test_block_dispatch_grad_finite(rng):
    cfg = _cfg(capacity_factor=2.0)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)), jnp.float32)

    def loss(p):
        out, aux = moe_apply(p, x, cfg, "train", n_blocks=2)
        return jnp.sum(out**2) + aux

    g = jax.grad(loss)(p)
    assert all(np.all(np.isfinite(np.asarray(l))) for l in jax.tree.leaves(g))
