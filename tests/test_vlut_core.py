"""Algorithm 1 correctness: every Vec-LUT variant must match the dense
ternary-matmul oracle bit-exactly on the integer path (lossless claim)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    lookup_accumulate,
    max_block_int16,
    mad_gemm,
    mad_gemm_int8,
    pack_ternary,
    pack_weight,
    precompute_lut,
    precompute_lut_naive,
    precompute_lut_topological,
    scalar_lut_gemm,
    sign_matrix,
    ternary_quantize,
    vlut_gemm,
)


def _oracle(tw_values, tw_scale, a):
    amax = np.abs(a).max(axis=0)
    a_scale = np.maximum(amax, 1e-6) / 127.0
    a_q = np.clip(np.round(a / a_scale[None, :]), -127, 127).astype(np.int8)
    out = np.asarray(tw_values, np.int32) @ a_q.astype(np.int32)
    return out.astype(np.float32) * np.asarray(tw_scale)[:, None] * a_scale[None, :]


def _mk(m, k, n, seed=0):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((m, k)).astype(np.float32)
    a = rng.standard_normal((k, n)).astype(np.float32)
    tw = ternary_quantize(jnp.asarray(w))
    return tw, a


class TestPrecompute:
    @pytest.mark.parametrize("g", [4, 5])
    def test_matmul_vs_definition(self, g, rng):
        k, n = 4 * g, 6
        a_q = rng.integers(-127, 128, (k, n)).astype(np.int8)
        t = np.asarray(precompute_lut(jnp.asarray(a_q), g))
        s = sign_matrix(g).astype(np.int32)
        want = np.einsum("eg,kgn->ken", s, a_q.reshape(k // g, g, n).astype(np.int32))
        assert np.array_equal(t, want.astype(np.int16))

    @pytest.mark.parametrize("g", [4, 5])
    def test_topological_equals_matmul(self, g, rng):
        """Paper §4: topological reuse computes the identical table."""
        a_q = rng.integers(-127, 128, (3 * g, 5)).astype(np.int8)
        t0 = np.asarray(precompute_lut(jnp.asarray(a_q), g))
        t1 = np.asarray(precompute_lut_topological(jnp.asarray(a_q), g))
        t2 = np.asarray(precompute_lut_naive(jnp.asarray(a_q), g))
        assert np.array_equal(t0, t1)
        assert np.array_equal(t0, t2)

    def test_int16_no_overflow(self):
        """Worst-case activations stay within int16 (|a| ≤ 127, g ≤ 5)."""
        for g in (4, 5):
            a_q = jnp.full((g, 2), 127, jnp.int8)
            t = precompute_lut(a_q, g)
            assert int(jnp.max(t)) == 127 * g  # no wraparound


class TestLookupAccumulate:
    @pytest.mark.parametrize("g", [4, 5])
    @pytest.mark.parametrize("hier", [True, False])
    def test_matches_dense(self, g, hier, rng):
        m, kg, n = 16, 3 * max_block_int16(g) + 2, 9  # force multiple blocks
        k = kg * g
        w = rng.integers(-1, 2, (m, k)).astype(np.int8)
        a_q = rng.integers(-127, 128, (k, n)).astype(np.int8)
        packed = pack_ternary(jnp.asarray(w), g)
        t = precompute_lut(jnp.asarray(a_q), g)
        out = np.asarray(lookup_accumulate(t, packed, hierarchical=hier, g=g))
        want = w.astype(np.int32) @ a_q.astype(np.int32)
        assert np.array_equal(out, want)

    def test_block_bound_is_safe(self):
        """Paper §3.4 overflow bound: B ≤ max(INT16)/(max(INT8)·g)."""
        for g in (4, 5):
            assert max_block_int16(g) * 127 * g <= 32767


class TestVlutGemm:
    # slow: every drawn (m, k, n) shape compiles a fresh jit entry
    @pytest.mark.slow
    @given(
        st.integers(1, 24),
        st.integers(12, 120),
        st.integers(1, 40),
        st.sampled_from(["i1", "i2", "auto"]),
        st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=15, deadline=None)
    def test_matches_oracle_property(self, m, k, n, mode, seed):
        if mode == "i1":
            k = (k // 5) * 5 or 5
        elif mode == "i2":
            k = (k // 4) * 4 or 4
        elif k in (6, 7, 11):
            k = 12
        tw, a = _mk(m, k, n, seed)
        pw = pack_weight(tw.values, tw.scale, mode=mode)
        out = np.asarray(vlut_gemm(pw, jnp.asarray(a)))
        want = _oracle(tw.values, tw.scale, a)
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize(
        "kwargs",
        [
            dict(streamed=False),
            dict(hierarchical=False),
            dict(precompute="topological"),
            dict(precompute="naive"),
            dict(token_contiguous=False),
            dict(k_tile_groups=4),
            dict(n_tile=8),
        ],
    )
    def test_variants_equal(self, kwargs):
        tw, a = _mk(32, 60, 16)
        pw = pack_weight(tw.values, tw.scale, "auto")
        base = np.asarray(vlut_gemm(pw, jnp.asarray(a)))
        out = np.asarray(vlut_gemm(pw, jnp.asarray(a), **kwargs))
        np.testing.assert_allclose(out, base, rtol=1e-6, atol=1e-6)


class TestBaselines:
    def test_scalar_lut_matches(self):
        tw, a = _mk(20, 40, 7)
        pw = pack_weight(tw.values, tw.scale, "auto")
        np.testing.assert_allclose(
            np.asarray(scalar_lut_gemm(pw, jnp.asarray(a))),
            _oracle(tw.values, tw.scale, a), rtol=1e-5, atol=1e-5,
        )

    def test_mad_int8_matches(self):
        tw, a = _mk(20, 40, 7)
        pw = pack_weight(tw.values, tw.scale, "auto")
        np.testing.assert_allclose(
            np.asarray(mad_gemm_int8(pw, jnp.asarray(a))),
            _oracle(tw.values, tw.scale, a), rtol=1e-5, atol=1e-5,
        )

    def test_mad_float_close(self):
        """MAD fp32 path skips act quant → only close, not exact."""
        tw, a = _mk(20, 40, 7)
        pw = pack_weight(tw.values, tw.scale, "auto")
        out = np.asarray(mad_gemm(pw, jnp.asarray(a)))
        want = _oracle(tw.values, tw.scale, a)
        np.testing.assert_allclose(out, want, rtol=0.1, atol=0.15)


class TestAutoSwitch:
    """Paper §6.3: scalar/vector switching by token count."""

    def test_matches_oracle_both_regimes(self):
        from repro.core import lut_gemm_auto

        tw, _ = _mk(24, 40, 1)
        pw = pack_weight(tw.values, tw.scale, "auto")
        for n in (1, 4, 16):
            _, a = _mk(24, 40, n, seed=n)
            out = np.asarray(lut_gemm_auto(pw, jnp.asarray(a)))
            want = _oracle(tw.values, tw.scale, a)
            np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)
