"""Pallas kernel validation (interpret=True on CPU): shape/dtype/g sweeps,
bit-exact against the pure-jnp oracle in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import pack_ternary, pack_weight, ternary_quantize
from repro.kernels import (
    ref_mpgemm,
    ref_segment_gemm_int,
    select_tiles,
    ternary_decode_gemm,
    ternary_matmul,
    vlut_lookup_gemm,
    vlut_mpgemm,
)


def _mk_int(m, k, n, g, seed=0):
    rng = np.random.default_rng(seed)
    w = rng.integers(-1, 2, (m, k)).astype(np.int8)
    a_q = rng.integers(-127, 128, (k, n)).astype(np.int8)
    packed = pack_ternary(jnp.asarray(w), g)
    a_r = jnp.asarray(a_q).reshape(k // g, g, n).transpose(1, 0, 2)
    ref = np.asarray(ref_segment_gemm_int(packed, jnp.asarray(a_q), g))
    return packed, a_r, ref


SHAPES = [(8, 1, 8), (16, 4, 32), (64, 16, 128), (128, 40, 256), (256, 7, 64)]


class TestDecodeKernel:
    @pytest.mark.parametrize("g", [4, 5])
    @pytest.mark.parametrize("m,kg,n", SHAPES)
    def test_exact_vs_ref(self, g, m, kg, n):
        packed, a_r, ref = _mk_int(m, kg * g, n, g, seed=kg)
        out = np.asarray(
            ternary_decode_gemm(packed, a_r, g=g, interpret=True, bm=32, bn=64, bkg=8)
        )
        assert np.array_equal(out, ref)


class TestLookupKernel:
    @pytest.mark.parametrize("g", [4, 5])
    @pytest.mark.parametrize("lookup", ["onehot", "serial"])
    @pytest.mark.parametrize("m,kg,n", [(16, 4, 32), (64, 16, 128)])
    def test_exact_vs_ref(self, g, lookup, m, kg, n):
        packed, a_r, ref = _mk_int(m, kg * g, n, g, seed=m)
        out = np.asarray(
            vlut_lookup_gemm(
                packed, a_r, g=g, lookup=lookup, interpret=True, bm=16, bn=32, bkg=4
            )
        )
        assert np.array_equal(out, ref)


class TestOpsWrapper:
    # slow: every drawn (m, k, n) shape is a fresh interpreted-Pallas compile
    @pytest.mark.slow
    @given(
        st.integers(1, 48),
        st.integers(12, 96),
        st.integers(1, 48),
        st.sampled_from(["xla", "decode", "lookup"]),
        st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=12, deadline=None)
    def test_all_impls_match_ref(self, m, k, n, impl, seed):
        if k in (6, 7, 11):
            k = 13
        rng = np.random.default_rng(seed)
        w = rng.standard_normal((m, k)).astype(np.float32)
        a = rng.standard_normal((k, n)).astype(np.float32)
        tw = ternary_quantize(jnp.asarray(w))
        pw = pack_weight(tw.values, tw.scale, "auto")
        out = np.asarray(vlut_mpgemm(pw, jnp.asarray(a), impl=impl, interpret=True))
        want = np.asarray(ref_mpgemm(pw, jnp.asarray(a)))
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)

    def test_ternary_matmul_leading_dims(self):
        rng = np.random.default_rng(3)
        k, m = 45, 32
        w = rng.standard_normal((m, k)).astype(np.float32)
        tw = ternary_quantize(jnp.asarray(w))
        pw = pack_weight(tw.values, tw.scale, "auto")
        x = rng.standard_normal((2, 3, 4, k)).astype(np.float32)
        y = np.asarray(ternary_matmul(pw, jnp.asarray(x)))
        assert y.shape == (2, 3, 4, m)
        want = np.asarray(
            ref_mpgemm(pw, jnp.asarray(x.reshape(-1, k).T))
        ).T.reshape(2, 3, 4, m)
        np.testing.assert_allclose(y, want, rtol=1e-4, atol=1e-4)

    def test_select_tiles_vmem_budget(self):
        """§4 K_tile rule adapted: streamed table tile must fit the budget."""
        for g in (4, 5):
            t = select_tiles(g, "lookup")
            table_bytes = (3**g) * t["bkg"] * t["bn"] * 2
            assert table_bytes <= 4 * 2**20
            assert t["bn"] % 128 == 0


class TestDtypeEdges:
    def test_extreme_activations(self):
        """Saturated int8 activations: accumulation must not overflow."""
        g, m, kg, n = 5, 8, 64, 16
        k = kg * g
        w = np.ones((m, k), np.int8)  # all +1 → worst-case accumulation
        a_q = np.full((k, n), 127, np.int8)
        packed = pack_ternary(jnp.asarray(w), g)
        a_r = jnp.asarray(a_q).reshape(kg, g, n).transpose(1, 0, 2)
        ref = np.asarray(ref_segment_gemm_int(packed, jnp.asarray(a_q), g))
        assert ref.max() == 127 * k  # int32 exact
        out = np.asarray(ternary_decode_gemm(packed, a_r, g=g, interpret=True))
        assert np.array_equal(out, ref)
