"""repro.lint.ir: one positive (firing) + one negative (clean) fixture per
IR pass (I1–I5), the registry-level suppression contract (I0), registry
coverage/determinism, and the repo-is-IR-clean acceptance gate."""
import json
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.lint.ir import (
    IREntry,
    all_eqns,
    default_entries,
    mpgemm_entries,
    pinned_trace_env,
    registered_passes,
    run_passes,
    signature,
    snapshot_dir,
    write_snapshot,
)

REPO = pathlib.Path(__file__).resolve().parent.parent


def entry(fn, *args, name="fixture/f", meta=None, suppress=None):
    return IREntry(
        name=name, jaxpr=jax.make_jaxpr(fn)(*args),
        meta=meta or {}, suppress=suppress or {},
    )


def rules_of(fs):
    return sorted(f.rule for f in fs)


def prims_of(e):
    return {eqn.primitive.name for eqn, _ in all_eqns(e.jaxpr.jaxpr)}


# fixture shapes: codes are the packed-trit stand-in (uint8 taints I1)
N, K, M = 8, 16, 4
CODES = jnp.zeros((N, K), jnp.uint8)
ACT = jnp.zeros((K, M), jnp.float32)
ACT_I8 = jnp.zeros((K, M), jnp.int8)
WSCALE = jnp.ones((N, 1), jnp.float32)

_DOT = (((1,), (0,)), ((), ()))


class TestRegistry:
    def test_all_passes_registered(self):
        assert set(registered_passes()) == {"I1", "I2", "I3", "I4", "I5"}


# --------------------------------------------------------------------------
# I1 — quantized-dtype flow
# --------------------------------------------------------------------------
class TestI1DtypeFlow:
    def test_flags_promoted_f32_lut_kernel(self):
        # the forbidden rework: decode trit codes straight to float and run
        # the heavy dot in f32 with NO scale applied — numerically fine,
        # performance class forfeited
        def promoted(codes, a):
            w = codes.astype(jnp.float32) - 1.0
            return jax.lax.dot_general(w, a, _DOT)

        fs = run_passes([entry(promoted, CODES, ACT)], select={"I1"})
        assert rules_of(fs) == ["I1"]
        assert "float" in fs[0].message

    def test_int8_datapath_is_clean(self):
        # the intended datapath: integer dot over decoded trits, dequant
        # (scale mul) only in the epilogue
        def int8_path(codes, a_q, w_scale):
            w = codes.astype(jnp.int8) - jnp.int8(1)
            acc = jax.lax.dot_general(
                w, a_q, _DOT, preferred_element_type=jnp.int32
            )
            return acc.astype(jnp.float32) * w_scale

        fs = run_passes(
            [entry(int8_path, CODES, ACT_I8, WSCALE)], select={"I1"}
        )
        assert fs == []

    def test_dequant_before_dot_is_clean(self):
        # mad_dense idiom: applying the scale BEFORE the dot is the dequant
        # event — the float dot downstream is legitimate
        def dequant_first(codes, a, w_scale):
            w = (codes.astype(jnp.float32) - 1.0) * w_scale
            return jax.lax.dot_general(w, a, _DOT)

        fs = run_passes(
            [entry(dequant_first, CODES, ACT, WSCALE)], select={"I1"}
        )
        assert fs == []

    def test_lut_index_use_is_clean(self):
        # using codes as gather indices IS the LUT technique — index
        # operands must not propagate taint
        def lut_gather(codes, table, a):
            w = table[codes.astype(jnp.int32)]     # (N, K) f32 via lookup
            return jax.lax.dot_general(w, a, _DOT)

        table = jnp.zeros((3,), jnp.float32)
        fs = run_passes([entry(lut_gather, CODES, table, ACT)], select={"I1"})
        assert fs == []

    def test_taint_follows_through_pjit(self):
        inner = jax.jit(
            lambda w, a: jax.lax.dot_general(w, a, _DOT)
        )

        def promoted_nested(codes, a):
            return inner(codes.astype(jnp.float32) - 1.0, a)

        fs = run_passes([entry(promoted_nested, CODES, ACT)], select={"I1"})
        assert rules_of(fs) == ["I1"]


# --------------------------------------------------------------------------
# I2 — effect/host audit
# --------------------------------------------------------------------------
class TestI2Effects:
    def test_flags_debug_callback(self):
        def chatty(x):
            jax.debug.print("x = {}", x)
            return x + 1.0

        fs = run_passes([entry(chatty, ACT)], select={"I2"})
        assert "I2" in rules_of(fs)
        assert "debug" in fs[0].message

    def test_flags_argument_derived_device_put(self):
        def shipping(x):
            return jax.device_put(x * 2.0) + 1.0

        fs = run_passes([entry(shipping, ACT)], select={"I2"})
        assert rules_of(fs) == ["I2"]
        assert "argument-derived" in fs[0].message

    def test_pure_device_program_is_clean(self):
        def pure(w, a):
            return jax.lax.dot_general(w.T, a, _DOT)

        fs = run_passes([entry(pure, jnp.zeros((K, N)), ACT)], select={"I2"})
        assert fs == []

    def test_constant_device_put_is_not_flagged(self):
        # the vlut trace threads its decode table through a compile-time
        # device_put of a closed-over CONSTANT — hoisted once, not a per-
        # step transfer, and must stay silent
        with pinned_trace_env():
            from repro.core import pack_weight, ternary_quantize, vlut_gemm

            w = np.random.default_rng(0).standard_normal((64, 80))
            tw = ternary_quantize(jnp.asarray(w, jnp.float32))
            pw = pack_weight(tw.values, tw.scale, "i2")
            e = entry(vlut_gemm, pw, jnp.zeros((80, 2), jnp.float32),
                      name="fixture/vlut")
        assert "device_put" in prims_of(e)   # the discrimination is real
        assert run_passes([e], select={"I2"}) == []


# --------------------------------------------------------------------------
# I3 — dead code
# --------------------------------------------------------------------------
class TestI3DeadCode:
    def test_flags_dead_dot(self):
        def leftover(w, a):
            _dead = jax.lax.dot_general(w.T, a, _DOT)
            return a + 1.0

        fs = run_passes([entry(leftover, jnp.zeros((K, N)), ACT)],
                        select={"I3"})
        assert rules_of(fs) == ["I3"]
        assert "dot_general" in fs[0].message

    def test_flags_large_dead_intermediate(self):
        def bloated(x):
            _dead = jnp.broadcast_to(x[0, 0], (512, 512))  # 1 MiB, dropped
            return x * 2.0

        fs = run_passes([entry(bloated, ACT)], select={"I3"})
        assert rules_of(fs) == ["I3"]

    def test_small_dead_plumbing_is_quiet(self):
        # serve-mode graphs drop small scalars all the time — not findings
        def tiny(x):
            _dead = x[0, 0] + 1.0
            return x * 2.0

        fs = run_passes([entry(tiny, ACT)], select={"I3"})
        assert fs == []

    def test_all_live_is_clean(self):
        def live(w, a):
            return jax.lax.dot_general(w.T, a, _DOT) + 1.0

        fs = run_passes([entry(live, jnp.zeros((K, N)), ACT)], select={"I3"})
        assert fs == []

    def test_dead_inside_pjit_dropped_by_caller(self):
        # pjit bodies are entered with the CALLER's output liveness: compute
        # returned by the jit but dropped by every caller is dead
        inner = jax.jit(lambda w, a: (
            jax.lax.dot_general(w.T, a, _DOT), a + 1.0
        ))

        def outer(w, a):
            _dropped, keep = inner(w, a)
            return keep

        fs = run_passes([entry(outer, jnp.zeros((K, N)), ACT)],
                        select={"I3"})
        assert rules_of(fs) == ["I3"]
        assert "pjit" in fs[0].message


# --------------------------------------------------------------------------
# I4 — traffic vs roofline
# --------------------------------------------------------------------------
class TestI4Traffic:
    GEMM_META = dict(m_out=N, k=K, m_tokens=M, fused=True)

    @staticmethod
    def gemm(w, a):
        return jax.lax.dot_general(w.T, a, _DOT)

    def test_forced_tiny_factor_fires(self):
        e = entry(self.gemm, jnp.zeros((K, N)), ACT,
                  meta=dict(self.GEMM_META, traffic_factor=1e-6))
        fs = run_passes([e], select={"I4"})
        assert rules_of(fs) == ["I4"]
        assert "roofline" in fs[0].message

    def test_generous_factor_is_clean(self):
        e = entry(self.gemm, jnp.zeros((K, N)), ACT,
                  meta=dict(self.GEMM_META, traffic_factor=1e6))
        assert run_passes([e], select={"I4"}) == []

    def test_entry_without_cost_meta_is_skipped(self):
        e = entry(self.gemm, jnp.zeros((K, N)), ACT)   # no m_out/k/m_tokens
        assert run_passes([e], select={"I4"}) == []

    def test_estimate_ignores_fused_away_views(self):
        from repro.lint.ir.traffic import estimate_bytes

        viewy = entry(lambda x: x.reshape(M, K).T, ACT)
        assert estimate_bytes(viewy.jaxpr.jaxpr) == 0.0

    def test_estimate_counts_dot_io(self):
        from repro.lint.ir.traffic import estimate_bytes

        e = entry(self.gemm, jnp.zeros((K, N)), ACT)
        # transpose is a view; the dot moves its two operands + one output
        want = 4 * (K * N + K * M + N * M)
        assert estimate_bytes(e.jaxpr.jaxpr) == float(want)


# --------------------------------------------------------------------------
# I5 — golden jaxpr snapshots
# --------------------------------------------------------------------------
def _snap_fn_a(w, a):
    return jax.lax.dot_general(w.T, a, _DOT)


def _snap_fn_b(w, a):
    # structurally different graph under the SAME entry name -> stale
    return jax.lax.dot_general(w.T, a, _DOT) * 2.0 + 1.0


class TestI5Snapshots:
    W = jnp.zeros((K, N), jnp.float32)

    def test_missing_snapshot_is_a_finding(self, tmp_path):
        e = entry(_snap_fn_a, self.W, ACT, name="fixture/snap")
        fs = run_passes([e], select={"I5"}, snapshot_root=str(tmp_path))
        assert rules_of(fs) == ["I5"]
        assert "no golden snapshot" in fs[0].message

    def test_update_then_check_roundtrip(self, tmp_path):
        e = entry(_snap_fn_a, self.W, ACT, name="fixture/snap")
        fs = run_passes([e], select={"I5"}, snapshot_root=str(tmp_path),
                        update_snapshots=True)
        assert fs == []
        path = tmp_path / jax.default_backend() / "fixture__snap.json"
        payload = json.loads(path.read_text())
        assert payload["entry"] == "fixture/snap"
        assert payload["primitives"].get("dot_general") == 1
        # retracing the same fn must verify clean
        e2 = entry(_snap_fn_a, self.W, ACT, name="fixture/snap")
        assert run_passes([e2], select={"I5"},
                          snapshot_root=str(tmp_path)) == []

    def test_stale_snapshot_is_a_finding(self, tmp_path):
        e = entry(_snap_fn_a, self.W, ACT, name="fixture/snap")
        write_snapshot(e, str(tmp_path))
        changed = entry(_snap_fn_b, self.W, ACT, name="fixture/snap")
        fs = run_passes([changed], select={"I5"},
                        snapshot_root=str(tmp_path))
        assert rules_of(fs) == ["I5"]
        assert "diverged" in fs[0].message
        assert "mul" in fs[0].message          # the primitive-count delta

    def test_signature_is_structural_not_identity(self):
        h1, c1 = signature(jax.make_jaxpr(_snap_fn_a)(self.W, ACT))
        h2, c2 = signature(jax.make_jaxpr(_snap_fn_a)(self.W, ACT))
        assert (h1, c1) == (h2, c2)            # fresh trace, same hash
        h3, _ = signature(
            jax.make_jaxpr(_snap_fn_a)(self.W, jnp.zeros((K, 2 * M)))
        )
        assert h3 != h1                        # shapes enter the hash


# --------------------------------------------------------------------------
# I0 — registry-level suppression contract
# --------------------------------------------------------------------------
class TestI0Suppressions:
    def firing_entry(self, suppress):
        return entry(
            TestI4Traffic.gemm, jnp.zeros((K, N)), ACT,
            meta=dict(TestI4Traffic.GEMM_META, traffic_factor=1e-6),
            suppress=suppress,
        )

    def test_justified_suppression_silences(self):
        e = self.firing_entry(
            {"I4": "table residency is measured by the crossover bench"}
        )
        assert run_passes([e], select={"I4"}) == []

    def test_under_justified_is_I0_and_does_not_suppress(self):
        e = self.firing_entry({"I4": "ok"})
        fs = run_passes([e], select={"I4"})
        assert rules_of(fs) == ["I0", "I4"]

    def test_wrong_pass_does_not_suppress(self):
        e = self.firing_entry(
            {"I1": "this justification names the wrong pass"}
        )
        fs = run_passes([e], select={"I4"})
        assert rules_of(fs) == ["I4"]


# --------------------------------------------------------------------------
# registry coverage, determinism, CLI contract, and the acceptance gate
# --------------------------------------------------------------------------
ENGINE_NAMES = {
    "engine/prefill1", "engine/decode", "engine/chunk_verify",
    "engine/verify", "engine/drafter.prefill", "engine/drafter.verify",
    "engine/drafter.decode", "engine/tree_verify", "engine/compact",
    "engine/paged_decode", "engine/paged_chunk_verify",
    "engine/set_tab", "engine/scrub", "engine/paged_compact",
}
FULL_ONLY_NAMES = {
    "engine/mla_decode", "engine/mla_chunk_verify",
    "engine/paged_mla_decode", "engine/paged_mla_chunk_verify",
}
IMPLS = (
    "vlut", "vlut_packed_fused", "vlut_packed_unfused",
    "scalar_lut", "mad_dense", "mad_int8",
)


class TestRegistryAndGate:
    @pytest.fixture(scope="class")
    def entries(self):
        return default_entries()

    def test_registry_covers_every_impl_and_entry_point(self, entries):
        from repro.lint.ir.registry import QUICK_MS

        names = {e.name for e in entries}
        for impl in IMPLS:
            for m in QUICK_MS:
                assert f"mpgemm/{impl}/M{m}" in names
        assert ENGINE_NAMES <= names

    def test_mpgemm_meta_feeds_the_traffic_pass(self, entries):
        for e in entries:
            if e.kind == "mpgemm":
                assert {"impl", "m_out", "k", "m_tokens", "fused"} <= set(
                    e.meta
                )

    def test_snapshots_exist_for_full_registry(self):
        """Acceptance: a committed golden snapshot for every engine entry
        and every mpGeMM impl x fusion combo at every nightly M — by
        filename, so this stays cheap (no full-lane tracing here)."""
        from repro.lint.ir.registry import FULL_MS

        snap = pathlib.Path(snapshot_dir(str(REPO / "tests"
                                              / "ir_snapshots")))
        want = {
            f"mpgemm/{impl}/M{m}" for impl in IMPLS for m in FULL_MS
        } | ENGINE_NAMES | FULL_ONLY_NAMES
        have = {p.stem.replace("__", "/") for p in snap.glob("*.json")}
        missing = want - have
        assert not missing, f"missing golden snapshots: {sorted(missing)}"

    def test_pinned_trace_env_restores_environment(self):
        from repro.kernels import autotune

        os.environ[autotune.VMEM_BUDGET_ENV] = "123456"
        os.environ[autotune.TUNE_ENV] = "1"
        try:
            with pinned_trace_env():
                assert os.environ[autotune.TUNE_ENV] == "0"
                assert autotune.VMEM_BUDGET_ENV not in os.environ
            assert os.environ[autotune.VMEM_BUDGET_ENV] == "123456"
            assert os.environ[autotune.TUNE_ENV] == "1"
        finally:
            os.environ.pop(autotune.VMEM_BUDGET_ENV, None)
            os.environ.pop(autotune.TUNE_ENV, None)

    def test_cli_ir_flags_require_ir(self):
        out = subprocess.run(
            [sys.executable, "-m", "repro.lint", "--update-snapshots"],
            capture_output=True, text=True,
        )
        assert out.returncode == 2
        assert "--ir" in out.stderr

    def test_repo_is_ir_clean(self, entries):
        """The acceptance gate, as a test: the traced hot path must stay
        clean under every IR pass, golden snapshots included."""
        fs = run_passes(
            entries,
            snapshot_root=str(REPO / "tests" / "ir_snapshots"),
        )
        assert fs == [], "\n".join(f.format() for f in fs)

    def test_retrace_hashes_are_deterministic(self, entries):
        """I5 stability: re-tracing two representative mpGeMM entries in
        the same process reproduces their hashes exactly."""
        fresh = {e.name: e for e in mpgemm_entries()}
        for e in entries:
            if e.name in ("mpgemm/vlut_packed_fused/M16",
                          "mpgemm/mad_int8/M1"):
                assert signature(fresh[e.name].jaxpr) == signature(e.jaxpr)
