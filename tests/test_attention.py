"""Attention: chunked == dense, windows, ring caches, MLA absorbed decode,
tree-verify ancestor masks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import LayerSpec
from repro.models.attention import (
    attn_apply,
    attn_cache_init,
    attn_init,
    sdpa,
    tree_step_gate,
)
from repro.spec import build_tree


def _qkv(rng, b, s, h, kv, d):
    q = rng.standard_normal((b, s, h, d)).astype(np.float32)
    k = rng.standard_normal((b, s, kv, d)).astype(np.float32)
    v = rng.standard_normal((b, s, kv, d)).astype(np.float32)
    pos = np.broadcast_to(np.arange(s, dtype=np.int32), (b, s))
    return map(jnp.asarray, (q, k, v, pos))


class TestSdpa:
    @pytest.mark.parametrize("window", [0, 7])
    def test_chunked_equals_dense(self, window, rng):
        b, s, h, kv, d = 2, 64, 4, 2, 8
        q, k, v, pos = _qkv(rng, b, s, h, kv, d)
        dense = sdpa(q, k, v, pos, pos, causal=True, window=window, dense_max=9999)
        chunked = sdpa(q, k, v, pos, pos, causal=True, window=window,
                       chunk=16, dense_max=1)
        np.testing.assert_allclose(
            np.asarray(dense), np.asarray(chunked), rtol=2e-3, atol=2e-3
        )

    def test_chunked_equals_dense_with_extra_mask(self, rng):
        """The tree gate rides sdpa's extra_mask — the chunked online-softmax
        path must apply it identically to the dense path."""
        b, s, h, kv, d = 1, 64, 2, 2, 4
        q, k, v, pos = _qkv(rng, b, s, h, kv, d)
        em = jnp.asarray(rng.random((b, s, s)) < 0.7)
        em = em | jnp.eye(s, dtype=bool)[None]     # keep self visible
        dense = sdpa(q, k, v, pos, pos, causal=True, dense_max=9999,
                     extra_mask=em)
        chunked = sdpa(q, k, v, pos, pos, causal=True, chunk=16, dense_max=1,
                       extra_mask=em)
        np.testing.assert_allclose(
            np.asarray(dense), np.asarray(chunked), rtol=2e-3, atol=2e-3
        )

    def test_causality(self, rng):
        b, s, h, kv, d = 1, 12, 2, 2, 4
        q, k, v, pos = _qkv(rng, b, s, h, kv, d)
        out1 = sdpa(q, k, v, pos, pos, causal=True)
        k2 = k.at[:, 8:].set(99.0)
        v2 = v.at[:, 8:].set(-99.0)
        out2 = sdpa(q, k2, v2, pos, pos, causal=True)
        np.testing.assert_array_equal(
            np.asarray(out1)[:, :8], np.asarray(out2)[:, :8]
        )

    def test_window_masks_far_tokens(self, rng):
        b, s, h, kv, d = 1, 16, 2, 2, 4
        q, k, v, pos = _qkv(rng, b, s, h, kv, d)
        w = 4
        out1 = sdpa(q, k, v, pos, pos, causal=True, window=w)
        # changing keys older than the window must not affect the last query
        k2 = k.at[:, : s - w].set(7.0)
        v2 = v.at[:, : s - w].set(-7.0)
        out2 = sdpa(q, k2, v2, pos, pos, causal=True, window=w)
        np.testing.assert_array_equal(
            np.asarray(out1)[:, -1], np.asarray(out2)[:, -1]
        )

    def test_invalid_slots_ignored(self, rng):
        b, s, h, kv, d = 1, 8, 2, 2, 4
        q, k, v, pos = _qkv(rng, b, s, h, kv, d)
        kv_pos = jnp.asarray(np.where(np.arange(s) < 6, np.arange(s), -1))[None, :]
        out1 = sdpa(q, k, v, pos, jnp.broadcast_to(kv_pos, (b, s)))
        k2 = k.at[:, 6:].set(50.0)
        out2 = sdpa(q, k2, v, pos, jnp.broadcast_to(kv_pos, (b, s)))
        np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


class TestRingCache:
    def test_ring_equals_full_for_windowed_layer(self):
        """A windowed layer served from a ring buffer of `window` slots must
        produce the same decode outputs as a full-length cache."""
        cfg = get_config("gemma3-1b", smoke=True)
        spec = LayerSpec(window=16, rope_theta=10_000.0)
        rng = jax.random.PRNGKey(0)
        from repro.models.attention import attn_init

        p = attn_init(rng, cfg, spec)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 40, cfg.d_model))

        outs = {}
        for max_len in (16, 64):  # ring (window) vs oversized buffer
            cache = attn_cache_init(cfg, spec, 2, max_len, jnp.float32)
            if max_len == 64:  # force full buffer (no ring wrap)
                cache = {
                    "k": jnp.zeros((2, 64, cfg.n_kv_heads, cfg.head_dim)),
                    "v": jnp.zeros((2, 64, cfg.n_kv_heads, cfg.head_dim)),
                    "slot_pos": jnp.full((2, 64), -1, jnp.int32),
                    "idx": jnp.zeros((2,), jnp.int32),
                }
            y, cache = attn_apply(
                p, x[:, :32], cfg=cfg, spec=spec, mode="eval", cache=cache
            )
            steps = []
            for t in range(32, 40):
                y, cache = attn_apply(
                    p, x[:, t : t + 1], cfg=cfg, spec=spec, mode="eval", cache=cache
                )
                steps.append(np.asarray(y))
            outs[max_len] = np.concatenate(steps, axis=1)
        np.testing.assert_allclose(outs[16], outs[64], rtol=2e-4, atol=2e-4)


class TestTreeVerify:
    """Tree-structured verify: the S incoming tokens are a flattened draft
    tree — each node must attend the cached prefix plus its tree *ancestors*
    only (per-node positions = node depth)."""

    def test_tree_step_gate_window_values(self):
        t = build_tree(2, (2,))           # 5 nodes; parents [0,0,0,1,2]
        start = jnp.asarray([3], jnp.int32)
        gate = np.asarray(tree_step_gate(t, start, t.n_nodes, 12))[0]
        assert gate.shape == (5, 12)
        # outside the slot window [3, 8): always True
        assert gate[:, :3].all() and gate[:, 8:].all()
        # inside: exactly the ancestor matrix (self included)
        np.testing.assert_array_equal(gate[:, 3:8], t.ancestors)

    def test_node_outputs_match_per_path_chain_verify(self, rng):
        """Every tree node's attention output must equal what a plain chain
        verify over that node's root-to-leaf path produces — ancestor-only
        masking, sibling isolation, and depth positions all at once."""
        cfg = get_config("smollm-360m", smoke=True)
        spec = LayerSpec(rope_theta=10_000.0)
        p = attn_init(jax.random.PRNGKey(0), cfg, spec)
        tree = build_tree(3, (2,))        # 7 nodes, 2 leaves
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
        cache = attn_cache_init(cfg, spec, 2, 24, jnp.float32)
        _, cache = attn_apply(p, x, cfg=cfg, spec=spec, mode="eval", cache=cache)
        xt = jax.random.normal(
            jax.random.PRNGKey(2), (2, tree.n_nodes, cfg.d_model)
        )
        out_tree, _ = attn_apply(
            p, xt, cfg=cfg, spec=spec, mode="eval", cache=cache,
            verify=True, tree=tree,
        )
        for path in tree.leaf_paths:
            out_chain, _ = attn_apply(
                p, xt[:, path], cfg=cfg, spec=spec, mode="eval", cache=cache,
                verify=True,
            )
            np.testing.assert_allclose(
                np.asarray(out_tree[:, path]), np.asarray(out_chain),
                rtol=2e-4, atol=2e-4,
            )

    def test_sibling_content_cannot_leak(self, rng):
        """Changing one branch's activations must not change the other
        branch's outputs (the exact bug a shared cache slot would cause)."""
        cfg = get_config("smollm-360m", smoke=True)
        spec = LayerSpec(rope_theta=10_000.0)
        p = attn_init(jax.random.PRNGKey(0), cfg, spec)
        tree = build_tree(2, (2,))        # nodes 0; 1,2; 3(=c(1)), 4(=c(2))
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, cfg.d_model))
        cache = attn_cache_init(cfg, spec, 1, 16, jnp.float32)
        _, cache = attn_apply(p, x, cfg=cfg, spec=spec, mode="eval", cache=cache)
        xt = jax.random.normal(
            jax.random.PRNGKey(2), (1, tree.n_nodes, cfg.d_model)
        )
        out1, _ = attn_apply(p, xt, cfg=cfg, spec=spec, mode="eval",
                             cache=cache, verify=True, tree=tree)
        # perturb branch 2 (nodes 2 and 4); branch 1 (nodes 1, 3) + root stay
        xt2 = xt.at[:, 2].add(5.0).at[:, 4].add(-3.0)
        out2, _ = attn_apply(p, xt2, cfg=cfg, spec=spec, mode="eval",
                             cache=cache, verify=True, tree=tree)
        np.testing.assert_array_equal(
            np.asarray(out1[:, [0, 1, 3]]), np.asarray(out2[:, [0, 1, 3]])
        )
        assert np.abs(np.asarray(out1[:, [2, 4]]) -
                      np.asarray(out2[:, [2, 4]])).max() > 0


class TestMLA:
    @pytest.mark.slow  # full MLA smoke forward ×2 paths: compile-heavy
    def test_absorbed_decode_close_to_naive(self):
        cfg = get_config("deepseek-v3-671b", smoke=True)
        from repro.models import decode_step, init_cache, init_lm, lm_hidden, prefill
        from repro.models.decoder import _head_matmul

        tok = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0, cfg.vocab)
        params = init_lm(jax.random.PRNGKey(0), cfg)
        h, _, _ = lm_hidden(params, tok, cfg, mode="eval")
        want = np.asarray(_head_matmul(params, h[:, -1:, :], cfg)[:, 0])
        cache = init_cache(cfg, 2, max_len=24)
        _, cache = prefill(params, tok[:, :16], cache, cfg, mode="eval")
        got, _ = decode_step(params, tok[:, 16:17], cache, cfg, mode="eval")
        rel = np.abs(np.asarray(got) - want).max() / (np.abs(want).max() + 1e-9)
        assert rel < 5e-2, rel  # int8-act-quant asymmetry only

    def test_latent_cache_is_compact(self):
        cfg = get_config("deepseek-v3-671b", smoke=True)
        from repro.models.mla import mla_cache_init

        c = mla_cache_init(cfg, None, batch=2, max_len=10, dtype=jnp.bfloat16)
        per_tok = c["ckv"].shape[-1] + c["krope"].shape[-1]
        naive = cfg.n_heads * cfg.mla.v_dim * 2  # k+v per token
        assert per_tok < naive / 2  # the MLA cache-compression win
