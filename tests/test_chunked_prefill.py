"""Chunked prefill + mixed prefill/decode batching.

The exactness contract: a chunked engine's greedy serving output is token-
identical to the whole-prompt admission-prefill path — across chunk sizes
(prompts shorter and longer than the chunk), GQA and MLA archs, and with
speculation in chain and tree modes. Plus the prefill-path bugfix
regressions this PR sweeps: the prefill bucket's max_len clamp, real-vs-pad
prefill token accounting, the idle-tick decode skip, and token-budget chunk
pacing.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import (
    init_cache, init_lm, lm_hidden, pack_params, prefill_bucket, verify_step,
)
from repro.models.decoder import _head_matmul
from repro.serve import ContinuousBatchingScheduler, Engine, Request
from repro.spec import SpecConfig


@pytest.fixture(scope="module")
def served():
    cfg = get_config("smollm-360m", smoke=True)
    params = pack_params(init_lm(jax.random.PRNGKey(0), cfg), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def served_mla():
    cfg = get_config("deepseek-v3-671b", smoke=True)
    params = pack_params(init_lm(jax.random.PRNGKey(0), cfg), cfg)
    return cfg, params


def _run(cfg, params, prompts, *, max_new=6, slots=3, max_len=96, **kw):
    eng = Engine(params, cfg, max_slots=slots, max_len=max_len, **kw)
    sched = ContinuousBatchingScheduler(eng)
    reqs = [Request(rid=i, prompt=p.copy(), max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    sched.submit(reqs)
    stats = sched.run_to_completion()
    return [r.generated for r in reqs], stats, eng


def _prompts(cfg, rng, lens):
    return [rng.integers(0, cfg.vocab, size=n).astype(np.int32) for n in lens]


# --------------------------------------------------------------------------
# prefill_bucket max_len clamp (pure, no model)
# --------------------------------------------------------------------------
class TestPrefillBucket:
    def test_rounds_up_to_16(self):
        assert prefill_bucket(1) == 16
        assert prefill_bucket(16) == 16
        assert prefill_bucket(17) == 32

    def test_clamped_to_max_len(self):
        """Regression: a prompt within 15 tokens of max_len bucketed past
        the cache — positions aliased mod max_len and the duplicate-index
        scatter clobbered real prompt K/V nondeterministically."""
        assert prefill_bucket(19, max_len=20) == 20
        assert prefill_bucket(30, max_len=32) == 32
        assert prefill_bucket(17, max_len=20) == 20
        # clamp never cuts below the prompt itself
        assert prefill_bucket(19, max_len=19) == 19
        # far from the boundary the bucket is unchanged
        assert prefill_bucket(19, max_len=512) == 32
        assert prefill_bucket(19) == 32


# --------------------------------------------------------------------------
# Chunked admission mechanics (no forward pass → fast lane)
# --------------------------------------------------------------------------
class TestChunkedAdmission:
    def test_claim_runs_no_forward(self):
        """Chunked admission only claims the slot: params are never touched
        (passing None proves no prefill ran) and the request sits in
        PREFILLING with nothing generated."""
        cfg = get_config("smollm-360m", smoke=True)
        eng = Engine(None, cfg, max_slots=3, max_len=64, prefill_chunk=16)
        for i in range(3):
            assert eng.add(Request(rid=i, prompt=np.arange(8, dtype=np.int32),
                                   max_new_tokens=4))
        assert sorted(eng.prefilling) == [0, 1, 2]
        assert eng.has_work and eng.n_active == 0
        assert all(not r.generated for r in eng.prefilling.values())
        # a fourth request has no slot
        assert not eng.add(Request(rid=3, prompt=np.arange(8, dtype=np.int32)))

    def test_admission_budget_still_enforced(self):
        cfg = get_config("smollm-360m", smoke=True)
        eng = Engine(None, cfg, max_slots=1, max_len=32, prefill_chunk=16)
        with pytest.raises(ValueError, match="max_len"):
            eng.add(Request(rid=0, prompt=np.arange(30, dtype=np.int32),
                            max_new_tokens=8))

    def test_rejects_windowed_and_ssm_archs(self):
        """Chunked prefill rolls back the mask-padded chunk tail — ring
        caches and SSM state can't be rolled back, mirroring speculation."""
        with pytest.raises(ValueError, match="window"):
            Engine(None, get_config("gemma3-1b", smoke=True),
                   max_slots=1, max_len=64, prefill_chunk=16)
        with pytest.raises(ValueError, match="ssm"):
            Engine(None, get_config("mamba2-1.3b", smoke=True),
                   max_slots=1, max_len=64, prefill_chunk=16)

    def test_knob_validation(self):
        cfg = get_config("smollm-360m", smoke=True)
        with pytest.raises(ValueError, match="prefill_chunk"):
            Engine(None, cfg, max_len=64, prefill_chunk=-1)
        with pytest.raises(ValueError, match="max_len"):
            Engine(None, cfg, max_len=64, prefill_chunk=128)
        with pytest.raises(ValueError, match="token_budget"):
            Engine(None, cfg, max_len=64, token_budget=-1)


# --------------------------------------------------------------------------
# Greedy exactness vs the whole-prompt path
# --------------------------------------------------------------------------
@pytest.mark.slow
class TestChunkedExactness:
    LENS = (7, 19, 34, 4, 25)           # spans <chunk and >chunk for 16

    def test_gqa_chunk16(self, served, rng):
        cfg, params = served
        prompts = _prompts(cfg, rng, self.LENS)
        base, bstats, _ = _run(cfg, params, prompts)
        got, cstats, _ = _run(cfg, params, prompts, prefill_chunk=16)
        assert got == base
        assert cstats.chunk_steps > 0
        # identical real prefill work, padding reported separately
        assert cstats.prefill_tokens == bstats.prefill_tokens == sum(self.LENS)

    def test_gqa_chunk64_prompts_shorter_and_longer(self, served, rng):
        """chunk=64: every prompt shorter than the chunk (single mask-padded
        chunk) plus one longer (multi-chunk)."""
        cfg, params = served
        prompts = _prompts(cfg, rng, (7, 40, 70))
        base, _, _ = _run(cfg, params, prompts, max_len=160)
        got, stats, _ = _run(cfg, params, prompts, max_len=160,
                             prefill_chunk=64)
        assert got == base
        assert stats.chunk_steps > 0

    def test_mla_chunk16(self, served_mla, rng):
        cfg, params = served_mla
        prompts = _prompts(cfg, rng, self.LENS)
        base, _, _ = _run(cfg, params, prompts)
        got, _, _ = _run(cfg, params, prompts, prefill_chunk=16)
        assert got == base

    @pytest.mark.parametrize("spec", [
        SpecConfig(k=3, drafter="ngram"),
        SpecConfig(k=3, drafter="ngram", adaptive_k=True),
        SpecConfig(k=3, drafter="ngram", tree=(2,)),
    ], ids=["chain", "adaptive", "tree"])
    def test_gqa_spec_modes(self, served, rng, spec):
        """PREFILLING slots are excluded from draft/verify rows until their
        last chunk lands; chain, adaptive-K, and tree speculation all stay
        token-identical to the plain whole-prompt engine."""
        cfg, params = served
        prompts = _prompts(cfg, rng, self.LENS)
        base, _, _ = _run(cfg, params, prompts)
        got, stats, _ = _run(cfg, params, prompts, prefill_chunk=16, spec=spec)
        assert got == base
        assert stats.spec_steps > 0 and stats.chunk_steps > 0

    def test_mla_spec_chain(self, served_mla, rng):
        cfg, params = served_mla
        prompts = _prompts(cfg, rng, (7, 19, 34))
        base, _, _ = _run(cfg, params, prompts)
        got, _, _ = _run(cfg, params, prompts, prefill_chunk=16,
                         spec=SpecConfig(k=3, drafter="ngram"))
        assert got == base

    def test_spec_model_drafter(self, served, rng):
        """ModelDrafter's mirrored cache syncs the full prompt once, at the
        PREFILLING→DECODING transition (self-draft oracle: target==draft)."""
        cfg, params = served
        prompts = _prompts(cfg, rng, (7, 19, 34))
        base, _, _ = _run(cfg, params, prompts)
        spec = SpecConfig(k=3, drafter="model",
                          draft_params=params, draft_cfg=cfg)
        got, stats, _ = _run(cfg, params, prompts, prefill_chunk=16, spec=spec)
        assert got == base
        # the oracle accepts everything it drafts
        assert stats.accepted_tokens == stats.drafted_tokens > 0

    def test_ttft_recorded_after_last_chunk(self, served, rng):
        cfg, params = served
        prompts = _prompts(cfg, rng, (34, 7))
        _, stats, _ = _run(cfg, params, prompts, prefill_chunk=16)
        assert len(stats.ttft_s) == len(prompts)
        assert all(t > 0 for t in stats.ttft_s)


# --------------------------------------------------------------------------
# Write-window boundary: padded columns past max_len must be DROPPED
# --------------------------------------------------------------------------
@pytest.mark.slow
class TestChunkWindowBoundary:
    """Regression: a chunk row whose fixed (chunk-wide) write window crosses
    max_len used to wrap its mask-padded tail onto the slot's own early
    prompt K/V (GQA `positions % buf`) or clamp onto the last entry (MLA) —
    and idx-only rollback can never restore clobbered K/V. Those scatter
    columns are dropped now (`mode="drop"`)."""

    def test_final_chunk_crossing_max_len_gqa(self, served, rng):
        cfg, params = served
        # prompt 70, chunk 64, max_len 96: the final chunk writes positions
        # 64..127 — columns 96..127 must be dropped, not wrapped onto 0..31
        prompts = _prompts(cfg, rng, (70,))
        base, _, _ = _run(cfg, params, prompts, max_len=96, slots=2)
        got, _, _ = _run(cfg, params, prompts, max_len=96, slots=2,
                         prefill_chunk=64)
        assert got == base

    def test_final_chunk_crossing_max_len_mla(self, served_mla, rng):
        cfg, params = served_mla
        prompts = _prompts(cfg, rng, (70,))
        base, _, _ = _run(cfg, params, prompts, max_len=96, slots=2)
        got, _, _ = _run(cfg, params, prompts, max_len=96, slots=2,
                         prefill_chunk=64)
        assert got == base

    def test_decode_rider_near_max_len(self, served, rng):
        """A decode rider's pad columns (1..chunk-1) cross max_len once its
        position nears the cache end — long generations must stay exact."""
        cfg, params = served
        prompts = _prompts(cfg, rng, (40, 70))
        base, _, _ = _run(cfg, params, prompts, max_len=96, slots=2,
                          max_new=20)
        got, _, _ = _run(cfg, params, prompts, max_len=96, slots=2,
                         max_new=20, prefill_chunk=64)
        assert got == base


# --------------------------------------------------------------------------
# Token-budget chunk pacing
# --------------------------------------------------------------------------
@pytest.mark.slow
class TestTokenBudget:
    def test_budget_paces_chunks_without_changing_output(self, served, rng):
        cfg, params = served
        prompts = _prompts(cfg, rng, (34, 34, 34))
        base, _, _ = _run(cfg, params, prompts)
        # unlimited: all three slots advance a chunk per tick
        wide, swide, _ = _run(cfg, params, prompts, prefill_chunk=16)
        # tight: one 16-token chunk per tick → more (cheaper) chunk steps
        tight, stight, _ = _run(cfg, params, prompts, prefill_chunk=16,
                                token_budget=16)
        assert wide == tight == base
        assert stight.chunk_steps > swide.chunk_steps
        # 3 prompts x ceil(34/16) = 9 chunks, one granted per tick
        assert stight.chunk_steps == 9

    def test_budget_always_advances_one_chunk(self, served, rng):
        """A budget smaller than one chunk must not starve prefill."""
        cfg, params = served
        prompts = _prompts(cfg, rng, (34,))
        base, _, _ = _run(cfg, params, prompts)
        got, stats, _ = _run(cfg, params, prompts, prefill_chunk=16,
                             token_budget=1)
        assert got == base and stats.completed == 1


# --------------------------------------------------------------------------
# Prefill-path bugfix regressions
# --------------------------------------------------------------------------
@pytest.mark.slow
class TestPrefillBugfixes:
    def test_bucket_boundary_prompt_is_exact(self, served, rng):
        """Regression: a prompt within 15 tokens of max_len (legal with
        max_new_tokens=1) used to prefill a 16-multiple bucket PAST max_len,
        wrapping positions mod max_len and corrupting the prompt's own K/V.
        The clamped bucket must reproduce the unpadded forward's argmax."""
        cfg, params = served
        max_len = 20                     # not a 16-multiple
        n = 19                           # rounds to 32 > max_len unclamped
        prompt = rng.integers(0, cfg.vocab, size=n).astype(np.int32)
        eng = Engine(params, cfg, max_slots=1, max_len=max_len)
        req = Request(rid=0, prompt=prompt, max_new_tokens=1)
        assert eng.add(req)
        h, _, _ = lm_hidden(params, jnp.asarray(prompt)[None, :], cfg,
                            mode="serve")
        want = int(np.argmax(np.asarray(
            _head_matmul(params, h[:, -1:, :], cfg)[:, 0]
        )))
        assert req.generated == [want]

    def test_prefill_tokens_count_real_work(self, served, rng):
        """Regression: Engine.add counted left-pad bucket tokens as prefill
        work, inflating prefill tok/s for any prompt not a 16-multiple."""
        cfg, params = served
        lens = (13, 16, 5)               # buckets 16, 16, 16
        prompts = _prompts(cfg, rng, lens)
        _, stats, _ = _run(cfg, params, prompts, max_new=2)
        assert stats.prefill_tokens == sum(lens)
        assert stats.prefill_pad_tokens == sum(16 - n for n in lens)

    def test_idle_tick_skips_decode(self, served, rng):
        """Regression: a tick whose admissions were all satisfied by prefill
        alone (max_new_tokens=1) still ran decode_once on an empty batch.
        The scheduler must skip the step and leave decode stats untouched."""
        cfg, params = served
        prompts = _prompts(cfg, rng, (6, 9, 12))
        got, stats, eng = _run(cfg, params, prompts, max_new=1)
        assert stats.completed == 3
        assert all(len(g) == 1 for g in got)
        assert eng.decode_steps == 0 and eng.chunk_steps == 0
        assert stats.decode_steps == 0 and stats.decode_tokens == 0

    def test_scheduler_counts_prefilling_as_pending(self, served, rng):
        """run_to_completion must not stop while slots are mid-prefill, and
        its per-run stats must cover requests finishing from PREFILLING."""
        cfg, params = served
        prompts = _prompts(cfg, rng, (34, 25))
        got, stats, _ = _run(cfg, params, prompts, max_new=1,
                             prefill_chunk=16)
        assert stats.completed == 2
        assert all(len(g) == 1 for g in got)
        # max_new_tokens=1: every token came from a final chunk — no decode
        assert stats.decode_steps == 0 and stats.decode_tokens == 0
        assert stats.chunk_steps > 0


# --------------------------------------------------------------------------
# last-position-only logits: the chunk step's head matmul is (B, 1, d)
# --------------------------------------------------------------------------
class TestLastPositionLogits:
    """Non-final chunk steps must not pay the (B, chunk, V) head matmul:
    the engine only ever reads one logits column per slot, so verify_step's
    logit_cols path gathers one hidden state per slot *before* the vocab
    projection. Token-identity of the whole serving path is already pinned
    by TestChunkedExactness (which runs through this code); here we pin the
    unit-level equivalence and the structural claim about the traced graph."""

    def test_logit_cols_matches_full_logits(self, served, rng):
        cfg, params = served
        B, S = 3, 8
        cache = init_cache(cfg, B, 64)
        toks = jnp.asarray(
            rng.integers(0, cfg.vocab, size=(B, S)), jnp.int32
        )
        cols = jnp.asarray([0, S - 1, 3], jnp.int32)
        full, _ = verify_step(
            params, toks, cache, cfg, mode="serve", prefill_resume=True
        )
        rows, _ = verify_step(
            params, toks, cache, cfg, mode="serve", prefill_resume=True,
            logit_cols=cols,
        )
        assert rows.shape == (B, cfg.vocab)
        want = jnp.take_along_axis(full, cols[:, None, None], axis=1)[:, 0]
        np.testing.assert_allclose(
            np.asarray(rows), np.asarray(want), rtol=2e-4, atol=2e-4
        )

    def test_chunk_verify_never_materializes_full_vocab(self, served):
        """No value anywhere in the chunk_verify jaxpr (recursing into
        pjit/scan/cond sub-jaxprs) may have the (max_slots, chunk, vocab)
        shape — the fused gather-then-project epilogue must survive tracing."""
        cfg, params = served
        slots, chunk = 3, 16
        eng = Engine(params, cfg, max_slots=slots, max_len=96,
                     prefill_chunk=chunk)
        tokens = jnp.zeros((slots, chunk), jnp.int32)
        cols = jnp.zeros((slots,), jnp.int32)
        closed = jax.make_jaxpr(eng._chunk_verify)(
            eng.params, eng.cache, tokens, cols
        )
        bad = (slots, chunk, cfg.vocab)

        def eqns(jx):
            for eqn in jx.eqns:
                yield eqn
                for v in eqn.params.values():
                    for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                        sub = getattr(sub, "jaxpr", sub)
                        if hasattr(sub, "eqns"):
                            yield from eqns(sub)

        offenders = [
            str(eqn.primitive)
            for eqn in eqns(closed.jaxpr)
            for v in eqn.outvars
            if tuple(getattr(v.aval, "shape", ())) == bad
        ]
        assert not offenders, (
            f"(B, chunk, V)={bad} intermediates found: {offenders}"
        )
        # and the entry returns per-slot rows, not a logits cube
        out_shapes = [tuple(v.aval.shape) for v in closed.jaxpr.outvars]
        assert (slots, cfg.vocab) in out_shapes
