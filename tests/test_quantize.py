"""Quantization properties (absmean ternary, per-token int8, STE)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (
    act_quant_int8,
    fake_act_quant,
    fake_ternary,
    fake_ternary_cols,
    ternary_dequantize,
    ternary_quantize,
)


class TestTernaryQuantize:
    @given(st.integers(1, 8), st.integers(2, 64), st.integers(0, 2**31 - 1))
    @settings(max_examples=30, deadline=None)
    def test_values_are_ternary(self, m, k, seed):
        w = np.random.default_rng(seed).standard_normal((m, k)).astype(np.float32)
        tw = ternary_quantize(jnp.asarray(w))
        assert set(np.unique(np.asarray(tw.values))) <= {-1, 0, 1}
        assert tw.scale.shape == (m,)
        assert np.all(np.asarray(tw.scale) > 0)

    def test_reconstruction_error_bounded(self):
        w = np.random.default_rng(0).standard_normal((64, 256)).astype(np.float32)
        tw = ternary_quantize(jnp.asarray(w))
        rec = np.asarray(ternary_dequantize(tw))
        # absmean ternary: error bounded by ~scale/2 per element in the clip
        # region; global check: correlation with the source stays high
        corr = np.corrcoef(w.ravel(), rec.ravel())[0, 1]
        assert corr > 0.7

    def test_scale_invariance(self):
        """quantize(c·W) has values equal, scale scaled by c."""
        w = np.random.default_rng(1).standard_normal((8, 32)).astype(np.float32)
        t1 = ternary_quantize(jnp.asarray(w))
        t2 = ternary_quantize(jnp.asarray(3.0 * w))
        assert np.array_equal(np.asarray(t1.values), np.asarray(t2.values))
        np.testing.assert_allclose(
            np.asarray(t2.scale), 3 * np.asarray(t1.scale), rtol=1e-4
        )


class TestActQuant:
    @given(st.integers(1, 16), st.integers(1, 64), st.integers(0, 2**31 - 1))
    @settings(max_examples=30, deadline=None)
    def test_int8_range_and_error(self, k, n, seed):
        a = np.random.default_rng(seed).standard_normal((k, n)).astype(np.float32) * 5
        q = act_quant_int8(jnp.asarray(a), axis=0)
        vals = np.asarray(q.values)
        assert vals.dtype == np.int8
        assert np.abs(vals).max() <= 127
        rec = vals.astype(np.float32) * np.asarray(q.scale)
        # per-token absmax quant: error ≤ scale/2 elementwise
        assert np.all(np.abs(rec - a) <= np.asarray(q.scale) / 2 + 1e-6)


class TestSTE:
    def test_fake_ternary_gradient_is_identity(self):
        w = jnp.asarray(np.random.default_rng(0).standard_normal((6, 9)), jnp.float32)
        g = jax.grad(lambda x: jnp.sum(fake_ternary(x) * 2.0))(w)
        np.testing.assert_allclose(np.asarray(g), 2.0 * np.ones_like(w), rtol=1e-6)

    def test_fake_ternary_cols_matches_transposed(self):
        """Axis-aware variant == transpose∘fake_ternary∘transpose (the SPMD-
        friendly rewrite must not change numerics)."""
        w = jnp.asarray(np.random.default_rng(1).standard_normal((12, 7)), jnp.float32)
        a = np.asarray(fake_ternary_cols(w))
        b = np.asarray(fake_ternary(w.T).T)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)

    def test_fake_act_quant_gradient_is_identity(self):
        x = jnp.asarray(np.random.default_rng(2).standard_normal((4, 5)), jnp.float32)
        g = jax.grad(lambda x: jnp.sum(fake_act_quant(x)))(x)
        np.testing.assert_allclose(np.asarray(g), np.ones_like(x), rtol=1e-6)
