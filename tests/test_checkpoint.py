"""Checkpointer: atomic roundtrip, GC, async, custom-pytree leaves, elastic."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.core import pack_weight, ternary_quantize
from repro.optim import AdamWConfig, adamw_init


def _state(rng):
    w = rng.standard_normal((8, 20)).astype(np.float32)
    tw = ternary_quantize(jnp.asarray(w))
    return {
        "params": {"w": jnp.asarray(w), "pw": pack_weight(tw.values, tw.scale)},
        "opt": adamw_init({"w": jnp.asarray(w)}, AdamWConfig(int8_state=True)),
        "count": jnp.asarray(3),
    }


def _trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


class TestRoundtrip:
    def test_save_restore(self, tmp_path, rng):
        ck = Checkpointer(str(tmp_path))
        state = _state(rng)
        ck.save(7, state, extra={"data": {"step": 7, "seed": 1}})
        abstract = jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), state)
        restored, extra = ck.restore(abstract)
        assert _trees_equal(state, restored)
        assert extra["data"]["step"] == 7

    def test_async_save(self, tmp_path, rng):
        ck = Checkpointer(str(tmp_path))
        state = _state(rng)
        ck.save(1, state, blocking=False)
        ck.wait()
        assert ck.latest_step() == 1

    def test_incomplete_checkpoint_ignored(self, tmp_path, rng):
        ck = Checkpointer(str(tmp_path))
        state = _state(rng)
        ck.save(1, state)
        # fake a torn write: step_2 without COMMIT
        os.makedirs(tmp_path / "step_2")
        (tmp_path / "step_2" / "manifest.json").write_text("{}")
        assert ck.latest_step() == 1

    def test_gc_keeps_last_k(self, tmp_path, rng):
        ck = Checkpointer(str(tmp_path), keep=2)
        state = _state(rng)
        for s in (1, 2, 3, 4):
            ck.save(s, state)
        assert ck.all_steps() == [3, 4]

    def test_shape_mismatch_raises(self, tmp_path, rng):
        ck = Checkpointer(str(tmp_path))
        state = {"w": jnp.ones((4, 4))}
        ck.save(1, state)
        bad = {"w": jax.ShapeDtypeStruct((5, 4), jnp.float32)}
        with pytest.raises(ValueError):
            ck.restore(bad)

    def test_elastic_restore_with_shardings(self, tmp_path, rng):
        """Restore onto explicit (single-device) NamedShardings — the elastic
        path: checkpoint bytes are mesh-agnostic, placement is the caller's."""
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

        ck = Checkpointer(str(tmp_path))
        state = {"w": jnp.arange(16.0).reshape(4, 4)}
        ck.save(1, state)
        mesh = Mesh(np.array(jax.devices()[:1]), ("x",))
        sh = {"w": NamedSharding(mesh, P())}
        abstract = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
        restored, _ = ck.restore(abstract, shardings=sh)
        assert restored["w"].sharding == sh["w"]
        assert _trees_equal(state, restored)
