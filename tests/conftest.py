"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see 1 real device;
multi-device sharding tests spawn subprocesses with their own flags."""
import pathlib
import sys

# `python -m pytest` from the repo root must find the src layout without a
# manually exported PYTHONPATH (subprocess tests still set PYTHONPATH=src
# explicitly for their children).
_SRC = str(pathlib.Path(__file__).resolve().parent.parent / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:  # property tests prefer the real package when present
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - env-dependent
    import _hypothesis_stub

    _hypothesis_stub.install(sys.modules)

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_addoption(parser):
    parser.addoption(
        "--sanitize", action="store_true", default=False,
        help="run under JAX strict modes: rank-promotion=raise, strict "
             "dtype promotion, debug_nans (also: REPRO_SANITIZE=1)",
    )


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
    import os

    if config.getoption("--sanitize") or os.environ.get(
        "REPRO_SANITIZE", "0"
    ) not in ("", "0", "false"):
        # must run before any jit traces: conftest imports precede tests
        from repro.lint.sanitize import enable_sanitizers

        enable_sanitizers()
        config._repro_sanitized = True


def pytest_report_header(config):
    if getattr(config, "_repro_sanitized", False):
        return ["repro sanitizer mode: rank_promotion=raise, "
                "dtype_promotion=strict, debug_nans=on"]
    return []
