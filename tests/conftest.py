"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see 1 real device;
multi-device sharding tests spawn subprocesses with their own flags."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
