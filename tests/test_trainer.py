"""Trainer integration: learning, resume, preemption, stragglers, restarts."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataConfig
from repro.dist.fault_tolerance import StragglerMonitor, run_with_restarts
from repro.optim import AdamWConfig
from repro.train import TrainConfig, Trainer


def _mk_trainer(tmp_path, steps=40, **kw):
    cfg = get_config("smollm-360m", smoke=True).with_(loss_chunk=64)
    tc = TrainConfig(
        total_steps=steps, checkpoint_every=20, log_every=10,
        checkpoint_dir=str(tmp_path), **kw,
    )
    opt = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=steps)
    dc = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4)
    return Trainer(cfg, opt, tc, dc)


@pytest.mark.slow
class TestTraining:
    def test_loss_decreases(self, tmp_path):
        tr = _mk_trainer(tmp_path / "a", steps=40)
        log = tr.run()
        assert log[-1]["loss"] < log[0]["loss"]
        assert all(np.isfinite(r["loss"]) for r in log)

    def test_resume_from_checkpoint(self, tmp_path):
        tr = _mk_trainer(tmp_path / "b", steps=20)
        tr.run()
        # second trainer picks up at step 20 and continues to 40
        cfg = get_config("smollm-360m", smoke=True).with_(loss_chunk=64)
        tc = TrainConfig(total_steps=40, checkpoint_every=20, log_every=10,
                         checkpoint_dir=str(tmp_path / "b"))
        tr2 = Trainer(cfg, AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=40),
                      tc, DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4))
        assert tr2.step == 20
        tr2.run()
        assert tr2.step == 40

    def test_preemption_checkpoints_and_exits(self, tmp_path):
        tr = _mk_trainer(tmp_path / "c", steps=1000)
        orig_step = tr._step

        def step_and_preempt(state, batch):
            out = orig_step(state, batch)
            if tr.step >= 4:
                tr.guard.requested = True
            return out

        tr._step = step_and_preempt
        tr.run()
        assert tr.step < 1000
        assert tr.ckpt.latest_step() == tr.step  # saved on the way out

    def test_microbatch_accumulation(self, tmp_path):
        tr = _mk_trainer(tmp_path / "d", steps=3, microbatches=2)
        log = tr.run()
        assert np.isfinite(log[-1]["loss"])

    def test_grad_compression_trains(self, tmp_path):
        tr = _mk_trainer(tmp_path / "e", steps=30, grad_compression=True)
        log = tr.run()
        assert log[-1]["loss"] < log[0]["loss"] + 0.05


class TestFaultTolerance:
    def test_straggler_monitor_flags_slow_host(self):
        events = []
        mon = StragglerMonitor(n_hosts=4, threshold=1.5, patience=2,
                               on_straggler=events.append)
        for step in range(10):
            times = [0.1, 0.1, 0.1, 0.5]  # host 3 consistently 5× slower
            mon.record(step, times)
        assert events and all(e.host == 3 for e in events)

    def test_straggler_monitor_ignores_uniform(self):
        mon = StragglerMonitor(n_hosts=4)
        for step in range(10):
            mon.record(step, [0.1, 0.11, 0.09, 0.1])
        assert not mon.events

    def test_run_with_restarts_retries(self):
        calls = []

        def fn(attempt):
            calls.append(attempt)
            if attempt < 2:
                raise RuntimeError("node lost")

        used = run_with_restarts(fn, max_restarts=3, sleep=lambda s: None)
        assert used == 2 and calls == [0, 1, 2]

    def test_run_with_restarts_gives_up(self):
        def fn(attempt):
            raise RuntimeError("permanent")

        with pytest.raises(RuntimeError):
            run_with_restarts(fn, max_restarts=2, sleep=lambda s: None)

    def test_non_retryable_propagates(self):
        def fn(attempt):
            raise ValueError("bug, not a fault")

        with pytest.raises(ValueError):
            run_with_restarts(fn, max_restarts=5, sleep=lambda s: None)
