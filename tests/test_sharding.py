"""Sharding rules (in-process, 1 device) + multi-device dry-run subprocess."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.dist.sharding import cache_spec, opt_spec, param_spec
from repro.launch.mesh import make_production_mesh


class FakeMesh:
    """Shape-only stand-in (no devices needed for rule unit tests)."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


MESH = FakeMesh(pod=2, data=16, model=16)


def _spec_for(tree_path_names, shape):
    class E:
        def __init__(self, k):
            self.key = k

    path = tuple(E(n) for n in tree_path_names)
    leaf = type("L", (), {"shape": shape})()
    cfg = get_config("smollm-360m")
    return param_spec(path, leaf, MESH, cfg)


class TestParamRules:
    def test_column_parallel(self):
        sp = _spec_for(["stages", "[0]", "b0", "mixer", "wq", "qw"], (32, 8192, 4096))
        assert sp == P(None, ("pod", "data"), "model")

    def test_row_parallel(self):
        sp = _spec_for(["stages", "[0]", "b0", "mixer", "wo", "qw"], (32, 4096, 8192))
        assert sp == P(None, "model", ("pod", "data"))

    def test_nondivisible_falls_back(self):
        # smollm wq M = 15*64 = 960: divisible by 16 but K=960 by 32 ✓;
        # a 15-dim axis must never be sharded over 16
        sp = _spec_for(["stages", "[0]", "b0", "mixer", "wq", "qw"], (32, 960, 15))
        assert sp[2] is None

    def test_experts_get_model_axis(self):
        sp = _spec_for(
            ["stages", "[0]", "b0", "ffn", "experts", "w1", "qw"],
            (58, 256, 7168, 2048),
        )
        assert sp == P(None, "model", ("pod", "data"), None)

    def test_embed_vocab_sharded(self):
        sp = _spec_for(["embed", "table"], (202048, 5120))
        assert sp == P("model", ("pod", "data"))

    def test_odd_vocab_not_sharded(self):
        sp = _spec_for(["embed", "table"], (51865, 1024))
        assert sp[0] is None

    def test_packed_weights(self):
        sp = _spec_for(
            ["stages", "[0]", "b0", "mixer", "wq", "pw", "packed5"], (32, 4096, 1024)
        )
        assert sp == P(None, "model", ("pod", "data"))
        sp = _spec_for(
            ["stages", "[0]", "b0", "mixer", "wo", "pw", "packed4"], (32, 4096, 2048)
        )
        assert sp == P(None, None, "model")

    def test_norm_replicated(self):
        sp = _spec_for(["stages", "[0]", "b0", "mixer_norm", "scale"], (32, 4096))
        assert sp == P(None, None)


class TestOptRules:
    def test_qtensor_q_inherits_param_spec(self):
        ga = jax.tree_util.GetAttrKey

        class E:
            def __init__(self, k):
                self.key = k

        path = tuple(
            [E(n) for n in ["m", "stages", "[0]", "b0", "mixer", "wq", "qw"]]
        ) + (ga("q"),)
        leaf = type("L", (), {"shape": (32, 8192, 4096)})()
        cfg = get_config("smollm-360m")
        assert opt_spec(path, leaf, MESH, cfg) == P(None, ("pod", "data"), "model")
        # scale drops the last dim's axis
        leaf2 = type("L", (), {"shape": (32, 8192)})()
        path2 = path[:-1] + (ga("scale"),)
        assert opt_spec(path2, leaf2, MESH, cfg) == P(None, ("pod", "data"))


class TestCacheRules:
    def _cspec(self, names, shape):
        class E:
            def __init__(self, k):
                self.key = k

        leaf = type("L", (), {"shape": shape})()
        return cache_spec(tuple(E(n) for n in names), leaf, MESH, get_config("smollm-360m"))

    def test_batched_decode_cache(self):
        sp = self._cspec(["[0]", "b0", "k"], (32, 128, 32768, 8, 128))
        assert sp[1] == ("pod", "data")

    def test_long_context_seq_parallel(self):
        sp = self._cspec(["[0]", "b0", "k"], (32, 1, 524288, 8, 128))
        assert sp[1] is None and sp[2] == "data"  # SP over sequence

    def test_ssm_state(self):
        sp = self._cspec(["[0]", "b0", "state"], (48, 128, 64, 64, 128))
        assert sp[2] == "model"


@pytest.mark.slow
class TestMultiDeviceDryRun:
    """8 fake devices in a subprocess: real lower+compile of representative
    cells on a small mesh (the production 512-dev sweep runs out-of-band)."""

    @pytest.mark.parametrize(
        "arch,shape",
        [
            ("smollm-360m", "train_4k"),
            ("gemma3-1b", "decode_32k"),
            ("mamba2-1.3b", "long_500k"),
        ],
    )
    def test_cell_compiles(self, arch, shape):
        env = dict(
            os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=8",
            PYTHONPATH="src",
        )
        res = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", arch, "--shape", shape, "--small-mesh"],
            env=env, capture_output=True, text=True, timeout=900,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
        assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
        assert "[OK]" in res.stdout


def test_production_mesh_shapes():
    """Constructible only when ≥512 devices exist — assert the geometry from
    the spec without touching device state (function introspection)."""
    import inspect

    src = inspect.getsource(make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '"pod", "data", "model"' in src
