"""repro.lint: one positive (flagged) + one negative (clean) fixture per
rule, the suppression-verification contract, report plumbing, and the
sanitizer/compile-guard runtime pieces that don't need a model."""
import json
import subprocess
import sys
import textwrap

import pytest

from repro.lint import (
    CompileGuard,
    lint_source,
    registered_rules,
    report_json,
)


def findings(src, path="x.py", select=None):
    return lint_source(textwrap.dedent(src), path, select=select)


def rules_of(fs):
    return [f.rule for f in fs]


class TestRegistry:
    def test_all_rules_registered(self):
        assert set(registered_rules()) == {"R1", "R2", "R3", "R4", "R5"}


# --------------------------------------------------------------------------
# R1 — cache scatter modes
# --------------------------------------------------------------------------
class TestR1Scatter:
    def test_flags_pr5_past_the_end_scatter(self):
        # the PR 5 bug, verbatim shape: a verify step's multi-token write
        # whose mask-padded tail positions run past max_len; without mode=
        # the scatter CLAMPS them onto the last valid entry, corrupting the
        # newest real K/V — rollback is idx-only and cannot undo it
        fs = findings(
            """
            def write(cache, bidx, positions, k):
                ck = cache["k"].at[bidx, positions].set(k)
                return ck
            """
        )
        assert rules_of(fs) == ["R1"]
        assert "clamp" in fs[0].message

    def test_explicit_mode_is_clean(self):
        fs = findings(
            """
            def write(cache, bidx, positions, k):
                return cache["k"].at[bidx, positions].set(k, mode="drop")
            """
        )
        assert fs == []

    def test_non_cache_target_is_clean(self):
        fs = findings(
            """
            def mask(logits, j):
                keep = logits.at[j].set(0.0)
                return keep
            """
        )
        assert fs == []

    def test_dynamic_update_slice_on_cache_needs_justification(self):
        fs = findings(
            """
            import jax
            def scat(full_cache, one, slot):
                return jax.lax.dynamic_update_slice_in_dim(
                    full_cache, one, slot, axis=1
                )
            """
        )
        assert rules_of(fs) == ["R1"]

    def test_add_scatter_also_flagged(self):
        fs = findings("y = kv_buf.at[i].add(x)\n")
        assert rules_of(fs) == ["R1"]


# --------------------------------------------------------------------------
# R2 — recompile hazards
# --------------------------------------------------------------------------
class TestR2Recompile:
    def test_flags_jit_in_loop(self):
        fs = findings(
            """
            import jax
            for s in (8, 16):
                fn = jax.jit(lambda x: x * s)
                fn(1.0)
            """
        )
        assert "R2" in rules_of(fs)

    def test_flags_throwaway_jit_wrapper(self):
        fs = findings("import jax\nout = jax.jit(lambda x: x + 1)(3.0)\n")
        assert rules_of(fs) == ["R2"]

    def test_hoisted_wrapper_is_clean(self):
        fs = findings(
            """
            import jax
            fn = jax.jit(lambda x: x + 1)
            for _ in range(3):
                out = fn(3.0)
            """
        )
        assert fs == []

    def test_aot_lower_chain_exempt(self):
        fs = findings(
            "import jax\nlowered = jax.jit(lambda x: x).lower(1.0)\n"
        )
        assert fs == []

    def test_flags_traced_value_branch_in_jit(self):
        fs = findings(
            """
            import jax
            @jax.jit
            def step(x, n):
                if n > 0:
                    return x + n
                return x
            """
        )
        assert rules_of(fs) == ["R2"]
        assert "step" in fs[0].message

    def test_static_shape_branch_is_clean(self):
        fs = findings(
            """
            import jax
            @jax.jit
            def step(x, cache):
                if x.shape[0] > 1 and cache is not None and len(x.shape) == 2:
                    return x * 2
                return x
            """
        )
        assert fs == []

    def test_metadata_attribute_branch_is_clean(self):
        # pytree params carry static fields as attributes (pw.M, spec.k)
        fs = findings(
            """
            import jax
            @jax.jit
            def gemm(pw, a):
                scale = pw.scale if pw.scale.shape[-1] == pw.M else pw.scale.T
                return a * scale
            """
        )
        assert fs == []

    def test_static_declared_arg_branch_is_clean(self):
        fs = findings(
            """
            import functools, jax
            @functools.partial(jax.jit, static_argnames=("k",))
            def step(x, k):
                if k > 2:
                    return x[:k]
                return x
            """
        )
        assert fs == []

    def test_flags_unhashable_static_literal(self):
        fs = findings(
            """
            import functools, jax
            @functools.partial(jax.jit, static_argnames=("dims",))
            def f(x, dims):
                return x
            y = f(1.0, dims=[1, 2])
            """
        )
        assert rules_of(fs) == ["R2"]
        assert "tuple" in fs[0].message


# --------------------------------------------------------------------------
# R3 — host syncs on the serving hot path
# --------------------------------------------------------------------------
class TestR3HostSync:
    PATH = "src/repro/serve/engine.py"   # rule is path-scoped

    def test_flags_item_in_tick_loop(self):
        fs = findings(
            """
            def tick(self, logits):
                for slot in range(8):
                    t = logits[slot].item()
            """,
            path=self.PATH,
        )
        assert rules_of(fs) == ["R3"]

    def test_flags_per_element_int_of_device_value(self):
        fs = findings(
            """
            def tick(self, device_out):
                for slot in range(8):
                    tok = int(device_out[slot])
            """,
            path=self.PATH,
        )
        assert rules_of(fs) == ["R3"]

    def test_batched_asarray_then_index_is_clean(self):
        # the idiom the rule pushes toward: one host transfer, host indexing
        fs = findings(
            """
            import numpy as np
            def tick(self, device_out):
                nxt = np.asarray(device_out)
                for slot in range(8):
                    tok = int(nxt[slot])
                    more = [int(t) for t in nxt]
            """,
            path=self.PATH,
        )
        assert fs == []

    def test_other_modules_not_in_scope(self):
        fs = findings(
            """
            def tick(self, logits):
                for slot in range(8):
                    t = logits[slot].item()
            """,
            path="src/repro/models/decoder.py",
        )
        assert fs == []

    def test_block_until_ready_outside_loop_is_clean(self):
        fs = findings(
            """
            import jax
            def run(self):
                jax.block_until_ready(self.cache)
            """,
            path=self.PATH,
        )
        assert fs == []


# --------------------------------------------------------------------------
# R4 — time.time
# --------------------------------------------------------------------------
class TestR4Timing:
    def test_flags_time_time(self):
        fs = findings("import time\nt0 = time.time()\n")
        assert rules_of(fs) == ["R4"]

    def test_flags_from_time_import_time(self):
        fs = findings("from time import time\n")
        assert rules_of(fs) == ["R4"]

    def test_perf_counter_is_clean(self):
        fs = findings("import time\nt0 = time.perf_counter()\n")
        assert fs == []


# --------------------------------------------------------------------------
# R5 — pallas_call geometry
# --------------------------------------------------------------------------
class TestR5Pallas:
    def test_flags_index_map_arity_mismatch(self):
        fs = findings(
            """
            import jax.experimental.pallas as pl
            def launch(kernel, w, bm, bn):
                return pl.pallas_call(
                    kernel,
                    grid=(4, 4, 2),
                    in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
                    out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
                    out_shape=None,
                )(w)
            """
        )
        assert rules_of(fs) == ["R5"]
        assert "2 grid indices" in fs[0].message

    def test_flags_index_map_rank_mismatch(self):
        fs = findings(
            """
            import jax.experimental.pallas as pl
            def launch(kernel, w, bm, bn):
                return pl.pallas_call(
                    kernel,
                    grid=(4, 4),
                    in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j, 0))],
                    out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
                    out_shape=None,
                )(w)
            """
        )
        assert rules_of(fs) == ["R5"]
        assert "rank 2" in fs[0].message

    def test_flags_non_affine_index_expr(self):
        fs = findings(
            """
            import jax.experimental.pallas as pl
            def launch(kernel, w, table, bm):
                return pl.pallas_call(
                    kernel,
                    grid=(4,),
                    in_specs=[pl.BlockSpec((bm,), lambda i: (table[i],))],
                    out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
                    out_shape=None,
                )(w)
            """
        )
        assert rules_of(fs) == ["R5"]
        assert "affine" in fs[0].message

    def test_flags_operand_count_mismatch(self):
        fs = findings(
            """
            import jax.experimental.pallas as pl
            def launch(kernel, w, a, bm):
                return pl.pallas_call(
                    kernel,
                    grid=(4,),
                    in_specs=[pl.BlockSpec((bm,), lambda i: (i,))],
                    out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
                    out_shape=None,
                )(w, a)
            """
        )
        assert rules_of(fs) == ["R5"]
        assert "operand" in fs[0].message

    def test_flags_undercovering_literal_grid(self):
        fs = findings(
            """
            import jax
            import jax.numpy as jnp
            import jax.experimental.pallas as pl
            def launch(kernel, w):
                return pl.pallas_call(
                    kernel,
                    grid=(3,),
                    in_specs=[pl.BlockSpec((128,), lambda i: (i,))],
                    out_specs=pl.BlockSpec((128,), lambda i: (i,)),
                    out_shape=jax.ShapeDtypeStruct((512,), jnp.float32),
                )(w)
            """
        )
        assert rules_of(fs) == ["R5"]
        assert "never" in fs[0].message

    def test_default_capture_and_floordiv_are_clean(self):
        # flash_attention idiom: GQA head-group map with default-arg capture
        fs = findings(
            """
            import jax.experimental.pallas as pl
            def launch(kernel, q, k, g, bq, bk, d):
                return pl.pallas_call(
                    kernel,
                    grid=(2, 8, 4, 4),
                    in_specs=[
                        pl.BlockSpec(
                            (1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)
                        ),
                        pl.BlockSpec(
                            (1, 1, bk, d),
                            lambda b, h, i, j, g=g: (b, h // g, j, 0),
                        ),
                    ],
                    out_specs=pl.BlockSpec(
                        (1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)
                    ),
                    out_shape=None,
                )(q, k)
            """
        )
        assert fs == []

    def test_vmem_budget_uses_autotune_math(self):
        # a "lookup" entry point whose default tile blows the streamed-table
        # budget: 3^4 * bkg * bn * 2 alone exceeds 4 MiB at bkg=256, bn=512
        fs = findings(
            """
            import jax.experimental.pallas as pl
            def vlut_lookup_entry(kernel, w, *, bm=128, bn=512, bkg=256):
                return pl.pallas_call(
                    kernel,
                    grid=(4, 4, 4),
                    in_specs=[
                        pl.BlockSpec((bm, bkg), lambda i, j, k: (i, k))
                    ],
                    out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
                    out_shape=None,
                )(w)
            """
        )
        assert rules_of(fs) == ["R5"]
        assert "VMEM" in fs[0].message and "autotune" in fs[0].message

    def test_repo_default_tiles_fit_budget(self):
        from repro.kernels.autotune import VMEM_BUDGET_BYTES, tile_vmem_bytes

        # the real entry-point defaults R5 validates in-tree
        assert tile_vmem_bytes(4, "lookup", 128, 128, 32, fused=True) \
            <= VMEM_BUDGET_BYTES
        assert tile_vmem_bytes(4, "decode", 128, 256, 128, fused=True) \
            <= VMEM_BUDGET_BYTES


# --------------------------------------------------------------------------
# suppressions (R0)
# --------------------------------------------------------------------------
class TestSuppressions:
    SRC_FLAGGED = "y = kv_cache.at[i].set(x)\n"

    def test_justified_suppression_silences(self):
        fs = findings(
            "y = kv_cache.at[i].set(x)  "
            "# lint: disable=R1 -- i is bounded by construction\n"
        )
        assert fs == []

    def test_standalone_comment_covers_next_line(self):
        fs = findings(
            "# lint: disable=R1 -- i is bounded by construction\n"
            "y = kv_cache.at[i].set(x)\n"
        )
        assert fs == []

    def test_missing_justification_is_R0_and_does_not_suppress(self):
        fs = findings(
            "y = kv_cache.at[i].set(x)  # lint: disable=R1 -- ok\n"
        )
        assert sorted(rules_of(fs)) == ["R0", "R1"]

    def test_malformed_suppression_is_R0(self):
        fs = findings(
            "y = kv_cache.at[i].set(x)  # lint: disable=R1\n"
        )
        assert sorted(rules_of(fs)) == ["R0", "R1"]

    def test_wrong_rule_does_not_suppress(self):
        fs = findings(
            "y = kv_cache.at[i].set(x)  "
            "# lint: disable=R4 -- wrong rule named here\n"
        )
        assert rules_of(fs) == ["R1"]

    def test_multi_rule_suppression(self):
        fs = findings(
            "import time\n"
            "t = time.time()  # lint: disable=R4, R1 -- display timestamp only\n"
        )
        assert fs == []


# --------------------------------------------------------------------------
# report + CLI
# --------------------------------------------------------------------------
class TestReport:
    def test_json_report_shape(self):
        fs = findings("import time\nt0 = time.time()\n")
        rep = report_json(fs, files_scanned=1)
        assert rep["version"] == 1
        assert rep["counts"] == {"R4": 1}
        assert rep["findings"][0]["rule"] == "R4"
        assert set(rep["rules"]) == {"R1", "R2", "R3", "R4", "R5"}
        json.dumps(rep)   # must be serializable as-is

    def test_syntax_error_is_reported_not_raised(self):
        fs = findings("def broken(:\n")
        assert rules_of(fs) == ["E0"]

    def test_cli_clean_and_dirty_exit_codes(self, tmp_path):
        clean = tmp_path / "clean.py"
        clean.write_text("import time\nt0 = time.perf_counter()\n")
        dirty = tmp_path / "dirty.py"
        dirty.write_text("import time\nt0 = time.time()\n")
        report = tmp_path / "report.json"

        ok = subprocess.run(
            [sys.executable, "-m", "repro.lint", str(clean)],
            capture_output=True, text=True,
        )
        assert ok.returncode == 0, ok.stdout + ok.stderr

        bad = subprocess.run(
            [sys.executable, "-m", "repro.lint", str(dirty),
             "--json", str(report)],
            capture_output=True, text=True,
        )
        assert bad.returncode == 1
        assert "R4" in bad.stdout
        payload = json.loads(report.read_text())
        assert payload["counts"] == {"R4": 1}

    def test_repo_is_lint_clean(self):
        """The acceptance gate, as a test: the tree must stay lint-clean."""
        from repro.lint import lint_paths

        import pathlib

        root = pathlib.Path(__file__).resolve().parent.parent
        fs, n = lint_paths(
            [str(root / "src"), str(root / "tests"), str(root / "benchmarks")]
        )
        assert n > 0
        assert fs == [], "\n".join(f.format() for f in fs)


# --------------------------------------------------------------------------
# runtime sanitizers + compile guard (model-free)
# --------------------------------------------------------------------------
class TestSanitizeRuntime:
    def test_enable_restore_roundtrip(self):
        import jax

        from repro.lint import enable_sanitizers, restore_sanitizers

        prev = enable_sanitizers(debug_nans=False)
        try:
            assert jax.config.jax_numpy_rank_promotion == "raise"
            assert jax.config.jax_numpy_dtype_promotion == "strict"
            import jax.numpy as jnp

            with pytest.raises(ValueError):
                # (3,) + (2, 3) silent rank promotion must now raise
                jnp.ones((3,)) + jnp.ones((2, 3))
        finally:
            restore_sanitizers(prev)
        assert jax.config.jax_numpy_rank_promotion == prev[
            "jax_numpy_rank_promotion"
        ]

    def test_compile_guard_detects_recompiles(self):
        import jax
        import jax.numpy as jnp

        fn = jax.jit(lambda x: x * 2)
        fn(jnp.ones((2,)))
        guard = CompileGuard({"fn": fn})
        guard.arm()
        fn(jnp.ones((2,)))          # cache hit: steady
        guard.assert_steady()
        fn(jnp.ones((3,)))          # new shape: one miss
        with pytest.raises(AssertionError, match="fn"):
            guard.assert_steady()
        assert guard.new_compiles() == {"fn": 1}

    def test_compile_guard_opaque_callable_is_tracked_as_zero(self):
        guard = CompileGuard({"plain": lambda x: x})
        guard.arm()
        guard.assert_steady()
