"""Optimizer: AdamW reference equivalence, int8-state error bounds, schedule."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    AdamWConfig,
    QTensor,
    adamw_init,
    adamw_update,
    dequantize_blockwise,
    global_norm,
    lr_at,
    quantize_blockwise,
)


def _ref_adamw(params, grads, m, v, step, cfg):
    """Plain fp32 AdamW (no clip for clarity — grads pre-scaled)."""
    out_p, out_m, out_v = {}, {}, {}
    b1c = 1 - cfg.b1**step
    b2c = 1 - cfg.b2**step
    for k in params:
        g = grads[k]
        out_m[k] = cfg.b1 * m[k] + (1 - cfg.b1) * g
        out_v[k] = cfg.b2 * v[k] + (1 - cfg.b2) * g * g
        mh, vh = out_m[k] / b1c, out_v[k] / b2c
        delta = mh / (np.sqrt(vh) + cfg.eps)
        if params[k].ndim >= 2:
            delta = delta + cfg.weight_decay * params[k]
        out_p[k] = params[k] - lr_at_np(cfg, step) * delta
    return out_p, out_m, out_v


def lr_at_np(cfg, step):
    return float(lr_at(cfg, jnp.asarray(step)))


class TestAdamW:
    def test_matches_reference_fp32(self, rng):
        cfg = AdamWConfig(lr=1e-2, int8_state=False, grad_clip=1e9,
                          warmup_steps=1, total_steps=10**9)
        params = {"a": rng.standard_normal((8, 16)).astype(np.float32),
                  "b": rng.standard_normal((32,)).astype(np.float32)}
        grads = {k: rng.standard_normal(v.shape).astype(np.float32) * 0.1
                 for k, v in params.items()}
        jp = jax.tree.map(jnp.asarray, params)
        jg = jax.tree.map(jnp.asarray, grads)
        opt = adamw_init(jp, cfg)
        new_p, new_opt, metrics = adamw_update(jp, jg, opt, cfg)
        ref_p, _, _ = _ref_adamw(
            params, grads,
            {k: np.zeros_like(v) for k, v in params.items()},
            {k: np.zeros_like(v) for k, v in params.items()}, 1, cfg)
        for k in params:
            np.testing.assert_allclose(np.asarray(new_p[k]), ref_p[k],
                                       rtol=1e-4, atol=1e-5)

    def test_grad_clip(self, rng):
        cfg = AdamWConfig(grad_clip=1.0, int8_state=False)
        params = {"a": jnp.zeros((4, 4))}
        grads = {"a": jnp.full((4, 4), 100.0)}
        _, _, metrics = adamw_update(params, grads, adamw_init(params, cfg), cfg)
        assert float(metrics["grad_norm"]) == pytest.approx(400.0)

    def test_int8_state_update_error_small(self, rng):
        """One step with int8 m / bf16 v must track fp32 closely."""
        big = rng.standard_normal((64, 128)).astype(np.float32)
        g = rng.standard_normal((64, 128)).astype(np.float32) * 0.01
        p = {"w": jnp.asarray(big)}
        gt = {"w": jnp.asarray(g)}
        outs = {}
        for int8 in (False, True):
            cfg = AdamWConfig(lr=1e-2, int8_state=int8, grad_clip=1e9)
            st = adamw_init(p, cfg)
            newp = p
            for _ in range(5):
                newp, st, _ = adamw_update(newp, gt, st, cfg)
            outs[int8] = np.asarray(newp["w"])
        err = np.abs(outs[True] - outs[False]).max()
        scale = np.abs(outs[False] - big).max()  # total movement
        # int8-m / bf16-v must track fp32 within half the step magnitude and
        # agree on update direction (convergence itself is asserted end-to-end
        # in test_trainer.py::test_grad_compression_trains)
        assert err < 0.5 * scale + 1e-6
        d_true = outs[False] - big
        d_q = outs[True] - big
        agree = np.sign(d_true[np.abs(d_true) > 1e-5]) == np.sign(
            d_q[np.abs(d_true) > 1e-5]
        )
        assert agree.mean() > 0.95

    def test_schedule_shape(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
        assert lr_at_np(cfg, 0) == 0.0
        assert lr_at_np(cfg, 10) == pytest.approx(1.0)
        assert lr_at_np(cfg, 100) == pytest.approx(0.1, rel=1e-3)
        assert lr_at_np(cfg, 55) < lr_at_np(cfg, 11)


class TestQTensor:
    def test_roundtrip_error_bound(self, rng):
        x = rng.standard_normal((16, 2048)).astype(np.float32)
        q = quantize_blockwise(jnp.asarray(x))
        back = np.asarray(dequantize_blockwise(q))
        rowmax = np.abs(x).max(axis=-1, keepdims=True)
        assert np.all(np.abs(back - x) <= rowmax / 127 + 1e-7)

    def test_is_pytree_with_static_shape(self):
        q = quantize_blockwise(jnp.ones((4, 8)))
        leaves = jax.tree.leaves(q)
        assert len(leaves) == 2  # q, scale — shape tuple must NOT leak
        dequant = jax.jit(dequantize_blockwise)
        out = dequant(q)
        assert out.shape == (4, 8)

    def test_global_norm(self):
        t = {"a": jnp.ones((3,)) * 2.0, "b": jnp.ones((4,)) * 1.0}
        assert float(global_norm(t)) == pytest.approx(np.sqrt(12 + 4))
