"""Minimal deterministic stand-in for `hypothesis` (not installed in every
environment this repo runs in; installing deps is not always possible).

Implements exactly the surface the test-suite uses — ``given``, ``settings``,
``strategies.integers`` and ``strategies.sampled_from`` — by running each
property test over `max_examples` pseudo-random draws from a fixed seed.
No shrinking, no database; failures report the drawn example in the assert
traceback. conftest.py installs this into ``sys.modules`` only when the real
package is unavailable.
"""
from __future__ import annotations

import inspect
import random
import types


class _Strategy:
    def __init__(self, sample):
        self.sample = sample

    def filter(self, pred):
        def draw(rng):
            for _ in range(10_000):
                v = self.sample(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate too strict")

        return _Strategy(draw)

    def map(self, fn):
        return _Strategy(lambda rng: fn(self.sample(rng)))


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(seq) -> _Strategy:
    seq = list(seq)
    return _Strategy(lambda rng: rng.choice(seq))


def settings(**kwargs):
    def deco(fn):
        fn._stub_settings = kwargs
        return fn

    return deco


def given(*strats):
    def deco(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        lead = params[: len(params) - len(strats)]

        def runner(*args):
            n = getattr(fn, "_stub_settings", {}).get("max_examples", 20)
            rng = random.Random(0xC0FFEE)
            for _ in range(n):
                drawn = [s.sample(rng) for s in strats]
                fn(*args, *drawn)

        # Expose only the non-drawn parameters (e.g. `self`) so pytest does
        # not try to resolve the strategy args as fixtures.
        runner.__signature__ = sig.replace(parameters=lead)
        runner.__name__ = fn.__name__
        runner.__qualname__ = fn.__qualname__
        runner.__doc__ = fn.__doc__
        return runner

    return deco


def install(sys_modules) -> None:
    """Register the stub as `hypothesis` / `hypothesis.strategies`."""
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.sampled_from = sampled_from
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    sys_modules["hypothesis"] = hyp
    sys_modules["hypothesis.strategies"] = st
