"""End-to-end system behaviour: train → convert → serve (the paper's full
lifecycle: QAT ternary training, offline packing, Vec-LUT-served continuous
batching)."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataConfig
from repro.models import pack_params, packed_param_bytes
from repro.optim import AdamWConfig
from repro.serve import ContinuousBatchingScheduler, Engine, Request
from repro.train import TrainConfig, Trainer


@pytest.mark.slow
def test_train_pack_serve_lifecycle(tmp_path):
    cfg = get_config("smollm-360m", smoke=True).with_(loss_chunk=64)
    tc = TrainConfig(total_steps=30, checkpoint_every=15, log_every=10,
                     checkpoint_dir=str(tmp_path))
    opt = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=30)
    dc = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4)
    trainer = Trainer(cfg, opt, tc, dc)
    log = trainer.run()
    assert log[-1]["loss"] < log[0]["loss"] + 0.05  # moving the right way

    # offline weight transformation (paper §3.1 stage i)
    dense_params = trainer.state["params"]
    packed = pack_params(dense_params, cfg)
    dense_bytes = packed_param_bytes(dense_params)
    packed_bytes = packed_param_bytes(packed)
    # ≤2-bit weights: big shrink vs bf16 even counting embeddings/scales
    assert packed_bytes < 0.55 * dense_bytes

    # serve with continuous batching
    eng = Engine(packed, cfg, max_slots=4, max_len=96)
    sched = ContinuousBatchingScheduler(eng)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=12).astype(np.int32),
                max_new_tokens=6)
        for i in range(6)
    ]
    sched.submit(reqs)
    stats = sched.run_to_completion()
    assert stats.completed == 6
    for r in reqs:
        assert len(r.generated) == 6
        assert all(0 <= t < cfg.vocab for t in r.generated)


def test_bpw_accounting():
    """Paper Table 3 analogue: I1=1.60, I2=2.00, mixed ≤ 2.0 bpw for the
    linears of every arch."""
    from repro.core import pack_weight, ternary_quantize

    for k, mode, want in [(960, "i1", 1.60), (960, "i2", 2.0), (133, "auto", None)]:
        w = jax.random.normal(jax.random.PRNGKey(0), (8, k))
        tw = ternary_quantize(w)
        pw = pack_weight(tw.values, tw.scale, mode)
        if want:
            assert pw.bits_per_weight == pytest.approx(want, abs=0.01)
        else:
            assert pw.bits_per_weight <= 2.0
