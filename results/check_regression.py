"""CI regression gate over BENCH_*.json files.

  python -m results.check_regression --baseline-dir /tmp/bench_baseline \
      --current-dir . [--threshold 0.15] [--pattern crossover/]

Compares every BENCH_*.json present in both directories row-by-row (rows are
matched by ``name``):

  * timing rows: fail when ``us_per_call`` regresses by more than
    ``--threshold`` (relative; default 15%, the ISSUE-7 gate);
  * crossover ``.../winner`` rows: the winner *identity* is compared instead
    of its time — a flipped winner is the regression the crossover table
    exists to catch (fail under ``--strict-winners``, warn otherwise, since
    near-tied cells legitimately flip between runs).

A baseline row missing from the current run FAILS the gate, as does a
timing row whose baseline has ``us_per_call`` but whose current run does
not: a tracked metric silently vanishing is exactly how a benchmark rots
into measuring nothing. Retiring a benchmark is an explicit act — delete
the row from the committed baseline in the same change. New rows without
a baseline only warn (new benchmarks land before their baseline does).
Absolute wall
times are host-dependent — the committed baseline should come from the same
class of runner as CI (the nightly job re-commits nothing; it compares
against the checked-in file and uploads the fresh run as an artifact).

Exit status: 0 clean, 1 regression(s), 2 usage/IO error.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys


def load_rows(path: str) -> dict[str, dict]:
    with open(path) as f:
        payload = json.load(f)
    return {r["name"]: r for r in payload.get("rows", []) if "name" in r}


def compare_suite(
    base: dict[str, dict], cur: dict[str, dict], *, threshold: float,
    pattern: str, strict_winners: bool,
) -> tuple[list[str], list[str]]:
    """→ (failures, warnings) for one suite's row maps."""
    failures, warnings = [], []
    for name in sorted(base.keys() & cur.keys()):
        if pattern and pattern not in name:
            continue
        b, c = base[name], cur[name]
        if name.endswith("/winner"):
            bw, cw = b.get("winner"), c.get("winner")
            if bw and cw and bw != cw:
                msg = f"{name}: winner flipped {bw} -> {cw}"
                (failures if strict_winners else warnings).append(msg)
            continue
        b_us, c_us = b.get("us_per_call", 0), c.get("us_per_call", 0)
        if b_us <= 0:
            continue            # baseline never tracked a time for this row
        if c_us <= 0:
            failures.append(
                f"{name}: tracked metric us_per_call missing from current "
                f"run (baseline {b_us:.1f} us/call)"
            )
            continue
        rel = c_us / b_us - 1.0
        if rel > threshold:
            failures.append(
                f"{name}: {b_us:.1f} -> {c_us:.1f} us/call "
                f"(+{100 * rel:.1f}% > {100 * threshold:.0f}%)"
            )
    only_base = sorted(
        n for n in base.keys() - cur.keys() if not pattern or pattern in n
    )
    only_cur = sorted(cur.keys() - base.keys())
    for name in only_base:
        failures.append(f"{name}: baseline row missing from current run "
                        f"(retire it by deleting the baseline row)")
    if only_cur:
        warnings.append(f"{len(only_cur)} new row(s) without baseline "
                        f"(first: {only_cur[0]})")
    return failures, warnings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir", required=True,
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--current-dir", required=True,
                    help="directory holding the freshly produced BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="relative us_per_call regression that fails (0.15)")
    ap.add_argument("--pattern", default="",
                    help="only gate rows whose name contains this substring")
    ap.add_argument("--strict-winners", action="store_true",
                    help="a flipped crossover winner fails (default: warns)")
    args = ap.parse_args(argv)

    base_files = {
        os.path.basename(p): p
        for p in glob.glob(os.path.join(args.baseline_dir, "BENCH_*.json"))
    }
    cur_files = {
        os.path.basename(p): p
        for p in glob.glob(os.path.join(args.current_dir, "BENCH_*.json"))
    }
    shared = sorted(base_files.keys() & cur_files.keys())
    if not shared:
        print("check_regression: no BENCH_*.json common to both dirs",
              file=sys.stderr)
        return 2

    all_failures: list[str] = []
    for fname in shared:
        try:
            base = load_rows(base_files[fname])
            cur = load_rows(cur_files[fname])
        except (OSError, ValueError) as e:
            print(f"check_regression: cannot read {fname}: {e}",
                  file=sys.stderr)
            return 2
        failures, warnings = compare_suite(
            base, cur, threshold=args.threshold, pattern=args.pattern,
            strict_winners=args.strict_winners,
        )
        status = "FAIL" if failures else "ok"
        print(f"[{status}] {fname}: {len(base)} baseline rows, "
              f"{len(failures)} regression(s), {len(warnings)} warning(s)")
        for w in warnings:
            print(f"  warn: {w}")
        for f in failures:
            print(f"  FAIL: {f}")
        all_failures += failures
    return 1 if all_failures else 0


if __name__ == "__main__":
    sys.exit(main())
