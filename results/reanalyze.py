"""Recompute roofline fields of dry-run JSONL records from saved HLO
(results/hlo/*.zst) — no recompilation needed after analyzer changes."""
import json
import sys

import zstandard as zstd

sys.path.insert(0, "src")
from repro.configs import SHAPES, get_config            # noqa: E402
from repro.roofline.analysis import Roofline, count_params, model_flops  # noqa: E402
from repro.roofline.hlo_stats import parse_hlo_stats    # noqa: E402


def main(paths):
    for path in paths:
        rows = [json.loads(l) for l in open(path)]
        out = []
        for r in rows:
            if r.get("status") == "ok" and r.get("hlo_path"):
                hlo = zstd.ZstdDecompressor().decompress(
                    open(r["hlo_path"], "rb").read()
                ).decode()
                stats = parse_hlo_stats(hlo)
                cfg = get_config(r["arch"])
                n_total, n_active = count_params(cfg)
                rl = Roofline(
                    arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                    chips=r["chips"],
                    flops_per_device=stats.dot_flops,
                    bytes_per_device=stats.traffic_bytes,
                    coll_bytes_per_device=stats.collective_bytes,
                    coll_detail=stats.collectives,
                    model_flops_total=model_flops(
                        cfg, SHAPES[r["shape"]], n_total, n_active),
                    min_bytes_per_device=float(r.get("state_bytes_per_device", 0)),
                )
                r["roofline"] = rl.row()
            out.append(r)
        with open(path, "w") as f:
            for r in out:
                f.write(json.dumps(r) + "\n")
        print(f"reanalyzed {len(out)} records in {path}")


if __name__ == "__main__":
    main(sys.argv[1:] or ["results/dryrun_baseline.jsonl",
                          "results/dryrun_multipod.jsonl"])
