"""Render EXPERIMENTS.md placeholders from dry-run / hillclimb JSONLs."""
import json
import sys

sys.path.insert(0, "src")
from repro.configs import SHAPES, list_archs  # noqa: E402


def _fmt(v, n=4):
    return f"{v:.{n}f}"


def baseline_table(path="results/dryrun_baseline.jsonl") -> str:
    rows = {}
    for line in open(path):
        r = json.loads(line)
        rows[(r["arch"], r["shape"])] = r
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | dominant | "
           "useful | memeff | state/chip GiB | what moves the dominant term |\n")
    hdr += "|" + "---|" * 10 + "\n"
    out = [hdr]
    notes = {
        ("decode", "memory"): "in-place carry cache (§Perf E4); ≤2bpw weights already in baseline",
        ("decode", "collective"): "batch-shard KV fully; overlap decode collectives",
        ("train", "memory"): "flash-attn VMEM scores (§4.3); bigger fusion chunks",
        ("train", "collective"): "shard MoE dispatch capacity (§4.2); async FSDP gathers",
        ("train", "compute"): "drop remat refwd on cheap layers; fuse QAT quant",
        ("prefill", "memory"): "flash-attn VMEM scores (§4.3)",
        ("prefill", "collective"): "shard MoE dispatch capacity (§4.2)",
    }
    for arch in list_archs():
        for shape in SHAPES:
            r = rows.get((arch, shape))
            if r is None:
                continue
            if r["status"] == "skipped":
                out.append(f"| {arch} | {shape} | — | — | — | N/A | — | — | — | "
                           f"full-attention arch: 500k N/A (DESIGN §4) |\n")
                continue
            if r["status"] != "ok":
                out.append(f"| {arch} | {shape} | — | — | — | ERROR | — | — | — | "
                           f"{r.get('error', '')[:60]} |\n")
                continue
            rl = r["roofline"]
            kind = SHAPES[shape].kind
            note = notes.get((kind, rl["dominant"]), "")
            out.append(
                f"| {arch} | {shape} | {_fmt(rl['compute_s'])} | "
                f"{_fmt(rl['memory_s'])} | {_fmt(rl['collective_s'])} | "
                f"**{rl['dominant']}** | {_fmt(rl['useful_flops_ratio'], 3)} | "
                f"{_fmt(rl.get('memory_efficiency', 0), 3)} | "
                f"{r.get('state_bytes_per_device', 0) / 2**30:.2f} | {note} |\n"
            )
    return "".join(out)


def hillclimb_table(cell: str, path="results/perf_iterations.jsonl") -> str:
    rows = [json.loads(l) for l in open(path)]
    rows = [r for r in rows if r.get("cell") == cell]
    if not rows:
        return "(pending)\n"
    out = ["| iter | hypothesis | compute_s | memory_s | collective_s | "
           "dominant | Δ dominant |\n",
           "|" + "---|" * 7 + "\n"]
    prev_dom = None
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['label']} | {r['hypothesis'][:60]} | — | — | — | "
                       f"ERROR | {r.get('error', '')[:40]} |\n")
            continue
        rl = r["roofline"]
        dom_val = rl[rl["dominant"] + "_s"]
        delta = ""
        if prev_dom is not None and prev_dom > 0:
            delta = f"{prev_dom / dom_val:.2f}× better" if dom_val < prev_dom \
                else f"{dom_val / prev_dom:.2f}× worse"
        prev_dom = dom_val
        out.append(
            f"| {r['label']} | {r['hypothesis'][:80]} | {_fmt(rl['compute_s'])} | "
            f"{_fmt(rl['memory_s'])} | {_fmt(rl['collective_s'])} | "
            f"{rl['dominant']} ({_fmt(dom_val)}s) | {delta} |\n"
        )
    return "".join(out)


def main():
    md = open("EXPERIMENTS.md").read()
    try:
        md = md.replace("TABLE-PLACEHOLDER-BASELINE", baseline_table())
    except FileNotFoundError:
        pass
    for i, cell in enumerate(
        ["deepseek_decode", "jamba_train", "internlm2_train"], 1
    ):
        try:
            md = md.replace(f"HILLCLIMB-PLACEHOLDER-{i}", hillclimb_table(cell))
        except FileNotFoundError:
            pass
    open("EXPERIMENTS.md", "w").write(md)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
