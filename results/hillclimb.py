"""Perf hillclimb driver: re-lower one cell with config overrides, record the
hypothesis → change → before → after trail into results/perf_iterations.jsonl.

Usage:
  PYTHONPATH=src python results/hillclimb.py CELL_NAME

Cells + iteration plans are defined inline (EXPERIMENTS.md §Perf narrates
them); each entry is (label, hypothesis, overrides).
"""
import json
import sys

sys.path.insert(0, "src")
import repro.launch.dryrun as dr  # noqa: E402  (sets XLA_FLAGS first)

PLANS = {
    # 1. Most representative of the paper's technique: packed MLA serving.
    "deepseek_decode": {
        "arch": "deepseek-v3-671b", "shape": "decode_32k",
        "iters": [
            ("baseline", "paper-faithful engine (ys-form cache scan)", {}),
            ("carry_cache",
             "cache read-xs/write-ys doubles HBM traffic + copies; scan-carry "
             "in-place DUS should roughly halve the memory term",
             {"cache_in_carry": True}),
            ("carry+chunked_scale",
             "larger attn chunk (2048) reduces per-chunk overhead ops in the "
             "latent-attention stream",
             {"cache_in_carry": True, "attn_chunk": 2048}),
        ],
    },
    # 2. Most collective-bound: jamba train (MoE all-to-all + FSDP gathers).
    "jamba_train": {
        "arch": "jamba-1.5-large-398b", "shape": "train_4k",
        "iters": [
            ("baseline", "capacity dim of the (E,C,d) MoE dispatch buffer is "
             "replicated across data shards → every expert gather crosses the "
             "mesh at full width", {}),
            ("shard_capacity",
             "sharding C over ('pod','data') should turn the dispatch "
             "all-gather into an all-to-all of 1/16 the bytes",
             {"moe_shard_capacity": True}),
            ("block_dispatch",
             "capacity sharding failed because positions are GLOBAL; making "
             "positions block-LOCAL (one block per data shard) keeps the "
             "scatter/gather on-shard — only the EP exchange crosses 'model'",
             {"moe_block_dispatch": True}),
        ],
    },
    # 3. Worst memory-bound train cell: attention interiors dominate.
    "internlm2_train": {
        "arch": "internlm2-1.8b", "shape": "train_4k",
        "iters": [
            ("baseline", "chunked-attention score tensors (B,KV,G,S,c) "
             "materialize to HBM every chunk step", {}),
            ("bigger_chunks",
             "chunk 2048 quarters the number of boundary crossings per layer "
             "(same score bytes, fewer aux tensors)",
             {"attn_chunk": 2048}),
            ("loss_chunk_512",
             "CE logits chunks (B,c,V) f32 are the other big temp; smaller "
             "chunks cut peak + traffic if XLA was spilling",
             {"attn_chunk": 2048, "loss_chunk": 512}),
            ("remat_dots",
             "full remat re-runs the whole attention chunk scan in backward "
             "(~2x its HBM traffic); saving dot outputs should cut the "
             "recompute traffic at modest extra live memory",
             {"remat_policy": "dots"}),
        ],
    },
}


def main():
    names = sys.argv[1:] or list(PLANS)
    out = open("results/perf_iterations.jsonl", "a")
    for name in names:
        plan = PLANS[name]
        for label, hypothesis, ov in plan["iters"]:
            rec = dr.run_cell(plan["arch"], plan["shape"], overrides=ov)
            rec.update(cell=name, label=label, hypothesis=hypothesis)
            out.write(json.dumps(rec) + "\n")
            out.flush()
            r = rec.get("roofline", {})
            print(f"[{name}/{label}] dom={r.get('dominant')} "
                  f"terms=({r.get('compute_s', 0):.3f},{r.get('memory_s', 0):.3f},"
                  f"{r.get('collective_s', 0):.3f})s")


if __name__ == "__main__":
    main()
