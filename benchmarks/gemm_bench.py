"""mpGeMM kernel benchmark — paper Fig. 9 (+ Fig. 4 BPW comparison) and the
fused-pipeline ablation from the single-pass refactor.

Measures runs/s of the Vec-LUT mpGeMM (I1 b1.60 / I2 b2.00) against the
paper's baselines (scalar-LUT à la T-MAC, MAD int8 à la bitnet.cpp I2_S, MAD
dequant-f32 à la llama.cpp TQ) on real-model GeMM shapes across parallel
token counts N. On this CPU host the *relative* ordering reproduces the
paper's qualitative claims (vector ≥ scalar for N ≥ 8; LUT ≥ MAD at ≤2 bpw).

The ``--fusion`` ablation compares the fused single-pass pipeline against
the original multi-pass one on the backend's kernel: on TPU both arms are
the real Pallas kernels (`vlut_mpgemm(fusion=...)`); elsewhere the unfused
arm stages the pipeline as *separate dispatches* (quantize → int gemm →
dequant) with each intermediate genuinely materialized — XLA fuses
anything inside one jit (it even elides optimization_barrier), so only
real dispatch boundaries reproduce what the old pipeline paid. Two columns
per cell: paired batched wall clock (runs/s) and the exact bytes of the
intermediates the single-pass kernel eliminates (int8 activation buffer,
int32 output, and — for Pallas impls — the de-interleaved layout copy).
Rows land in BENCH_gemm.json via benchmarks.common.
"""
from __future__ import annotations

import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    mad_gemm,
    mad_gemm_int8,
    pack_weight,
    scalar_lut_gemm,
    ternary_quantize,
    vlut_gemm,
)
from repro.kernels import vlut_mpgemm
from repro.kernels.ops import on_tpu
from .common import emit, time_fn, time_paired, write_results

# (M, K) from the evaluated models: T-MAC Table 1 (BitNet 3B) + Llama3-8B
SHAPES = [
    ("bitnet3b", 320, 3200),
    ("bitnet3b", 128, 8640),
    ("llama3-8b", 1024, 4096),
    ("llama3-8b", 4096, 4096),
]
NS = [1, 8, 32, 128]
#: fusion-ablation cells: edge-scale layer GeMMs (the paper's deployment
#: regime) in the parallel-token range the fusion serves — where the
#: eliminated dispatches + intermediate passes are large relative to the
#: weight-decode compute, so the win clears shared-host timing noise.
FUSION_SHAPES = [
    ("edge-s", 160, 1280),
    ("edge-m", 512, 2048),
]
FUSION_NS = [32, 128, 256]


def _methods(pw_i1, pw_i2):
    return {
        "vlut_i1": functools.partial(vlut_gemm, pw_i1),
        "vlut_i2": functools.partial(vlut_gemm, pw_i2),
        "scalar_lut_i2": functools.partial(scalar_lut_gemm, pw_i2),
        "mad_int8_i2": functools.partial(mad_gemm_int8, pw_i2),
        "mad_f32_i2": functools.partial(mad_gemm, pw_i2),
    }


def _eliminated_bytes(m: int, k: int, n: int, impl: str) -> int:
    """Exact per-call HBM bytes of the intermediates the fused single-pass
    kernel never materializes — each written by one stage and read by the
    next (2× apiece): the (K, N) int8 activation buffer and the (M, N)
    int32 output; Pallas impls additionally drop the (K, N)-sized
    de-interleaved layout copy (the XLA stand-in never materialized one)."""
    layout = 2 * k * n if impl != "xla" else 0
    return 2 * k * n + layout + 2 * 4 * m * n


def _staged_unfused(pw, impl: str):
    """The unfused pipeline staged at its real boundaries — quantize (int8
    activation buffer), int gemm (int32 output), dequant — as *separate
    dispatches* with each intermediate genuinely materialized. On TPU the
    Pallas pallas_call boundary provides that materialization from within
    one jit (`vlut_mpgemm(fusion='unfused')`); XLA-on-CPU fuses anything
    inside one jit (it even elides optimization_barrier), so the stand-in
    must stage real dispatch boundaries. Both arms run the identical gemm
    graph (`_segment_gemm_int(impl='xla')`), so the measured delta is
    exactly what stage fusion buys on this backend."""
    from repro.core.quantize import act_quant_tokens
    from repro.kernels import ops as kops

    segs = kops._segments(pw)
    w_scale = kops._w_scale(pw)

    quant = jax.jit(act_quant_tokens)

    @jax.jit
    def gemm(a_q):
        out = None
        for packed, lo, hi, g in segs:
            part = kops._segment_gemm_int(packed, a_q[lo:hi], g, impl, False, None)
            out = part if out is None else out + part
        return out

    dequant = jax.jit(
        lambda o, s: o.astype(jnp.float32) * w_scale[:, None] * s[None, :]
    )

    def run(a):
        q, s = quant(a)
        return dequant(gemm(q), s)

    return run


def fusion_ablation(quick: bool = True, fusion: str = "both"):
    """fused vs unfused single-pass pipeline (the PR's --fusion column)."""
    shapes = FUSION_SHAPES
    ns = FUSION_NS[:2] if quick else FUSION_NS
    variants = ["fused", "unfused"] if fusion == "both" else [fusion]
    impl = "decode" if on_tpu() else "xla"
    rng = np.random.default_rng(0)
    rows = []
    for model, m, k in shapes:
        w = rng.standard_normal((m, k)).astype(np.float32)
        tw = ternary_quantize(jnp.asarray(w))
        pw = pack_weight(tw.values, tw.scale, "auto")
        unfused_run = _staged_unfused(pw, impl)
        for n in ns:
            a = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
            fns = {}
            if "fused" in variants:
                fns["fused"] = functools.partial(
                    vlut_mpgemm, pw, impl=impl, fusion="fused"
                )
            if "unfused" in variants:
                fns["unfused"] = (
                    unfused_run if impl == "xla"
                    else functools.partial(
                        vlut_mpgemm, pw, impl=impl, fusion="unfused"
                    )
                )
            secs = time_paired(fns, a, rounds=9 if quick else 13)
            saved = _eliminated_bytes(m, k, n, impl)
            for v in variants:
                emit(
                    f"gemm/fusion/{model}_{m}x{k}/N{n}/{v}",
                    secs[v],
                    f"{1.0 / secs[v]:.1f} runs/s",
                    fusion=v, impl=impl, m=m, k=k, n=n,
                )
            if len(variants) == 2:
                speed = secs["unfused"] / secs["fused"]
                emit(
                    f"gemm/fusion_speedup/{model}_{m}x{k}/N{n}",
                    secs["fused"],
                    f"{speed:.2f}x {saved / 1e6:.2f}MB-eliminated",
                    impl=impl, m=m, k=k, n=n,
                    speedup=speed, traffic_saved_bytes=saved,
                )
                rows.append((model, m, k, n, speed, saved))
    return rows


def run(quick: bool = True, fusion: str = "both"):
    shapes = SHAPES[:2] if quick else SHAPES
    ns = NS[:3] if quick else NS
    rng = np.random.default_rng(0)
    rows = []
    for model, m, k in shapes:
        w = rng.standard_normal((m, k)).astype(np.float32)
        tw = ternary_quantize(jnp.asarray(w))
        pw_i1 = pack_weight(tw.values, tw.scale, "i1")
        pw_i2 = pack_weight(tw.values, tw.scale, "i2")
        for n in ns:
            a = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
            for name, fn in _methods(pw_i1, pw_i2).items():
                s = time_fn(fn, a, warmup=1, repeats=3)
                runs = 1.0 / s
                emit(f"gemm/{model}_{m}x{k}/N{n}/{name}", s, f"{runs:.1f} runs/s")
                rows.append((model, m, k, n, name, s))
    # headline: vlut vs scalar speedup at the largest N measured
    byn = {}
    for model, m, k, n, name, s in rows:
        byn.setdefault((m, k, n), {})[name] = s
    for (m, k, n), d in sorted(byn.items()):
        if "vlut_i2" in d and "scalar_lut_i2" in d and n >= 8:
            emit(
                f"gemm/speedup_vlut_vs_scalar/{m}x{k}/N{n}",
                d["vlut_i2"],
                f"{d['scalar_lut_i2'] / d['vlut_i2']:.2f}x",
            )
    fusion_ablation(quick=quick, fusion=fusion)
    write_results("gemm")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="larger shapes/sweeps")
    ap.add_argument(
        "--fusion", default="both", choices=["fused", "unfused", "both"],
        help="fused-pipeline ablation arm(s) to measure",
    )
    args = ap.parse_args()
    run(quick=not args.full, fusion=args.fusion)
