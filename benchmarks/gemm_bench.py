"""mpGeMM kernel benchmark — paper Fig. 9 (+ Fig. 4 BPW comparison).

Measures runs/s of the Vec-LUT mpGeMM (I1 b1.60 / I2 b2.00) against the
paper's baselines (scalar-LUT à la T-MAC, MAD int8 à la bitnet.cpp I2_S, MAD
dequant-f32 à la llama.cpp TQ) on real-model GeMM shapes across parallel
token counts N. On this CPU host the *relative* ordering reproduces the
paper's qualitative claims (vector ≥ scalar for N ≥ 8; LUT ≥ MAD at ≤2 bpw).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    mad_gemm,
    mad_gemm_int8,
    pack_weight,
    scalar_lut_gemm,
    ternary_quantize,
    vlut_gemm,
)
from .common import emit, time_fn

# (M, K) from the evaluated models: T-MAC Table 1 (BitNet 3B) + Llama3-8B
SHAPES = [
    ("bitnet3b", 320, 3200),
    ("bitnet3b", 128, 8640),
    ("llama3-8b", 1024, 4096),
    ("llama3-8b", 4096, 4096),
]
NS = [1, 8, 32, 128]


def _methods(pw_i1, pw_i2):
    return {
        "vlut_i1": functools.partial(vlut_gemm, pw_i1),
        "vlut_i2": functools.partial(vlut_gemm, pw_i2),
        "scalar_lut_i2": functools.partial(scalar_lut_gemm, pw_i2),
        "mad_int8_i2": functools.partial(mad_gemm_int8, pw_i2),
        "mad_f32_i2": functools.partial(mad_gemm, pw_i2),
    }


def run(quick: bool = True):
    shapes = SHAPES[:2] if quick else SHAPES
    ns = NS[:3] if quick else NS
    rng = np.random.default_rng(0)
    rows = []
    for model, m, k in shapes:
        w = rng.standard_normal((m, k)).astype(np.float32)
        tw = ternary_quantize(jnp.asarray(w))
        pw_i1 = pack_weight(tw.values, tw.scale, "i1")
        pw_i2 = pack_weight(tw.values, tw.scale, "i2")
        for n in ns:
            a = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
            base_s = None
            for name, fn in _methods(pw_i1, pw_i2).items():
                s = time_fn(fn, a, warmup=1, repeats=3)
                runs = 1.0 / s
                emit(f"gemm/{model}_{m}x{k}/N{n}/{name}", s, f"{runs:.1f} runs/s")
                rows.append((model, m, k, n, name, s))
    # headline: vlut vs scalar speedup at the largest N measured
    byn = {}
    for model, m, k, n, name, s in rows:
        byn.setdefault((m, k, n), {})[name] = s
    for (m, k, n), d in sorted(byn.items()):
        if "vlut_i2" in d and "scalar_lut_i2" in d and n >= 8:
            emit(
                f"gemm/speedup_vlut_vs_scalar/{m}x{k}/N{n}",
                d["vlut_i2"],
                f"{d['scalar_lut_i2'] / d['vlut_i2']:.2f}x",
            )
    return rows


if __name__ == "__main__":
    run(quick=False)
