"""End-to-end prefill throughput — paper Fig. 10 / Fig. 13.

Tokens/s of the packed-ternary serve path vs the MAD-style dense path over
prompt lengths (the paper's headline: Vec-LUT throughput scales ~linearly
with parallel tokens, unlike scalar LUT). The serving arm compares
admission-time whole-prompt prefill (serial B=1 passes per request) against
chunked prefill (every prefilling slot's chunk batched into one mixed step
per tick) on a bursty multi-request admission."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_cache, init_lm, pack_params, prefill
from repro.serve import ContinuousBatchingScheduler, Engine, Request
from .common import emit, time_fn

LENS = [32, 64, 128, 256]


def run(quick: bool = True):
    cfg = get_config("smollm-360m", smoke=True)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    packed = pack_params(params, cfg)
    lens = LENS[:3] if quick else LENS
    rng = np.random.default_rng(0)
    out = []
    # hoisted wrapper: every (length, params) combination still compiles
    # once, but the compile cache survives both loops (cache length derives
    # from the static token shape instead of the loop variable)
    prefill_fn = jax.jit(
        lambda p, t, mode: prefill(
            p, t, init_cache(cfg, 1, max_len=t.shape[1] + 8), cfg, mode=mode
        ),
        static_argnums=(2,),
    )
    for s in lens:
        tok = jnp.asarray(rng.integers(0, cfg.vocab, (1, s)), jnp.int32)
        for name, ps, mode in [
            ("vlut_packed", packed, "serve"),
            ("mad_dense", params, "serve"),
        ]:
            sec = time_fn(prefill_fn, ps, tok, mode, warmup=1, repeats=3)
            tps = s / sec
            emit(f"prefill/len{s}/{name}", sec, f"{tps:.1f} tok/s")
            out.append((s, name, tps))
    # Fig 13 claim: throughput grows with prompt length for vec-LUT
    vl = [t for s, n, t in out if n == "vlut_packed"]
    if len(vl) >= 2:
        emit("prefill/scaling_first_to_last", 0.0, f"{vl[-1] / vl[0]:.2f}x")

    # ---- serving-path prefill: whole-prompt vs chunked mixed steps --------
    # A burst of simultaneous admissions, one token of decode each: prefill
    # work dominates, so tok/s isolates admission. Whole-prompt runs each
    # prompt as a blocking B=1 pass; chunked batches all slots' chunks into
    # one (slots, chunk) mixed step per tick.
    slots, plen = 4, 64 if quick else 128
    for name, kw in [("whole_prompt", {}), ("chunked", dict(prefill_chunk=32))]:
        # one engine per arm, warmed on the same shapes: each Engine owns
        # its own jit closures, so a fresh instance would time compilation
        eng = Engine(packed, cfg, max_slots=slots, max_len=plen + 8, **kw)

        def serve_once(eng=eng):
            r = np.random.default_rng(5)
            sched = ContinuousBatchingScheduler(eng)
            sched.submit([
                Request(rid=i,
                        prompt=r.integers(0, cfg.vocab, plen).astype(np.int32),
                        max_new_tokens=1)
                for i in range(slots)
            ])
            return sched.run_to_completion()

        serve_once()                       # compile warmup
        eng.reset_stats()
        stats = serve_once()
        emit(
            f"prefill/serving_{name}", stats.wall_s,
            f"{stats.prefill_tok_s:.1f} tok/s "
            f"(pad {stats.prefill_pad_tokens})",
            prefill_tok_s=stats.prefill_tok_s,
            prefill_pad_tokens=stats.prefill_pad_tokens,
        )
    return out


if __name__ == "__main__":
    run(quick=False)
