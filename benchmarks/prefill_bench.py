"""End-to-end prefill throughput — paper Fig. 10 / Fig. 13.

Tokens/s of the packed-ternary serve path vs the MAD-style dense path over
prompt lengths (the paper's headline: Vec-LUT throughput scales ~linearly
with parallel tokens, unlike scalar LUT)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_cache, init_lm, pack_params, prefill
from .common import emit, time_fn

LENS = [32, 64, 128, 256]


def run(quick: bool = True):
    cfg = get_config("smollm-360m", smoke=True)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    packed = pack_params(params, cfg)
    lens = LENS[:3] if quick else LENS
    rng = np.random.default_rng(0)
    out = []
    for s in lens:
        tok = jnp.asarray(rng.integers(0, cfg.vocab, (1, s)), jnp.int32)
        for name, ps, mode in [
            ("vlut_packed", packed, "serve"),
            ("mad_dense", params, "serve"),
        ]:
            fn = jax.jit(
                lambda p, t, mode=mode: prefill(
                    p, t, init_cache(cfg, 1, max_len=s + 8), cfg, mode=mode
                )
            )
            sec = time_fn(fn, ps, tok, warmup=1, repeats=3)
            tps = s / sec
            emit(f"prefill/len{s}/{name}", sec, f"{tps:.1f} tok/s")
            out.append((s, name, tps))
    # Fig 13 claim: throughput grows with prompt length for vec-LUT
    vl = [t for s, n, t in out if n == "vlut_packed"]
    if len(vl) >= 2:
        emit("prefill/scaling_first_to_last", 0.0, f"{vl[-1] / vl[0]:.2f}x")
    return out


if __name__ == "__main__":
    run(quick=False)
