"""Roofline table from dry-run JSONL results (deliverable g / §Roofline).

Reads results/dryrun_*.jsonl and prints, per (arch × shape × mesh):
three roofline terms (s), dominant bottleneck, MODEL_FLOPS/HLO ratio,
memory efficiency, and per-device state bytes."""
from __future__ import annotations

import glob
import json
import os

from .common import emit


def load_rows(pattern: str = "results/dryrun_*.jsonl"):
    rows = []
    for path in sorted(glob.glob(pattern)):
        for line in open(path):
            rows.append(json.loads(line))
    # last record wins per (arch, shape, mesh, variant)
    dedup = {}
    for r in rows:
        key = (r["arch"], r["shape"], r.get("mesh"), r.get("variant", "baseline"))
        dedup[key] = r
    return list(dedup.values())


def run(quick: bool = True):
    rows = load_rows()
    if not rows:
        emit("roofline/no_results", 0.0, "run repro.launch.dryrun first")
        return
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r.get("mesh", ""))):
        tag = f"roofline/{r['arch']}/{r['shape']}/{r.get('mesh')}/{r.get('variant','baseline')}"
        if r["status"] == "skipped":
            emit(tag, 0.0, "N/A (full-attention arch at 500k)")
            continue
        if r["status"] != "ok":
            emit(tag, 0.0, f"ERROR {r.get('error', '')[:80]}")
            continue
        rl = r["roofline"]
        emit(
            tag,
            rl[max(("compute_s", "memory_s", "collective_s"), key=lambda k: rl[k])] * 1e6,
            f"dom={rl['dominant']} "
            f"terms=({rl['compute_s']:.4f}/{rl['memory_s']:.4f}/{rl['collective_s']:.4f})s "
            f"useful={rl['useful_flops_ratio']:.3f} "
            f"memeff={rl.get('memory_efficiency', 0):.3f} "
            f"state/dev={r.get('state_bytes_per_device', 0) / 2**30:.2f}GiB",
        )


if __name__ == "__main__":
    run(quick=False)
