"""Technique ablation + tile sweep — paper Fig. 12 & Fig. 13 / §5.5.

Applies Vec-LUT's techniques one at a time on the same mpGeMM:
  layout   : token-contiguous vs feature-contiguous (§3.3, the up-to-12× one)
  stream   : streamed precompute-lookup vs whole-table (§3.4)
  accum    : hierarchical INT16→INT32 vs direct INT32 (§3.4)
  topo     : topological vs naive precompute op-count (§4)
and sweeps N_tile / K_tile (§4 tile-size selection)."""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from repro.core import pack_weight, ternary_quantize, vlut_gemm
from .common import emit, time_fn


def run(quick: bool = True):
    m, k, n = (320, 3200, 64) if quick else (1024, 4096, 128)
    rng = np.random.default_rng(0)
    w = rng.standard_normal((m, k)).astype(np.float32)
    tw = ternary_quantize(jnp.asarray(w))
    pw = pack_weight(tw.values, tw.scale, "i1")
    a = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))

    steps = [
        ("feature_first_whole_naive",
         dict(token_contiguous=False, streamed=False, hierarchical=False,
              precompute="naive")),
        ("+token_layout",
         dict(token_contiguous=True, streamed=False, hierarchical=False,
              precompute="naive")),
        ("+hierarchical_accum",
         dict(token_contiguous=True, streamed=False, hierarchical=True,
              precompute="naive")),
        ("+streamed",
         dict(token_contiguous=True, streamed=True, hierarchical=True,
              precompute="naive")),
        ("+topological(matmul)",
         dict(token_contiguous=True, streamed=True, hierarchical=True,
              precompute="matmul")),
    ]
    base = None
    for name, kw in steps:
        fn = functools.partial(vlut_gemm, pw, **kw)
        s = time_fn(fn, a, warmup=1, repeats=3)
        base = base or s
        emit(f"ablation/{m}x{k}xN{n}/{name}", s, f"{base / s:.2f}x vs start")

    # Fig 13: N-tile sweep (0 = untiled)
    for n_tile in (0, 8, 16, 32):
        fn = functools.partial(vlut_gemm, pw, n_tile=n_tile)
        s = time_fn(fn, a, warmup=1, repeats=3)
        emit(f"tile_sweep/{m}x{k}xN{n}/n_tile{n_tile}", s, f"{1.0 / s:.1f} runs/s")
    for kt in (4, 16, 64):
        fn = functools.partial(vlut_gemm, pw, k_tile_groups=kt)
        s = time_fn(fn, a, warmup=1, repeats=3)
        emit(f"tile_sweep/{m}x{k}xN{n}/k_tile{kt}", s, f"{1.0 / s:.1f} runs/s")


if __name__ == "__main__":
    run(quick=False)
