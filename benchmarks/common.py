"""Benchmark utilities: wall-clock timing of jit'd callables + CSV emission.

Output contract (consumed by benchmarks.run): one CSV line per measurement,
    name,us_per_call,derived
where `derived` is a benchmark-specific figure of merit (runs/s, tokens/s,
GB/s, speedup, …).
"""
from __future__ import annotations

import time

import jax


def time_fn(fn, *args, warmup: int = 2, repeats: int = 5) -> float:
    """Median wall seconds per call of a jit'd fn (blocks on outputs)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}")
