"""Benchmark utilities: wall-clock timing of jit'd callables + CSV/JSON
emission.

Output contract (consumed by benchmarks.run): one CSV line per measurement,
    name,us_per_call,derived
where `derived` is a benchmark-specific figure of merit (runs/s, tokens/s,
GB/s, speedup, …). Every `emit` is also buffered; `write_results(suite)`
dumps the buffered rows (plus backend metadata) to ``BENCH_<suite>.json`` so
headline numbers — e.g. the gemm fusion speedup — are tracked across PRs.
"""
from __future__ import annotations

import datetime
import json
import subprocess
import time

import jax

#: rows buffered by emit(); flushed per-suite by write_results()
_ROWS: list[dict] = []


def run_metadata() -> dict:
    """Provenance stamp for every BENCH_*.json: git sha, jax version, device
    kind/platform, UTC timestamp — so the perf trajectory across PRs is
    attributable to a code state and a host. Each probe degrades to None
    rather than failing a benchmark run."""
    meta: dict = {
        "timestamp_utc": datetime.datetime.now(datetime.timezone.utc)
        .isoformat(timespec="seconds"),
        "jax_version": jax.__version__,
        "platform": jax.default_backend(),
    }
    try:
        meta["git_sha"] = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=5, check=True,
        ).stdout.strip()
    except Exception:  # noqa: BLE001 — not a git checkout / no git binary
        meta["git_sha"] = None
    try:
        meta["device_kind"] = jax.devices()[0].device_kind
        meta["device_count"] = jax.device_count()
    except Exception:  # noqa: BLE001 — backend init failure
        meta["device_kind"] = None
    return meta


def time_fn(fn, *args, warmup: int = 2, repeats: int = 5) -> float:
    """Median wall seconds per call of a jit'd fn (blocks on outputs)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def time_paired(
    fns: dict, *args, warmup: int = 1, rounds: int = 9, calls: int = 3
) -> dict:
    """Noise-robust A/B timing for shared/throttled hosts: interleave the
    variants (alternating order each round), time *batches* of `calls`
    back-to-back calls so CPU-quota throttle periods average into every
    sample instead of randomly hitting one arm, and take the per-variant
    median sample. Returns seconds per single call."""
    samples: dict = {name: [] for name in fns}
    for fn in fns.values():
        for _ in range(warmup):
            jax.block_until_ready(fn(*args))
    names = list(fns)
    for r in range(rounds):
        order = names if r % 2 == 0 else names[::-1]
        for name in order:
            t0 = time.perf_counter()
            for _ in range(calls):
                out = fns[name](*args)
            jax.block_until_ready(out)
            samples[name].append((time.perf_counter() - t0) / calls)
    return {
        name: sorted(ts)[len(ts) // 2] for name, ts in samples.items()
    }


def emit(name: str, seconds: float, derived: str = "", **extra) -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}")
    _ROWS.append(
        dict(name=name, us_per_call=seconds * 1e6, derived=derived, **extra)
    )


def write_results(suite: str, path: str | None = None) -> str | None:
    """Flush ALL buffered rows to BENCH_<suite>.json (cwd).

    Suites run sequentially (benchmarks.run flushes after each), so the
    buffer holds exactly the current suite's rows; flushing everything —
    rather than prefix-filtering — keeps the buffer from accumulating rows
    of suites that never flush themselves. No-op (returns None) when the
    buffer is empty, so a suite that already flushed isn't overwritten."""
    global _ROWS
    rows, _ROWS = _ROWS, []
    if not rows:
        return None
    path = path or f"BENCH_{suite}.json"
    payload = {
        "suite": suite,
        "backend": jax.default_backend(),
        "meta": run_metadata(),
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path
