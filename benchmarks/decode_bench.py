"""Parallel decoding + continuous batching — paper Fig. 11 / §5.3.2.

(i) batched decode throughput across batch sizes (paper Fig. 11);
(ii) a mixed continuous-batching run (prefill+decode interleaved) reporting
     total/prefill/decode tok/s — the paper's 273.5 tok/s experiment shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import decode_step, init_cache, init_lm, pack_params, prefill
from repro.serve import ContinuousBatchingScheduler, Engine, Request
from .common import emit, time_fn

BATCHES = [1, 4, 8, 16]


def run(quick: bool = True):
    cfg = get_config("smollm-360m", smoke=True)
    params = pack_params(init_lm(jax.random.PRNGKey(0), cfg), cfg)
    rng = np.random.default_rng(0)
    batches = BATCHES[:3] if quick else BATCHES

    # ---- Fig 11: parallel decode throughput vs batch ----------------------
    for b in batches:
        cache = init_cache(cfg, b, max_len=64)
        tok = jnp.asarray(rng.integers(0, cfg.vocab, (b, 16)), jnp.int32)
        _, cache = jax.jit(lambda p, c, t: prefill(p, t, c, cfg, mode="serve"))(
            params, cache, tok
        )
        one = jnp.asarray(rng.integers(0, cfg.vocab, (b, 1)), jnp.int32)
        fn = jax.jit(lambda p, c, t: decode_step(p, t, c, cfg, mode="serve"))
        sec = time_fn(fn, params, cache, one, warmup=1, repeats=5)
        emit(f"decode/batch{b}", sec, f"{b / sec:.1f} tok/s")

    # ---- §5.3.2: continuous batching --------------------------------------
    eng = Engine(params, cfg, max_slots=4, max_len=96)
    sched = ContinuousBatchingScheduler(eng)
    n_req = 8 if quick else 32
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, size=16).astype(np.int32),
            max_new_tokens=16,
        )
        for i in range(n_req)
    ]
    # warmup compile with one throwaway request
    w = ContinuousBatchingScheduler(Engine(params, cfg, max_slots=4, max_len=96))
    w.submit([Request(rid=-1, prompt=reqs[0].prompt.copy(), max_new_tokens=2)])
    w.run_to_completion()
    sched.submit(reqs)
    stats = sched.run_to_completion()
    emit(
        "continuous_batching/total", stats.wall_s,
        f"{stats.throughput_tok_s:.1f} tok/s "
        f"(prefill {stats.prefill_tok_s:.1f} decode {stats.decode_tok_s:.1f}) "
        f"completed {stats.completed}/{n_req}",
    )
    return stats


if __name__ == "__main__":
    run(quick=False)
