"""Parallel decoding + continuous batching — paper Fig. 11 / §5.3.2.

(i)  batched decode throughput across batch sizes (paper Fig. 11);
(ii) a mixed continuous-batching run (prefill+decode interleaved) reporting
     total/prefill/decode tok/s and median TTFT — the paper's 273.5 tok/s
     experiment shape;
(iii) the same workload under speculative decoding (n-gram drafter),
     reporting tokens/step and acceptance rate;
(iv) a mixed long-prompt/decode arm: the same queued-request stream served
     by whole-prompt admission prefill vs chunked prefill (mixed
     prefill/decode batched steps) — the chunked rows report the median
     TTFT improvement for queued requests at equal total throughput.

All rows land in BENCH_decode.json via benchmarks.common (parity with
gemm_bench), with tokens/s, TTFT, and acceptance-rate columns machine-
readable in `extra` fields. Runs that record no TTFT events emit
`ttft_median_ms: null` (never a fake 0) and omit the console column.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import decode_step, init_cache, init_lm, pack_params, prefill
from repro.serve import ContinuousBatchingScheduler, Engine, Request
from repro.spec import SpecConfig
from .common import emit, time_fn, write_results

BATCHES = [1, 4, 8, 16]


def _mixed_requests(rng, cfg, n_req):
    return [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, size=16).astype(np.int32),
            max_new_tokens=16,
        )
        for i in range(n_req)
    ]


def _ttft_ms(stats):
    """Median TTFT in ms, or None when the run recorded no TTFT events."""
    return 1e3 * float(np.median(stats.ttft_s)) if stats.ttft_s else None


def _serve_run(params, cfg, reqs, *, spec=None, slots=4, max_len=96,
               temperature=0.0, seed=0, prefill_chunk=0, token_budget=0,
               paged_kv=None):
    # Warm THE SAME engine with a throwaway request: each Engine owns its own
    # jax.jit closures, so warming a separate instance leaves the timed one
    # to re-trace/re-compile inside the measured region (~150x on first add).
    # On a paged engine the warm request also seeds the radix prefix index
    # with its prompt's full pages — the shared-prefix arm relies on this
    # (every timed request then admits against a warm prefix, which is the
    # steady-state a shared system prompt reaches after one request).
    eng = Engine(params, cfg, max_slots=slots, max_len=max_len, spec=spec,
                 temperature=temperature, seed=seed,
                 prefill_chunk=prefill_chunk, token_budget=token_budget,
                 paged_kv=paged_kv)
    warm = ContinuousBatchingScheduler(eng)
    warm.submit([Request(rid=-1, prompt=reqs[0].prompt.copy(), max_new_tokens=2)])
    warm.run_to_completion()
    if paged_kv is not None:
        # a second, identical warm request (after the first released its
        # pages into the radix index) takes the prefix-HIT admission path,
        # compiling the tail-width prefill the timed requests will run
        warm2 = ContinuousBatchingScheduler(eng)
        warm2.submit(
            [Request(rid=-2, prompt=reqs[0].prompt.copy(), max_new_tokens=2)]
        )
        warm2.run_to_completion()
    eng.reset_stats()
    sched = ContinuousBatchingScheduler(eng)
    sched.submit(reqs)
    stats = sched.run_to_completion()
    stats.engine = eng
    return stats


def run(quick: bool = True):
    cfg = get_config("smollm-360m", smoke=True)
    params = pack_params(init_lm(jax.random.PRNGKey(0), cfg), cfg)
    rng = np.random.default_rng(0)
    batches = BATCHES[:3] if quick else BATCHES

    # ---- Fig 11: parallel decode throughput vs batch ----------------------
    # one wrapper per fn outside the batch loop: each batch size is a fresh
    # shape (one compile each) but the wrapper's cache survives the loop
    prefill_fn = jax.jit(lambda p, c, t: prefill(p, t, c, cfg, mode="serve"))
    decode_fn = jax.jit(lambda p, c, t: decode_step(p, t, c, cfg, mode="serve"))
    for b in batches:
        cache = init_cache(cfg, b, max_len=64)
        tok = jnp.asarray(rng.integers(0, cfg.vocab, (b, 16)), jnp.int32)
        _, cache = prefill_fn(params, cache, tok)
        one = jnp.asarray(rng.integers(0, cfg.vocab, (b, 1)), jnp.int32)
        sec = time_fn(decode_fn, params, cache, one, warmup=1, repeats=5)
        emit(f"decode/batch{b}", sec, f"{b / sec:.1f} tok/s",
             batch=b, tok_s=b / sec)

    # ---- §5.3.2: continuous batching --------------------------------------
    n_req = 8 if quick else 32
    reqs = _mixed_requests(rng, cfg, n_req)

    def fresh():
        return [
            Request(rid=r.rid, prompt=r.prompt.copy(),
                    max_new_tokens=r.max_new_tokens)
            for r in reqs
        ]

    stats = _serve_run(params, cfg, fresh())
    ttft_ms = _ttft_ms(stats)
    ttft_col = f"ttft {ttft_ms:.0f}ms " if ttft_ms is not None else ""
    emit(
        "continuous_batching/total", stats.wall_s,
        f"{stats.throughput_tok_s:.1f} tok/s "
        f"(prefill {stats.prefill_tok_s:.1f} decode {stats.decode_tok_s:.1f}) "
        f"{ttft_col}completed {stats.completed}/{n_req}",
        tok_s=stats.throughput_tok_s,
        prefill_tok_s=stats.prefill_tok_s,
        decode_tok_s=stats.decode_tok_s,
        ttft_median_ms=ttft_ms,
        completed=stats.completed,
    )

    # ---- speculative continuous batching: same workload, spec on ----------
    spec_stats = _serve_run(params, cfg, fresh(), spec=SpecConfig(k=4))
    emit(
        "continuous_batching/spec_k4", spec_stats.wall_s,
        f"{spec_stats.throughput_tok_s:.1f} tok/s "
        f"{spec_stats.decode_tokens_per_step:.2f} tok/step "
        f"accept {spec_stats.acceptance_rate:.2f} "
        f"completed {spec_stats.completed}/{n_req}",
        tok_s=spec_stats.throughput_tok_s,
        decode_tok_s=spec_stats.decode_tok_s,
        ttft_median_ms=_ttft_ms(spec_stats),
        acceptance_rate=spec_stats.acceptance_rate,
        tokens_per_step=spec_stats.decode_tokens_per_step,
        completed=spec_stats.completed,
    )

    # ---- mixed long-prompt/decode: whole-prompt vs chunked prefill --------
    # Long prompts queued behind a full engine: whole-prompt admission runs
    # each prompt as one blocking B=1 pass per tick while every decode slot
    # stalls; chunked prefill batches all prefilling slots' chunks and the
    # decode rows into ONE mixed step per tick. Median TTFT over the queued
    # requests is the headline (same total work either way).
    long_len = 64 if quick else 96
    n_long = 8 if quick else 16
    slots, max_len = 4, 2 * long_len

    def long_reqs():
        r = np.random.default_rng(7)    # same stream for both arms
        return [
            Request(
                rid=i,
                prompt=r.integers(0, cfg.vocab, size=long_len).astype(np.int32),
                max_new_tokens=16,
            )
            for i in range(n_long)
        ]

    whole = _serve_run(params, cfg, long_reqs(), slots=slots, max_len=max_len)
    chunked = _serve_run(
        params, cfg, long_reqs(), slots=slots, max_len=max_len,
        prefill_chunk=32,
    )
    for name, s in (("whole_prompt", whole), ("chunked_prefill", chunked)):
        t = _ttft_ms(s)
        tc = f"ttft {t:.0f}ms " if t is not None else ""
        emit(
            f"mixed_long_prompt/{name}", s.wall_s,
            f"{s.throughput_tok_s:.1f} tok/s "
            f"(prefill {s.prefill_tok_s:.1f} decode {s.decode_tok_s:.1f}) "
            f"{tc}pad {s.prefill_pad_tokens} "
            f"completed {s.completed}/{n_long}",
            tok_s=s.throughput_tok_s,
            prefill_tok_s=s.prefill_tok_s,
            decode_tok_s=s.decode_tok_s,
            ttft_median_ms=t,
            prefill_pad_tokens=s.prefill_pad_tokens,
            chunk_steps=s.chunk_steps,
            completed=s.completed,
        )
    wt, ct = _ttft_ms(whole), _ttft_ms(chunked)
    if wt and ct:
        emit(
            "mixed_long_prompt/ttft_speedup", 0.0, f"{wt / ct:.2f}x",
            ttft_speedup=wt / ct,
            throughput_ratio=(
                chunked.throughput_tok_s / whole.throughput_tok_s
                if whole.throughput_tok_s else 0.0
            ),
        )

    # ---- shared system prompt: dense vs paged prefix sharing --------------
    # Every request carries the same long "system prompt" plus a short unique
    # tail — the chatbot steady state. The dense engine re-prefills the full
    # prompt per request; the paged engine's radix index matches the shared
    # pages on admission (CoW refcounts, no copy) and prefills only the tail,
    # so the headline is TTFT. Pool occupancy shows the memory side: shared
    # pages are counted once, not per-slot.
    from repro.serve import PagedKVConfig

    # prefill-dominated shape: a long system prompt, short tails, and few
    # decode steps — the arm measures admission cost, which is what prefix
    # sharing removes (the paged decode gather itself is benched above)
    sys_len = 176 if quick else 232
    tail_len, n_shared = 8, 4 if quick else 8
    s_slots, s_max_len = 4, 256
    sys_prompt = rng.integers(0, cfg.vocab, size=sys_len).astype(np.int32)

    def shared_reqs():
        r = np.random.default_rng(11)   # same tails for both arms
        return [
            Request(
                rid=i,
                prompt=np.concatenate(
                    [sys_prompt,
                     r.integers(0, cfg.vocab, size=tail_len).astype(np.int32)]
                ),
                max_new_tokens=4,
            )
            for i in range(n_shared)
        ]

    dense = _serve_run(params, cfg, shared_reqs(),
                       slots=s_slots, max_len=s_max_len)
    paged = _serve_run(params, cfg, shared_reqs(),
                       slots=s_slots, max_len=s_max_len,
                       paged_kv=PagedKVConfig(page_size=16))
    for name, s in (("dense", dense), ("paged", paged)):
        t = _ttft_ms(s)
        tc = f"ttft {t:.0f}ms " if t is not None else ""
        pager = getattr(s.engine, "pager", None)
        hit_col = (
            f"prefix_hit {s.prefix_hit_tokens}tok/{s.prefix_hit_requests}req "
            f"pages {pager.total_pages - pager.free_pages}/{pager.total_pages} "
            if pager is not None else ""
        )
        emit(
            f"shared_prefix/{name}", s.wall_s,
            f"{s.throughput_tok_s:.1f} tok/s {tc}{hit_col}"
            f"completed {s.completed}/{n_shared}",
            tok_s=s.throughput_tok_s,
            prefill_tok_s=s.prefill_tok_s,
            decode_tok_s=s.decode_tok_s,
            ttft_median_ms=t,
            prefill_tokens=s.prefill_tokens,
            prefix_hit_tokens=s.prefix_hit_tokens,
            prefix_hit_requests=s.prefix_hit_requests,
            pages_used=(
                pager.total_pages - pager.free_pages if pager else None
            ),
            pages_total=pager.total_pages if pager else None,
            completed=s.completed,
        )
    dt, pt = _ttft_ms(dense), _ttft_ms(paged)
    if dt and pt:
        emit(
            "shared_prefix/ttft_speedup", 0.0, f"{dt / pt:.2f}x",
            ttft_speedup=dt / pt,
            prefill_tokens_saved=dense.prefill_tokens - paged.prefill_tokens,
        )
    write_results("decode")
    return stats


if __name__ == "__main__":
    run(quick=False)
