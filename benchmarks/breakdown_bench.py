"""Kernel-stage time breakdown — paper Tables 1 & 5.

Times the four stages of the Vec-LUT pipeline separately (activation quant,
LUT precompute, lookup+accumulate, dequant/scale) and reports each as % of
total — the paper's diagnosis that vector LUT collapses "Lookup" to <1% and
shifts cost into contiguous accumulation."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    lookup_accumulate,
    pack_ternary,
    precompute_lut,
    ternary_quantize,
)
from .common import emit, time_fn


def run(quick: bool = True):
    m, k, n, g = (320, 3200, 64, 5) if quick else (1024, 4096, 128, 5)
    rng = np.random.default_rng(0)
    w = rng.standard_normal((m, k)).astype(np.float32)
    tw = ternary_quantize(jnp.asarray(w))
    packed = pack_ternary(tw.values, g)
    a = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))

    @jax.jit
    def stage_quant(a):
        amax = jnp.max(jnp.abs(a), axis=0)
        sc = jnp.maximum(amax, 1e-6) / 127.0
        return jnp.clip(jnp.round(a / sc[None, :]), -127, 127).astype(jnp.int8), sc

    a_q, a_scale = stage_quant(a)
    stage_pre = jax.jit(functools.partial(precompute_lut, g=g))
    t = stage_pre(a_q)

    @jax.jit
    def stage_lookup(t, packed):
        return lookup_accumulate(t, packed, hierarchical=True, g=g)

    o_i = stage_lookup(t, packed)

    @jax.jit
    def stage_scale(o_i, a_scale):
        return o_i.astype(jnp.float32) * tw.scale[:, None] * a_scale[None, :]

    times = {
        "act_quant": time_fn(stage_quant, a, warmup=1, repeats=3),
        "precompute": time_fn(stage_pre, a_q, warmup=1, repeats=3),
        "lookup_accum": time_fn(stage_lookup, t, packed, warmup=1, repeats=3),
        "scale": time_fn(stage_scale, o_i, a_scale, warmup=1, repeats=3),
    }
    total = sum(times.values())
    for name, s in times.items():
        emit(f"breakdown/{m}x{k}xN{n}/{name}", s, f"{100 * s / total:.1f}%")
    emit(f"breakdown/{m}x{k}xN{n}/total", total, "100%")
    return times


if __name__ == "__main__":
    run(quick=False)
