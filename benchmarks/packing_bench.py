"""Packing compactness & shape support — paper Table 3 / §3.3.

Reports bits-per-weight of I1/I2/flexible packing on every assigned arch's
linear dimensions, against llama.cpp's TQ1_0 (1.6875 bpw, needs 256|K) and
TQ2_0 (2.0625 bpw, needs 256|K) — including the support-matrix point that
llama.cpp falls back to Q4_0 (4.5 bpw) when 256 ∤ K (HF BitNet 3B case)."""
from __future__ import annotations

import numpy as np

from repro.configs import get_config, list_archs
from repro.core import pack_group_sizes
from .common import emit

TQ1_BPW, TQ2_BPW, Q4_BPW = 1.6875, 2.0625, 4.5


def _k_dims(cfg):
    ks = {cfg.d_model}
    if cfg.d_ff:
        ks.add(cfg.d_ff)
    if cfg.moe:
        ks.add(cfg.moe.d_ff_expert)
    if cfg.mla:
        ks.add(cfg.mla.kv_lora_rank)
        ks.add(cfg.mla.q_lora_rank)
    if cfg.ssm:
        ks.add(cfg.ssm.d_inner)
    return sorted(ks)


def run(quick: bool = True):
    for arch in list_archs():
        cfg = get_config(arch)
        for k in _k_dims(cfg):
            n5, n4 = pack_group_sizes(k)
            ours = 8.0 * (n5 + n4) / k
            llamacpp = TQ1_BPW if k % 256 == 0 else Q4_BPW
            emit(
                f"packing/{arch}/K{k}", 0.0,
                f"ours={ours:.3f}bpw llama.cpp_best={llamacpp:.3f}bpw "
                f"saving={llamacpp / ours:.2f}x",
            )
    # summary of the flexible-packing support claim: any K ≥ 12 packs ≤ 2bpw
    supported = sum(
        1 for k in range(12, 8192) if _packs(k)
    )
    emit("packing/support_12_to_8192", 0.0, f"{supported}/{8192 - 12} K values")


def _packs(k: int) -> bool:
    try:
        pack_group_sizes(k)
        return True
    except ValueError:
        return False


if __name__ == "__main__":
    run(quick=False)
