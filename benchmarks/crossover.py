"""M × impl crossover table — the CI-tracked perf surface for ROADMAP item 1.

The paper's headline claim is that the *vector* LUT beats the scalar LUT
precisely in multi-token (parallel-M) regimes; BENCH_gemm.json currently
shows that inverted on this host. This benchmark makes the crossover
explicit and gate-able: for every M ∈ {1, 4, 16, 32, 64, 128} it times each
GeMM impl on a fixed layer shape, names the **winner per M row**, and emits

  * ``BENCH_crossover.json`` (via benchmarks.common, with run metadata) —
    the committed baseline ``results/check_regression.py`` gates against;
  * ``results/crossover.md`` — a human-readable winner table.

Impls (paper §5.1 vocabulary):
  vlut        — core.vlut.vlut_gemm: the vector-LUT reference (unified table
                per token tile, streamed lookups)
  vlut_packed — kernels.vlut_mpgemm: the packed serving path (fused
                single-pass kernel on TPU, streamed XLA decode elsewhere) —
                what serve/engine.py actually dispatches
  scalar_lut  — core.baselines.scalar_lut_gemm: T-MAC-style per-token tables
  mad_dense   — core.baselines.mad_gemm: llama.cpp-style dequant + f32 MAD
  mad_int8    — core.baselines.mad_gemm_int8: bitnet.cpp-style int8 MAD

Winner rows carry bytes/FLOPs: parsed from the winner's optimized HLO
(roofline.hlo_stats — trip-count-aware, the ground truth) with the analytic
roofline.analysis.mpgemm_cost as fallback, so achieved GB/s / GFLOP/s ride
along in the JSON for the bandwidth-crossover analysis.
"""
from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    mad_gemm,
    mad_gemm_int8,
    pack_weight,
    scalar_lut_gemm,
    ternary_quantize,
    vlut_gemm,
)
from repro.kernels import vlut_mpgemm
from repro.roofline.analysis import mpgemm_cost
from repro.roofline.hlo_stats import parse_hlo_stats
from .common import emit, time_paired, write_results

#: the M sweep the acceptance gate requires a winner for. Beyond the
#: powers-of-two scaling curve, the grid pins the M values the Engine
#: actually dispatches (ROADMAP item 1's serving-realistic shapes):
#:   M=4    chain-verify K+1 rows (draft_k=3, B=1)
#:   M=7    tree-verify n_nodes for tree=(2, 2): 1 + 2 + 4 nodes
#:   M=16   chain verify across slots (4 slots x K+1) / spec_bench batch
#:   M=48   chunked prefill, chunk=16 x 3 prefilling slots
#:   M=256  chunked prefill, chunk=32 x 8 slots (saturated admission burst)
MS = (1, 4, 7, 16, 32, 48, 64, 128, 256)
#: (tag, M_out, K) layer shapes; quick keeps one edge-scale cell
SHAPES = [
    ("edge-m", 512, 2048),
    ("llama3-8b", 1024, 4096),
]


def _impls(pw, pw_i2):
    # mirror the serving dispatch (kernels.ops.ternary_matmul): the fused
    # Pallas decode kernel on TPU, the streamed XLA path elsewhere
    packed_impl = "decode" if jax.default_backend() == "tpu" else "xla"
    return {
        "vlut": functools.partial(vlut_gemm, pw_i2),
        "vlut_packed": functools.partial(vlut_mpgemm, pw, impl=packed_impl),
        "scalar_lut": functools.partial(scalar_lut_gemm, pw_i2),
        "mad_dense": functools.partial(mad_gemm, pw_i2),
        "mad_int8": functools.partial(mad_gemm_int8, pw_i2),
    }


def _winner_cost(fn, a, m_out: int, k: int, m_tokens: int):
    """(flops, bytes) of one winner call: HLO-parsed when the impl lowers
    cleanly, analytic mpgemm_cost otherwise."""
    try:
        text = jax.jit(fn).lower(a).compile().as_text()
        st = parse_hlo_stats(text)
        if st.dot_flops > 0:
            return st.dot_flops, st.traffic_bytes, "hlo"
    except Exception:  # noqa: BLE001 — fall back to the analytic model
        pass
    flops, bytes_ = mpgemm_cost(m_out, k, m_tokens, g=4)
    return flops, bytes_, "analytic"


def run(quick: bool = True):
    shapes = SHAPES[:1] if quick else SHAPES
    rng = np.random.default_rng(0)
    table: list[dict] = []
    for tag, m_out, k in shapes:
        w = rng.standard_normal((m_out, k)).astype(np.float32)
        tw = ternary_quantize(jnp.asarray(w))
        pw = pack_weight(tw.values, tw.scale, "auto")
        pw_i2 = pack_weight(tw.values, tw.scale, "i2")
        fns = _impls(pw, pw_i2)
        for m in MS:
            a = jnp.asarray(rng.standard_normal((k, m)).astype(np.float32))
            secs = time_paired(fns, a, rounds=5 if quick else 9, calls=2)
            for name, s in secs.items():
                emit(
                    f"crossover/{tag}_{m_out}x{k}/M{m}/{name}", s,
                    f"{1.0 / s:.1f} runs/s",
                    impl=name, m_tokens=m, m_out=m_out, k=k,
                )
            ranked = sorted(secs.items(), key=lambda kv: kv[1])
            (win, win_s), (second, second_s) = ranked[0], ranked[1]
            flops, bytes_, src = _winner_cost(
                fns[win], a, m_out, k, m
            )
            emit(
                f"crossover/{tag}_{m_out}x{k}/M{m}/winner", win_s,
                f"{win} {second_s / win_s:.2f}x-vs-{second}",
                winner=win, runner_up=second, margin=second_s / win_s,
                m_tokens=m, m_out=m_out, k=k,
                flops=flops, traffic_bytes=bytes_, cost_source=src,
                achieved_gflops=flops / win_s / 1e9,
                achieved_gbps=bytes_ / win_s / 1e9,
            )
            table.append(dict(
                shape=f"{tag} {m_out}x{k}", m=m, winner=win,
                margin=second_s / win_s,
                **{n: 1.0 / s for n, s in secs.items()},
            ))
    _write_markdown(table)
    write_results("crossover")
    return table


def _write_markdown(table: list[dict], path: str = "results/crossover.md"):
    """Winner table (runs/s per impl, winner bolded) for the PR surface."""
    if not table:
        return
    impls = [n for n in ("vlut", "vlut_packed", "scalar_lut", "mad_dense",
                         "mad_int8") if n in table[0]]
    lines = [
        "# GeMM crossover: winner per (shape, M)",
        "",
        f"Backend: `{jax.default_backend()}` — runs/s per impl; "
        "**winner** per row. Regenerate: `python -m benchmarks.crossover`.",
        "",
        "| shape | M | " + " | ".join(impls) + " | winner (margin) |",
        "|---|---|" + "---|" * (len(impls) + 1),
    ]
    for row in table:
        cells = []
        for n in impls:
            v = f"{row[n]:.1f}"
            cells.append(f"**{v}**" if n == row["winner"] else v)
        lines.append(
            f"| {row['shape']} | {row['m']} | " + " | ".join(cells)
            + f" | {row['winner']} ({row['margin']:.2f}x) |"
        )
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="all shapes")
    args = ap.parse_args()
    run(quick=not args.full)
