"""Benchmark harness — one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--full]

Mapping to the paper:
  gemm_bench       Fig. 9 / Fig. 4    mpGeMM kernel vs baselines
  prefill_bench    Fig. 10 / Fig. 13  e2e prefill tokens/s
  decode_bench     Fig. 11 / §5.3.2   parallel decode + continuous batching
  spec_bench       §5.3 multi-token   speculative decoding: K×batch sweep +
                                      scalar-vs-vector verify GeMMs
  crossover        ROADMAP item 1     M × impl winner table (CI-gated)
  breakdown_bench  Tables 1 & 5       stage time breakdown
  ablation_bench   Fig. 12 / §5.5     technique ablation + tile sweep
  packing_bench    Table 3 / §3.3     bpw compactness & shape support
  roofline_report  §Roofline          dry-run roofline table
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="larger shapes/sweeps")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    quick = not args.full

    from . import (
        ablation_bench,
        breakdown_bench,
        crossover,
        decode_bench,
        gemm_bench,
        packing_bench,
        prefill_bench,
        roofline_report,
        spec_bench,
    )

    suites = {
        "gemm": gemm_bench,
        "crossover": crossover,
        "prefill": prefill_bench,
        "decode": decode_bench,
        "spec": spec_bench,
        "breakdown": breakdown_bench,
        "ablation": ablation_bench,
        "packing": packing_bench,
        "roofline": roofline_report,
    }
    failures = 0
    for name, mod in suites.items():
        if args.only and name != args.only:
            continue
        print(f"# === {name} ===", flush=True)
        try:
            mod.run(quick=quick)
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
        finally:
            # flush any rows the suite buffered but didn't write itself
            from . import common

            common.write_results(name)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
