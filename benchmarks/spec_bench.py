"""Speculative decoding benchmark — draft length K × batch sweep.

Five arms, all landing in BENCH_spec.json via benchmarks.common:

  (i)  verify-GeMM scaling: one verify step turns each slot's decode GeMM
       from M=1 into M=K+1 parallel tokens — exactly the 1→N regime the
       paper's vector lookup targets. We time the fused Vec-LUT mpGeMM
       against the scalar-LUT baseline (T-MAC-style 1→1 lookups) on a
       layer-shaped GeMM at N = batch·(K+1) and report the vector/scalar
       speedup ("the N-scaling advantage on verification").
  (ii) end-to-end speculative serving: Engine(spec=SpecConfig(k=K)) with the
       n-gram drafter over repetitive prompts, sweeping K × batch; rows
       report decode tok/s, tokens/step, and acceptance rate.
  (iii) the self-draft oracle (ModelDrafter wrapping the target's own
       params): acceptance is 1.0 by construction, so tokens/step == K+1 —
       the verification-side ceiling once drafting is free and perfect.
  (iv) adaptive-vs-fixed K on a mixed warm/cold workload (half repetitive
       prompts the n-gram drafter feeds on, half adversarial random ones):
       rows add per-slot mean k_eff and skip-rate columns, showing the
       adaptive policy recovering plain-decode cost on the cold half.
  (v)  stochastic-vs-greedy ModelDrafter proposals at temperature>0: greedy
       drafting is scored as a one-hot proposal, stochastic drafting
       (SpecConfig(stochastic=True)) samples at the serving temperature and
       feeds its distributions to rejection sampling — the acceptance-rate
       gap is the draft probability mass the greedy mode throws away.
  (vi) tree-vs-chain verification (SpecConfig(tree=...)): one verify pass
       carries the whole draft tree, so each slot's verify row holds
       n_nodes > k+1 candidates — rows report verified nodes/step,
       tokens/step, and the vector-vs-scalar verify-GeMM speedup at the
       tree's M, the deeper multi-token regime the paper's vector lookup
       targets.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pack_weight, scalar_lut_gemm, ternary_quantize, vlut_gemm
from repro.kernels import vlut_mpgemm
from repro.kernels.ops import on_tpu
from repro.models import init_lm, pack_params
from repro.configs import get_config
from repro.serve import Request
from repro.spec import SpecConfig
from .common import emit, time_fn, time_paired, write_results
from .decode_bench import _serve_run

KS = [2, 4, 8]
BATCHES = [1, 4]
#: slot batches for the verify-GeMM arm — N = batch·(K+1) parallel tokens,
#: the regime where the paper's vector-vs-scalar crossover (N ≥ 8) shows
GEMM_BATCHES = [4, 16]
#: verify-GeMM shape: an edge-scale layer (M_out, K_in) from the paper's regime
GEMM_SHAPE = (160, 1280)


# --------------------------------------------------------------------------
# (i) scalar vs vector LUT on verify-shaped GeMMs
# --------------------------------------------------------------------------
def _bench_verify_gemm(quick: bool):
    m_out, k_in = GEMM_SHAPE
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(m_out, k_in)), jnp.float32)
    tw = ternary_quantize(w)
    pw = pack_weight(tw.values, tw.scale, "i2")
    for b in GEMM_BATCHES[:1] if quick else GEMM_BATCHES:
        for k in KS:
            n = b * (k + 1)                     # parallel tokens one verify sees
            a = jnp.asarray(rng.normal(size=(k_in, n)), jnp.float32)
            secs = time_paired(
                {
                    "vector": lambda a_: vlut_gemm(pw, a_),
                    "scalar": lambda a_: scalar_lut_gemm(pw, a_),
                },
                a, warmup=1, rounds=9, calls=3,
            )
            speedup = secs["scalar"] / secs["vector"]
            emit(
                f"verify_gemm/K{k}b{b}/vector", secs["vector"],
                f"{speedup:.2f}x vs scalar", m=k + 1, n_tokens=n, arm="vector",
            )
            emit(
                f"verify_gemm/K{k}b{b}/scalar", secs["scalar"], "",
                m=k + 1, n_tokens=n, arm="scalar",
            )
            # the kernel the engine's verify pass actually dispatches to:
            # fused single-pass Pallas on TPU, streamed XLA decode elsewhere
            impl = "decode" if on_tpu() else "xla"
            fused = time_fn(
                lambda a_: vlut_mpgemm(pw, a_, impl=impl), a, warmup=1, repeats=3
            )
            emit(
                f"verify_gemm/K{k}b{b}/engine_mpgemm", fused,
                f"M={k + 1} via impl={impl}", m=k + 1, n_tokens=n, arm="engine",
                impl=impl,
            )


# --------------------------------------------------------------------------
# (ii)+(iii) end-to-end speculative serving
# --------------------------------------------------------------------------
def _repetitive_prompts(rng, n_req, vocab, length=16, period=4):
    pat = rng.integers(0, vocab, size=period)
    return [
        np.tile(pat, length // period).astype(np.int32) for _ in range(n_req)
    ]


def _serve(params, cfg, prompts, *, spec, slots, max_new, max_len=128,
           temperature=0.0):
    # _serve_run does a throwaway warmup pass first, so the timed region
    # excludes the one-time jit compiles (which differ per draft length K)
    return _serve_run(
        params, cfg,
        [Request(rid=i, prompt=p, max_new_tokens=max_new)
         for i, p in enumerate(prompts)],
        spec=spec, slots=slots, max_len=max_len, temperature=temperature,
    )


def _bench_engine(quick: bool):
    cfg = get_config("smollm-360m", smoke=True)
    params = pack_params(init_lm(jax.random.PRNGKey(0), cfg), cfg)
    rng = np.random.default_rng(0)
    max_new = 16 if quick else 32
    batches = BATCHES[:1] if quick else BATCHES
    ks = KS[:2] if quick else KS

    for b in batches:
        prompts = _repetitive_prompts(rng, 2 * b, cfg.vocab)
        # non-speculative baseline
        base = _serve(params, cfg, [p.copy() for p in prompts],
                      spec=None, slots=b, max_new=max_new)
        emit(
            f"spec/baseline/b{b}", base.wall_s,
            f"{base.decode_tok_s:.1f} decode tok/s", k=0, batch=b,
            tokens_per_step=1.0, acceptance_rate=0.0,
        )
        for k in ks:
            st = _serve(params, cfg, [p.copy() for p in prompts],
                        spec=SpecConfig(k=k, drafter="ngram"),
                        slots=b, max_new=max_new)
            emit(
                f"spec/ngram/K{k}b{b}", st.wall_s,
                f"{st.decode_tok_s:.1f} decode tok/s, "
                f"{st.decode_tokens_per_step:.2f} tok/step, "
                f"accept {st.acceptance_rate:.2f}",
                k=k, batch=b,
                tokens_per_step=st.decode_tokens_per_step,
                acceptance_rate=st.acceptance_rate,
                spec_steps=st.spec_steps,
            )
        # oracle: self-draft with the target's own weights → accept-all
        k = ks[-1]
        st = _serve(params, cfg, [p.copy() for p in prompts],
                    spec=SpecConfig(k=k, drafter="model",
                                    draft_params=params, draft_cfg=cfg),
                    slots=b, max_new=max_new)
        emit(
            f"spec/oracle/K{k}b{b}", st.wall_s,
            f"{st.decode_tokens_per_step:.2f} tok/step ceiling, "
            f"accept {st.acceptance_rate:.2f}",
            k=k, batch=b,
            tokens_per_step=st.decode_tokens_per_step,
            acceptance_rate=st.acceptance_rate,
        )


# --------------------------------------------------------------------------
# (iv) adaptive-vs-fixed K on a mixed warm/cold workload
# --------------------------------------------------------------------------
def _mixed_prompts(rng, n_req, vocab, length=16):
    """Half repetitive (n-gram drafting feeds → warm acceptance), half
    random (prompt lookup whiffs → cold acceptance)."""
    warm = _repetitive_prompts(rng, n_req - n_req // 2, vocab, length=length)
    cold = [rng.integers(0, vocab, size=length).astype(np.int32)
            for _ in range(n_req // 2)]
    return warm + cold


def _emit_spec_row(name, st, *, k, batch, arm):
    emit(
        name, st.wall_s,
        f"{st.decode_tok_s:.1f} decode tok/s, "
        f"{st.decode_tokens_per_step:.2f} tok/step, "
        f"{st.nodes_per_step:.1f} nodes/step, "
        f"accept {st.acceptance_rate:.2f}, mean_k {st.mean_draft_k:.2f}, "
        f"skip {st.skip_rate:.2f}",
        k=k, batch=batch, arm=arm,
        tokens_per_step=st.decode_tokens_per_step,
        nodes_per_step=st.nodes_per_step,
        acceptance_rate=st.acceptance_rate,
        mean_draft_k=st.mean_draft_k,
        skip_rate=st.skip_rate,
        spec_steps=st.spec_steps,
        spec_skipped_steps=st.spec_skipped_steps,
    )


def _bench_adaptive(quick: bool):
    cfg = get_config("smollm-360m", smoke=True)
    params = pack_params(init_lm(jax.random.PRNGKey(0), cfg), cfg)
    rng = np.random.default_rng(1)
    max_new = 16 if quick else 32
    k = KS[1]
    for b in BATCHES[:1] if quick else BATCHES:
        prompts = _mixed_prompts(rng, 2 * b, cfg.vocab)
        fixed = _serve(params, cfg, [p.copy() for p in prompts],
                       spec=SpecConfig(k=k, drafter="ngram"),
                       slots=b, max_new=max_new)
        _emit_spec_row(f"spec/fixed_k/K{k}b{b}", fixed, k=k, batch=b,
                       arm="fixed_k")
        adapt = _serve(params, cfg, [p.copy() for p in prompts],
                       spec=SpecConfig(k=k, drafter="ngram", adaptive_k=True,
                                       skip_below=0.25, probe_every=4),
                       slots=b, max_new=max_new)
        _emit_spec_row(f"spec/adaptive_k/K{k}b{b}", adapt, k=k, batch=b,
                       arm="adaptive_k")


# --------------------------------------------------------------------------
# (v) stochastic-vs-greedy ModelDrafter proposals at temperature>0
# --------------------------------------------------------------------------
def _bench_stochastic(quick: bool):
    cfg = get_config("smollm-360m", smoke=True)
    params = pack_params(init_lm(jax.random.PRNGKey(0), cfg), cfg)
    rng = np.random.default_rng(2)
    max_new, b, k, temp = (12 if quick else 24), 2, KS[0], 0.8
    prompts = _repetitive_prompts(rng, 2 * b, cfg.vocab)
    # self-draft keeps the arm about the proposal mode, not draft quality:
    # stochastic proposals then satisfy q == p → acceptance 1.0 ceiling,
    # while greedy one-hot proposals only get accept prob p(argmax).
    common = dict(drafter="model", draft_params=params, draft_cfg=cfg)
    greedy = _serve(params, cfg, [p.copy() for p in prompts],
                    spec=SpecConfig(k=k, **common),
                    slots=b, max_new=max_new, temperature=temp)
    _emit_spec_row(f"spec/greedy_draft_t{temp}/K{k}b{b}", greedy, k=k,
                   batch=b, arm="greedy_draft")
    stoch = _serve(params, cfg, [p.copy() for p in prompts],
                   spec=SpecConfig(k=k, stochastic=True, **common),
                   slots=b, max_new=max_new, temperature=temp)
    _emit_spec_row(f"spec/stochastic_draft_t{temp}/K{k}b{b}", stoch, k=k,
                   batch=b, arm="stochastic_draft")


# --------------------------------------------------------------------------
# (vi) tree-vs-chain multi-candidate verification
# --------------------------------------------------------------------------
#: branching factors of the benchmark draft tree (depth = the sweep's k)
TREE = (2, 2)


def _bench_tree(quick: bool):
    cfg = get_config("smollm-360m", smoke=True)
    params = pack_params(init_lm(jax.random.PRNGKey(0), cfg), cfg)
    rng = np.random.default_rng(3)
    max_new = 16 if quick else 32
    k = KS[1]
    n_nodes = SpecConfig(k=k, tree=TREE).tree_struct().n_nodes
    # (a) vector-vs-scalar LUT on the verify GeMM at chain vs tree M — the
    # per-slot parallel-token count the one flattened pass hands the kernels
    m_out, k_in = GEMM_SHAPE
    w = jnp.asarray(rng.normal(size=(m_out, k_in)), jnp.float32)
    tw = ternary_quantize(w)
    pw = pack_weight(tw.values, tw.scale, "i2")
    for b in GEMM_BATCHES[:1] if quick else GEMM_BATCHES:
        for arm, m in (("chain", k + 1), ("tree", n_nodes)):
            n = b * m
            a = jnp.asarray(rng.normal(size=(k_in, n)), jnp.float32)
            secs = time_paired(
                {
                    "vector": lambda a_: vlut_gemm(pw, a_),
                    "scalar": lambda a_: scalar_lut_gemm(pw, a_),
                },
                a, warmup=1, rounds=9, calls=3,
            )
            emit(
                f"verify_gemm_tree/{arm}K{k}b{b}/vector", secs["vector"],
                f"{secs['scalar'] / secs['vector']:.2f}x vs scalar at M={m}",
                m=m, n_tokens=n, arm=f"{arm}_gemm",
                speedup=secs["scalar"] / secs["vector"],
            )
            emit(
                f"verify_gemm_tree/{arm}K{k}b{b}/scalar", secs["scalar"], "",
                m=m, n_tokens=n, arm=f"{arm}_gemm_scalar",
            )
    # (b) end-to-end tree vs chain serving (n-gram drafter)
    for b in BATCHES[:1] if quick else BATCHES:
        prompts = _repetitive_prompts(rng, 2 * b, cfg.vocab)
        chain = _serve(params, cfg, [p.copy() for p in prompts],
                       spec=SpecConfig(k=k, drafter="ngram"),
                       slots=b, max_new=max_new)
        _emit_spec_row(f"spec/chain/K{k}b{b}", chain, k=k, batch=b,
                       arm="chain")
        treed = _serve(params, cfg, [p.copy() for p in prompts],
                       spec=SpecConfig(k=k, drafter="ngram", tree=TREE),
                       slots=b, max_new=max_new)
        _emit_spec_row(f"spec/tree/K{k}b{b}", treed, k=k, batch=b, arm="tree")


def run(quick: bool = True):
    _bench_verify_gemm(quick)
    _bench_engine(quick)
    _bench_adaptive(quick)
    _bench_stochastic(quick)
    _bench_tree(quick)
    write_results("spec")


if __name__ == "__main__":
    run(quick=False)
