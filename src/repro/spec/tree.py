"""DraftTree — the static draft-tree layout tree-speculative decoding runs on.

`SpecConfig(tree=(b1, b2, ...))` describes a token tree of depth `k` (the
draft length): the root is the last sampled token, depth-d nodes carry the
d-th drafted candidate, and the branching factor at depth d is ``tree[d-1]``
for the first ``len(tree)`` depths and 1 (a chain continuation per leaf)
afterwards. One engine verify pass flattens the whole tree into a single
``(B, n_nodes)`` token batch, so the Vec-LUT mpGeMM kernels see M = n_nodes
parallel tokens per slot instead of the chain mode's M = k+1.

Flattening order (the contract every consumer shares — drafters emit node
tokens in it, the verify step scatters cache entries by it, and acceptance
indexes logits with it): **breadth-first by depth, siblings in candidate-rank
order, parents in their own flattened order**. Node 0 is the root; depth-1
nodes are 1..b1 (rank 0 first); depth-2 nodes follow parent-major
(parent 1's b2 children, then parent 2's, ...), and so on. A node's rank
among its siblings (`ranks`) is the drafter's candidate index: rank 0 is the
drafter's best (argmax/most-frequent) candidate, so the all-rank-0 path is
exactly the chain-mode proposal.

The structure is static per SpecConfig — everything here is host-side numpy
baked into the jit'd verify/accept traces as constants.
"""
from __future__ import annotations

import dataclasses

import numpy as np

#: hard cap on flattened tree width — verify cost is linear in n_nodes and a
#: typo like tree=(8, 8, 8) would silently compile a 585-node step
MAX_NODES = 256


@dataclasses.dataclass(frozen=True)
class DraftTree:
    """Static draft-tree layout.

    k          tree depth == draft tokens along any root-to-leaf path.
    branching  per-depth branching factors (padded with 1s to depth k).
    n_nodes    flattened node count incl. the root (the verify step's S).
    parents    (n_nodes,) node index of each node's parent (root: itself).
    depths     (n_nodes,) node depth (root 0; cache position = idx + depth).
    ranks      (n_nodes,) candidate rank among siblings (root 0).
    ancestors  (n_nodes, n_nodes) bool; ancestors[i, j] ⇔ j is on the
               root-to-i path, i itself included — the intra-step attention
               mask of the verify pass.
    leaf_paths (n_leaves, k+1) node indices of every root-to-leaf path,
               column d = the path's depth-d node — acceptance scans these.
    """

    k: int
    branching: tuple
    n_nodes: int
    parents: np.ndarray
    depths: np.ndarray
    ranks: np.ndarray
    ancestors: np.ndarray
    leaf_paths: np.ndarray

    @property
    def n_draft(self) -> int:
        """Drafted (non-root) nodes — the per-slot proposal count."""
        return self.n_nodes - 1


def build_tree(k: int, branching: tuple) -> DraftTree:
    """Build the flattened draft tree for depth `k` and the given per-depth
    branching factors (see module docstring for the flattening order)."""
    if not branching:
        raise ValueError("tree branching must name at least one depth factor")
    if len(branching) > k:
        raise ValueError(
            f"tree names {len(branching)} branching depths but k={k}; "
            "the tree can be at most k deep"
        )
    if any(int(b) < 1 for b in branching):
        raise ValueError(f"tree branching factors must be >= 1, got {branching}")
    full = tuple(int(b) for b in branching) + (1,) * (k - len(branching))

    parents = [0]
    depths = [0]
    ranks = [0]
    frontier = [0]                      # node ids at the previous depth
    for d, b in enumerate(full, start=1):
        nxt = []
        for p in frontier:
            for r in range(b):
                nxt.append(len(parents))
                parents.append(p)
                depths.append(d)
                ranks.append(r)
        frontier = nxt
        if len(parents) > MAX_NODES:
            raise ValueError(
                f"tree {branching} at k={k} flattens to > {MAX_NODES} nodes"
            )
    n = len(parents)
    parents_a = np.asarray(parents, np.int32)
    depths_a = np.asarray(depths, np.int32)

    anc = np.zeros((n, n), bool)
    for i in range(n):
        j = i
        while True:
            anc[i, j] = True
            if j == 0:
                break
            j = int(parents_a[j])

    paths = np.zeros((len(frontier), k + 1), np.int32)
    for li, leaf in enumerate(frontier):
        j = leaf
        for d in range(k, -1, -1):
            paths[li, d] = j
            j = int(parents_a[j])

    return DraftTree(
        k=k,
        branching=full,
        n_nodes=n,
        parents=parents_a,
        depths=depths_a,
        ranks=np.asarray(ranks, np.int32),
        ancestors=anc,
        leaf_paths=paths,
    )
