"""repro.spec — speculative decoding on the Vec-LUT hot path.

The serving engine's plain decode runs the target model one token per slot
per tick, so the Vec-LUT mpGeMM kernels only ever see M=1 at decode time —
the exact regime the paper's 1→N vector lookup was built to escape. This
subsystem turns decode into draft → verify → accept: a cheap *drafter*
proposes K candidate tokens per slot, one batched `models.verify_step` runs
the target over all (B, K+1) candidates against the slot KV caches (the
kernels see M=K+1 parallel tokens), and an acceptance rule keeps the longest
valid prefix, rolling the caches back past the first rejection
(`models.rollback_cache`).

Components
  * SpecConfig     — knobs: draft length `k`, drafter choice, n-gram window,
                     draft-model params/config. `Engine(spec=SpecConfig(...))`
                     switches `decode_once` to the speculative step.
                     `adaptive_k=True` adds per-slot adaptive draft lengths:
                     the engine tracks a per-slot acceptance EWMA
                     (`accept_ewma` decay) and drafts k_eff = `k_policy(ewma)`
                     ∈ {0} ∪ [k_min, k] real tokens per slot — cold slots
                     (`skip_below`) skip drafting entirely and re-probe every
                     `probe_every` steps — padding rows so the one compiled
                     (B, k+1) verify serves every mixture. `stochastic=True`
                     (drafter='model') samples proposals at the serving
                     temperature and threads the draft distributions into
                     rejection sampling (`draft_probs`). `tree=(b1, b2, ...)`
                     switches to tree-structured multi-candidate
                     verification: the drafter branches top-b_d candidates
                     at each of the first depths and ONE flattened
                     (B, n_nodes) verify pass scores the whole tree — the
                     kernels see M = n_nodes > k+1 parallel tokens per slot
                     (see `DraftTree` / `serve.sampling.accept_tree`).
  * DraftTree      — the static flattened tree layout (`build_tree`): node
                     order, per-node depth/rank, ancestor masks, and
                     root-to-leaf paths shared by drafters, the tree verify
                     masks, acceptance, and cache compaction.
  * NgramDrafter   — prompt-lookup / self-drafting: matches the context's
                     trailing n-gram against earlier context and proposes the
                     historical continuation. No extra weights.
  * ModelDrafter   — wraps a smaller ternary model (its own packed params +
                     config) with a mirrored slot cache; drafts greedily and
                     resyncs to the accepted tokens by the same rollback
                     trick the target uses.

Exactness: with greedy sampling the accepted tokens are token-for-token
identical to non-speculative decoding (each verified position's logits
depend only on the already-accepted prefix); with temperature sampling,
`serve.sampling.accept_speculative` applies Leviathan-style rejection
sampling so emitted tokens are distributed exactly as target-model samples.

Rollback semantics: only the per-slot cache `idx` is restored — stale K/V
past the restored index is never read (position-masked attention +
scatter-before-attend), so rollback is O(1). This requires full-buffer
attention or MLA caches; ring (windowed) caches and SSM state are refused at
engine construction.
"""
from .config import SpecConfig
from .drafter import Drafter, NgramDrafter
from .model_drafter import ModelDrafter
from .tree import DraftTree, build_tree

__all__ = [
    "SpecConfig", "Drafter", "NgramDrafter", "ModelDrafter",
    "DraftTree", "build_tree",
]
