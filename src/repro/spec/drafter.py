"""Drafter protocol + the weight-free prompt-lookup (n-gram) drafter.

A drafter proposes K candidate continuation tokens per active slot each
decode tick. The engine hands it the full per-slot context (prompt +
everything generated so far) and expects a dense (max_slots, K) proposal —
static shapes keep the verify step compile-once.
"""
from __future__ import annotations

import numpy as np


class Drafter:
    """Interface the engine drives. Subclasses override `propose`; the slot
    lifecycle hooks are optional (stateless drafters ignore them)."""

    def on_admit(self, slot: int, prompt: np.ndarray) -> None:
        """A request was prefilled into `slot` (prompt = its tokens)."""

    def on_release(self, slot: int) -> None:
        """The request in `slot` finished; the slot will be reused."""

    def propose(
        self,
        contexts: list,
        k: int,
        *,
        slot_k: np.ndarray | None = None,
        rng=None,
        temperature: float = 0.0,
        return_probs: bool = False,
    ):
        """contexts: one entry per slot — the full token context (prompt +
        generated) as a 1-D int array for active slots, None for free slots.
        → (max_slots, k) int32 draft tokens (free-slot rows are ignored).

        slot_k: per-slot effective draft length in [0, k] (adaptive-K
        engines). Columns >= slot_k[i] are padding the engine masks out of
        acceptance — a drafter may fill them with anything valid and may
        skip per-slot work for slot_k[i]==0 rows, but must keep the dense
        (max_slots, k) shape.

        rng / temperature: stochastic drafters sample proposals at
        `temperature` using the JAX PRNG key `rng` (greedy when
        temperature<=0 or rng is None).

        return_probs: also return the per-position proposal distributions —
        `(draft, probs)` with probs (max_slots, k, V) float, or
        `(draft, None)` from a deterministic drafter (the engine then treats
        the proposal as one-hot)."""
        raise NotImplementedError


class NgramDrafter(Drafter):
    """Prompt-lookup / self-drafting (no extra weights): match the context's
    trailing n-gram (n = max_n .. min_n) against earlier context; if it
    recurred, propose the k tokens that followed its most recent earlier
    occurrence. Repetition-heavy contexts — code, summarization, test-time
    scaling loops re-reading their own output — hit constantly; the fallback
    (repeat the last token) keeps shapes static when nothing matches."""

    def __init__(self, max_n: int = 3, min_n: int = 1):
        if not 1 <= min_n <= max_n:
            raise ValueError(f"need 1 <= min_n <= max_n, got {min_n}..{max_n}")
        self.max_n = max_n
        self.min_n = min_n

    def _propose_one(self, ctx: np.ndarray, k: int) -> np.ndarray:
        L = len(ctx)
        for n in range(min(self.max_n, L - 1), self.min_n - 1, -1):
            suffix = ctx[L - n:]
            windows = np.lib.stride_tricks.sliding_window_view(ctx, n)
            starts = np.nonzero((windows == suffix).all(axis=1))[0]
            starts = starts[starts < L - n]          # drop the suffix itself
            if starts.size:
                cont = ctx[starts[-1] + n : starts[-1] + n + k]
                out = np.full(k, cont[-1] if cont.size else ctx[-1], ctx.dtype)
                out[: cont.size] = cont
                return out
        return np.full(k, ctx[-1], ctx.dtype)

    def propose(
        self,
        contexts: list,
        k: int,
        *,
        slot_k: np.ndarray | None = None,
        rng=None,
        temperature: float = 0.0,
        return_probs: bool = False,
    ):
        out = np.zeros((len(contexts), k), np.int32)
        for i, ctx in enumerate(contexts):
            if ctx is None or (slot_k is not None and slot_k[i] == 0):
                continue                    # free or skip-drafting slot
            out[i] = self._propose_one(np.asarray(ctx, np.int64), k)
        if return_probs:
            return out, None                # deterministic → one-hot proposal
        return out
