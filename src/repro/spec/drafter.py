"""Drafter protocol + the weight-free prompt-lookup (n-gram) drafter.

A drafter proposes K candidate continuation tokens per active slot each
decode tick. The engine hands it the full per-slot context (prompt +
everything generated so far) and expects a dense (max_slots, K) proposal —
static shapes keep the verify step compile-once.
"""
from __future__ import annotations

import numpy as np


class Drafter:
    """Interface the engine drives. Subclasses override `propose`; the slot
    lifecycle hooks are optional (stateless drafters ignore them)."""

    def on_admit(self, slot: int, prompt: np.ndarray) -> None:
        """A request's prompt is fully in `slot`'s cache (prompt = its
        tokens). Under chunked prefill this fires at the PREFILLING→DECODING
        transition — after the *last* chunk — never mid-prefill, so a
        mirrored-cache drafter syncs the whole prompt exactly once."""

    def on_release(self, slot: int) -> None:
        """The request in `slot` finished; the slot will be reused."""

    def propose(
        self,
        contexts: list,
        k: int,
        *,
        slot_k: np.ndarray | None = None,
        rng=None,
        temperature: float = 0.0,
        return_probs: bool = False,
        tree=None,
    ):
        """contexts: one entry per slot — the full token context (prompt +
        generated) as a 1-D int array for active slots, None for free slots.
        → (max_slots, k) int32 draft tokens (free-slot rows are ignored).

        slot_k: per-slot effective draft length in [0, k] (adaptive-K
        engines, chain mode only). Columns >= slot_k[i] are padding the
        engine masks out of acceptance — a drafter may fill them with
        anything valid and may skip per-slot work for slot_k[i]==0 rows,
        but must keep the dense (max_slots, k) shape.

        rng / temperature: stochastic drafters sample proposals at
        `temperature` using the JAX PRNG key `rng` (greedy when
        temperature<=0 or rng is None).

        return_probs: also return the per-position proposal distributions —
        `(draft, probs)` with probs (max_slots, k, V) float, or
        `(draft, None)` from a deterministic drafter (the engine then treats
        the proposal as one-hot).

        tree: a spec.tree.DraftTree — propose a draft *tree* instead of a
        chain: → (max_slots, tree.n_draft) int32 node tokens in the
        DraftTree flattening order (column j-1 = node j; rank-0 children are
        the drafter's best candidate, so the all-rank-0 path should be the
        chain proposal). Mutually exclusive with slot_k/return_probs."""
        raise NotImplementedError


class NgramDrafter(Drafter):
    """Prompt-lookup / self-drafting (no extra weights): match the context's
    trailing n-gram (n = max_n .. min_n) against earlier context; if it
    recurred, propose the k tokens that followed its most recent earlier
    occurrence. Repetition-heavy contexts — code, summarization, test-time
    scaling loops re-reading their own output — hit constantly; the fallback
    (repeat the last token) keeps shapes static when nothing matches."""

    def __init__(self, max_n: int = 3, min_n: int = 1):
        if not 1 <= min_n <= max_n:
            raise ValueError(f"need 1 <= min_n <= max_n, got {min_n}..{max_n}")
        self.max_n = max_n
        self.min_n = min_n

    def _propose_one(self, ctx: np.ndarray, k: int) -> np.ndarray:
        L = len(ctx)
        for n in range(min(self.max_n, L - 1), self.min_n - 1, -1):
            suffix = ctx[L - n:]
            windows = np.lib.stride_tricks.sliding_window_view(ctx, n)
            starts = np.nonzero((windows == suffix).all(axis=1))[0]
            starts = starts[starts < L - n]          # drop the suffix itself
            if starts.size:
                cont = ctx[starts[-1] + n : starts[-1] + n + k]
                out = np.full(k, cont[-1] if cont.size else ctx[-1], ctx.dtype)
                out[: cont.size] = cont
                return out
        return np.full(k, ctx[-1], ctx.dtype)

    def _candidates(self, ctx: np.ndarray, c: int) -> np.ndarray:
        """Top-c next-token candidates after `ctx`: the tokens that followed
        earlier occurrences of the trailing n-gram, ranked by occurrence
        count (recency breaks ties); padded with the best candidate (or the
        fallback last token) when fewer than c distinct continuations
        exist."""
        L = len(ctx)
        for n in range(min(self.max_n, L - 1), self.min_n - 1, -1):
            suffix = ctx[L - n:]
            windows = np.lib.stride_tricks.sliding_window_view(ctx, n)
            starts = np.nonzero((windows == suffix).all(axis=1))[0]
            starts = starts[starts < L - n]          # drop the suffix itself
            if starts.size:
                nxt = ctx[starts + n]
                uniq, inv, counts = np.unique(
                    nxt, return_inverse=True, return_counts=True
                )
                last_seen = np.zeros(len(uniq), np.int64)
                last_seen[inv] = np.arange(len(nxt))  # most recent occurrence
                order = np.lexsort((last_seen, counts))[::-1]
                ranked = uniq[order]
                out = np.full(c, ranked[0], ranked.dtype)
                out[: min(c, len(ranked))] = ranked[:c]
                return out
        return np.full(c, ctx[-1], ctx.dtype)

    def _propose_tree_one(self, ctx: np.ndarray, tree) -> np.ndarray:
        """Fill one slot's draft tree: every node's children are the top-b
        n-gram continuations of that node's *hypothesis* context (ctx + the
        tokens along its root path), so each branch tracks its own history
        rather than the chain's."""
        out = np.zeros(tree.n_draft, np.int64)
        hyp = {0: ctx}
        cands: dict = {}
        for j in range(1, tree.n_nodes):
            p = int(tree.parents[j])
            if p not in cands:
                width = int(tree.branching[int(tree.depths[j]) - 1])
                cands[p] = self._candidates(hyp[p], width)
            tok = cands[p][int(tree.ranks[j])]
            out[j - 1] = tok
            hyp[j] = np.concatenate([hyp[p], [tok]])
        return out

    def propose(
        self,
        contexts: list,
        k: int,
        *,
        slot_k: np.ndarray | None = None,
        rng=None,
        temperature: float = 0.0,
        return_probs: bool = False,
        tree=None,
    ):
        width = tree.n_draft if tree is not None else k
        out = np.zeros((len(contexts), width), np.int32)
        for i, ctx in enumerate(contexts):
            if ctx is None or (slot_k is not None and slot_k[i] == 0):
                continue                    # free or skip-drafting slot
            ctx = np.asarray(ctx, np.int64)
            if tree is not None:
                out[i] = self._propose_tree_one(ctx, tree)
            else:
                out[i] = self._propose_one(ctx, k)
        if return_probs:
            return out, None                # deterministic → one-hot proposal
        return out
