"""SpecConfig — the speculative-decoding knobs `Engine(spec=...)` consumes."""
from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass
class SpecConfig:
    """Configuration for speculative decoding.

    k            draft tokens proposed per verify step; each step runs the
                 target once over (B, k+1) tokens and emits 1..k+1 of them.
    drafter      'ngram' (prompt-lookup, no extra weights) | 'model' (a
                 smaller ternary draft model).
    ngram_max/min  longest/shortest suffix n-gram the NgramDrafter matches.
    draft_params / draft_cfg  packed params + ModelConfig of the draft model
                 (drafter='model' only). Passing the target's own params is
                 the always-accept oracle — useful for benchmarking the
                 verification ceiling.

    Adaptive per-slot draft length (all shapes stay static — one compiled
    (B, k+1) verify serves every mixture of slot speeds):

    adaptive_k   track a per-slot acceptance-rate EWMA and draft only
                 k_eff = k_policy(ewma) real tokens per slot, padding the
                 row's tail with masked drafts that acceptance never runs
                 past. Cold slots (ewma < skip_below) skip drafting entirely
                 (k_eff=0: a plain last-token decode row), recovering plain-
                 decode cost on adversarial contexts.
    accept_ewma  EWMA decay: after each verify step a drafting slot updates
                 ewma ← accept_ewma·ewma + (1-accept_ewma)·(n_acc/k_eff).
                 Slots start optimistic (ewma=1.0) on admission.
    k_min        floor on k_eff for slots that do draft (and the probe
                 length for cold slots).
    skip_below   acceptance EWMA below which a slot stops drafting.
    probe_every  a cold slot re-probes with k_min drafts after this many
                 consecutive skipped steps, so it can warm back up.

    Stochastic drafting (drafter='model' only):

    stochastic   with temperature>0 serving, the ModelDrafter samples its
                 proposals at the serving temperature and returns the
                 per-position draft distributions; the engine feeds them to
                 `accept_speculative(draft_probs=...)` so emitted tokens are
                 exact target-model samples with the draft model's full
                 (not just argmax) probability mass counted toward
                 acceptance. With temperature<=0 drafting stays greedy.

    Tree-structured verification (Medusa/SpecInfer-style):

    tree         per-depth branching factors (b1, b2, ...) of a draft
                 *tree* of depth k: the drafter proposes its top-b_d
                 candidates at each of the first len(tree) depths (a chain
                 continuation per leaf afterwards), the engine flattens the
                 tree into ONE (B, n_nodes) verify pass — the Vec-LUT
                 kernels see M = n_nodes parallel tokens per slot, well past
                 the chain mode's M = k+1 — and acceptance keeps the longest
                 accepted root-to-leaf path (see spec.tree.DraftTree for the
                 flattening order and serve.sampling.accept_tree for the
                 rule). None (the default) is chain mode, bit-identical to
                 pre-tree behavior. Greedy tree output stays token-for-token
                 identical to plain decode. tree is mutually exclusive with
                 adaptive_k and stochastic (per-slot row padding and exact
                 multi-candidate rejection sampling are chain-mode
                 machinery; see accept_tree's TODO).
    """
    k: int = 4
    drafter: str = "ngram"
    ngram_max: int = 3
    ngram_min: int = 1
    draft_params: Any = None
    draft_cfg: Any = None
    # adaptive per-slot draft length
    adaptive_k: bool = False
    accept_ewma: float = 0.75
    k_min: int = 1
    skip_below: float = 0.125
    probe_every: int = 8
    # stochastic (sampled) ModelDrafter proposals
    stochastic: bool = False
    # tree-structured multi-candidate verification
    tree: tuple | None = None

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"SpecConfig.k must be >= 1, got {self.k}")
        if self.drafter not in ("ngram", "model"):
            raise ValueError(
                f"SpecConfig.drafter must be 'ngram' or 'model', got {self.drafter!r}"
            )
        if self.drafter == "model" and (
            self.draft_params is None or self.draft_cfg is None
        ):
            raise ValueError("drafter='model' needs draft_params and draft_cfg")
        if not 0.0 <= self.accept_ewma < 1.0:
            raise ValueError(
                f"SpecConfig.accept_ewma must be in [0, 1), got {self.accept_ewma}"
            )
        if not 1 <= self.k_min <= self.k:
            raise ValueError(
                f"SpecConfig.k_min must be in [1, k={self.k}], got {self.k_min}"
            )
        if not 0.0 <= self.skip_below <= 1.0:
            raise ValueError(
                f"SpecConfig.skip_below must be in [0, 1], got {self.skip_below}"
            )
        if self.probe_every < 1:
            raise ValueError(
                f"SpecConfig.probe_every must be >= 1, got {self.probe_every}"
            )
        if self.stochastic and self.drafter != "model":
            raise ValueError(
                "SpecConfig.stochastic needs drafter='model'; deterministic "
                "drafters are already exact as one-hot proposals"
            )
        if self.tree is not None:
            if self.adaptive_k:
                raise ValueError(
                    "SpecConfig.tree is incompatible with adaptive_k: per-slot "
                    "k_eff row padding is chain-mode machinery"
                )
            if self.stochastic:
                raise ValueError(
                    "SpecConfig.tree is incompatible with stochastic: exact "
                    "multi-candidate rejection sampling is not implemented "
                    "(accept_tree falls back to greedy path matching at "
                    "temperature>0; see its TODO)"
                )
            self.tree = tuple(int(b) for b in self.tree)
            # validates factors, depth <= k, and the flattened node cap
            from .tree import build_tree

            build_tree(self.k, self.tree)

    def k_policy(self, ewma: float, skip_streak: int = 0) -> int:
        """Effective draft length for a slot whose acceptance EWMA is `ewma`.

        Warm slots draft proportionally to their acceptance (clamped to
        [k_min, k]); cold slots (ewma < skip_below) draft nothing — their
        verify row is a plain last-token decode — except for a k_min probe
        after `probe_every` consecutive skips so acceptance can recover."""
        if not self.adaptive_k:
            return self.k
        if ewma < self.skip_below:
            return self.k_min if skip_streak >= self.probe_every else 0
        return min(self.k, max(self.k_min, int(round(ewma * self.k))))

    def tree_struct(self):
        """The static DraftTree layout for `tree`, or None in chain mode."""
        if self.tree is None:
            return None
        from .tree import build_tree

        return build_tree(self.k, self.tree)

    def build(self, *, max_slots: int, max_len: int, mode: str = "serve"):
        """Instantiate the configured drafter for an engine's slot layout."""
        from .drafter import NgramDrafter
        from .model_drafter import ModelDrafter

        if self.drafter == "ngram":
            return NgramDrafter(max_n=self.ngram_max, min_n=self.ngram_min)
        return ModelDrafter(
            self.draft_params, self.draft_cfg,
            max_slots=max_slots, max_len=max_len, mode=mode,
        )
