"""SpecConfig — the speculative-decoding knobs `Engine(spec=...)` consumes."""
from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass
class SpecConfig:
    """Configuration for speculative decoding.

    k            draft tokens proposed per verify step; each step runs the
                 target once over (B, k+1) tokens and emits 1..k+1 of them.
    drafter      'ngram' (prompt-lookup, no extra weights) | 'model' (a
                 smaller ternary draft model).
    ngram_max/min  longest/shortest suffix n-gram the NgramDrafter matches.
    draft_params / draft_cfg  packed params + ModelConfig of the draft model
                 (drafter='model' only). Passing the target's own params is
                 the always-accept oracle — useful for benchmarking the
                 verification ceiling.
    """
    k: int = 4
    drafter: str = "ngram"
    ngram_max: int = 3
    ngram_min: int = 1
    draft_params: Any = None
    draft_cfg: Any = None

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"SpecConfig.k must be >= 1, got {self.k}")
        if self.drafter not in ("ngram", "model"):
            raise ValueError(
                f"SpecConfig.drafter must be 'ngram' or 'model', got {self.drafter!r}"
            )
        if self.drafter == "model" and (
            self.draft_params is None or self.draft_cfg is None
        ):
            raise ValueError("drafter='model' needs draft_params and draft_cfg")

    def build(self, *, max_slots: int, max_len: int, mode: str = "serve"):
        """Instantiate the configured drafter for an engine's slot layout."""
        from .drafter import NgramDrafter
        from .model_drafter import ModelDrafter

        if self.drafter == "ngram":
            return NgramDrafter(max_n=self.ngram_max, min_n=self.ngram_min)
        return ModelDrafter(
            self.draft_params, self.draft_cfg,
            max_slots=max_slots, max_len=max_len, mode=mode,
        )
