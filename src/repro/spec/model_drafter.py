"""ModelDrafter — a smaller ternary draft model with a mirrored slot cache.

The drafter owns its own packed params, ModelConfig, and a batched KV cache
shaped like the engine's (max_slots, max_len). Each `propose` call:

  1. *resync* — the tokens the target accepted since the last call (1..k+1 of
     them per slot) are pushed through the draft model in ONE multi-token
     `verify_step` (per-slot positions, padded to k+1 so the step is
     compile-once), giving the first draft token from the final real
     position's logits;
  2. *draft* — k-1 single-token decode steps extend the proposal;
  3. *rollback* — the cache idx is restored to the accepted-token count
     (`models.rollback_cache`), so speculated draft state never contaminates
     the next resync. The same stale-entry safety argument as the target's
     rollback applies (position-masked attention + scatter-before-attend).

Drafting is greedy by default, making the proposal deterministic so rejection
sampling treats it as a one-hot proposal distribution. With `temperature > 0`
and a PRNG key the proposal is instead *sampled* at that temperature, and
`propose(..., return_probs=True)` returns the per-position sampling
distributions q (max_slots, k, V) — `sampling.accept_speculative` consumes
them as `draft_probs`, so temperature>0 serving still emits exact target-model
samples while crediting the draft model's full probability mass toward
acceptance (see sampling.accept_speculative; SpecConfig.stochastic wires this
up). Passing the target's own params/config yields the always-accept oracle.

`propose(..., tree=DraftTree)` proposes a token *tree* instead: the same
single chain pass runs (resync + k-1 greedy decode steps — never a per-path
loop), but each position keeps its top-b logits and the tree's depth-d
candidates are the top-b_d tokens after d-1 argmax tokens (Medusa-style; the
all-rank-0 path is exactly the chain proposal).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (
    decode_step,
    init_cache,
    prefill,
    prefill_into_slot,
    rollback_cache,
    verify_step,
)

from .drafter import Drafter


class ModelDrafter(Drafter):
    def __init__(self, params, cfg, *, max_slots: int, max_len: int, mode="serve"):
        if any(s.mixer == "ssm" for s in cfg.layer_specs()):
            raise ValueError("ModelDrafter needs a rollbackable cache; the "
                             "draft config has ssm mixers")
        if any(s.window for s in cfg.layer_specs()):
            raise ValueError("ModelDrafter needs a rollbackable cache; the "
                             "draft config has windowed (ring-cache) layers")
        self.params = params
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_len = max_len
        self.cache = init_cache(cfg, max_slots, max_len)
        #: per-slot count of context tokens the draft cache has absorbed
        self.synced = np.zeros(max_slots, np.int64)
        self._prefill = jax.jit(
            lambda p, c, t: prefill(p, t, c, cfg, mode=mode)
        )
        self._verify = jax.jit(
            lambda p, c, t: verify_step(p, t, c, cfg, mode=mode),
            donate_argnums=(1,),
        )
        self._decode = jax.jit(
            lambda p, c, t: decode_step(p, t, c, cfg, mode=mode),
            donate_argnums=(1,),
        )

    def jit_entries(self) -> dict:
        """Jitted entry points for repro.lint.CompileGuard (via
        Engine.jit_entries)."""
        return {
            "prefill": self._prefill,
            "verify": self._verify,
            "decode": self._decode,
        }

    # ------------------------------------------------------------------
    def on_admit(self, slot: int, prompt: np.ndarray) -> None:
        # the same bucketed admission as Engine.add, so the draft cache's
        # positions can never drift from the target's
        _, self.cache, _ = prefill_into_slot(
            self.params, self.cache, slot, prompt, self.cfg,
            max_len=self.max_len, prefill_fn=self._prefill,
        )
        self.synced[slot] = len(prompt)

    # ------------------------------------------------------------------
    def _pick(self, row_logits, key, temperature: float, want_q: bool):
        """One draft position: (B, V) logits → (B,) host tokens (+ (B, V)
        on-device proposal distribution when requested — kept as a jnp array
        so the engine can hand it to acceptance without a host round-trip).
        Greedy (one-hot q) unless temperature>0 and a key is given, in which
        case tokens are sampled at that temperature and q is the matching
        softmax."""
        if temperature > 0.0 and key is not None:
            scaled = row_logits / temperature
            tok = jax.random.categorical(key, scaled, axis=-1)
            q = jax.nn.softmax(scaled, axis=-1) if want_q else None
        else:
            tok = jnp.argmax(row_logits, axis=-1)
            q = (
                jax.nn.one_hot(tok, row_logits.shape[-1], dtype=jnp.float32)
                if want_q else None
            )
        return np.asarray(tok, np.int32), q

    def _resync(self, contexts: list, window: int):
        """Absorb the tokens the target accepted since the last call (one
        multi-token verify over a (B, window) batch) and roll the cache back
        to the synced boundary. Free slots are left completely alone — their
        `synced` entry and cache rows are whatever the last occupant left
        (admission rescatters both). → (last-real-position logits (B, V),
        rolled-back cache)."""
        b = self.max_slots
        tokens = np.zeros((b, window), np.int32)
        delta = np.ones(b, np.int64)
        base = np.zeros(b, np.int64)
        active = np.zeros(b, bool)
        for i, ctx in enumerate(contexts):
            if ctx is None:
                continue
            active[i] = True
            base[i] = self.synced[i]
            d = len(ctx) - self.synced[i]
            assert 1 <= d <= window, (
                f"slot {i}: draft cache out of sync ({d} unseen tokens, "
                f"window {window}) — was on_admit called?"
            )
            delta[i] = d
            tokens[i, :d] = ctx[self.synced[i]:]
            tokens[i, d:] = ctx[-1]     # pad; rolled back below
        logits, cache = self._verify(self.params, self.cache, jnp.asarray(tokens))
        row = jnp.take_along_axis(
            logits, jnp.asarray(delta - 1)[:, None, None], axis=1
        )[:, 0]                                                # (B, V)
        # keep only the real (accepted) tokens in the cache; free slots keep
        # their stale synced value rather than being scribbled on
        self.synced = np.where(active, base + delta, self.synced)
        cache = rollback_cache(cache, jnp.asarray(self.synced))
        return row, cache, active

    def propose(
        self,
        contexts: list,
        k: int,
        *,
        slot_k: np.ndarray | None = None,
        rng=None,
        temperature: float = 0.0,
        return_probs: bool = False,
        tree=None,
    ):
        if tree is not None:
            return self._propose_tree(contexts, tree)
        b = self.max_slots
        stochastic = temperature > 0.0 and rng is not None
        keys = jax.random.split(rng, k) if stochastic else [None] * k
        # 1. resync: absorb the accepted tokens, one multi-token step
        #    (window k+1 = the most a chain verify step can emit)
        row, cache, active = self._resync(contexts, k + 1)
        draft = np.zeros((b, k), np.int32)
        qs: list = []                   # per-position (B, V) device arrays
        draft[:, 0], q0 = self._pick(row, keys[0], temperature, return_probs)
        qs.append(q0)
        # 2. draft: decode steps (positions continue per slot), capped at
        # the deepest k_eff any *active* slot asked for — a batch that only
        # wants shallow drafts must not pay for k-1 steps. Padded columns
        # (beyond a slot's k_eff, or beyond the cap) repeat the previous
        # token; the engine's draft_mask keeps acceptance away from them.
        k_hi = k if slot_k is None else int(
            max((int(slot_k[i]) for i in range(b) if active[i]), default=0)
        )
        last = jnp.asarray(draft[:, :1])
        for j in range(1, k):
            if j < k_hi:
                step_logits, cache = self._decode(self.params, cache, last)
                draft[:, j], qj = self._pick(
                    step_logits, keys[j], temperature, return_probs
                )
                last = jnp.asarray(draft[:, j : j + 1])
            else:
                draft[:, j] = draft[:, j - 1]
                qj = (
                    jax.nn.one_hot(
                        jnp.asarray(draft[:, j]), self.cfg.vocab,
                        dtype=jnp.float32,
                    )
                    if return_probs else None
                )
            qs.append(qj)
        # 3. rollback: drop the speculated draft state
        self.cache = rollback_cache(cache, jnp.asarray(self.synced))
        if return_probs:
            return draft, jnp.stack(qs, axis=1)      # (B, K, V), on device
        return draft

    def _propose_tree(self, contexts: list, tree) -> np.ndarray:
        """Medusa-style batched tree proposal: ONE greedy chain pass (the
        same resync verify + k-1 decode steps chain mode runs — no per-path
        decode loops), keeping each position's top-b tokens. The depth-d
        candidates are the top-b_d tokens of the chain's logits after d-1
        argmax tokens; rank 0 is the argmax itself, so the all-rank-0 path
        is exactly the chain proposal. Children of non-argmax branches are
        conditioned on the argmax prefix — the standard Medusa
        approximation, traded for keeping drafting a single chain pass.
        → (max_slots, tree.n_draft) int32 node tokens."""
        b = self.max_slots
        k = tree.k
        row, cache, _ = self._resync(contexts, k + 1)
        # per-depth top-b candidates off the greedy chain's logits
        cand: list = []                  # cand[d-1]: (B, branching[d-1])
        _, top = jax.lax.top_k(row, int(tree.branching[0]))
        cand.append(np.asarray(top, np.int32))
        last = jnp.asarray(cand[0][:, :1])          # argmax chain token
        for d in range(2, k + 1):
            step_logits, cache = self._decode(self.params, cache, last)
            _, top = jax.lax.top_k(step_logits, int(tree.branching[d - 1]))
            cand.append(np.asarray(top, np.int32))
            last = jnp.asarray(cand[-1][:, :1])
        self.cache = rollback_cache(cache, jnp.asarray(self.synced))
        out = np.zeros((b, tree.n_draft), np.int32)
        for j in range(1, tree.n_nodes):
            d = int(tree.depths[j])
            out[:, j - 1] = cand[d - 1][:, int(tree.ranks[j])]
        return out
