"""ModelDrafter — a smaller ternary draft model with a mirrored slot cache.

The drafter owns its own packed params, ModelConfig, and a batched KV cache
shaped like the engine's (max_slots, max_len). Each `propose` call:

  1. *resync* — the tokens the target accepted since the last call (1..k+1 of
     them per slot) are pushed through the draft model in ONE multi-token
     `verify_step` (per-slot positions, padded to k+1 so the step is
     compile-once), giving the first draft token from the final real
     position's logits;
  2. *draft* — k-1 single-token decode steps extend the proposal;
  3. *rollback* — the cache idx is restored to the accepted-token count
     (`models.rollback_cache`), so speculated draft state never contaminates
     the next resync. The same stale-entry safety argument as the target's
     rollback applies (position-masked attention + scatter-before-attend).

Drafting is greedy by default, making the proposal deterministic so rejection
sampling treats it as a one-hot proposal distribution. With `temperature > 0`
and a PRNG key the proposal is instead *sampled* at that temperature, and
`propose(..., return_probs=True)` returns the per-position sampling
distributions q (max_slots, k, V) — `sampling.accept_speculative` consumes
them as `draft_probs`, so temperature>0 serving still emits exact target-model
samples while crediting the draft model's full probability mass toward
acceptance (see sampling.accept_speculative; SpecConfig.stochastic wires this
up). Passing the target's own params/config yields the always-accept oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (
    decode_step,
    init_cache,
    prefill,
    prefill_into_slot,
    rollback_cache,
    verify_step,
)

from .drafter import Drafter


class ModelDrafter(Drafter):
    def __init__(self, params, cfg, *, max_slots: int, max_len: int, mode="serve"):
        if any(s.mixer == "ssm" for s in cfg.layer_specs()):
            raise ValueError("ModelDrafter needs a rollbackable cache; the "
                             "draft config has ssm mixers")
        if any(s.window for s in cfg.layer_specs()):
            raise ValueError("ModelDrafter needs a rollbackable cache; the "
                             "draft config has windowed (ring-cache) layers")
        self.params = params
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_len = max_len
        self.cache = init_cache(cfg, max_slots, max_len)
        #: per-slot count of context tokens the draft cache has absorbed
        self.synced = np.zeros(max_slots, np.int64)
        self._prefill = jax.jit(
            lambda p, c, t: prefill(p, t, c, cfg, mode=mode)
        )
        self._verify = jax.jit(
            lambda p, c, t: verify_step(p, t, c, cfg, mode=mode),
            donate_argnums=(1,),
        )
        self._decode = jax.jit(
            lambda p, c, t: decode_step(p, t, c, cfg, mode=mode),
            donate_argnums=(1,),
        )

    # ------------------------------------------------------------------
    def on_admit(self, slot: int, prompt: np.ndarray) -> None:
        # the same bucketed admission as Engine.add, so the draft cache's
        # positions can never drift from the target's
        _, self.cache, _ = prefill_into_slot(
            self.params, self.cache, slot, prompt, self.cfg,
            max_len=self.max_len, prefill_fn=self._prefill,
        )
        self.synced[slot] = len(prompt)

    # ------------------------------------------------------------------
    def _pick(self, row_logits, key, temperature: float, want_q: bool):
        """One draft position: (B, V) logits → (B,) host tokens (+ (B, V)
        on-device proposal distribution when requested — kept as a jnp array
        so the engine can hand it to acceptance without a host round-trip).
        Greedy (one-hot q) unless temperature>0 and a key is given, in which
        case tokens are sampled at that temperature and q is the matching
        softmax."""
        if temperature > 0.0 and key is not None:
            scaled = row_logits / temperature
            tok = jax.random.categorical(key, scaled, axis=-1)
            q = jax.nn.softmax(scaled, axis=-1) if want_q else None
        else:
            tok = jnp.argmax(row_logits, axis=-1)
            q = (
                jax.nn.one_hot(tok, row_logits.shape[-1], dtype=jnp.float32)
                if want_q else None
            )
        return np.asarray(tok, np.int32), q

    def propose(
        self,
        contexts: list,
        k: int,
        *,
        slot_k: np.ndarray | None = None,
        rng=None,
        temperature: float = 0.0,
        return_probs: bool = False,
    ):
        b = self.max_slots
        pad = k + 1                     # max tokens a verify step can emit
        tokens = np.zeros((b, pad), np.int32)
        delta = np.ones(b, np.int64)
        base = np.zeros(b, np.int64)
        for i, ctx in enumerate(contexts):
            if ctx is None:
                continue
            base[i] = self.synced[i]
            d = len(ctx) - self.synced[i]
            assert 1 <= d <= pad, (
                f"slot {i}: draft cache out of sync ({d} unseen tokens, "
                f"window {pad}) — was on_admit called?"
            )
            delta[i] = d
            tokens[i, :d] = ctx[self.synced[i]:]
            tokens[i, d:] = ctx[-1]     # pad; rolled back below
        stochastic = temperature > 0.0 and rng is not None
        keys = jax.random.split(rng, k) if stochastic else [None] * k
        # 1. resync: absorb the accepted tokens, one multi-token step
        logits, cache = self._verify(self.params, self.cache, jnp.asarray(tokens))
        draft = np.zeros((b, k), np.int32)
        qs: list = []                   # per-position (B, V) device arrays
        row = jnp.take_along_axis(
            logits, jnp.asarray(delta - 1)[:, None, None], axis=1
        )[:, 0]                                                # (B, V)
        draft[:, 0], q0 = self._pick(row, keys[0], temperature, return_probs)
        qs.append(q0)
        # keep only the real (accepted) tokens in the cache
        cache = rollback_cache(cache, jnp.asarray(base + delta))
        self.synced = base + delta
        # 2. draft: k-1 decode steps (positions continue per slot). slot_k
        # rows needing fewer tokens still ride along — the step is batched
        # and compile-once, and the engine masks their padded columns.
        last = jnp.asarray(draft[:, :1])
        for j in range(1, k):
            step_logits, cache = self._decode(self.params, cache, last)
            draft[:, j], qj = self._pick(
                step_logits, keys[j], temperature, return_probs
            )
            qs.append(qj)
            last = jnp.asarray(draft[:, j : j + 1])
        # 3. rollback: drop the speculated draft state
        self.cache = rollback_cache(cache, jnp.asarray(self.synced))
        if return_probs:
            return draft, jnp.stack(qs, axis=1)      # (B, K, V), on device
        return draft
