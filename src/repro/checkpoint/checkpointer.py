"""Sharded, atomic, async checkpointing with elastic restore.

Layout:  <dir>/step_<N>/
           manifest.json       — leaf paths, shapes, dtypes, treedef repr
           leaf_<i>.npy        — one file per pytree leaf (process 0's view;
                                 multi-host would write per-process shards)
           COMMIT              — written last; a step dir without COMMIT is
                                 ignored (atomicity against mid-write failure)

Restore re-shards onto the *current* mesh via device_put with the caller's
NamedShardings — elastic scaling: a checkpoint written on mesh A restores
onto mesh B (different shape/axis sizes) unchanged.

Async: `save(..., blocking=False)` snapshots to host (device_get) then writes
on a daemon thread; `wait()` joins before the next save or program exit.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

_SENTINEL = "COMMIT"

#: numpy can't natively serialize ml_dtypes (bfloat16, fp8): store as a raw
#: same-width integer view and record the true dtype in the manifest.
_RAW_VIEWS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
              "float8_e5m2": np.uint8}


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [l for _, l in flat]
    return paths, leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state, extra: dict | None = None, blocking=True):
        self.wait()
        paths, leaves, _ = _leaf_paths(state)
        host_leaves = []
        for l in leaves:
            arr = np.asarray(jax.device_get(l))
            if str(arr.dtype) in _RAW_VIEWS:
                arr = arr.view(_RAW_VIEWS[str(arr.dtype)])
            host_leaves.append(arr)

        def _write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            manifest = {
                "step": step,
                "extra": extra or {},
                "leaves": [
                    {"path": p, "shape": list(l.shape), "dtype": str(t.dtype)}
                    for p, (l, t) in zip(paths, zip(host_leaves, leaves))
                ],
            }
            for i, l in enumerate(host_leaves):
                np.save(os.path.join(tmp, f"leaf_{i}.npy"), l)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            with open(os.path.join(tmp, _SENTINEL), "w") as f:
                f.write("ok")
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                os.path.join(self.dir, name, _SENTINEL)
            ):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, abstract_state, step: int | None = None, shardings=None):
        """abstract_state: pytree matching the saved structure (shapes may be
        resharded). shardings: optional matching tree of NamedShardings for
        elastic placement; default = single-device host arrays.
        → (state, extra)"""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        paths, leaves, treedef = _leaf_paths(abstract_state)
        saved = {e["path"]: i for i, e in enumerate(manifest["leaves"])}
        out_leaves = []
        sh_leaves = (
            jax.tree_util.tree_flatten_with_path(shardings)[0]
            if shardings is not None else None
        )
        for j, (p, ab) in enumerate(zip(paths, leaves)):
            if p not in saved:
                raise KeyError(f"checkpoint missing leaf {p}")
            arr = np.load(os.path.join(d, f"leaf_{saved[p]}.npy"))
            want_dt = manifest["leaves"][saved[p]]["dtype"]
            if str(arr.dtype) != want_dt and want_dt in _RAW_VIEWS:
                arr = arr.view(np.dtype(want_dt))
            if tuple(arr.shape) != tuple(ab.shape):
                raise ValueError(f"shape mismatch for {p}: {arr.shape} vs {ab.shape}")
            if sh_leaves is not None:
                arr = jax.device_put(arr, sh_leaves[j][1])
            out_leaves.append(arr)
        state = jax.tree_util.tree_unflatten(treedef, out_leaves)
        return state, manifest.get("extra", {})
