"""repro.checkpoint — sharded atomic async checkpointing, elastic restore."""
from .checkpointer import Checkpointer

__all__ = ["Checkpointer"]
