"""repro.obs — serving/kernel observability: metrics registry + tracer.

The layer is **off by default and free when off**: `Engine(obs=None)` gets
the shared null `Obs` whose every method early-returns (no events, no metric
objects, no allocation on the step path), so the hot loop pays one attribute
check per tick. Enabling it costs host-side bookkeeping only — nothing here
touches jax arrays or adds device work.

Wiring (see docs/observability.md):

  * ``Engine(obs=ObsConfig(...))`` — the engine records TTFT/TPOT histograms,
    per-step wall-time histograms and spans, and per-tick effective-M samples
    (the parallel-token count the Vec-LUT mpGeMM kernels actually saw — the
    paper's central variable);
  * ``ContinuousBatchingScheduler`` — per-tick spans + queue-depth /
    slot-occupancy gauges synced to engine state every tick;
  * ``kernels/ops.ternary_matmul`` — trace-time mpGeMM dispatch spans
    annotated with (M, N, K, impl, fusion, tile);
  * ``kernels/autotune.tune`` — per-(shape, impl) timing samples + achieved
    GB/s / GFLOP/s gauges (bytes/FLOPs from roofline.analysis.mpgemm_cost);
  * ``launch.serve --metrics-out/--trace-out/--stats-interval`` — exports and
    registry-backed periodic stats lines.

Kernel-side hooks discover the active instance through ``install()`` /
``current()`` (module global): the kernels cannot take an `obs` parameter
without changing every call signature, and at most one engine per process is
being observed in practice. ``install(None)`` detaches.
"""
from __future__ import annotations

import dataclasses
import time

from .metrics import (
    M_BUCKETS,
    STEP_BUCKETS,
    TPOT_BUCKETS,
    TTFT_BUCKETS,
    MetricsRegistry,
)
from .trace import _NULL_SPAN, Tracer

__all__ = [
    "ObsConfig", "Obs", "NULL_OBS", "install", "current",
    "MetricsRegistry", "Tracer",
]


@dataclasses.dataclass
class ObsConfig:
    """Observability knobs. `enabled=False` yields the shared null instance
    (identical to passing no config at all)."""
    enabled: bool = True
    trace: bool = True                  # record trace_event spans
    trace_capacity: int = 65536         # ring size; oldest events dropped
    series_capacity: int = 4096         # per-tick sample ring size
    metrics_out: str | None = None      # finalize(): JSON metrics dump path
    trace_out: str | None = None        # finalize(): trace JSON path


class Obs:
    """Facade owning one MetricsRegistry + one Tracer, with the serving
    metric surface pre-named in one place so engine/scheduler/launch can
    never diverge on naming."""

    def __init__(self, config: ObsConfig | None = None):
        self.config = config or ObsConfig()
        self.enabled = self.config.enabled
        self.registry = MetricsRegistry()
        self.tracer = Tracer(
            capacity=self.config.trace_capacity,
            enabled=self.enabled and self.config.trace,
        )
        if not self.enabled:
            return
        r = self.registry
        cap = self.config.series_capacity
        # gauges synced tick-by-tick to engine/scheduler state
        self.g_waiting = r.gauge(
            "repro:num_requests_waiting", "requests queued, not yet admitted")
        self.g_running = r.gauge(
            "repro:num_requests_running", "slots in DECODING state")
        self.g_prefilling = r.gauge(
            "repro:num_requests_prefilling", "slots in PREFILLING state")
        self.g_slots_free = r.gauge(
            "repro:num_slots_free", "slots in FREE state")
        # request lifecycle counters (synced from scheduler/engine totals)
        self.c_completed = r.counter(
            "repro:request_success_total", "requests finished with output")
        self.c_rejected = r.counter(
            "repro:request_rejected_total", "admission rejections (won't fit)")
        self.c_prompt_tok = r.counter(
            "repro:prompt_tokens_total", "real prompt tokens prefilled")
        self.c_gen_tok = r.counter(
            "repro:generation_tokens_total", "tokens emitted by decode/verify")
        self.c_drafted = r.counter(
            "repro:spec_num_draft_tokens_total", "draft tokens proposed")
        self.c_accepted = r.counter(
            "repro:spec_num_accepted_tokens_total", "draft tokens accepted")
        # latency histograms
        self.h_ttft = r.histogram(
            "repro:time_to_first_token_seconds",
            "submit → first generated token", buckets=TTFT_BUCKETS)
        self.h_tpot = r.histogram(
            "repro:time_per_output_token_seconds",
            "mean inter-token latency per finished request",
            buckets=TPOT_BUCKETS)
        # paged-KV pool + radix prefix sharing (flat zero on unpaged engines)
        self.g_pages_free = r.gauge(
            "repro:kv_pages_free", "allocatable KV pages currently free")
        self.g_pages_total = r.gauge(
            "repro:kv_pages_total", "allocatable KV pages (null page excluded)")
        self.g_pages_shared = r.gauge(
            "repro:kv_pages_shared",
            "device-resident pages held by the radix prefix index")
        self.g_pages_offloaded = r.gauge(
            "repro:kv_pages_offloaded", "prefix pages parked in host RAM")
        self.c_prefix_hit_tok = r.counter(
            "repro:prefix_hit_tokens_total",
            "prompt tokens admitted straight off shared prefix pages")
        self.c_prefix_hit_req = r.counter(
            "repro:prefix_hit_requests_total",
            "admissions that matched at least one shared prefix page")
        self.c_pages_out = r.counter(
            "repro:kv_pages_paged_out_total", "cold pages moved to host RAM")
        self.c_pages_in = r.counter(
            "repro:kv_pages_paged_in_total", "host pages restored on a hit")
        self.c_pages_dropped = r.counter(
            "repro:kv_pages_dropped_total",
            "cold prefix pages evicted outright (offload tier full/off)")
        # per-tick batch composition: the M the mpGeMM kernels actually saw
        self.s_eff_m = r.series(
            "repro:tick_effective_m",
            "real parallel tokens through the batched step, per tick",
            capacity=cap)
        self.h_eff_m = r.histogram(
            "repro:mpgemm_batch_tokens",
            "real parallel tokens (M) per batched step", buckets=M_BUCKETS)

    # -- engine step instrumentation ------------------------------------
    def now(self) -> float:
        return time.perf_counter()

    def span(self, name: str, **args):
        if not self.enabled:
            return _NULL_SPAN
        return self.tracer.span(name, **args)

    def step_event(self, kind: str, t0: float, m_real: int, m_padded: int,
                   **extra) -> None:
        """One batched engine step ran over `m_real` real parallel tokens
        (`m_padded` including pad rows) in [t0, now]."""
        if not self.enabled:
            return
        t1 = time.perf_counter()
        self.registry.histogram(
            "repro:engine_step_seconds", "batched step wall time",
            labels={"kind": kind}, buckets=STEP_BUCKETS,
        ).observe(t1 - t0)
        self.s_eff_m.record(m_real)
        self.h_eff_m.observe(m_real)
        self.tracer.complete(
            f"engine_step/{kind}", t0, t1,
            args=dict(m_real=int(m_real), m_padded=int(m_padded), **extra),
        )

    def observe_ttft(self, seconds: float) -> None:
        if self.enabled:
            self.h_ttft.observe(seconds)

    def observe_tpot(self, seconds: float) -> None:
        if self.enabled:
            self.h_tpot.observe(seconds)

    def on_tick(self, engine, queue_depth: int, completed: int,
                rejected: int) -> None:
        """End-of-tick sync: queue/slot gauges + engine counter mirrors (the
        engine's plain attributes stay the source of truth; the registry is
        the export surface, so nothing is double-counted)."""
        if not self.enabled:
            return
        self.g_waiting.set(queue_depth)
        self.g_running.set(int(engine.active.sum()))
        self.g_prefilling.set(len(engine.prefilling))
        self.g_slots_free.set(sum(engine.slot_free))
        self.c_completed.sync_to(completed)
        self.c_rejected.sync_to(rejected)
        self.c_prompt_tok.sync_to(engine.prefill_tokens)
        self.c_gen_tok.sync_to(engine.decode_tokens)
        self.c_drafted.sync_to(engine.drafted_tokens)
        self.c_accepted.sync_to(engine.accepted_tokens)
        pager = getattr(engine, "pager", None)
        if pager is not None:
            self.g_pages_free.set(pager.free_pages)
            self.g_pages_total.set(pager.total_pages)
            self.g_pages_shared.set(pager.shared_pages)
            self.g_pages_offloaded.set(pager.offloaded_pages)
            self.c_prefix_hit_tok.sync_to(pager.prefix_hit_tokens)
            self.c_prefix_hit_req.sync_to(pager.prefix_hit_requests)
            self.c_pages_out.sync_to(pager.pages_paged_out)
            self.c_pages_in.sync_to(pager.pages_paged_in)
            self.c_pages_dropped.sync_to(pager.pages_dropped)

    # -- kernel hooks (ops.py / autotune.py via install()/current()) -----
    def mpgemm_span(self, m_tokens: int, k: int, n_out: int, impl: str,
                    fusion: str, tiles=None):
        """Trace-time span around one mpGeMM dispatch. m_tokens is the
        paper's M (parallel tokens); n_out × k is the weight shape."""
        if not self.enabled:
            return _NULL_SPAN
        self.registry.counter(
            "repro:mpgemm_dispatch_total",
            "mpGeMM dispatches traced (one per compiled shape)",
            labels={"impl": str(impl), "fusion": str(fusion)},
        ).inc()
        return self.tracer.span(
            "mpgemm_dispatch", m=int(m_tokens), k=int(k), n=int(n_out),
            impl=str(impl), fusion=str(fusion), tile=tiles,
        )

    def record_kernel_sample(self, *, g: int, impl: str, m: int, kg: int,
                             n: int, fused: bool, seconds: float) -> None:
        """One measured kernel timing (autotune trial winner / benchmark):
        per-(shape, impl) series + achieved-bandwidth/compute gauges. Here
        (m, kg·g) is the weight shape and n the parallel-token count (the
        autotuner's convention)."""
        if not self.enabled or seconds <= 0:
            return
        labels = {"impl": str(impl), "g": str(g), "shape": f"{m}x{kg * g}",
                  "m_tokens": str(n)}
        self.registry.series(
            "repro:mpgemm_kernel_seconds", "measured kernel wall seconds",
            labels=labels, capacity=self.config.series_capacity,
        ).record(seconds)
        from repro.roofline.analysis import mpgemm_cost

        flops, bytes_ = mpgemm_cost(m, kg * g, n, g, fused=fused)
        self.registry.gauge(
            "repro:mpgemm_achieved_gflops", "achieved GFLOP/s (last sample)",
            labels=labels).set(flops / seconds / 1e9)
        self.registry.gauge(
            "repro:mpgemm_achieved_gbps", "achieved HBM GB/s (last sample)",
            labels=labels).set(bytes_ / seconds / 1e9)

    # -- reporting -------------------------------------------------------
    def stats_line(self) -> str:
        """One compact human line from the registry (launch.serve's periodic
        logger) — every figure read back from the metric objects, not from
        ad-hoc engine/ServeStats fields."""
        if not self.enabled:
            return "obs disabled"
        parts = [
            f"wait={int(self.g_waiting.value)}",
            f"run={int(self.g_running.value)}",
            f"prefill={int(self.g_prefilling.value)}",
            f"free={int(self.g_slots_free.value)}",
            f"done={int(self.c_completed.value)}",
            f"tok={int(self.c_prompt_tok.value)}+{int(self.c_gen_tok.value)}",
        ]
        if self.h_ttft.count:
            parts.append(f"ttft_p50={1e3 * self.h_ttft.percentile(0.5):.1f}ms")
        if self.h_tpot.count:
            parts.append(f"tpot_p50={1e3 * self.h_tpot.percentile(0.5):.1f}ms")
        if self.s_eff_m.count:
            parts.append(f"eff_m={self.s_eff_m.mean:.1f}")
        if self.c_drafted.value:
            acc = self.c_accepted.value / self.c_drafted.value
            parts.append(f"accept={acc:.2f}")
        if self.g_pages_total.value:
            parts.append(
                f"pages={int(self.g_pages_free.value)}/"
                f"{int(self.g_pages_total.value)}"
            )
            if self.c_prefix_hit_tok.value:
                parts.append(f"prefix_hit={int(self.c_prefix_hit_tok.value)}")
        if self.c_rejected.value:
            parts.append(f"rejected={int(self.c_rejected.value)}")
        return " ".join(parts)

    def finalize(self) -> list[str]:
        """Write the configured exports; returns the paths written."""
        out = []
        if self.enabled and self.config.metrics_out:
            out.append(self.registry.dump(self.config.metrics_out))
        if self.enabled and self.config.trace_out:
            out.append(self.tracer.write(self.config.trace_out))
        return out


#: the shared always-off instance — `Engine(obs=None)` resolves to this
NULL_OBS = Obs(ObsConfig(enabled=False))

_current: Obs | None = None


def install(obs: Obs | None) -> None:
    """Publish `obs` to the kernel-side hooks (ops/autotune); None detaches."""
    global _current
    _current = obs if (obs is not None and obs.enabled) else None


def current() -> Obs | None:
    """The installed Obs, or None — kernel hooks must treat None as off."""
    return _current
