"""Ring-buffered span tracer emitting Chrome/Perfetto ``trace_event`` JSON.

Spans wrap the serving stack's host-side control flow — scheduler tick →
chunk_step / decode_step / verify → mpGeMM dispatch — with free-form ``args``
(the mpGeMM spans carry (M, N, K, impl, fusion, tile), so a slow tick is
attributable to the kernel shape it compiled/launched). Events land in a
bounded deque (oldest dropped, drop count kept), so an always-on tracer in a
long serve can never grow without bound.

Timestamps come from ``time.perf_counter()`` rebased to the tracer's start,
in microseconds (the trace_event unit). Output is the JSON *object* format
(``{"traceEvents": [...]}``) which both ``chrome://tracing`` and
https://ui.perfetto.dev load directly.

One semantic caveat, documented rather than hidden: the engine's compute runs
inside jit-compiled steps, so per-kernel spans cannot be recorded at
execution time from python. The mpGeMM spans are therefore **trace-time**
events — they fire when a step traces/compiles for a new shape and their
duration is the host-side dispatch (tracing) cost — while the per-tick step
spans carry the measured wall time of every execution. Shape attribution +
tick timing together give the (shape → slow tick) mapping the crossover
analysis needs.
"""
from __future__ import annotations

import json
import time
from collections import deque


class _Span:
    """Mutable in-flight span; ``args`` may be extended before exit."""

    __slots__ = ("tracer", "name", "args", "t0")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self.tracer = tracer
        self.name = name
        self.args = args
        self.t0 = 0.0

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.tracer.complete(self.name, self.t0, args=self.args)
        return False


class _NullSpan:
    """Shared no-op span: zero allocation on the disabled path. Its ``args``
    is a throwaway dict so `sp.args[...] = v` stays legal (and discarded)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    @property
    def args(self) -> dict:
        return {}


_NULL_SPAN = _NullSpan()


class Tracer:
    def __init__(self, capacity: int = 65536, enabled: bool = True,
                 pid: int = 0, tid: int = 0):
        self.enabled = enabled
        self.capacity = capacity
        self.pid = pid
        self.tid = tid
        self.events: deque[dict] = deque(maxlen=capacity)
        self.emitted = 0            # lifetime count (dropped = emitted - len)
        self._t0 = time.perf_counter()

    # -- recording -------------------------------------------------------
    def _ts(self, t: float) -> float:
        return (t - self._t0) * 1e6

    def span(self, name: str, **args):
        """Context manager recording a complete ('X') event on exit."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, args)

    def complete(self, name: str, t0: float, t1: float | None = None,
                 args: dict | None = None) -> None:
        """Record a complete event for work measured externally
        ([t0, t1 or now] in perf_counter seconds)."""
        if not self.enabled:
            return
        t1 = time.perf_counter() if t1 is None else t1
        self.emitted += 1
        self.events.append({
            "name": name, "ph": "X", "pid": self.pid, "tid": self.tid,
            "ts": self._ts(t0), "dur": max((t1 - t0) * 1e6, 0.0),
            "args": dict(args or {}),
        })

    def instant(self, name: str, **args) -> None:
        if not self.enabled:
            return
        self.emitted += 1
        self.events.append({
            "name": name, "ph": "i", "s": "t", "pid": self.pid,
            "tid": self.tid, "ts": self._ts(time.perf_counter()),
            "args": args,
        })

    @property
    def dropped(self) -> int:
        return self.emitted - len(self.events)

    # -- export ----------------------------------------------------------
    def to_json(self) -> dict:
        return {
            "traceEvents": list(self.events),
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped},
        }

    def write(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)
        return path
