"""Metrics registry: counters, gauges, fixed-bucket histograms, sample series.

Pure-stdlib (no jax import): the registry is host-side bookkeeping that the
serving stack updates at tick boundaries, so it must never add device work to
the hot path. Metric names follow the vLLM serving vocabulary with a
``repro:`` prefix (``repro:num_requests_waiting``,
``repro:time_to_first_token_seconds``, ...) so dashboards built against vLLM
transfer with a prefix swap; see docs/observability.md for the full table.

Two export surfaces:

  * ``to_prometheus()`` — Prometheus text exposition format 0.0.4 (counters
    get a ``_total``-preserving TYPE line, histograms expand to
    ``_bucket{le=...}`` / ``_sum`` / ``_count``);
  * ``to_json()`` / ``dump(path)`` — a lossless JSON snapshot (histogram
    bucket counts, raw series samples) for offline analysis and the
    acceptance checks in tests/test_obs.py.

Histograms are fixed-bucket (cumulative-count semantics, like Prometheus);
``percentile(q)`` linearly interpolates within the winning bucket, which is
exact enough for TTFT/TPOT p50/p95/p99 reporting at the bucket resolutions
used here.
"""
from __future__ import annotations

import bisect
import json
import math
from collections import deque

#: default latency bucket edges (seconds) — vLLM's TTFT histogram ladder
TTFT_BUCKETS = (
    0.001, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)
#: per-output-token latency ladder (decode steps are ms-scale)
TPOT_BUCKETS = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0,
)
#: engine-step wall-time ladder (same scale as TPOT but wider tail: a chunk
#: step over many slots legitimately runs long)
STEP_BUCKETS = TPOT_BUCKETS + (2.5, 5.0)
#: effective parallel-token (M) ladder — powers of two up to a big batch
M_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)


def _fmt(v: float) -> str:
    """Prometheus float formatting: integers without the trailing .0."""
    if v == math.inf:
        return "+Inf"
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


def _label_str(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))
    return "{" + inner + "}"


class Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str = "", labels: dict | None = None):
        self.name = name
        self.help = help
        self.labels = dict(labels or {})


class Counter(Metric):
    kind = "counter"

    def __init__(self, name, help="", labels=None):
        super().__init__(name, help, labels)
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name} cannot decrease (inc {v})")
        self.value += v

    def sync_to(self, total: float) -> None:
        """Mirror an external monotone counter (the engine's live attributes
        are the source of truth; the registry copy can only move forward)."""
        if total > self.value:
            self.value = float(total)


class Gauge(Metric):
    kind = "gauge"

    def __init__(self, name, help="", labels=None):
        super().__init__(name, help, labels)
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Histogram(Metric):
    """Fixed-bucket histogram with Prometheus cumulative-bucket exposition
    and interpolated percentiles. Bucket edges are upper bounds; an implicit
    +Inf bucket catches the tail."""

    kind = "histogram"

    def __init__(self, name, help="", labels=None, buckets=TTFT_BUCKETS):
        super().__init__(name, help, labels)
        self.edges = tuple(sorted(float(b) for b in buckets))
        if not self.edges:
            raise ValueError(f"histogram {name} needs at least one bucket")
        self.counts = [0] * (len(self.edges) + 1)   # per-bucket (not cumulative)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.edges, float(v))] += 1
        self.sum += float(v)
        self.count += 1

    def cumulative(self) -> list[int]:
        out, acc = [], 0
        for c in self.counts:
            acc += c
            out.append(acc)
        return out

    def percentile(self, q: float) -> float:
        """Interpolated q-quantile (q in [0, 1]) from the bucket counts.
        Within the winning bucket the mass is assumed uniform; the +Inf
        bucket reports its lower edge (the histogram cannot see further)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        if self.count == 0:
            return 0.0
        rank = q * self.count
        acc = 0
        for i, c in enumerate(self.counts):
            if acc + c >= rank and c > 0:
                lo = self.edges[i - 1] if i > 0 else 0.0
                if i == len(self.edges):           # +Inf tail
                    return self.edges[-1]
                hi = self.edges[i]
                return lo + (hi - lo) * max(rank - acc, 0.0) / c
            acc += c
        return self.edges[-1]


class Series(Metric):
    """Bounded ring of raw samples (newest kept) + lifetime count/sum — for
    low-volume per-tick signals where the raw sequence matters (effective M
    per tick, kernel timing samples). JSON dump includes the samples."""

    kind = "series"

    def __init__(self, name, help="", labels=None, capacity: int = 4096):
        super().__init__(name, help, labels)
        self.samples: deque[float] = deque(maxlen=capacity)
        self.sum = 0.0
        self.count = 0

    def record(self, v: float) -> None:
        self.samples.append(float(v))
        self.sum += float(v)
        self.count += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


class MetricsRegistry:
    """get-or-create registry keyed on (name, sorted labels)."""

    def __init__(self):
        self._metrics: dict[tuple, Metric] = {}

    def _get(self, cls, name, help, labels, **kw) -> Metric:
        key = (name, tuple(sorted((labels or {}).items())))
        m = self._metrics.get(key)
        if m is None:
            m = cls(name, help, labels, **kw)
            self._metrics[key] = m
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name} already registered as {m.kind}, not {cls.kind}"
            )
        return m

    def counter(self, name, help="", labels=None) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name, help="", labels=None) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name, help="", labels=None, buckets=TTFT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, labels, buckets=buckets)

    def series(self, name, help="", labels=None, capacity: int = 4096) -> Series:
        return self._get(Series, name, help, labels, capacity=capacity)

    def find(self, name: str, labels: dict | None = None) -> Metric | None:
        return self._metrics.get((name, tuple(sorted((labels or {}).items()))))

    def all(self) -> list[Metric]:
        return list(self._metrics.values())

    # -- export ----------------------------------------------------------
    def to_prometheus(self) -> str:
        lines: list[str] = []
        typed: set[str] = set()
        for m in self._metrics.values():
            if m.name not in typed:
                typed.add(m.name)
                if m.help:
                    lines.append(f"# HELP {m.name} {m.help}")
                # series exposes like an (uncapped-observation) summary
                kind = "summary" if m.kind == "series" else m.kind
                lines.append(f"# TYPE {m.name} {kind}")
            ls = _label_str(m.labels)
            if isinstance(m, Histogram):
                cum = m.cumulative()
                for edge, c in zip(m.edges + (math.inf,), cum):
                    le = dict(m.labels, le=_fmt(edge))
                    lines.append(f"{m.name}_bucket{_label_str(le)} {c}")
                lines.append(f"{m.name}_sum{ls} {_fmt(m.sum)}")
                lines.append(f"{m.name}_count{ls} {m.count}")
            elif isinstance(m, Series):
                lines.append(f"{m.name}_sum{ls} {_fmt(m.sum)}")
                lines.append(f"{m.name}_count{ls} {m.count}")
            else:
                lines.append(f"{m.name}{ls} {_fmt(m.value)}")
        return "\n".join(lines) + "\n"

    def to_json(self) -> dict:
        out: list[dict] = []
        for m in self._metrics.values():
            d: dict = dict(name=m.name, kind=m.kind, labels=m.labels)
            if isinstance(m, Histogram):
                d.update(
                    buckets=list(m.edges), counts=list(m.counts),
                    sum=m.sum, count=m.count,
                    p50=m.percentile(0.50), p95=m.percentile(0.95),
                    p99=m.percentile(0.99),
                )
            elif isinstance(m, Series):
                d.update(samples=list(m.samples), sum=m.sum, count=m.count,
                         mean=m.mean)
            else:
                d["value"] = m.value
            out.append(d)
        return {"metrics": out}

    def dump(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)
        return path
