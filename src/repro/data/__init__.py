"""repro.data — deterministic synthetic LM pipeline."""
from .pipeline import DataConfig, SyntheticLM, host_batch_slice

__all__ = ["DataConfig", "SyntheticLM", "host_batch_slice"]
