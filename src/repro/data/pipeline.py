"""Deterministic synthetic LM data pipeline.

Sequences are drawn from a fixed random bigram chain (seeded by `data_seed`),
so models can genuinely learn (loss decreases below the unigram entropy) —
the end-to-end training example demonstrates real optimization, not noise.

Production posture:
  * host-sharded loading: each process materializes only its
    `global_batch / process_count` rows (`host_batch_slice`);
  * fully deterministic and *stateless per step*: batch(step) is a pure
    function of (seed, step), so restart-after-failure replays exactly;
  * checkpointable: `state_dict()` is just {step, seed} — restored by the
    trainer alongside the model state.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    branching: int = 4   # out-degree of the bigram chain (entropy = log b)


class SyntheticLM:
    def __init__(self, cfg: DataConfig, process_index: int = 0, process_count: int = 1):
        self.cfg = cfg
        self.process_index = process_index
        self.process_count = process_count
        assert cfg.global_batch % process_count == 0
        self.host_batch = cfg.global_batch // process_count
        self.step = 0
        # fixed bigram transition table: vocab x branching successor ids
        rng = np.random.default_rng(cfg.seed)
        self._succ = rng.integers(
            0, cfg.vocab, size=(cfg.vocab, cfg.branching), dtype=np.int32
        )

    # -- checkpointing ------------------------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def load_state_dict(self, d: dict) -> None:
        assert d["seed"] == self.cfg.seed, "data seed changed across restore"
        self.step = int(d["step"])

    # -- batch generation ----------------------------------------------------
    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """Pure function of (seed, step) — replay-exact across restarts."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step, self.process_index))
        b, s = self.host_batch, cfg.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab, size=b)
        choices = rng.integers(0, cfg.branching, size=(b, s))
        for t in range(s):
            toks[:, t + 1] = self._succ[toks[:, t], choices[:, t]]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __next__(self) -> dict[str, np.ndarray]:
        batch = self.batch_at(self.step)
        self.step += 1
        return batch

    def __iter__(self):
        return self


def host_batch_slice(global_batch: int, process_index: int, process_count: int):
    """Row range of the global batch owned by this host."""
    per = global_batch // process_count
    return slice(process_index * per, (process_index + 1) * per)
