"""R5 — Pallas `pallas_call` structural + VMEM-budget checks.

The paper's §3 layout/streaming techniques make the kernel launch geometry
*checkable*: every BlockSpec index map must address exactly the grid axes,
return one block coordinate per block-shape dimension, and the per-step VMEM
working set implied by the enclosing entry point's default tile sizes must
fit the autotuner's budget. All of this is visible in the AST:

R5/index-arity    index_map lambda's non-default parameter count ≠ grid
                  tuple length (a lambda with default-arg captures like
                  ``lambda i, j, k, g=g: ...`` counts only i, j, k).
R5/index-rank     index_map returns a tuple whose length ≠ the BlockSpec
                  block-shape rank.
R5/index-expr     an index expression uses something other than grid
                  parameters, captured defaults, constants, and arithmetic
                  (``//``, ``%``, ``+``, ``-``, ``*``) over them — calls or
                  subscripts inside an index map defeat static bounds
                  reasoning (and Mosaic's affine analysis).
R5/operand-count  number of operands passed to the ``pallas_call(...)``
                  result ≠ number of ``in_specs``.
R5/grid-divisibility  when grid entries AND the matching block dims are both
                  integer literals, the grid must cover the block exactly
                  (flag ``grid=(3,)`` with ``BlockSpec((128,), ...)`` only
                  when an operand dim literal disagrees — rarely statically
                  decidable; checked when it is).
R5/vmem-budget    the enclosing entry point's default (bm, bn, bkg) tile,
                  run through `kernels.autotune.tile_vmem_bytes` for the
                  supported ternary group sizes g ∈ {2, 3, 4}, exceeds
                  `autotune.VMEM_BUDGET_BYTES`. `impl`/`fused` are inferred
                  from the enclosing function's name (``lookup``/``decode``,
                  ``fused``); entry points outside that naming scheme skip
                  the budget check (the structural checks still apply).
"""
from __future__ import annotations

import ast
from typing import Iterable

from .core import Finding, LintModule, rule

_IDX_BINOPS = (ast.FloorDiv, ast.Mod, ast.Add, ast.Sub, ast.Mult)
_SUPPORTED_G = (2, 3, 4)


def _is_pallas_call(node: ast.Call) -> bool:
    f = node.func
    return (isinstance(f, ast.Attribute) and f.attr == "pallas_call") or (
        isinstance(f, ast.Name) and f.id == "pallas_call"
    )


def _kw(node: ast.Call, name: str) -> ast.AST | None:
    for kw in node.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _blockspecs(spec_node: ast.AST | None) -> list[ast.Call]:
    """BlockSpec(...) calls inside an in_specs list / a bare out_specs."""
    out: list[ast.Call] = []
    if spec_node is None:
        return out
    candidates = (
        spec_node.elts if isinstance(spec_node, (ast.List, ast.Tuple))
        else [spec_node]
    )
    for el in candidates:
        if isinstance(el, ast.Call):
            f = el.func
            name = f.attr if isinstance(f, ast.Attribute) else (
                f.id if isinstance(f, ast.Name) else ""
            )
            if name == "BlockSpec":
                out.append(el)
    return out


def _index_expr_ok(expr: ast.AST, allowed: set[str]) -> bool:
    if isinstance(expr, ast.Constant):
        return isinstance(expr.value, int)
    if isinstance(expr, ast.Name):
        return expr.id in allowed
    if isinstance(expr, ast.BinOp) and isinstance(expr.op, _IDX_BINOPS):
        return _index_expr_ok(expr.left, allowed) and _index_expr_ok(
            expr.right, allowed
        )
    if isinstance(expr, ast.UnaryOp) and isinstance(expr.op, ast.USub):
        return _index_expr_ok(expr.operand, allowed)
    return False


def _check_blockspec(
    mod: LintModule, spec: ast.Call, grid_len: int | None, where: str
) -> Iterable[Finding]:
    shape_node = spec.args[0] if spec.args else None
    imap = spec.args[1] if len(spec.args) > 1 else None
    block_rank = (
        len(shape_node.elts)
        if isinstance(shape_node, (ast.Tuple, ast.List))
        else None
    )
    if not isinstance(imap, ast.Lambda):
        return
    n_required = len(imap.args.args) - len(imap.args.defaults)
    params = {a.arg for a in imap.args.args[:n_required]}
    captured = {a.arg for a in imap.args.args[n_required:]}
    if grid_len is not None and n_required != grid_len:
        yield Finding(
            "R5", mod.path, imap.lineno, imap.col_offset,
            f"{where}: index_map takes {n_required} grid indices but the "
            f"grid has {grid_len} axes — each grid axis must be a "
            f"parameter (captures go in defaults)",
        )
    body = imap.body
    returned = (
        list(body.elts) if isinstance(body, (ast.Tuple, ast.List)) else [body]
    )
    if block_rank is not None and len(returned) != block_rank:
        yield Finding(
            "R5", mod.path, imap.lineno, imap.col_offset,
            f"{where}: index_map returns {len(returned)} block "
            f"coordinate(s) but the block shape has rank {block_rank}",
        )
    for expr in returned:
        if not _index_expr_ok(expr, params | captured):
            yield Finding(
                "R5", mod.path, expr.lineno, expr.col_offset,
                f"{where}: index expression `{mod.text(expr)}` is not "
                f"affine in the grid indices (params/captures/constants "
                f"and +,-,*,//,% only) — Mosaic cannot bound it "
                f"statically",
            )


def _int_elts(node: ast.AST | None) -> list[int | None]:
    if not isinstance(node, (ast.Tuple, ast.List)):
        return []
    return [
        el.value if isinstance(el, ast.Constant) and isinstance(el.value, int)
        else None
        for el in node.elts
    ]


def _tile_defaults(fn: ast.FunctionDef) -> dict[str, int]:
    """bm/bn/bkg keyword-default ints of the enclosing entry point."""
    out: dict[str, int] = {}
    kwonly = zip(fn.args.kwonlyargs, fn.args.kw_defaults)
    pos = zip(reversed(fn.args.args), reversed(fn.args.defaults))
    for arg, default in list(kwonly) + list(pos):
        if (
            arg is not None
            and arg.arg in ("bm", "bn", "bkg")
            and isinstance(default, ast.Constant)
            and isinstance(default.value, int)
        ):
            out[arg.arg] = default.value
    return out


def _vmem_check(
    mod: LintModule, call: ast.Call, fn: ast.FunctionDef
) -> Iterable[Finding]:
    name = fn.name.lower()
    if "lookup" in name or "vlut" in name:
        impl = "lookup"
    elif "decode" in name or "mad" in name:
        impl = "decode"
    else:
        return
    tiles = _tile_defaults(fn)
    if set(tiles) != {"bm", "bn", "bkg"}:
        return
    # the budget comes from the autotuner's own env-overridable helper — a
    # single source of truth, so REPRO_VLUT_VMEM_BUDGET re-tunes and
    # re-lints coherently and the two can never drift apart
    from repro.kernels.autotune import tile_vmem_bytes, vmem_budget_bytes

    budget = vmem_budget_bytes()
    fused = "fused" in name or _kw(call, "scratch_shapes") is not None
    for g in _SUPPORTED_G:
        need = tile_vmem_bytes(
            g, impl, tiles["bm"], tiles["bn"], tiles["bkg"], fused=fused
        )
        if need > budget:
            yield Finding(
                "R5", mod.path, call.lineno, call.col_offset,
                f"default tile (bm={tiles['bm']}, bn={tiles['bn']}, "
                f"bkg={tiles['bkg']}) of `{fn.name}` needs {need} B of "
                f"VMEM at g={g} ({impl}, fused={fused}) — over the "
                f"autotune budget of {budget} B; shrink the "
                f"default or route through autotune.get_tiles",
            )
            break  # one budget finding per call site is enough


@rule("R5", "pallas_call geometry: index-map arity/rank/affinity vs grid "
            "and BlockSpec, operand/in_specs count, default-tile VMEM "
            "budget vs kernels.autotune")
def check_pallas(mod: LintModule) -> Iterable[Finding]:
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call) or not _is_pallas_call(node):
            continue
        grid = _kw(node, "grid")
        grid_len = (
            len(grid.elts) if isinstance(grid, (ast.Tuple, ast.List)) else None
        )
        in_specs = _kw(node, "in_specs")
        specs = _blockspecs(in_specs)
        for i, spec in enumerate(specs):
            yield from _check_blockspec(
                mod, spec, grid_len, f"in_specs[{i}]"
            )
        for spec in _blockspecs(_kw(node, "out_specs")):
            yield from _check_blockspec(mod, spec, grid_len, "out_specs")

        # operand count vs in_specs
        parent = mod.parents.get(node)
        if (
            isinstance(parent, ast.Call)
            and parent.func is node
            and isinstance(in_specs, (ast.List, ast.Tuple))
            and len(specs) == len(in_specs.elts)  # all entries are BlockSpecs
            and not any(
                isinstance(a, ast.Starred) for a in parent.args
            )
        ):
            if len(parent.args) != len(specs):
                yield Finding(
                    "R5", mod.path, parent.lineno, parent.col_offset,
                    f"pallas_call invoked with {len(parent.args)} "
                    f"operand(s) but in_specs declares {len(specs)} "
                    f"BlockSpec(s)",
                )

        # literal grid ↔ literal block-shape coverage: with all three of
        # grid entry, block dim, and out_shape dim known as ints, a grid
        # that UNDER-covers the output (n_blocks · block < dim) leaves a
        # tail no step ever writes. Only decidable for literal launches
        # (tests/fixtures); the repo's cdiv-computed grids are skipped.
        grid_ints = _int_elts(grid)
        out_shape = _kw(node, "out_shape")
        if grid_ints and isinstance(out_shape, ast.Call):
            dims = _int_elts(out_shape.args[0] if out_shape.args else None)
            for spec in _blockspecs(_kw(node, "out_specs")):
                blk = _int_elts(spec.args[0] if spec.args else None)
                if not dims or len(blk) != len(dims):
                    continue
                if len(grid_ints) != len(blk):
                    continue
                for n_blocks, b, d in zip(grid_ints, blk, dims):
                    if None in (n_blocks, b, d) or b <= 0:
                        continue
                    if n_blocks * b < d:
                        yield Finding(
                            "R5", mod.path, spec.lineno, spec.col_offset,
                            f"grid covers {n_blocks}×{b} elements of a "
                            f"{d}-wide output dim — the tail is never "
                            f"written",
                        )

        # default-tile VMEM budget of the enclosing entry point
        fn = mod.enclosing_function(node)
        while fn is not None and not isinstance(fn, ast.FunctionDef):
            fn = mod.enclosing_function(fn)
        if isinstance(fn, ast.FunctionDef):
            yield from _vmem_check(mod, node, fn)
