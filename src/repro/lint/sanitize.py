"""Runtime sanitizer mode + jit compile-count guard.

`enable_sanitizers()` flips JAX into its strict modes — silent rank
promotion, silent dtype promotion, and NaN propagation all become hard
errors — so the fast test lane catches the shape/dtype sloppiness the
static rules can't see. Wired to pytest via `tests/conftest.py`
(``pytest --sanitize`` or ``REPRO_SANITIZE=1``).

`CompileGuard` is the dynamic complement of lint rule R2: snapshot the
compile-cache sizes of a set of jitted callables, run N steady-state
steps, and assert the caches did not grow — i.e. zero recompiles after
warmup. Engine/ModelDrafter expose their jitted entries via
``jit_entries()`` for exactly this.
"""
from __future__ import annotations

import os
from typing import Callable, Iterable, Mapping


def enable_sanitizers(*, debug_nans: bool = True) -> dict:
    """Turn on strict JAX modes; returns the previous values for restore."""
    import jax

    prev = {
        "jax_numpy_rank_promotion": jax.config.jax_numpy_rank_promotion,
        "jax_numpy_dtype_promotion": jax.config.jax_numpy_dtype_promotion,
        "jax_debug_nans": jax.config.jax_debug_nans,
    }
    jax.config.update("jax_numpy_rank_promotion", "raise")
    jax.config.update("jax_numpy_dtype_promotion", "strict")
    jax.config.update("jax_debug_nans", bool(debug_nans))
    return prev


def restore_sanitizers(prev: Mapping) -> None:
    import jax

    for key, val in prev.items():
        jax.config.update(key, val)


def sanitizers_requested(env: Mapping[str, str] | None = None) -> bool:
    env = os.environ if env is None else env
    return env.get("REPRO_SANITIZE", "0") not in ("", "0", "false")


def _cache_size(fn) -> int:
    """Compile-cache entry count of one jax.jit wrapper (0 if opaque)."""
    probe = getattr(fn, "_cache_size", None)
    if callable(probe):
        try:
            return int(probe())
        except Exception:
            return 0
    return 0


class CompileGuard:
    """Assert a set of jitted callables stop compiling after warmup.

        guard = CompileGuard(engine.jit_entries())
        ... warmup ticks ...
        guard.arm()
        ... steady-state ticks ...
        guard.assert_steady()   # raises AssertionError naming the culprit

    Entries are a name → jitted-callable mapping; callables without a
    ``_cache_size`` probe are tracked as permanently 0 (the guard can then
    only prove nothing, never fail spuriously).
    """

    def __init__(self, entries: Mapping[str, Callable]):
        self.entries = dict(entries)
        self._baseline: dict[str, int] | None = None

    def sizes(self) -> dict[str, int]:
        return {name: _cache_size(fn) for name, fn in self.entries.items()}

    def arm(self) -> dict[str, int]:
        self._baseline = self.sizes()
        return dict(self._baseline)

    def new_compiles(self) -> dict[str, int]:
        assert self._baseline is not None, "arm() before assert/new_compiles"
        now = self.sizes()
        return {
            name: now[name] - self._baseline[name]
            for name in self.entries
            if now[name] > self._baseline[name]
        }

    def assert_steady(self, what: str = "steady state") -> None:
        grew = self.new_compiles()
        assert not grew, (
            f"recompiles during {what}: "
            + ", ".join(f"{k} (+{v})" for k, v in sorted(grew.items()))
            + " — a traced-value branch or unstable static arg is re-keying "
              "the jit cache (lint rule R2 class)"
        )


def guard_entries(obj) -> dict[str, Callable]:
    """Collect jitted entries from an object exposing ``jit_entries()``."""
    probe = getattr(obj, "jit_entries", None)
    return dict(probe()) if callable(probe) else {}
