"""repro.lint core: file walking, rule registry, suppressions, reporting.

The analyzer is a plain-AST pass (no imports of the linted code, no type
inference): each rule registers a ``check(module) -> Iterable[Finding]``
callable and receives a `LintModule` — the parsed tree plus cheap derived
structure (parent links, per-line comments, suppression map). Heuristics are
deliberately textual where the hazard is textual (e.g. R1's cache-name match)
— the point is mechanically catching the bug classes PRs 2–6 fixed by hand,
not general soundness. See docs/static_analysis.md for the rule catalog.

Suppression contract (verified, not free-form):

    x = cache["k"].at[b, s].set(v)  # lint: disable=R1 -- in-bounds: s % buf

One comment suppresses the named rule(s) on its own line and, when the
comment stands alone on a line, on the following line. The justification
after ``--`` is mandatory and must carry at least three words; a bare or
under-justified suppression is itself reported (rule R0, unsuppressable).
"""
from __future__ import annotations

import ast
import dataclasses
import io
import json
import os
import re
import tokenize
from typing import Callable, Iterable, Iterator

#: rule id -> (one-line description, check callable)
_REGISTRY: dict[str, tuple[str, Callable[["LintModule"], Iterable["Finding"]]]] = {}

_SUPPRESS_RE = re.compile(
    r"#\s*lint:\s*disable=([A-Za-z0-9_,\s]+?)\s*(?:--|—)\s*(.*)$"
)
_SUPPRESS_ANY_RE = re.compile(r"#\s*lint:\s*disable")

#: minimum justification: three words — "slot is host-int" style, not "ok"
MIN_JUSTIFICATION_WORDS = 3


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Suppression:
    line: int
    rules: tuple[str, ...]
    justification: str
    standalone: bool  # comment is the whole line -> also covers line + 1

    @property
    def covered_lines(self) -> tuple[int, ...]:
        return (self.line, self.line + 1) if self.standalone else (self.line,)


def rule(rule_id: str, description: str):
    """Decorator registering ``check(module) -> Iterable[Finding]``."""

    def deco(fn):
        _REGISTRY[rule_id] = (description, fn)
        fn.rule_id = rule_id
        fn.description = description
        return fn

    return deco


def registered_rules() -> dict[str, str]:
    return {rid: desc for rid, (desc, _) in sorted(_REGISTRY.items())}


class LintModule:
    """One parsed source file + the derived structure rules need."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.tree = ast.parse(source, filename=path)
        self.lines = source.splitlines()
        self._parents: dict[ast.AST, ast.AST] | None = None
        self.comments: list[tuple[int, str, bool]] = self._scan_comments(source)
        self.suppressions: list[Suppression] = []
        self.bad_suppressions: list[tuple[int, str]] = []
        self._collect_suppressions()

    # -- structure ---------------------------------------------------------
    @property
    def parents(self) -> dict[ast.AST, ast.AST]:
        if self._parents is None:
            self._parents = {}
            for node in ast.walk(self.tree):
                for child in ast.iter_child_nodes(node):
                    self._parents[child] = node
        return self._parents

    def ancestors(self, node: ast.AST) -> Iterator[ast.AST]:
        cur = self.parents.get(node)
        while cur is not None:
            yield cur
            cur = self.parents.get(cur)

    def enclosing_function(self, node: ast.AST):
        for anc in self.ancestors(node):
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                return anc
        return None

    def in_loop(self, node: ast.AST) -> bool:
        """True when `node` sits inside a for/while *body* without an
        intervening function definition (a nested def is a new scope whose
        execution frequency the loop does not determine — a def in a loop
        that jits per iteration is still caught: the jit call's own chain
        passes the For before any FunctionDef only if inline)."""
        for anc in self.ancestors(node):
            if isinstance(anc, (ast.For, ast.While)):
                return True
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                return False
        return False

    def text(self, node: ast.AST) -> str:
        try:
            return ast.unparse(node)
        except Exception:  # pragma: no cover - unparse is total on 3.9+
            return ""

    # -- comments / suppressions ------------------------------------------
    @staticmethod
    def _scan_comments(source: str) -> list[tuple[int, str, bool]]:
        out: list[tuple[int, str, bool]] = []
        try:
            toks = tokenize.generate_tokens(io.StringIO(source).readline)
            for tok in toks:
                if tok.type == tokenize.COMMENT:
                    standalone = tok.line[: tok.start[1]].strip() == ""
                    out.append((tok.start[0], tok.string, standalone))
        except tokenize.TokenizeError:  # pragma: no cover - parse succeeded
            pass
        return out

    def _collect_suppressions(self) -> None:
        for line, comment, standalone in self.comments:
            m = _SUPPRESS_RE.search(comment)
            if not m:
                if _SUPPRESS_ANY_RE.search(comment):
                    # disable marker without the required `--` separator
                    self.bad_suppressions.append(
                        (line, "malformed suppression: use "
                               "`# lint: disable=<RULES> -- <justification>`")
                    )
                continue
            rules = tuple(
                r.strip() for r in m.group(1).split(",") if r.strip()
            )
            justification = m.group(2).strip()
            if len(justification.split()) < MIN_JUSTIFICATION_WORDS:
                self.bad_suppressions.append(
                    (line, f"suppression of {','.join(rules)} lacks a "
                           f"justification (≥{MIN_JUSTIFICATION_WORDS} words "
                           f"after `--`)")
                )
                continue
            self.suppressions.append(
                Suppression(line, rules, justification, standalone)
            )

    def suppressed(self, rule_id: str, line: int) -> bool:
        for s in self.suppressions:
            if rule_id in s.rules and line in s.covered_lines:
                return True
        return False


def iter_py_files(paths: Iterable[str]) -> Iterator[str]:
    for p in paths:
        if os.path.isfile(p) and p.endswith(".py"):
            yield p
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(
                d for d in dirs
                if not d.startswith(".") and d != "__pycache__"
            )
            for f in sorted(files):
                if f.endswith(".py"):
                    yield os.path.join(root, f)


def lint_file(path: str, select: set[str] | None = None) -> list[Finding]:
    with open(path, encoding="utf-8") as f:
        source = f.read()
    return lint_source(source, path, select=select)


def lint_source(
    source: str, path: str = "<string>", select: set[str] | None = None
) -> list[Finding]:
    try:
        mod = LintModule(path, source)
    except SyntaxError as e:
        return [Finding("E0", path, e.lineno or 1, e.offset or 0,
                        f"syntax error: {e.msg}")]
    findings: list[Finding] = []
    # R0 — bad suppressions are findings themselves and cannot be suppressed
    if select is None or "R0" in select:
        for line, msg in mod.bad_suppressions:
            findings.append(Finding("R0", path, line, 0, msg))
    for rid, (_desc, check) in sorted(_REGISTRY.items()):
        if select is not None and rid not in select:
            continue
        for f in check(mod):
            if not mod.suppressed(f.rule, f.line):
                findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def lint_paths(
    paths: Iterable[str], select: set[str] | None = None
) -> tuple[list[Finding], int]:
    """-> (findings, files_scanned)."""
    findings: list[Finding] = []
    n = 0
    for path in iter_py_files(paths):
        n += 1
        findings.extend(lint_file(path, select=select))
    return findings, n


def report_json(findings: list[Finding], files_scanned: int) -> dict:
    counts: dict[str, int] = {}
    for f in findings:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    return {
        "version": 1,
        "files_scanned": files_scanned,
        "rules": registered_rules(),
        "counts": counts,
        "findings": [f.as_dict() for f in findings],
    }


def write_json(path: str, findings: list[Finding], files_scanned: int) -> None:
    with open(path, "w", encoding="utf-8") as f:
        json.dump(report_json(findings, files_scanned), f, indent=1)
        f.write("\n")
