"""R3 — host-sync calls inside engine/scheduler hot-loop bodies.

``float(x)``, ``int(x)``, ``x.item()``, ``np.asarray(x)``, ``bool(x)`` on a
device array block the host until the value materializes; inside the
scheduler tick loop each one serializes the pipeline per element. The same
goes for a ``block_until_ready`` that sits inside a per-item loop (one
barrier per element instead of one per batch).

Scope and precision:

* Only the serving hot-path modules (`HOT_PATH_SUFFIXES`) are checked, and
  only calls lexically inside a for/while body — one batched
  ``np.asarray(device_result)`` at tick end is the correct pattern and is
  not flagged.
* ``int()``/``float()``/``bool()`` are *not* flagged when the argument's
  base name was provably materialized to host numpy earlier in the same
  function (assigned from an ``np.*`` call, or bound by iterating such a
  value) — ``nxt = np.asarray(...); for s in ...: int(nxt[s])`` is the
  batch-then-index idiom this rule exists to push code toward.
* Anything the AST can't prove host-side (attribute state, helper-method
  returns) stays flagged; genuinely-host sites carry a justified
  suppression instead of a silent exemption.
"""
from __future__ import annotations

import ast
from typing import Iterable

from .core import Finding, LintModule, rule

#: modules where per-element host syncs are a throughput bug
HOT_PATH_SUFFIXES = (
    "serve/engine.py",
    "serve/scheduler.py",
)

_SYNC_BUILTINS = {"float", "int", "bool"}
_SYNC_METHODS = {"item", "tolist"}
_SYNC_NP_FUNCS = {"asarray", "array"}
#: calls whose result is host-resident numpy (never a device array)
_HOST_PRODUCERS = {"np", "numpy"}


def _base_name(node: ast.AST) -> str | None:
    """Leftmost plain Name of a Name/Subscript/chained expression, or None
    when the base is an attribute/call (origin unknowable locally)."""
    while True:
        if isinstance(node, ast.Name):
            return node.id
        if isinstance(node, ast.Subscript):
            node = node.value
        elif isinstance(node, ast.Call) and isinstance(
            node.func, ast.Attribute
        ) and node.func.attr in ("copy", "astype", "reshape"):
            node = node.func.value
        else:
            return None


def _is_np_call(node: ast.AST) -> bool:
    """np.<anything>(...) — asarray/zeros/arange/full/concatenate/..."""
    if not isinstance(node, ast.Call):
        return False
    f = node.func
    while isinstance(f, ast.Attribute):
        if isinstance(f.value, ast.Name) and f.value.id in _HOST_PRODUCERS:
            return True
        f = f.value if isinstance(f.value, ast.Attribute) else f.value
        if not isinstance(f, ast.Attribute):
            break
    return False


def _host_names(fn: ast.AST) -> set[str]:
    """Names in `fn` provably bound to host numpy values (flow-insensitive:
    one np.* assignment marks the name for the whole function — good enough
    because the codebase never reuses a name for device and host data)."""
    host: set[str] = set()
    # pass 1: direct np.* assignments (incl. pairwise tuple assigns)
    for node in ast.walk(fn):
        if not isinstance(node, ast.Assign):
            continue
        pairs: list[tuple[ast.AST, ast.AST]] = []
        for tgt in node.targets:
            if isinstance(tgt, ast.Tuple) and isinstance(
                node.value, ast.Tuple
            ) and len(tgt.elts) == len(node.value.elts):
                pairs += list(zip(tgt.elts, node.value.elts))
            else:
                pairs.append((tgt, node.value))
        for tgt, val in pairs:
            if not isinstance(tgt, ast.Name):
                continue
            base = _base_name(val)
            if _is_np_call(val) or (base is not None and base in host):
                host.add(tgt.id)
    # pass 2: loop/comprehension targets iterating a host value
    for node in ast.walk(fn):
        if isinstance(node, ast.For):
            it, tgt = node.iter, node.target
        elif isinstance(node, ast.comprehension):
            it, tgt = node.iter, node.target
        else:
            continue
        base = _base_name(it)
        if base in host and isinstance(tgt, ast.Name):
            host.add(tgt.id)
    return host


@rule("R3", "host-sync call (float()/.item()/np.asarray/block_until_ready) "
            "inside an engine/scheduler loop body")
def check_hostsync(mod: LintModule) -> Iterable[Finding]:
    if not mod.path.replace("\\", "/").endswith(HOT_PATH_SUFFIXES):
        return
    host_cache: dict[ast.AST, set[str]] = {}
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        if not mod.in_loop(node):
            continue
        f = node.func
        desc = None
        if isinstance(f, ast.Name) and f.id in _SYNC_BUILTINS and node.args:
            arg = node.args[0]
            if isinstance(arg, ast.Constant):
                continue
            fn = mod.enclosing_function(node)
            if fn is not None:
                if fn not in host_cache:
                    host_cache[fn] = _host_names(fn)
                if _base_name(arg) in host_cache[fn]:
                    continue  # proven host numpy — no device round-trip
            desc = f"`{f.id}(...)`"
        elif isinstance(f, ast.Attribute) and f.attr in _SYNC_METHODS:
            desc = f"`.{f.attr}()`"
        elif (
            isinstance(f, ast.Attribute)
            and f.attr in _SYNC_NP_FUNCS
            and isinstance(f.value, ast.Name)
            and f.value.id in _HOST_PRODUCERS
        ):
            desc = f"`np.{f.attr}(...)`"
        elif isinstance(f, ast.Attribute) and f.attr == "block_until_ready":
            desc = "`block_until_ready()`"
        if desc is None:
            continue
        yield Finding(
            "R3", mod.path, node.lineno, node.col_offset,
            f"{desc} inside a loop body on the serving hot path forces a "
            f"per-element host sync — batch the transfer outside the loop "
            f"(or justify: host-side data needs no device round-trip)",
        )
