"""R4 — wall-clock timing must use ``time.perf_counter``.

``time.time()`` is subject to NTP slews/steps and has coarse resolution on
some platforms; two PR-6 benchmark bugs came from exactly this. Any
reference to ``time.time`` (call, alias, or ``from time import time``) is
flagged — there is no legitimate *timing* use in this codebase, and
timestamp-for-display uses can justify a suppression.
"""
from __future__ import annotations

import ast
from typing import Iterable

from .core import Finding, LintModule, rule


@rule("R4", "time.time used for timing (NTP-unstable, coarse) — "
            "use time.perf_counter")
def check_timing(mod: LintModule) -> Iterable[Finding]:
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ImportFrom) and node.module == "time":
            for alias in node.names:
                if alias.name == "time":
                    yield Finding(
                        "R4", mod.path, node.lineno, node.col_offset,
                        "`from time import time` — import perf_counter "
                        "instead",
                    )
        elif isinstance(node, ast.Attribute):
            if node.attr == "time" and isinstance(node.value, ast.Name) \
                    and node.value.id == "time":
                yield Finding(
                    "R4", mod.path, node.lineno, node.col_offset,
                    "`time.time` — use `time.perf_counter` for intervals "
                    "(monotonic, high-resolution)",
                )
