"""R2 — jit recompile hazards.

Three sub-patterns, one rule id (the finding message names the sub-pattern):

R2/jit-in-loop      ``jax.jit(...)`` called inside a for/while body — each
                    iteration builds a fresh wrapper with an empty compile
                    cache, so every call retraces.
R2/jit-immediate    ``jax.jit(fn)(args)`` — wrapper created and discarded in
                    one expression; the compilation is never reused. (AOT
                    ``.lower()``/``.compile()`` chains are exempt: there the
                    throwaway wrapper is the point.)
R2/traced-branch    Python ``if``/``while`` on a *parameter-derived value*
                    inside a function decorated with ``@jit`` /
                    ``@partial(jax.jit, ...)``. Under trace this raises a
                    ConcretizationTypeError or — with static args — silently
                    keys the compile cache on the value, recompiling per
                    distinct value. Branching on trace-time statics
                    (``.shape``, ``.ndim``, ``.dtype``, ``len()``,
                    ``is None``, ``isinstance``) is fine and not flagged.
R2/unhashable-static  a list/dict/set literal passed to a ``static_arg*``
                    parameter of a jit'd call — unhashable statics raise at
                    call time or defeat cache keying.
"""
from __future__ import annotations

import ast
from typing import Iterable

from .core import Finding, LintModule, rule


def _call_name(node: ast.Call) -> str:
    f = node.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


def _is_jit_call(node: ast.Call) -> bool:
    return _call_name(node) == "jit"


def _is_partial_jit(node: ast.Call) -> bool:
    """``partial(jax.jit, ...)`` / ``functools.partial(jit, ...)``."""
    if _call_name(node) != "partial" or not node.args:
        return False
    first = node.args[0]
    return (isinstance(first, ast.Attribute) and first.attr == "jit") or (
        isinstance(first, ast.Name) and first.id == "jit"
    )


def _jit_decorated(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> bool:
    for dec in fn.decorator_list:
        if isinstance(dec, ast.Name) and dec.id == "jit":
            return True
        if isinstance(dec, ast.Attribute) and dec.attr == "jit":
            return True
        if isinstance(dec, ast.Call) and (
            _is_jit_call(dec) or _is_partial_jit(dec)
        ):
            return True
    return False


def _static_argnames(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> set[str]:
    """Names listed in static_argnames= of a jit/partial-jit decorator."""
    out: set[str] = set()
    for dec in fn.decorator_list:
        if not isinstance(dec, ast.Call):
            continue
        if not (_is_jit_call(dec) or _is_partial_jit(dec)):
            continue
        for kw in dec.keywords:
            if kw.arg in ("static_argnames", "static_argnums"):
                for el in ast.walk(kw.value):
                    if isinstance(el, ast.Constant) and isinstance(
                        el.value, str
                    ):
                        out.add(el.value)
    return out


# trace-time-static callables: branching on these never concretizes a tracer
_STATIC_CALLS = {"len", "isinstance", "hasattr", "getattr", "callable"}


def _branch_is_static(test: ast.AST, params: set[str]) -> bool:
    """True when every parameter reference in `test` flows through a
    trace-time-static accessor (so the branch can't concretize a tracer)."""

    def name_is_raw_param(n: ast.AST) -> bool:
        return isinstance(n, ast.Name) and n.id in params

    # walk, but stop descending below static accessors
    def scan(n: ast.AST) -> bool:  # -> contains a raw (non-static) param use
        if isinstance(n, ast.Attribute):
            # attribute access on a param is presumed metadata: `.shape`/
            # `.dtype` are trace-static, and this codebase's pytree params
            # carry static fields (`pw.M`, `spec.k`) as plain attributes.
            # The traced-branch bug class enters through raw names and
            # subscript element reads, which still flag below.
            return False
        if isinstance(n, ast.Subscript):
            # x.shape[0] handled by the Attribute case above; a raw
            # subscript of a param is a traced element access
            return scan(n.value) or scan(n.slice)
        if isinstance(n, ast.Call):
            fname = _call_name(n)
            if fname in _STATIC_CALLS:
                return False
            return any(scan(a) for a in n.args) or any(
                scan(k.value) for k in n.keywords
            )
        if isinstance(n, ast.Compare):
            # `x is None` / `x is not None` is an identity check, not a
            # concretization
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in n.ops):
                return False
            return scan(n.left) or any(scan(c) for c in n.comparators)
        if name_is_raw_param(n):
            return True
        return any(scan(c) for c in ast.iter_child_nodes(n))

    return not scan(test)


def _aot_exempt(mod: LintModule, node: ast.Call) -> bool:
    """jax.jit(fn).lower(...) / .compile() — AOT chains are deliberate."""
    parent = mod.parents.get(node)
    return isinstance(parent, ast.Attribute) and parent.attr in (
        "lower", "compile", "trace",
    )


@rule("R2", "jit recompile hazard (jit-in-loop, throwaway jit wrapper, "
            "traced-value Python branch, unhashable static arg)")
def check_recompile(mod: LintModule) -> Iterable[Finding]:
    # -- jit-in-loop and jit-immediate ------------------------------------
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        if _is_jit_call(node) or _is_partial_jit(node):
            if _aot_exempt(mod, node):
                continue
            parent = mod.parents.get(node)
            if isinstance(parent, ast.Call) and parent.func is node:
                yield Finding(
                    "R2", mod.path, node.lineno, node.col_offset,
                    "`jax.jit(...)(args)` builds a throwaway wrapper — the "
                    "compilation is never reused; hoist the jitted callable "
                    "out of the call expression",
                )
                continue
            if mod.in_loop(node):
                yield Finding(
                    "R2", mod.path, node.lineno, node.col_offset,
                    "`jax.jit(...)` inside a loop body creates a fresh "
                    "wrapper (empty compile cache) every iteration — hoist "
                    "it above the loop",
                )
    # -- traced-value branches inside @jit functions ----------------------
    for fn in ast.walk(mod.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if not _jit_decorated(fn):
            continue
        statics = _static_argnames(fn)
        params = {
            a.arg
            for a in (
                fn.args.posonlyargs + fn.args.args + fn.args.kwonlyargs
            )
        } - statics - {"self"}
        for sub in ast.walk(fn):
            if not isinstance(sub, (ast.If, ast.While, ast.IfExp, ast.Assert)):
                continue
            test = sub.test
            if _branch_is_static(test, params):
                continue
            # only flag when a non-static parameter actually appears
            names = {
                n.id for n in ast.walk(test) if isinstance(n, ast.Name)
            }
            if not (names & params):
                continue
            kind = type(sub).__name__.lower()
            yield Finding(
                "R2", mod.path, sub.lineno, sub.col_offset,
                f"Python `{kind}` on traced parameter(s) "
                f"{sorted(names & params)} inside @jit function "
                f"`{fn.name}` — concretizes the tracer (error) or, with "
                f"static args, recompiles per distinct value; use "
                f"`jnp.where`/`lax.cond` or declare the arg static",
            )
        # unhashable literals bound to declared-static params at call sites
        # within this module
        if statics:
            for call in ast.walk(mod.tree):
                if not isinstance(call, ast.Call):
                    continue
                if _call_name(call) != fn.name:
                    continue
                for kw in call.keywords:
                    if kw.arg in statics and isinstance(
                        kw.value, (ast.List, ast.Dict, ast.Set)
                    ):
                        yield Finding(
                            "R2", mod.path, kw.value.lineno,
                            kw.value.col_offset,
                            f"unhashable {type(kw.value).__name__.lower()} "
                            f"literal passed to static arg `{kw.arg}` of "
                            f"jit'd `{fn.name}` — statics must be hashable "
                            f"(use a tuple)",
                        )
