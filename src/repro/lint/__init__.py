"""repro.lint — JAX/Pallas-aware static analysis + runtime sanitizers.

Static side: ``python -m repro.lint src/ tests/ benchmarks/`` runs the AST
rules (R1 scatter modes, R2 recompile hazards, R3 host syncs, R4 timing,
R5 Pallas geometry/VMEM; R0 verifies suppression justifications). Runtime
side: `sanitize.enable_sanitizers` (strict JAX modes for the test lane) and
`sanitize.CompileGuard` (zero-recompile steady-state assertion). Rule
catalog and suppression syntax: docs/static_analysis.md.
"""
from __future__ import annotations

# importing the rule modules registers them with core's registry
from . import (  # noqa: F401
    rules_hostsync,
    rules_pallas,
    rules_recompile,
    rules_scatter,
    rules_timing,
)
from .core import (  # noqa: F401
    Finding,
    LintModule,
    lint_file,
    lint_paths,
    lint_source,
    registered_rules,
    report_json,
    write_json,
)
from .sanitize import (  # noqa: F401
    CompileGuard,
    enable_sanitizers,
    guard_entries,
    restore_sanitizers,
    sanitizers_requested,
)

__all__ = [
    "Finding",
    "LintModule",
    "lint_file",
    "lint_paths",
    "lint_source",
    "registered_rules",
    "report_json",
    "write_json",
    "CompileGuard",
    "enable_sanitizers",
    "guard_entries",
    "restore_sanitizers",
    "sanitizers_requested",
]
