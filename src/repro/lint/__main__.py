"""CLI: ``python -m repro.lint src/ tests/ benchmarks/ [--json out.json]``.

Exit status: 0 clean, 1 findings (including bad suppressions), 2 usage.
"""
from __future__ import annotations

import argparse
import sys

from .core import lint_paths, registered_rules, report_json, write_json


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="JAX/Pallas-aware static analysis for this repo "
                    "(rule catalog: docs/static_analysis.md)",
    )
    ap.add_argument("paths", nargs="*", default=None,
                    help="files or directories to lint (default: src tests "
                         "benchmarks)")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="also write a machine-readable JSON report")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the registered rules and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid, desc in registered_rules().items():
            print(f"{rid}: {desc}")
        return 0

    paths = args.paths or ["src", "tests", "benchmarks"]
    select = (
        {s.strip() for s in args.select.split(",") if s.strip()}
        if args.select else None
    )
    findings, n_files = lint_paths(paths, select=select)
    for f in findings:
        print(f.format())
    if args.json:
        write_json(args.json, findings, n_files)
    counts = report_json(findings, n_files)["counts"]
    summary = ", ".join(f"{k}={v}" for k, v in sorted(counts.items()))
    print(
        f"repro.lint: {n_files} file(s), {len(findings)} finding(s)"
        + (f" [{summary}]" if summary else "")
    )
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
