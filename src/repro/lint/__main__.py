"""CLI: ``python -m repro.lint src/ tests/ benchmarks/ [--json out.json]``.

AST mode (default) lints source files; IR mode (``--ir``) traces the
entry-point registry and runs the jaxpr passes (I1–I5). Both share the
exit-code contract: 0 clean, 1 findings (including bad suppressions),
2 usage.

    python -m repro.lint --ir                     # fast-lane IR gate
    python -m repro.lint --ir --ir-full           # nightly: full registry
    python -m repro.lint --ir --update-snapshots  # refresh golden jaxprs
"""
from __future__ import annotations

import argparse
import sys

from .core import lint_paths, registered_rules, report_json, write_json


def _run_ir(args) -> int:
    # imported lazily: IR mode needs jax + the model stack, AST mode doesn't
    from . import ir

    select = (
        {s.strip() for s in args.select.split(",") if s.strip()}
        if args.select else None
    )
    entries = ir.default_entries(full=args.ir_full)
    findings = ir.run_passes(
        entries, select=select,
        snapshot_root=args.snapshot_dir,
        update_snapshots=args.update_snapshots,
    )
    for f in findings:
        print(f.format())
    if args.json:
        write_json(args.json, findings, len(entries))
    counts = report_json(findings, len(entries))["counts"]
    summary = ", ".join(f"{k}={v}" for k, v in sorted(counts.items()))
    verb = "snapshotted" if args.update_snapshots else "checked"
    print(
        f"repro.lint --ir: {len(entries)} entry point(s) {verb}, "
        f"{len(findings)} finding(s)" + (f" [{summary}]" if summary else "")
    )
    return 1 if findings else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="JAX/Pallas-aware static analysis for this repo "
                    "(rule catalog: docs/static_analysis.md)",
    )
    ap.add_argument("paths", nargs="*", default=None,
                    help="files or directories to lint (default: src tests "
                         "benchmarks; ignored with --ir)")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="also write a machine-readable JSON report")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule/pass ids to run "
                         "(default: all)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the registered rules and exit")
    ap.add_argument("--ir", action="store_true",
                    help="run the jaxpr-level IR passes over the traced "
                         "entry-point registry instead of the AST rules")
    ap.add_argument("--ir-full", action="store_true",
                    help="with --ir: trace the full registry (all configs "
                         "and token counts; the nightly lane)")
    ap.add_argument("--update-snapshots", action="store_true",
                    help="with --ir: rewrite the golden jaxpr snapshots "
                         "instead of checking them")
    ap.add_argument("--snapshot-dir", default=None,
                    help="with --ir: snapshot root (default "
                         "tests/ir_snapshots)")
    ap.add_argument("--list-passes", action="store_true",
                    help="print the registered IR passes and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid, desc in registered_rules().items():
            print(f"{rid}: {desc}")
        return 0
    if args.list_passes:
        from . import ir

        for pid, desc in ir.registered_passes().items():
            print(f"{pid}: {desc}")
        return 0
    if args.ir:
        return _run_ir(args)
    if args.ir_full or args.update_snapshots or args.snapshot_dir:
        ap.error("--ir-full/--update-snapshots/--snapshot-dir require --ir")

    paths = args.paths or ["src", "tests", "benchmarks"]
    select = (
        {s.strip() for s in args.select.split(",") if s.strip()}
        if args.select else None
    )
    findings, n_files = lint_paths(paths, select=select)
    for f in findings:
        print(f.format())
    if args.json:
        write_json(args.json, findings, n_files)
    counts = report_json(findings, n_files)["counts"]
    summary = ", ".join(f"{k}={v}" for k, v in sorted(counts.items()))
    print(
        f"repro.lint: {n_files} file(s), {len(findings)} finding(s)"
        + (f" [{summary}]" if summary else "")
    )
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
