"""R1 — cache scatters must pass an explicit out-of-bounds ``mode=``.

The PR 5 corruption class: ``.at[idx].set(v)`` on a KV/slot cache silently
*clamps* out-of-bounds indices, so a past-the-end write lands on the last
valid position instead of being dropped — corrupting the newest real entry.
Every scatter whose target looks like a cache buffer must spell out the
intended semantics (``mode="drop"`` / ``"promise_in_bounds"`` / ...).

``jax.lax.dynamic_update_slice*`` has no ``mode=`` parameter at all (it
always clamps), so a cache-targeted call there can only be justified with a
suppression explaining why the start index is in bounds.

Target detection is a name heuristic: the scattered-into expression's
identifier chain must contain one of `CACHE_NAME_PARTS`. This is textual on
purpose — the codebase consistently names its cache buffers, and a rename
that dodges the linter would also dodge every human reviewer's pattern
memory, which is the failure mode this rule exists to remove.
"""
from __future__ import annotations

import ast
from typing import Iterable

from .core import Finding, LintModule, rule

#: substrings of identifiers that mark a scatter target as a cache buffer.
#: Verified against the repo's full scatter inventory: matches the KV/slot
#: cache sites in models/ + serve/ and none of the local-temp scatters
#: (sampling masks, test arrays, LUT tables).
CACHE_NAME_PARTS = (
    "cache", "ckv", "krope", "slot", "last_token", "leaf", "buf", "kv",
    "state", "full",
)

#: functions with clamp-always semantics and no mode= escape hatch
_DUS_NAMES = {
    "dynamic_update_slice",
    "dynamic_update_slice_in_dim",
    "dynamic_update_index_in_dim",
}


def _name_chain(node: ast.AST) -> list[str]:
    """Identifier parts of an attribute/subscript chain, outermost first:
    ``cache["k"].at[i]`` -> ["cache", "k", "at"]."""
    parts: list[str] = []

    def walk(n: ast.AST) -> None:
        if isinstance(n, ast.Name):
            parts.append(n.id)
        elif isinstance(n, ast.Attribute):
            walk(n.value)
            parts.append(n.attr)
        elif isinstance(n, ast.Subscript):
            walk(n.value)
            if isinstance(n.slice, ast.Constant) and isinstance(
                n.slice.value, str
            ):
                parts.append(n.slice.value)
        elif isinstance(n, ast.Call):
            walk(n.func)

    walk(node)
    return parts


def _is_cache_name(node: ast.AST) -> bool:
    chain = _name_chain(node)
    return any(
        part in ident.lower()
        for ident in chain
        for part in CACHE_NAME_PARTS
    )


def _at_scatter_target(call: ast.Call) -> ast.AST | None:
    """For ``<target>.at[...].set/add/mul/min/max(...)`` return <target>."""
    f = call.func
    if not isinstance(f, ast.Attribute):
        return None
    if f.attr not in {"set", "add", "mul", "min", "max", "apply"}:
        return None
    sub = f.value
    if not isinstance(sub, ast.Subscript):
        return None
    at = sub.value
    if isinstance(at, ast.Attribute) and at.attr == "at":
        return at.value
    return None


def _has_mode_kw(call: ast.Call) -> bool:
    return any(kw.arg == "mode" for kw in call.keywords)


@rule("R1", "cache scatter without explicit out-of-bounds mode= "
            "(silent clamp corrupts the last valid entry)")
def check_scatter_modes(mod: LintModule) -> Iterable[Finding]:
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        target = _at_scatter_target(node)
        if target is not None:
            if _is_cache_name(target) and not _has_mode_kw(node):
                yield Finding(
                    "R1", mod.path, node.lineno, node.col_offset,
                    f"`.at[...].{node.func.attr}` scatter onto cache-like "
                    f"target `{mod.text(target)}` without explicit mode= — "
                    f"default silently clamps OOB indices onto the last "
                    f"valid entry (the PR-5 corruption class)",
                )
            continue
        # dynamic_update_slice family: clamp-only, no mode= exists
        fn = node.func
        fname = fn.attr if isinstance(fn, ast.Attribute) else (
            fn.id if isinstance(fn, ast.Name) else ""
        )
        if fname in _DUS_NAMES and node.args:
            if _is_cache_name(node.args[0]):
                yield Finding(
                    "R1", mod.path, node.lineno, node.col_offset,
                    f"`{fname}` onto cache-like target "
                    f"`{mod.text(node.args[0])}` always clamps OOB starts "
                    f"and has no mode= — prove the index in bounds with a "
                    f"justified suppression or use `.at[...].set(mode=...)`",
                )
