"""I2 — effect/host audit: hot-path jaxprs must be pure device programs.

A serving step that smuggles in an `io_callback` / `debug_callback` /
`debug_print`, an infeed/outfeed, or an unexpected `device_put` boundary
serializes the dispatch queue on the host (the dynamic R3 rule's static
sibling). Tracing makes these explicit: callback-class primitives appear
as eqns, and anything effectful also lands in `ClosedJaxpr.effects`.

Findings:
* any callback/infeed-class primitive anywhere in the graph (recursing
  through pjit/scan/cond bodies);
* a `device_put` whose operand derives from the traced *arguments* — a
  host->device transfer of live data baked into a hot-path step. A
  device_put of a closed-over constant (a decode table, a tree mask) is
  NOT flagged: constants are hoisted once at compile time, not shipped
  per step;
* a non-empty `jaxpr.effects` set not explained by a flagged eqn (belt
  and braces: new effect kinds fail loudly).
"""
from __future__ import annotations

from typing import Iterable

from ..core import Finding
from .core import IREntry, ir_pass

_HOST_PRIMS = (
    "io_callback", "pure_callback", "debug_callback", "debug_print",
    "infeed", "outfeed", "host_callback", "callback",
)


def _is_var(v) -> bool:
    return hasattr(v, "aval") and type(v).__name__ != "Literal"


def _audit(jaxpr, in_derived, entry, findings, depth=0):
    """Walk one Jaxpr level tracking which vars derive from the traced
    arguments (constvars seed False). -> per-outvar derived flags."""
    derived: dict = {}
    for v, d in zip(jaxpr.invars, in_derived):
        derived[v] = d
    for v in jaxpr.constvars:
        derived[v] = False

    def get(v):
        return _is_var(v) and derived.get(v, False)

    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name in _HOST_PRIMS:
            findings.append(Finding(
                "I2", entry.path, 0, 0,
                f"host-callback primitive `{name}` at nesting depth "
                f"{depth} — hot-path steps must not synchronize with the "
                f"host (route diagnostics through repro.obs instead)",
            ))
        elif name == "device_put" and any(get(v) for v in eqn.invars):
            findings.append(Finding(
                "I2", entry.path, 0, 0,
                f"`device_put` of argument-derived data at nesting depth "
                f"{depth} — live values are shipped host->device every "
                f"step instead of staying resident",
            ))
        in_d = [get(v) for v in eqn.invars]
        out_d = any(in_d)
        sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
        sub = getattr(sub, "jaxpr", sub)
        if name == "pallas_call":
            sub = None                     # opaque; outputs derive from ins
        if sub is not None and hasattr(sub, "eqns"):
            if len(sub.invars) == len(eqn.invars):
                out = _audit(sub, in_d, entry, findings, depth + 1)
                for ov, d in zip(eqn.outvars, out):
                    derived[ov] = d
                continue
            # arity mismatch (unusual call convention): conservative
            _audit(sub, [True] * len(sub.invars), entry, findings,
                   depth + 1)
        elif name == "cond":
            outs = None
            for br in eqn.params.get("branches", ()):
                bj = getattr(br, "jaxpr", br)
                t = _audit(bj, in_d[1:], entry, findings, depth + 1)
                outs = t if outs is None else [a or b
                                               for a, b in zip(outs, t)]
            for ov, d in zip(eqn.outvars, outs or []):
                derived[ov] = d
            continue
        for ov in eqn.outvars:
            derived[ov] = out_d
    return [get(v) for v in jaxpr.outvars]


@ir_pass("I2", "effect/host audit: no callback/infeed-class primitives, no "
              "argument-derived device_put boundaries, no unexplained "
              "effects in hot-path jaxprs")
def check_effects(entry: IREntry) -> Iterable[Finding]:
    findings: list[Finding] = []
    jaxpr = entry.jaxpr.jaxpr
    _audit(jaxpr, [True] * len(jaxpr.invars), entry, findings)
    effects = getattr(entry.jaxpr, "effects", None) or ()
    if effects and not findings:
        findings.append(Finding(
            "I2", entry.path, 0, 0,
            f"jaxpr carries unexplained effects {sorted(map(str, effects))} "
            f"— a new effectful primitive reached the hot path",
        ))
    return findings
