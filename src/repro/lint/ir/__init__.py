"""repro.lint.ir — jaxpr-level static analysis of the serving hot path.

``python -m repro.lint --ir`` traces the entry-point registry
(kernels/ops.py mpGeMM impls x fusion modes, `Engine.jit_entries()`,
`ModelDrafter.jit_entries()`) and runs the IR passes:

  I1 quantized-dtype flow   I2 effect/host audit   I3 dead code
  I4 traffic vs roofline    I5 golden jaxpr snapshots

Pass catalog and the snapshot workflow: docs/static_analysis.md.
"""
from __future__ import annotations

# importing the pass modules registers them with the pass registry
from . import (  # noqa: F401
    deadcode,
    dtype_flow,
    effects,
    snapshots,
    traffic,
)
from .core import (  # noqa: F401
    IREntry,
    all_eqns,
    aval_bytes,
    fmt_aval,
    ir_pass,
    registered_passes,
    run_passes,
    subjaxprs,
)
from .registry import (  # noqa: F401
    default_entries,
    engine_entries,
    mpgemm_entries,
    pinned_trace_env,
)
from .snapshots import signature, snapshot_dir, write_snapshot  # noqa: F401

__all__ = [
    "IREntry",
    "all_eqns",
    "aval_bytes",
    "fmt_aval",
    "ir_pass",
    "registered_passes",
    "run_passes",
    "subjaxprs",
    "default_entries",
    "engine_entries",
    "mpgemm_entries",
    "pinned_trace_env",
    "signature",
    "snapshot_dir",
    "write_snapshot",
]
