"""I3 — dead code: expensive eqns whose results reach no output.

The fused-epilogue refactor class: a kernel rework leaves the old
intermediate (a full-vocab logits cube, a dequantized dense weight, an
extra materialized layout pass) still computed but no longer consumed.
XLA's DCE usually saves the FLOPs at compile time — but not across
`optimization_barrier`/donation boundaries, and either way the traced
graph documents intent: dead heavy compute in the jaxpr is a refactor
that forgot to delete something.

Liveness runs backward per jaxpr level. To keep the pass quiet on the
swept tree, only *expensive* dead eqns are findings: heavy primitives
(dot/conv/scan/pallas_call/sort) at any size, or any dead eqn whose
output exceeds ``MIN_DEAD_BYTES``. Effectful eqns are always live.
pjit bodies are entered with the *caller's* liveness of the call's
outputs, so an output computed inside a jit but dropped by every caller
in the graph is found too. scan/while/cond bodies are analyzed with all
body outputs assumed live (conservative: no false positives from carry
plumbing).
"""
from __future__ import annotations

from typing import Iterable

from ..core import Finding
from .core import IREntry, aval_bytes, fmt_aval, ir_pass, subjaxprs

_HEAVY = {
    "dot_general", "conv_general_dilated", "scan", "while", "pallas_call",
    "sort", "top_k", "custom_jvp_call", "custom_vjp_call",
}
#: a dead cheap eqn must at least materialize this much to be worth a report
MIN_DEAD_BYTES = 1 << 16


def _is_var(v) -> bool:
    return hasattr(v, "aval") and type(v).__name__ != "Literal"


def _analyze(jaxpr, live_out, entry, findings, where=""):
    """Backward liveness over one Jaxpr level.

    live_out: per-outvar liveness booleans from the caller's perspective.
    """
    live: set = set()
    for v, is_live in zip(jaxpr.outvars, live_out):
        if is_live and _is_var(v):
            live.add(v)

    for eqn in reversed(jaxpr.eqns):
        out_live = [
            _is_var(v) and v in live for v in eqn.outvars
        ]
        effectful = bool(getattr(eqn, "effects", None))
        if any(out_live) or effectful:
            for v in eqn.invars:
                if _is_var(v):
                    live.add(v)
            # enter pjit-style bodies with the caller's output liveness so
            # dead compute *inside* a jit whose result is dropped outside
            # is still found
            name = eqn.primitive.name
            sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
            sub = getattr(sub, "jaxpr", sub)
            if sub is not None and hasattr(sub, "eqns"):
                if name in ("pjit", "closed_call", "core_call"):
                    _analyze(sub, out_live, entry, findings,
                             where=f"{where}{name}/")
                else:
                    # scan/while/cond etc: conservative — everything the
                    # body returns counts as live
                    for s in subjaxprs(eqn):
                        _analyze(s, [True] * len(s.outvars), entry,
                                 findings, where=f"{where}{name}/")
            continue
        # fully dead eqn — expensive enough to report?
        out_bytes = sum(aval_bytes(v.aval) for v in eqn.outvars)
        if eqn.primitive.name in _HEAVY or out_bytes >= MIN_DEAD_BYTES:
            shapes = ", ".join(fmt_aval(v.aval) for v in eqn.outvars)
            findings.append(Finding(
                "I3", entry.path, 0, 0,
                f"dead `{where}{eqn.primitive.name}` — its result(s) "
                f"[{shapes}] reach no output ({out_bytes} B computed and "
                f"dropped); a refactor left the old intermediate behind",
            ))


@ir_pass("I3", "dead code: heavy eqns / large intermediates whose results "
              "reach no jaxpr output (the fused-epilogue refactor class)")
def check_deadcode(entry: IREntry) -> Iterable[Finding]:
    findings: list[Finding] = []
    jaxpr = entry.jaxpr.jaxpr
    _analyze(jaxpr, [True] * len(jaxpr.outvars), entry, findings)
    return findings
