"""The IR entry-point registry: what gets traced, at which shapes.

Two families:

* ``mpgemm_entries`` — the kernel-facing mpGeMM impls (paper §5.1
  vocabulary, mirroring benchmarks/crossover.py): {vlut, vlut_packed,
  scalar_lut, mad_dense, mad_int8}, with the packed serving path traced in
  BOTH fusion modes, each at representative token counts M. Shapes carry
  (m_out, k, m_tokens, fused) meta so the I4 traffic pass can cross-check
  against roofline.analysis.mpgemm_cost.
* ``engine_entries`` — every `Engine.jit_entries()` /
  `ModelDrafter.jit_entries()` surface, traced off real smoke-config
  engines: the base chunked-prefill engine (prefill1 / decode /
  chunk_verify), a ModelDrafter chain-spec engine (verify + drafter.*),
  and a tree-spec engine (tree verify + compact).

Tracing is `jax.make_jaxpr` only — nothing compiles, nothing executes, so
the whole default registry traces in seconds on CPU.

Determinism: traced graphs must be a pure function of (code, backend) or
the I5 golden snapshots would flap. `pinned_trace_env()` therefore forces
the §4 heuristic tiles (empty isolated autotune cache + measurement off +
no VMEM-budget env override) and explicit backend-default mpGeMM dispatch
for the duration of tracing.
"""
from __future__ import annotations

import contextlib
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from .core import IREntry

#: token counts every impl is traced at (the fast lane); M=16 is the chain
#: verify across slots, M=1 the single-token decode column
QUICK_MS = (1, 16)
#: nightly adds the serving-burst shapes (chunk x slots, saturated burst)
FULL_MS = (1, 16, 48, 256)
#: representative layer shape: M_out x K (divisible by g=5 and g=4 packing)
MPGEMM_SHAPE = (256, 1280)


@contextlib.contextmanager
def pinned_trace_env():
    """Deterministic tracing context: heuristic tiles only (isolated empty
    autotune cache, measurement disabled, no VMEM budget override) and
    explicit backend-default dispatch."""
    from repro.kernels import autotune, ops

    saved = {
        k: os.environ.pop(k, None)
        for k in (autotune.TUNE_ENV, autotune.VMEM_BUDGET_ENV)
    }
    os.environ[autotune.TUNE_ENV] = "0"
    tmp = tempfile.NamedTemporaryFile(
        suffix=".json", prefix="ir_tiles_", delete=False
    )
    tmp.close()
    os.unlink(tmp.name)                        # want an empty, absent cache
    autotune.reset_default_cache(tmp.name)
    try:
        with ops.dispatch_override(
            impl="decode" if ops.on_tpu() else "xla",
            fusion="fused", interpret=False,
        ):
            yield
    finally:
        autotune.reset_default_cache()
        if os.path.exists(tmp.name):
            os.unlink(tmp.name)
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


def _packed_pair(m_out: int, k: int):
    """(auto-packed, i2-packed) ternary weights for the mpGeMM traces —
    fixed seed so constvars (if any) are stable."""
    from repro.core import pack_weight, ternary_quantize

    rng = np.random.default_rng(0)
    w = rng.standard_normal((m_out, k)).astype(np.float32)
    tw = ternary_quantize(jnp.asarray(w))
    return (
        pack_weight(tw.values, tw.scale, "auto"),
        pack_weight(tw.values, tw.scale, "i2"),
    )


def mpgemm_entries(full: bool = False) -> list[IREntry]:
    """Trace every mpGeMM impl x fusion combination at each M."""
    from repro.core import (
        mad_gemm, mad_gemm_int8, scalar_lut_gemm, vlut_gemm,
    )
    from repro.kernels import ops

    m_out, k = MPGEMM_SHAPE
    ms = FULL_MS if full else QUICK_MS
    packed_impl = "decode" if ops.on_tpu() else "xla"
    pw, pw_i2 = _packed_pair(m_out, k)
    combos = [
        ("vlut", vlut_gemm, pw_i2, {}),
        ("vlut_packed_fused", ops.vlut_mpgemm, pw,
         dict(impl=packed_impl, fusion="fused")),
        ("vlut_packed_unfused", ops.vlut_mpgemm, pw,
         dict(impl=packed_impl, fusion="unfused")),
        ("scalar_lut", scalar_lut_gemm, pw_i2, {}),
        ("mad_dense", mad_gemm, pw_i2, {}),
        ("mad_int8", mad_gemm_int8, pw_i2, {}),
    ]
    # I4 ceilings, ~2x over the measured estimate/model ratio at the
    # worst M in FULL_MS: the reference impls materialize the full LUT
    # table (vlut peaks ~150x at M=256, scalar_lut ~50x) or the dense
    # dequantized weight (mad_dense ~15x); the packed serving path and
    # the int8 MAD stay within the DEFAULT_FACTOR=8 serving budget.
    traffic_factors = {"vlut": 320.0, "scalar_lut": 112.0,
                       "mad_dense": 32.0}
    entries: list[IREntry] = []
    with pinned_trace_env():
        for name, fn, weight, kw in combos:
            for m in ms:
                a = jnp.zeros((k, m), jnp.float32)
                jaxpr = jax.make_jaxpr(
                    lambda w_, a_, fn=fn, kw=kw: fn(w_, a_, **kw)
                )(weight, a)
                entries.append(IREntry(
                    name=f"mpgemm/{name}/M{m}",
                    jaxpr=jaxpr,
                    kind="mpgemm",
                    meta=dict(
                        impl=name, m_out=m_out, k=k, m_tokens=m,
                        fused="unfused" not in name,
                        **({"traffic_factor": traffic_factors[name]}
                           if name in traffic_factors else {}),
                    ),
                ))
    return entries


def _smoke_model():
    from repro.configs import get_config
    from repro.models import init_lm, pack_params

    cfg = get_config("smollm-360m", smoke=True)
    params = pack_params(init_lm(jax.random.PRNGKey(0), cfg), cfg)
    return cfg, params


def engine_entries(full: bool = False) -> list[IREntry]:
    """Trace the serving hot path: every distinct `Engine.jit_entries()` /
    `ModelDrafter.jit_entries()` name across the base, chain-spec
    (ModelDrafter oracle), and tree-spec engine configurations."""
    from repro.models import init_cache
    from repro.serve import Engine
    from repro.spec import SpecConfig

    cfg, params = _smoke_model()
    slots, max_len, chunk, k_draft = 2, 64, 16, 2
    entries: list[IREntry] = []

    def trace(name: str, fn, *args, kind: str = "engine", **meta):
        entries.append(IREntry(
            name=f"engine/{name}", jaxpr=jax.make_jaxpr(fn)(*args),
            kind=kind, meta=meta,
        ))

    with pinned_trace_env():
        base = Engine(params, cfg, max_slots=slots, max_len=max_len,
                      prefill_chunk=chunk)
        be = base.jit_entries()
        t1 = jnp.zeros((1, 16), jnp.int32)
        c1 = init_cache(cfg, 1, 16)
        trace("prefill1", be["prefill1"], params, c1, t1)
        trace("decode", be["decode"], params, base.cache,
              jnp.zeros((slots, 1), jnp.int32))
        trace("chunk_verify", be["chunk_verify"], params, base.cache,
              jnp.zeros((slots, chunk), jnp.int32),
              jnp.zeros((slots,), jnp.int32))

        spec_eng = Engine(
            params, cfg, max_slots=slots, max_len=max_len,
            spec=SpecConfig(k=k_draft, drafter="model",
                            draft_params=params, draft_cfg=cfg),
        )
        se = spec_eng.jit_entries()
        trace("verify", se["verify"], params, spec_eng.cache,
              jnp.zeros((slots, k_draft + 1), jnp.int32))
        trace("drafter.prefill", se["drafter.prefill"], params, c1, t1,
              kind="drafter")
        trace("drafter.verify", se["drafter.verify"], params,
              spec_eng.drafter.cache,
              jnp.zeros((slots, k_draft + 1), jnp.int32), kind="drafter")
        trace("drafter.decode", se["drafter.decode"], params,
              spec_eng.drafter.cache, jnp.zeros((slots, 1), jnp.int32),
              kind="drafter")

        tree_eng = Engine(
            params, cfg, max_slots=slots, max_len=max_len,
            spec=SpecConfig(k=k_draft, drafter="ngram", tree=(2,)),
        )
        te = tree_eng.jit_entries()
        n_nodes = tree_eng._tree.n_nodes
        trace("tree_verify", te["verify"], params, tree_eng.cache,
              jnp.zeros((slots, n_nodes), jnp.int32))
        trace("compact", te["compact"], tree_eng.cache,
              jnp.zeros((slots,), jnp.int32),
              jnp.zeros((slots, n_nodes), jnp.int32),
              jnp.zeros((slots,), jnp.int32))

        # paged KV: decode/chunk_verify gather K/V through the block table,
        # plus the host pager's two flush entries (table broadcast + scrub)
        # and the tree-compact walk over a paged pool
        from repro.serve import PagedKVConfig

        paged_cfg = PagedKVConfig(page_size=16)
        paged = Engine(params, cfg, max_slots=slots, max_len=max_len,
                       prefill_chunk=chunk, paged_kv=paged_cfg)
        pe = paged.jit_entries()
        trace("paged_decode", pe["decode"], params, paged.cache,
              jnp.zeros((slots, 1), jnp.int32))
        trace("paged_chunk_verify", pe["chunk_verify"], params, paged.cache,
              jnp.zeros((slots, chunk), jnp.int32),
              jnp.zeros((slots,), jnp.int32))
        trace("set_tab", pe["set_tab"], paged.cache,
              jnp.zeros((slots, max_len // paged_cfg.page_size), jnp.int32))
        trace("scrub", pe["scrub"], paged.cache,
              jnp.zeros((paged_cfg.scrub_batch,), jnp.int32))

        paged_tree = Engine(
            params, cfg, max_slots=slots, max_len=max_len,
            spec=SpecConfig(k=k_draft, drafter="ngram", tree=(2,)),
            paged_kv=paged_cfg,
        )
        pt = paged_tree.jit_entries()
        trace("paged_compact", pt["compact"], paged_tree.cache,
              jnp.zeros((slots,), jnp.int32),
              jnp.zeros((slots, paged_tree._tree.n_nodes), jnp.int32),
              jnp.zeros((slots,), jnp.int32))

        if full:
            from repro.configs import get_config
            from repro.models import init_lm, pack_params

            mla_cfg = get_config("deepseek-v3-671b", smoke=True)
            mla_params = pack_params(
                init_lm(jax.random.PRNGKey(0), mla_cfg), mla_cfg
            )
            mla = Engine(mla_params, mla_cfg, max_slots=slots,
                         max_len=max_len, prefill_chunk=chunk)
            me = mla.jit_entries()
            entries.append(IREntry(
                name="engine/mla_decode",
                jaxpr=jax.make_jaxpr(me["decode"])(
                    mla_params, mla.cache, jnp.zeros((slots, 1), jnp.int32)
                ),
                kind="engine",
            ))
            entries.append(IREntry(
                name="engine/mla_chunk_verify",
                jaxpr=jax.make_jaxpr(me["chunk_verify"])(
                    mla_params, mla.cache,
                    jnp.zeros((slots, chunk), jnp.int32),
                    jnp.zeros((slots,), jnp.int32),
                ),
                kind="engine",
            ))
            # paged MLA: the compressed-KV pool gathers through the same
            # block tables (ckv/krope leaves, no slot_pos — no scrub pass)
            pmla = Engine(mla_params, mla_cfg, max_slots=slots,
                          max_len=max_len, prefill_chunk=chunk,
                          paged_kv=paged_cfg)
            pme = pmla.jit_entries()
            trace("paged_mla_decode", pme["decode"], mla_params, pmla.cache,
                  jnp.zeros((slots, 1), jnp.int32))
            trace("paged_mla_chunk_verify", pme["chunk_verify"], mla_params,
                  pmla.cache, jnp.zeros((slots, chunk), jnp.int32),
                  jnp.zeros((slots,), jnp.int32))
    return entries


def default_entries(full: bool = False) -> list[IREntry]:
    """The registry `python -m repro.lint --ir` runs: every mpGeMM
    impl x fusion combination plus every serving entry point."""
    return mpgemm_entries(full=full) + engine_entries(full=full)
