"""repro.lint.ir core: IR entry model, pass registry, jaxpr walking.

The IR suite is the AST linter's complement: instead of parsing source it
*traces* a registry of hot-path entry points (kernels/ops.py mpGeMM impls,
`Engine.jit_entries()`, `ModelDrafter.jit_entries()`) to ClosedJaxprs with
`jax.make_jaxpr` — no compilation, no execution — and runs pluggable passes
over the equations. What the AST cannot see (a quantized value silently
promoted to f32 mid-graph, a dead intermediate surviving a fused-epilogue
refactor, a host callback smuggled into a decode step, a graph whose traffic
outgrew the roofline model, or *any* structural change to a serving graph)
is exactly what these passes check. Pass catalog: docs/static_analysis.md.

Findings reuse `lint.core.Finding` and the same exit-code contract
(0 clean / 1 findings / 2 usage). Source-comment suppressions make no sense
for traced IR, so the suppression contract moves to the registry: an entry
declares ``suppress={"I4": "<justification, ≥3 words>"}``; an
under-justified suppression is itself a finding (I0, unsuppressable) —
mirroring the AST side's R0.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Iterator

from ..core import MIN_JUSTIFICATION_WORDS, Finding

#: pass id -> (one-line description, check callable)
_PASSES: dict[
    str, tuple[str, Callable[["IREntry"], Iterable[Finding]]]
] = {}


def ir_pass(pass_id: str, description: str):
    """Decorator registering ``check(entry) -> Iterable[Finding]``."""

    def deco(fn):
        _PASSES[pass_id] = (description, fn)
        fn.pass_id = pass_id
        fn.description = description
        return fn

    return deco


def registered_passes() -> dict[str, str]:
    return {pid: desc for pid, (desc, _) in sorted(_PASSES.items())}


@dataclasses.dataclass
class IREntry:
    """One traced entry point: a name, its ClosedJaxpr, and pass metadata.

    name      stable identifier ("mpgemm/vlut/M16", "engine/chunk_verify");
              doubles as the snapshot filename (with '/' -> '__').
    jaxpr     the ClosedJaxpr from jax.make_jaxpr.
    kind      "mpgemm" | "engine" | "drafter" — passes gate on it.
    meta      pass inputs: mpgemm entries carry m_out/k/m_tokens/g/fused for
              the I4 roofline cross-check and traffic_factor overrides.
    suppress  pass id -> justification (≥3 words); suppressed passes are
              skipped for this entry, bad justifications are I0 findings.
    """

    name: str
    jaxpr: Any
    kind: str = "mpgemm"
    meta: dict = dataclasses.field(default_factory=dict)
    suppress: dict = dataclasses.field(default_factory=dict)

    @property
    def path(self) -> str:
        """Pseudo-path used in Finding rows (there is no source file)."""
        return f"<jaxpr:{self.name}>"


# --------------------------------------------------------------------------
# jaxpr walking
# --------------------------------------------------------------------------
def subjaxprs(eqn) -> list:
    """The Jaxprs nested in one eqn's params (pjit/scan/while/cond bodies).
    pallas_call kernels are deliberately EXCLUDED: their jaxpr has Mosaic
    ref/memory semantics the passes do not model — the AST R5 rules and the
    kernel tests own that boundary."""
    if eqn.primitive.name == "pallas_call":
        return []
    out = []
    for v in eqn.params.values():
        vals = v if isinstance(v, (list, tuple)) else (v,)
        for item in vals:
            j = getattr(item, "jaxpr", item)  # ClosedJaxpr -> Jaxpr
            if hasattr(j, "eqns") and hasattr(j, "invars"):
                out.append(j)
    return out


def all_eqns(jaxpr) -> Iterator[tuple[Any, int]]:
    """Depth-first (eqn, depth) over a Jaxpr and its nested call bodies."""

    def walk(j, depth):
        for eqn in j.eqns:
            yield eqn, depth
            for sub in subjaxprs(eqn):
                yield from walk(sub, depth + 1)

    yield from walk(jaxpr, 0)


def aval_bytes(aval) -> int:
    """Nominal byte size of an abstract value (0 for non-array avals)."""
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    n = 1
    for d in shape:
        try:
            n *= int(d)
        except TypeError:  # symbolic dim — don't guess
            return 0
    return n * dtype.itemsize


def fmt_aval(aval) -> str:
    dtype = getattr(aval, "dtype", None)
    shape = getattr(aval, "shape", None)
    if dtype is None:
        return str(aval)
    return f"{dtype.name}[{','.join(str(d) for d in (shape or ()))}]"


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------
def run_passes(
    entries: Iterable[IREntry],
    select: set[str] | None = None,
    **pass_kwargs,
) -> list[Finding]:
    """Run every registered pass over every entry -> sorted findings.

    Extra keyword args are forwarded to passes that accept them (the
    snapshot pass takes ``snapshot_dir``/``update_snapshots``); passes that
    do not declare the kwarg are called with the entry alone.
    """
    findings: list[Finding] = []
    for entry in entries:
        # registry-level suppression contract (I0 mirrors the AST R0)
        active_suppress: set[str] = set()
        for pid, justification in sorted(entry.suppress.items()):
            if len(str(justification).split()) < MIN_JUSTIFICATION_WORDS:
                findings.append(Finding(
                    "I0", entry.path, 0, 0,
                    f"suppression of {pid} lacks a justification "
                    f"(≥{MIN_JUSTIFICATION_WORDS} words)",
                ))
            else:
                active_suppress.add(pid)
        for pid, (_desc, check) in sorted(_PASSES.items()):
            if select is not None and pid not in select:
                continue
            if pid in active_suppress:
                continue
            kw = {
                k: v for k, v in pass_kwargs.items()
                if k in check.__code__.co_varnames[: check.__code__.co_argcount]
            }
            findings.extend(check(entry, **kw))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings
