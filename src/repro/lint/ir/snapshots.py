"""I5 — golden jaxpr signatures: structural snapshots of the hot path.

A refactor that changes the traced graph of a serving entry point —
different primitive sequence, different shapes, an extra materialization —
should be a *reviewable diff*, not a silent perf change discovered three
PRs later by a benchmark. Each registry entry gets a stable structural
hash over (primitive sequence + input/output avals + canonicalized
static params), recursing through nested call bodies; object identities,
variable names, and trace-order artifacts do not enter the hash.

Snapshots live under ``tests/ir_snapshots/<backend>/<entry>.json`` and
carry the hash plus per-primitive counts, so a mismatch's diff shows
*what kind* of structure changed. Workflow:

    python -m repro.lint --ir                     # gate: hash must match
    python -m repro.lint --ir --update-snapshots  # intentional change:
                                                  # rewrite + commit

Findings: missing snapshot (new entry never snapshotted) and stale
snapshot (hash mismatch, message includes the primitive-count delta).
"""
from __future__ import annotations

import hashlib
import json
import os
from collections import Counter
from typing import Iterable

import jax

from ..core import Finding
from .core import IREntry, fmt_aval, ir_pass, subjaxprs

#: default snapshot root (keyed by backend inside)
SNAPSHOT_ROOT = os.path.join("tests", "ir_snapshots")


def snapshot_dir(root: str | None = None) -> str:
    return os.path.join(root or SNAPSHOT_ROOT, jax.default_backend())


def _canon_param(v) -> str:
    """Stable rendering of one static param value (no object ids)."""
    if hasattr(v, "eqns") or hasattr(getattr(v, "jaxpr", None), "eqns"):
        return "<jaxpr>"  # nested bodies are hashed by the recursion
    if isinstance(v, (list, tuple)):
        return "(" + ",".join(_canon_param(x) for x in v) + ")"
    if isinstance(v, dict):
        return "{" + ",".join(
            f"{k}:{_canon_param(v[k])}" for k in sorted(map(str, v))
        ) + "}"
    if isinstance(v, (int, float, bool, str, bytes, type(None))):
        return repr(v)
    if callable(v):
        return getattr(v, "__name__", type(v).__name__)
    r = repr(v)
    return r if "0x" not in r else type(v).__name__


def _sig_lines(jaxpr, out: list[str], depth: int = 0) -> None:
    pad = "." * depth
    out.append(
        f"{pad}in:{','.join(fmt_aval(v.aval) for v in jaxpr.invars)}"
    )
    for eqn in jaxpr.eqns:
        ins = ",".join(
            fmt_aval(v.aval) if hasattr(v, "aval") else "lit"
            for v in eqn.invars
        )
        outs = ",".join(fmt_aval(v.aval) for v in eqn.outvars)
        params = ";".join(
            f"{k}={_canon_param(v)}" for k, v in sorted(eqn.params.items())
        )
        out.append(f"{pad}{eqn.primitive.name}({ins})->({outs})[{params}]")
        for sub in subjaxprs(eqn):
            _sig_lines(sub, out, depth + 1)
    out.append(
        f"{pad}out:{','.join(fmt_aval(v.aval) for v in jaxpr.outvars)}"
    )


def signature(closed_jaxpr) -> tuple[str, dict]:
    """-> (sha256 structural hash, {primitive: recursive count})."""
    lines: list[str] = []
    _sig_lines(closed_jaxpr.jaxpr, lines)
    digest = hashlib.sha256("\n".join(lines).encode()).hexdigest()
    counts: Counter = Counter()

    def count(j):
        for eqn in j.eqns:
            counts[eqn.primitive.name] += 1
            for sub in subjaxprs(eqn):
                count(sub)

    count(closed_jaxpr.jaxpr)
    return digest, dict(sorted(counts.items()))


def _snapshot_path(entry: IREntry, root: str | None) -> str:
    fname = entry.name.replace("/", "__") + ".json"
    return os.path.join(snapshot_dir(root), fname)


def write_snapshot(entry: IREntry, root: str | None = None) -> str:
    digest, counts = signature(entry.jaxpr)
    path = _snapshot_path(entry, root)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    jaxpr = entry.jaxpr.jaxpr
    payload = {
        "entry": entry.name,
        "backend": jax.default_backend(),
        "hash": digest,
        "n_eqns": sum(counts.values()),
        "primitives": counts,
        "invars": [fmt_aval(v.aval) for v in jaxpr.invars],
        "outvars": [fmt_aval(v.aval) for v in jaxpr.outvars],
    }
    with open(path, "w", encoding="utf-8") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def _count_delta(old: dict, new: dict) -> str:
    keys = sorted(set(old) | set(new))
    parts = [
        f"{k}: {old.get(k, 0)}->{new.get(k, 0)}"
        for k in keys if old.get(k, 0) != new.get(k, 0)
    ]
    return ", ".join(parts) if parts else "same primitive counts"


@ir_pass("I5", "golden jaxpr signatures: structural hash vs the committed "
              "snapshot under tests/ir_snapshots/ (update with "
              "--update-snapshots)")
def check_snapshots(
    entry: IREntry,
    snapshot_root: str | None = None,
    update_snapshots: bool = False,
) -> Iterable[Finding]:
    if update_snapshots:
        write_snapshot(entry, snapshot_root)
        return
    path = _snapshot_path(entry, snapshot_root)
    if not os.path.exists(path):
        yield Finding(
            "I5", entry.path, 0, 0,
            f"no golden snapshot at {path} — run `python -m repro.lint "
            f"--ir --update-snapshots` and commit the result",
        )
        return
    with open(path, encoding="utf-8") as f:
        want = json.load(f)
    digest, counts = signature(entry.jaxpr)
    if digest != want.get("hash"):
        yield Finding(
            "I5", entry.path, 0, 0,
            f"traced graph diverged from golden snapshot {path} "
            f"({_count_delta(want.get('primitives', {}), counts)}); if "
            f"intentional, re-run with --update-snapshots and commit the "
            f"diff",
        )
