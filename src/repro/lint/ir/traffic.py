"""I4 — traffic model: per-jaxpr bytes-moved estimate vs the roofline.

From eqn shapes alone, estimate the memory the traced graph moves: every
*materializing* leaf eqn contributes its operand + result bytes (a read
and a write per array), scan bodies are multiplied by their trip count,
pjit bodies are entered (the call eqn itself contributes nothing — its
body does), and `pallas_call` contributes only its HBM operands/results
(kernel-internal VMEM movement is the AST R5 budget rule's
jurisdiction). Pure layout/view and cheap elementwise eqns
(reshape/transpose/broadcast/compare/...) are excluded — XLA fuses them
into their consumers, and counting them made the estimate track graph
*size* instead of graph *traffic*.

For mpGeMM entries the estimate is cross-checked against the analytic
`roofline.analysis.mpgemm_cost` model: a finding fires when

    estimate > factor * mpgemm_cost(m_out, k, m_tokens).bytes

with ``factor`` = entry.meta["traffic_factor"] (default
``DEFAULT_FACTOR``; the registry sets per-impl factors ~2x above the
measured ratio of the current graphs — see tests/test_lint_ir.py — so a
rework that suddenly materializes a few times more intermediates blows
through). Entries without cost meta (the engine graphs have no
single-GeMM cost model) are skipped.
"""
from __future__ import annotations

from typing import Iterable

from ..core import Finding
from .core import IREntry, aval_bytes, ir_pass

#: serving-path default (vlut_packed/mad_int8 measure <= ~4x); the
#: registry overrides per impl for the table-materializing reference impls
DEFAULT_FACTOR = 8.0

_CALL_LIKE = ("pjit", "closed_call", "core_call")

#: eqns XLA fuses away (views, broadcasts, cheap elementwise/compare):
#: counted at zero so the estimate tracks materialized traffic
_FUSED_AWAY = frozenset({
    "reshape", "transpose", "broadcast_in_dim", "squeeze", "expand_dims",
    "convert_element_type", "slice", "pad", "rev", "copy",
    "add", "sub", "mul", "div", "rem", "neg", "sign", "abs", "max", "min",
    "floor", "ceil", "round", "exp", "log", "pow", "integer_pow", "clamp",
    "select_n", "eq", "ne", "ge", "gt", "le", "lt", "and", "or", "not",
    "xor", "shift_left", "shift_right_logical", "shift_right_arithmetic",
    "iota", "stop_gradient", "reduce_sum", "reduce_max", "reduce_min",
    "reduce_and", "reduce_or", "argmax", "argmin", "is_finite", "square",
    "sqrt", "rsqrt", "tanh", "logistic",
})


def _is_var(v) -> bool:
    return hasattr(v, "aval") and type(v).__name__ != "Literal"


def estimate_bytes(jaxpr, trip: float = 1.0) -> float:
    """Trip-count-aware materialized-bytes estimate over one Jaxpr level."""
    total = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
        sub = getattr(sub, "jaxpr", sub)
        if name in _CALL_LIKE and sub is not None:
            total += estimate_bytes(sub, trip)
            continue
        if name == "scan" and sub is not None:
            length = float(eqn.params.get("length", 1) or 1)
            total += estimate_bytes(sub, trip * length)
            continue
        if name == "while" and "body_jaxpr" in eqn.params:
            body = getattr(eqn.params["body_jaxpr"], "jaxpr",
                           eqn.params["body_jaxpr"])
            # unknown trip count: count one iteration (lower bound)
            total += estimate_bytes(body, trip)
            continue
        if name in _FUSED_AWAY:
            continue
        io_bytes = sum(
            aval_bytes(v.aval) for v in eqn.invars if _is_var(v)
        ) + sum(aval_bytes(v.aval) for v in eqn.outvars)
        total += trip * io_bytes
    return total


@ir_pass("I4", "traffic model: shape-derived bytes-moved estimate cross-"
              "checked against roofline.analysis.mpgemm_cost (finding when "
              "estimate exceeds the model by the configured factor)")
def check_traffic(entry: IREntry) -> Iterable[Finding]:
    meta = entry.meta
    if not all(k in meta for k in ("m_out", "k", "m_tokens")):
        return  # no analytic model for this entry's graph
    from repro.roofline.analysis import mpgemm_cost

    est = estimate_bytes(entry.jaxpr.jaxpr)
    _, model = mpgemm_cost(
        meta["m_out"], meta["k"], meta["m_tokens"], g=4,
        fused=bool(meta.get("fused", True)),
    )
    factor = float(meta.get("traffic_factor", DEFAULT_FACTOR))
    if model > 0 and est > factor * model:
        yield Finding(
            "I4", entry.path, 0, 0,
            f"traffic estimate {est / 1e6:.2f} MB exceeds {factor:g}x the "
            f"roofline model ({model / 1e6:.2f} MB) for "
            f"M={meta['m_tokens']}, K={meta['k']}, N={meta['m_out']} — the "
            f"graph materializes far more than the mpGeMM cost model "
            f"allows (estimate/model = {est / model:.1f}x)",
        )
