"""I1 — quantized-dtype flow: the LUT datapath stays in narrow int types.

T-MAC / LUT Tensor Core (PAPERS.md) and this repo's §3.3 fused kernel all
hinge on one invariant: values *derived from the packed ternary weights*
flow through {uint8, int8, int32} until the scale epilogue dequantizes
them. A graph that converts quantized values to float and then runs the
heavy math in float (a float dot_general over decoded trits) has silently
forfeited the paper's arithmetic — numerically identical, performance
class lost. That promotion is invisible to the AST but explicit in the
jaxpr.

Abstract interpretation: taint seeds are the uint8 leaves of the traced
inputs/consts (the packed trit-code segments). Taint propagates through
value-producing eqns, with two deliberate kills:

* the *dequant event* — a `mul` between a tainted float operand and an
  untainted float operand (the w_scale/a_scale epilogue): past the scale
  application the value is legitimately float;
* a `pallas_call` boundary — the kernel body has its own (AST R5 + test)
  coverage, and its outputs are post-epilogue by construction.

Index-like operands (gather/scatter indices, dynamic_slice starts) do not
propagate taint: using codes as LUT *indices* is the whole point.

Finding: a dot_general / conv whose floating-dtype operand is tainted —
quantized values were promoted to float BEFORE any scale was applied and
then fed the heavy op. (Integer dots over tainted int8/int32 operands are
the intended datapath and stay silent.)

Sub-jaxpr handling: pjit/closed_call bodies are entered positionally;
scan/while bodies iterate taint to a fixpoint over the carry.
"""
from __future__ import annotations

from typing import Iterable

import jax.numpy as jnp

from ..core import Finding
from .core import IREntry, ir_pass

_HEAVY = ("dot_general", "conv_general_dilated")

#: primitives whose trailing operands are indices, not values
_INDEX_OPERANDS = {
    "gather": 1,            # operands[1:] are indices
    "dynamic_slice": 1,     # operands[1:] are start indices
    "take_along_axis": 1,
    "argsort": 1,
}


def _is_float(aval) -> bool:
    dt = getattr(aval, "dtype", None)
    return dt is not None and jnp.issubdtype(dt, jnp.floating)


def _value_operands(eqn):
    """The invars that carry *values* (index operands stripped)."""
    name = eqn.primitive.name
    if name == "scatter" or name.startswith("scatter"):
        # (operand, indices, updates) — indices carry no value taint
        ops = list(eqn.invars)
        return [v for i, v in enumerate(ops) if i != 1]
    cut = _INDEX_OPERANDS.get(name)
    if cut is not None:
        return list(eqn.invars)[:cut]
    return list(eqn.invars)


def _sub_jaxpr(eqn):
    j = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
    return getattr(j, "jaxpr", j) if j is not None else None


def _analyze(jaxpr, in_taint, entry, findings, depth=0):
    """Propagate taint through one Jaxpr. -> per-outvar taint list."""
    taint: dict = {}

    def get(v):
        if not hasattr(v, "aval") or type(v).__name__ == "Literal":
            return False
        return taint.get(v, False)

    for var, t in zip(jaxpr.invars, in_taint):
        taint[var] = t
    for var in jaxpr.constvars:
        dt = getattr(var.aval, "dtype", None)
        taint[var] = dt is not None and dt == jnp.uint8
    if depth > 12:  # defensive: pathological nesting
        return [False] * len(jaxpr.outvars)

    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "pallas_call":
            for ov in eqn.outvars:
                taint[ov] = False
            continue
        sub = _sub_jaxpr(eqn)
        if sub is not None and name in ("pjit", "closed_call", "core_call",
                                        "custom_jvp_call", "custom_vjp_call",
                                        "remat", "checkpoint"):
            out_t = _analyze(sub, [get(v) for v in eqn.invars],
                             entry, findings, depth + 1)
            for ov, t in zip(eqn.outvars, out_t):
                taint[ov] = t
            continue
        if sub is not None and name in ("scan", "while"):
            # fixpoint over the carry: grow taint until stable
            in_t = [get(v) for v in eqn.invars]
            for _ in range(len(jaxpr.eqns) + 2):
                out_t = _analyze(sub, list(in_t[: len(sub.invars)]) + [False]
                                 * max(0, len(sub.invars) - len(in_t)),
                                 entry, findings, depth + 1)
                nc = int(eqn.params.get("num_consts", 0))
                grown = False
                # map body outputs back onto the carry slice of the inputs
                for i, t in enumerate(out_t):
                    j = nc + i
                    if j < len(in_t) and t and not in_t[j]:
                        in_t[j] = True
                        grown = True
                if not grown:
                    break
            for ov, t in zip(eqn.outvars, out_t):
                taint[ov] = t
            continue
        if name == "cond":
            branches = eqn.params.get("branches", ())
            outs = None
            for br in branches:
                bj = getattr(br, "jaxpr", br)
                t = _analyze(bj, [get(v) for v in eqn.invars[1:]],
                             entry, findings, depth + 1)
                outs = t if outs is None else [a or b
                                               for a, b in zip(outs, t)]
            for ov, t in zip(eqn.outvars, outs or []):
                taint[ov] = t
            continue

        vals = _value_operands(eqn)
        tainted_in = [v for v in vals if get(v)]
        if name in _HEAVY and any(
            get(v) and _is_float(v.aval) for v in vals
        ):
            off = next(v for v in vals if get(v) and _is_float(v.aval))
            findings.append(Finding(
                "I1", entry.path, 0, 0,
                f"{name} consumes a floating-dtype operand "
                f"({off.aval.dtype.name}{list(off.aval.shape)}) derived "
                f"from packed ternary weights with no scale applied — the "
                f"quantized datapath was promoted to float before the "
                f"dequant epilogue",
            ))
        out_tainted = bool(tainted_in)
        if out_tainted and name == "mul":
            # dequant kill: tainted float x untainted float scale
            a, b = (eqn.invars + [None, None])[:2]
            ta, tb = get(a), get(b)
            fa = a is not None and hasattr(a, "aval") and _is_float(a.aval)
            fb = b is not None and hasattr(b, "aval") and _is_float(b.aval)
            if fa and fb and (ta != tb):
                out_tainted = False
        for ov in eqn.outvars:
            taint[ov] = out_tainted
    return [get(v) for v in jaxpr.outvars]


@ir_pass("I1", "quantized-dtype flow: values derived from packed ternary "
              "weights stay integer until the scale epilogue; a float "
              "dot/conv over still-quantized values is a finding")
def check_dtype_flow(entry: IREntry) -> Iterable[Finding]:
    closed = entry.jaxpr
    jaxpr = closed.jaxpr
    seeds = [
        getattr(v.aval, "dtype", None) == jnp.uint8 for v in jaxpr.invars
    ]
    findings: list[Finding] = []
    _analyze(jaxpr, seeds, entry, findings)
    return findings
