"""Ternary weight packing for Vec-LUT (paper §3.3, Fig. 6).

A ternary weight group of ``g`` elements (each in {-1, 0, 1}) is packed into a
single byte holding the base-3 ("trit") code

    idx = sum_j (w[j] + 1) * 3**j,   0 <= idx < 3**g,

so the packed byte is *directly* the row index into the vector LUT (paper's
"packed weights as flexible decimal indices" — no hardware-shuffle bit-width
limit, hence g=5 → 243 entries → 1.60 bits/weight).

Supported packings (paper §3.3 "Flexible sub-2-bit weight packing"):
  * I2 : g=4, 2.00 bpw
  * I1 : g=5, 1.60 bpw
  * mixed (I1F): K = 5*b + 4*a split into a 5-group segment followed by a
    4-group segment — covers any K >= 12 (and many below) losslessly with
    near-1.6 bpw.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

GROUP_SIZES = (4, 5)
#: trit radix
_R = 3


@functools.lru_cache(maxsize=None)
def sign_matrix(g: int, dtype=np.int8) -> np.ndarray:
    """The (3**g, g) enumeration matrix S with S[i, j] = j-th trit of i, minus 1.

    Row i of ``S`` is the ternary weight pattern whose packed index is i
    (paper Fig. 6); the vector LUT sub-table is exactly ``S @ A_group``.
    """
    idx = np.arange(_R**g, dtype=np.int32)
    js = _R ** np.arange(g, dtype=np.int32)
    trits = (idx[:, None] // js[None, :]) % _R - 1
    return trits.astype(dtype)


def pack_group_sizes(K: int) -> tuple[int, int]:
    """Return (n5, n4): number of g=5 and g=4 groups with 5*n5 + 4*n4 == K.

    Maximizes the number of 5-groups (lowest bpw). Raises if K cannot be
    expressed (only K in {1,2,3,6,7,11} fail).
    """
    for n5 in range(K // 5, -1, -1):
        rem = K - 5 * n5
        if rem % 4 == 0:
            return n5, rem // 4
    raise ValueError(f"K={K} cannot be packed with groups of 4 and 5")


def pack_ternary(w: jax.Array, g: int) -> jax.Array:
    """Pack ternary int8 weights (..., K) with g | K into uint8 codes (..., K//g)."""
    K = w.shape[-1]
    if K % g:
        raise ValueError(f"K={K} not divisible by group size g={g}")
    wg = w.reshape(*w.shape[:-1], K // g, g).astype(jnp.int32) + 1
    place = (_R ** jnp.arange(g, dtype=jnp.int32))
    idx = jnp.sum(wg * place, axis=-1)
    return idx.astype(jnp.uint8)


def unpack_ternary(packed: jax.Array, g: int) -> jax.Array:
    """Inverse of :func:`pack_ternary` → int8 ternary values (..., Kg*g)."""
    idx = packed.astype(jnp.int32)
    place = (_R ** jnp.arange(g, dtype=jnp.int32))
    trits = (idx[..., None] // place) % _R - 1
    return trits.reshape(*packed.shape[:-1], packed.shape[-1] * g).astype(jnp.int8)


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class PackedWeight:
    """Ternary weight (M, K) stored as 1–2 packed uint8 segments + scales.

    Segment 0 packs K5 = 5*n5 input features with g=5; segment 1 packs the
    remaining 4*n4 features with g=4. Either may be empty. ``scale`` is the
    per-output-channel (M,) dequantization scale (float32); ``scale_in`` an
    optional per-input-channel scale is folded into activations by callers.
    """

    packed5: jax.Array  # (..., M, K5//5) uint8  (possibly zero-width)
    packed4: jax.Array  # (..., M, K4//4) uint8  (possibly zero-width)
    scale: jax.Array    # (..., M) or (..., 1) float32
    K: int              # static: total input features

    def tree_flatten_with_keys(self):
        ga = jax.tree_util.GetAttrKey
        return (
            (ga("packed5"), self.packed5),
            (ga("packed4"), self.packed4),
            (ga("scale"), self.scale),
        ), (self.K,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, K=aux[0])

    # -- static geometry ---------------------------------------------------
    @property
    def M(self) -> int:
        return self.packed5.shape[-2]

    @property
    def k5(self) -> int:
        return self.packed5.shape[-1] * 5

    @property
    def k4(self) -> int:
        return self.packed4.shape[-1] * 4

    @property
    def bits_per_weight(self) -> float:
        nbytes = self.packed5.shape[-1] + self.packed4.shape[-1]
        return 8.0 * nbytes / self.K

    def unpack(self) -> jax.Array:
        """Dense ternary int8 (..., M, K)."""
        parts = []
        if self.packed5.shape[-1]:
            parts.append(unpack_ternary(self.packed5, 5))
        if self.packed4.shape[-1]:
            parts.append(unpack_ternary(self.packed4, 4))
        return jnp.concatenate(parts, axis=-1) if len(parts) > 1 else parts[0]


def pack_weight(w_ternary: jax.Array, scale: jax.Array, mode: str = "auto") -> PackedWeight:
    """Pack a ternary int8 weight (..., M, K) into a :class:`PackedWeight`.

    mode: 'i2' (g=4 only), 'i1' (g=5 only; requires 5|K), 'auto'/'i1f'
    (maximal 5-groups, remainder in 4-groups).
    """
    K = w_ternary.shape[-1]
    if mode == "i2":
        n5, n4 = 0, K // 4
        if K % 4:
            raise ValueError(f"I2 packing needs 4|K, got K={K}")
    elif mode == "i1":
        if K % 5:
            raise ValueError(f"I1 packing needs 5|K, got K={K}")
        n5, n4 = K // 5, 0
    else:
        n5, n4 = pack_group_sizes(K)
    k5 = 5 * n5
    lead = w_ternary.shape[:-2]
    m = w_ternary.shape[-2]
    p5 = (pack_ternary(w_ternary[..., :k5], 5) if n5
          else jnp.zeros((*lead, m, 0), jnp.uint8))
    p4 = (pack_ternary(w_ternary[..., k5:], 4) if n4
          else jnp.zeros((*lead, m, 0), jnp.uint8))
    scale = jnp.asarray(scale, jnp.float32)
    if scale.ndim == len(lead):  # per-tensor -> broadcastable (..., 1)
        scale = scale[..., None]
    return PackedWeight(p5, p4, scale, K=K)
