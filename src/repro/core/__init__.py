"""repro.core — the paper's contribution: Vec-LUT vector-table-lookup mpGeMM.

Public surface:
  packing    — ternary trit-code packing (I1/I2/mixed sub-2-bit), PackedWeight
  quantize   — BitNet-b1.58 absmean ternary + per-token int8 activations (+STE)
  vlut       — Algorithm 1: unified vector LUT precompute + 1→N lookup GEMM
  baselines  — scalar-LUT (T-MAC-like) and MAD (llama.cpp-like) comparators
"""
from .packing import (
    GROUP_SIZES,
    PackedWeight,
    pack_group_sizes,
    pack_ternary,
    pack_weight,
    sign_matrix,
    unpack_ternary,
)
from .quantize import (
    QuantizedActivation,
    TernaryWeight,
    act_quant_int8,
    act_quant_tokens,
    act_token_scale,
    fake_act_quant,
    fake_ternary,
    fake_ternary_cols,
    ternary_dequantize,
    ternary_quantize,
)
from .vlut import (
    lookup_accumulate,
    max_block_int16,
    precompute_lut,
    precompute_lut_naive,
    precompute_lut_topological,
    vlut_gemm,
)
from .baselines import (
    dense_gemm_f32,
    lut_gemm_auto,
    mad_gemm,
    mad_gemm_int8,
    scalar_lut_gemm,
)

__all__ = [
    "GROUP_SIZES", "PackedWeight", "pack_group_sizes", "pack_ternary",
    "pack_weight", "sign_matrix", "unpack_ternary",
    "QuantizedActivation", "TernaryWeight", "act_quant_int8",
    "act_quant_tokens", "act_token_scale", "fake_act_quant",
    "fake_ternary", "fake_ternary_cols", "ternary_dequantize", "ternary_quantize",
    "lookup_accumulate", "max_block_int16", "precompute_lut",
    "precompute_lut_naive", "precompute_lut_topological", "vlut_gemm",
    "dense_gemm_f32", "lut_gemm_auto", "mad_gemm", "mad_gemm_int8", "scalar_lut_gemm",
]
