"""Vector LUT mpGeMM — faithful JAX implementation of the paper's Algorithm 1.

Computes  O = W x A  with ternary W (M, K) packed as uint8 trit-codes and
activation A (K, N) in the paper's *token-contiguous* layout (N last/minor).

Pipeline (paper §3.2):
  1. LUT precompute:  T[k, i, :] = sum_j GetSign(i, j) * A[k*g + j, :]
     == S(3^g, g) @ A_group(g, N)   — one unified table for all N tokens.
  2. Table lookup & accumulate:  O[m, :] += T[k, W[m, k], :]
     — a single 1→N row gather per index (vector LUT), never a per-token
     (1→1, scalar LUT) lookup.

Implemented variants (each maps to a paper technique; the benchmark/ablation
harness toggles them to reproduce Fig. 12):
  * streamed vs whole-table execution       (§3.4 Cache-Aware Streamed Lookup)
  * hierarchical INT16→INT32 accumulation   (§3.4)
  * token-contiguous vs feature-contiguous LUT layout (§3.3, the 12× ablation)
  * topological (3^g-op) vs naive (2*3^{g-1}*g-op) precompute (§4)
  * K/N tiling with paper §4 tile-size rules (N_tile, K_tile)

All functions are jit-friendly pure JAX; these are the *reference semantics*
for the Pallas kernels in `repro.kernels` and the engine used by the CPU
benchmarks.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from .packing import PackedWeight, sign_matrix
from .quantize import act_quant_int8, act_quant_tokens


def max_block_int16(g: int) -> int:
    """Paper §3.4: INT16 intra-block accumulation is overflow-free for
    B <= floor(max(INT16) / (max(INT8) * g)) — 64 is quoted for g=4 with the
    paper's looser bound; we use the strict bound (64 for g=4, 51 for g=5)."""
    return int(32767 // (127 * g))


# --------------------------------------------------------------------------
# LUT precompute
# --------------------------------------------------------------------------
def precompute_lut(a_q: jax.Array, g: int) -> jax.Array:
    """Unified vector LUT. a_q: (K, N) int8 → T: (K//g, 3^g, N) int16.

    TPU-adapted "topological precompute": the whole sub-table is one matmul
    with the compile-time sign-enumeration matrix S (DESIGN.md §2) — same
    op-reduction goal as the paper's serial reuse chain, MXU-friendly.
    """
    K, N = a_q.shape
    if K % g:
        raise ValueError(f"K={K} not divisible by g={g}")
    s = jnp.asarray(sign_matrix(g), jnp.int8)                        # (3^g, g)
    a_grp = a_q.reshape(K // g, g, N)                                # (Kg, g, N)
    t = jax.lax.dot_general(
        s, a_grp,
        dimension_numbers=(((1,), (1,)), ((), ())),                  # (3^g, Kg, N)
        preferred_element_type=jnp.int32,
    )
    return t.transpose(1, 0, 2).astype(jnp.int16)                    # (Kg, 3^g, N)


def precompute_lut_topological(a_q: jax.Array, g: int) -> jax.Array:
    """Paper §4 'Topological precomputing' — builds the 3^g entries with
    3^g - 1 vector add/subs by reusing already-computed entries.

    For entry i, let j be the position of its lowest nonzero trit; then
    T[i] = T[i - 3^j] + a_j (one vector add), and T[0] = -sum_j a_j seeds the
    chain. Serial dependency chain → kept for the CPU benchmarks / op-count
    ablation (on TPU the MXU matmul in :func:`precompute_lut` wins; DESIGN.md).
    """
    K, N = a_q.shape
    kg = K // g
    a_grp = a_q.reshape(kg, g, N).astype(jnp.int16)
    n_entries = 3 ** g

    # Host-side dependency plan (static for a given g).
    parents = np.zeros(n_entries, np.int32)
    addrow = np.zeros(n_entries, np.int32)
    for i in range(1, n_entries):
        j, ii = 0, i
        while ii % 3 == 0:
            ii //= 3
            j += 1
        parents[i] = i - 3 ** j
        addrow[i] = j

    table = jnp.zeros((kg, n_entries, N), jnp.int16)
    table = table.at[:, 0, :].set(-jnp.sum(a_grp, axis=1, dtype=jnp.int16))
    parents_j = jnp.asarray(parents)
    addrow_j = jnp.asarray(addrow)

    def step(i, tab):
        entry = tab[:, parents_j[i], :] + a_grp[:, addrow_j[i], :]
        return tab.at[:, i, :].set(entry)

    return jax.lax.fori_loop(1, n_entries, step, table)


def precompute_lut_naive(a_q: jax.Array, g: int) -> jax.Array:
    """Paper Alg. 1 lines 7–19 verbatim (per-entry sign add/sub loop): the
    2*3^{g-1}*g-op baseline for the topological-precompute ablation."""
    K, N = a_q.shape
    s = sign_matrix(g)                                               # host const
    a_grp = a_q.reshape(K // g, g, N).astype(jnp.int16)

    entries = []
    for i in range(3 ** g):
        acc = jnp.zeros((K // g, N), jnp.int16)
        for j in range(g):
            sgn = int(s[i, j])
            if sgn == 1:
                acc = acc + a_grp[:, j, :]
            elif sgn == -1:
                acc = acc - a_grp[:, j, :]
        entries.append(acc)
    return jnp.stack(entries, axis=1)                                # (Kg, 3^g, N)


# --------------------------------------------------------------------------
# Lookup & accumulate
# --------------------------------------------------------------------------
def lookup_accumulate(
    t: jax.Array,
    w_idx: jax.Array,
    hierarchical: bool = True,
    g: int | None = None,
) -> jax.Array:
    """O[m, n] = sum_k T[k, W[m, k], n]   (paper Eq. 2) → int32 (M, N).

    hierarchical=True performs the paper's INT16 intra-block / INT32
    inter-block accumulation; False accumulates each row straight into INT32.
    """
    kg, n_entries, n = t.shape
    m = w_idx.shape[0]
    g = g if g is not None else {81: 4, 243: 5}[n_entries]
    block = max_block_int16(g)

    def gather_rows(t_k, w_k):  # (3^g, N), (M,) -> (M, N): the 1→N lookup
        return jnp.take(t_k, w_k.astype(jnp.int32), axis=0)

    if hierarchical and kg > 1:
        pad = (-kg) % block
        zero_code = (n_entries - 1) // 2  # all-zero-trit row ≡ 0 contribution
        tp = jnp.pad(t, ((0, pad), (0, 0), (0, 0)))
        wp = jnp.pad(w_idx, ((0, 0), (0, pad)), constant_values=zero_code)
        nb = (kg + pad) // block
        tb = tp.reshape(nb, block, n_entries, n)
        wb = wp.reshape(m, nb, block).transpose(1, 2, 0)             # (nb, block, M)

        def blk(carry, xs):
            t_blk, w_blk = xs                     # (block, 3^g, N), (block, M)
            rows = jax.vmap(gather_rows)(t_blk, w_blk)   # (block, M, N) int16
            part = jnp.sum(rows, axis=0, dtype=jnp.int16)  # INT16 intra-block
            return carry + part.astype(jnp.int32), None

        out, _ = jax.lax.scan(blk, jnp.zeros((m, n), jnp.int32), (tb, wb))
        return out

    def one_k(carry, xs):
        t_k, w_k = xs
        return carry + gather_rows(t_k, w_k).astype(jnp.int32), None

    out, _ = jax.lax.scan(one_k, jnp.zeros((m, n), jnp.int32), (t, w_idx.T))
    return out


def _segment_gemm_int(
    packed: jax.Array,
    a_q: jax.Array,
    g: int,
    *,
    streamed: bool,
    k_tile_groups: int,
    hierarchical: bool,
    precompute: Literal["matmul", "topological", "naive"],
) -> jax.Array:
    """Integer vlut GEMM for one homogeneous-g segment. a_q: (K, N) int8.

    streamed=True: scan over K-tiles, precomputing each LUT tile on demand and
    consuming it immediately (§3.4 — the full table never exists in memory).
    streamed=False: materialize the entire T first (the "existing kernels'
    practice" the paper ablates against in Fig. 12).
    """
    kfn = {
        "matmul": precompute_lut,
        "topological": precompute_lut_topological,
        "naive": precompute_lut_naive,
    }[precompute]
    K, N = a_q.shape
    kg = K // g
    m = packed.shape[0]

    if not streamed:
        t = kfn(a_q, g)
        return lookup_accumulate(t, packed, hierarchical=hierarchical, g=g)

    kt = max(1, min(k_tile_groups, kg))
    pad_g = (-kg) % kt
    zero_code = (3 ** g - 1) // 2  # all-zero trits → contributes 0
    a_pad = jnp.pad(a_q.reshape(kg, g, N), ((0, pad_g), (0, 0), (0, 0)))
    w_pad = jnp.pad(packed, ((0, 0), (0, pad_g)), constant_values=zero_code)
    nkt = (kg + pad_g) // kt
    a_tiles = a_pad.reshape(nkt, kt * g, N)
    w_tiles = w_pad.reshape(m, nkt, kt).transpose(1, 0, 2)

    def tile_step(carry, xs):
        a_t, w_t = xs                                  # (kt*g, N), (M, kt)
        t_tile = kfn(a_t, g)                           # (kt, 3^g, N) in "cache"
        out = lookup_accumulate(t_tile, w_t, hierarchical=hierarchical, g=g)
        return carry + out, None

    out, _ = jax.lax.scan(tile_step, jnp.zeros((m, N), jnp.int32), (a_tiles, w_tiles))
    return out


# --------------------------------------------------------------------------
# Public mpGeMM entry point
# --------------------------------------------------------------------------
@functools.partial(
    jax.jit,
    static_argnames=(
        "streamed", "k_tile_groups", "n_tile", "hierarchical", "precompute",
        "token_contiguous",
    ),
)
def vlut_gemm(
    pw: PackedWeight,
    a: jax.Array,
    *,
    streamed: bool = True,
    k_tile_groups: int = 16,
    n_tile: int = 0,
    hierarchical: bool = True,
    precompute: Literal["matmul", "topological", "naive"] = "matmul",
    token_contiguous: bool = True,
) -> jax.Array:
    """Full Vec-LUT mpGeMM:  O(M, N) f32 = dequant( W_packed × quant(A) ).

    a: (K, N) float — token-contiguous activation (N minor), matching the
    paper's Vector-LUT-centric layout. `token_contiguous=False` runs the
    layout-ablation variant (feature-contiguous compute order, reproducing
    the up-to-12× degradation of §5.5 qualitatively). `n_tile=0` disables
    N tiling; otherwise tokens are processed in N_tile chunks (§4 rule:
    multiples of the vector width).
    """
    if a.shape[0] != pw.K:
        raise ValueError(f"A rows {a.shape[0]} != packed K {pw.K}")
    N = a.shape[1]
    if not token_contiguous:
        # Feature-contiguous compute order: quantize & index along the hostile
        # axis so every token touches strided memory (scalar-LUT-style layout).
        qa = act_quant_int8(a.T, axis=-1)                             # (N, K)
        a_q = qa.values.T
        a_scale = qa.scale[:, 0]                                      # (N,)
    else:
        # Shared per-token quantizer (same rounding as the kernels/oracle).
        a_q, a_scale = act_quant_tokens(a)

    def run(a_q_chunk):
        out = jnp.zeros((pw.M, a_q_chunk.shape[1]), jnp.int32)
        k5 = pw.k5
        if pw.packed5.shape[-1]:
            out = out + _segment_gemm_int(
                pw.packed5, a_q_chunk[:k5], 5,
                streamed=streamed, k_tile_groups=k_tile_groups,
                hierarchical=hierarchical, precompute=precompute,
            )
        if pw.packed4.shape[-1]:
            out = out + _segment_gemm_int(
                pw.packed4, a_q_chunk[k5:], 4,
                streamed=streamed, k_tile_groups=k_tile_groups,
                hierarchical=hierarchical, precompute=precompute,
            )
        return out

    if n_tile and n_tile < N and N % n_tile == 0:
        chunks = a_q.reshape(pw.K, N // n_tile, n_tile).transpose(1, 0, 2)
        out = jax.lax.map(run, chunks)                                # (nc, M, nt)
        out_i32 = out.transpose(1, 0, 2).reshape(pw.M, N)
    else:
        out_i32 = run(a_q)

    w_scale = pw.scale if pw.scale.shape[-1] == pw.M else jnp.broadcast_to(pw.scale, (pw.M,))
    return out_i32.astype(jnp.float32) * w_scale[:, None] * a_scale[None, :]
