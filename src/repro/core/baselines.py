"""Baseline mpGeMM kernels the paper compares against (§2.2, §5.1).

* scalar_lut_gemm  — T-MAC-style scalar LUT: one table *per token*, N×
  repeated 1→1 lookups (paper Fig. 1(b-1)). Implemented as a vmap over tokens
  of a single-token LUT GEMM, with the per-token feature-major table layout —
  the memory-access pattern the paper diagnoses.
* mad_gemm         — llama.cpp-style MAD: dequantize the packed weights to a
  dense matrix at use time, then multiply-add (paper §2.2.1).
* dense_int8_gemm  — dequantization-free int8 reference (Q8_0 analogue).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .packing import PackedWeight, sign_matrix, unpack_ternary


def _token_lut_gemm(packed: jax.Array, a_tok: jax.Array, g: int) -> jax.Array:
    """Single-token scalar-LUT GEMM: a_tok (K,) int8 → (M,) int32.

    Builds this token's own table T_n (Kg, 3^g) — feature-major, as in T-MAC —
    then performs a 1→1 lookup per (m, k).
    """
    K = a_tok.shape[0]
    s = jnp.asarray(sign_matrix(g), jnp.int8)                        # (3^g, g)
    a_grp = a_tok.reshape(K // g, g)
    t_n = jax.lax.dot_general(                                       # (Kg, 3^g)
        a_grp, s, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    ).astype(jnp.int16)

    def one_row(w_row):                                              # (Kg,)
        vals = jnp.take_along_axis(t_n, w_row.astype(jnp.int32)[:, None], axis=1)
        return jnp.sum(vals[:, 0].astype(jnp.int32))

    return jax.vmap(one_row)(packed)                                 # (M,)


def _segment_scalar(packed: jax.Array, a_q: jax.Array, g: int) -> jax.Array:
    # N independent tables + N independent lookup passes (the 1→1 paradigm).
    return jax.vmap(
        functools.partial(_token_lut_gemm, g=g), in_axes=(None, 1), out_axes=1
    )(packed, a_q)


@jax.jit
def scalar_lut_gemm(pw: PackedWeight, a: jax.Array) -> jax.Array:
    """T-MAC-style scalar-LUT mpGeMM. a: (K, N) float → (M, N) f32."""
    amax = jnp.max(jnp.abs(a), axis=0)
    a_scale = jnp.maximum(amax, 1e-6) / 127.0
    a_q = jnp.clip(jnp.round(a / a_scale[None, :]), -127, 127).astype(jnp.int8)
    out = jnp.zeros((pw.M, a.shape[1]), jnp.int32)
    if pw.packed5.shape[-1]:
        out = out + _segment_scalar(pw.packed5, a_q[: pw.k5], 5)
    if pw.packed4.shape[-1]:
        out = out + _segment_scalar(pw.packed4, a_q[pw.k5:], 4)
    w_scale = pw.scale if pw.scale.shape[-1] == pw.M else jnp.broadcast_to(pw.scale, (pw.M,))
    return out.astype(jnp.float32) * w_scale[:, None] * a_scale[None, :]


@functools.partial(jax.jit, static_argnames=("compute_dtype",))
def mad_gemm(pw: PackedWeight, a: jax.Array, compute_dtype=jnp.float32) -> jax.Array:
    """MAD-based mpGeMM: unpack → dequantize → dense multiply-add (llama.cpp
    TQ1_0/TQ2_0 analogue). a: (K, N) float → (M, N) f32."""
    w_t = pw.unpack().astype(compute_dtype)                          # (M, K)
    w_scale = pw.scale if pw.scale.shape[-1] == pw.M else jnp.broadcast_to(pw.scale, (pw.M,))
    w = w_t * w_scale[:, None].astype(compute_dtype)
    return jnp.dot(w, a.astype(compute_dtype)).astype(jnp.float32)


@jax.jit
def mad_gemm_int8(pw: PackedWeight, a: jax.Array) -> jax.Array:
    """MAD with int8 activations and int8 ternary weights (bitnet.cpp I2_S
    analogue): unpack (no dequant) then int8×int8→int32 dot."""
    amax = jnp.max(jnp.abs(a), axis=0)
    a_scale = jnp.maximum(amax, 1e-6) / 127.0
    a_q = jnp.clip(jnp.round(a / a_scale[None, :]), -127, 127).astype(jnp.int8)
    w_t = pw.unpack()                                                # int8 (M, K)
    out = jax.lax.dot_general(
        w_t, a_q, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    w_scale = pw.scale if pw.scale.shape[-1] == pw.M else jnp.broadcast_to(pw.scale, (pw.M,))
    return out.astype(jnp.float32) * w_scale[:, None] * a_scale[None, :]


@jax.jit
def dense_gemm_f32(w: jax.Array, a: jax.Array) -> jax.Array:
    """Unquantized dense GEMM (upper-accuracy reference)."""
    return jnp.dot(w.astype(jnp.float32), a.astype(jnp.float32))


def lut_gemm_auto(pw: PackedWeight, a: jax.Array, n_switch: int = 8) -> jax.Array:
    """Paper §6.3: switch between scalar and vector LUT by parallel-token
    count — scalar-LUT wins single-token decode, vector-LUT wins N ≥ ~8
    (crossover measured on this host in benchmarks/gemm_bench: scalar is
    2–3× faster at N=1, vector 2.3–3.6× faster at N ≥ 8). N is static under
    jit, so the dispatch costs nothing at runtime."""
    from .vlut import vlut_gemm

    if a.shape[1] < n_switch:
        return scalar_lut_gemm(pw, a)
    return vlut_gemm(pw, a)
