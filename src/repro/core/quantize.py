"""Quantization: BitNet-b1.58 absmean ternary weights + per-token int8 activations.

Weight path (paper §2.1.2/§5.1: the models are *natively* ternary — BitNet,
Llama3-1.58, Falcon3-1.58; for the assigned architecture zoo we ternarize with
the BitNet b1.58 recipe):

    scale = mean(|W|)           (per output channel or per tensor)
    W_t   = round(clip(W / scale, -1, 1))  in {-1, 0, 1}
    W     ~= scale * W_t

Activation path (paper §3.4: "per-token symmetrically quantized to INT8"):

    a_scale[n] = max_k |A[k, n]| / 127
    A_q = round(A / a_scale)  int8

Training uses the straight-through estimator (QAT) so the same module
definition trains with fake-quant and serves with packed weights.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

EPS = 1e-6
Q_MAX = 127.0


class TernaryWeight(NamedTuple):
    values: jax.Array  # int8 ternary, same shape as source weight
    scale: jax.Array   # f32, per-channel (M,) or scalar ()


def ternary_quantize(w: jax.Array, per_channel: bool = True) -> TernaryWeight:
    """Absmean ternary quantization (BitNet b1.58). w: (..., M, K) float."""
    w = w.astype(jnp.float32)
    if per_channel:
        scale = jnp.mean(jnp.abs(w), axis=-1) + EPS        # (..., M)
        t = jnp.round(w / scale[..., None])
    else:
        scale = jnp.mean(jnp.abs(w), axis=(-2, -1)) + EPS  # (...,)
        t = jnp.round(w / scale[..., None, None])
    t = jnp.clip(t, -1, 1)
    return TernaryWeight(t.astype(jnp.int8), scale)


def ternary_dequantize(tw: TernaryWeight) -> jax.Array:
    scale = tw.scale[..., None] if tw.scale.ndim == tw.values.ndim - 1 else tw.scale
    return tw.values.astype(jnp.float32) * scale


def fake_ternary(w: jax.Array, per_channel: bool = True) -> jax.Array:
    """QAT fake-quant with straight-through estimator: forward = dequant(quant(w)),
    backward = identity. Used by BitLinear in training mode."""
    tw = ternary_quantize(w, per_channel)
    wq = ternary_dequantize(tw).astype(w.dtype)
    return w + jax.lax.stop_gradient(wq - w)


def fake_ternary_cols(w: jax.Array) -> jax.Array:
    """STE fake-quant of a (..., K, M) weight with per-OUTPUT-channel (M)
    absmean scales, computed without transposes — keeps pjit shardings
    intact (transposing a (fsdp, model)-sharded weight forces an SPMD
    "involuntary full rematerialization")."""
    wf = w.astype(jnp.float32)
    scale = jnp.mean(jnp.abs(wf), axis=-2, keepdims=True) + EPS      # (...,1,M)
    t = jnp.clip(jnp.round(wf / scale), -1, 1)
    wq = (t * scale).astype(w.dtype)
    return w + jax.lax.stop_gradient(wq - w)


class QuantizedActivation(NamedTuple):
    values: jax.Array  # int8
    scale: jax.Array   # f32, per-token (broadcastable against values on `axis`)


def act_quant_int8(a: jax.Array, axis: int = -1) -> QuantizedActivation:
    """Symmetric per-token int8 quantization; `axis` is the *feature* axis that
    is reduced (each token keeps its own scale)."""
    a = a.astype(jnp.float32)
    amax = jnp.max(jnp.abs(a), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, EPS) / Q_MAX
    q = jnp.clip(jnp.round(a / scale), -Q_MAX, Q_MAX).astype(jnp.int8)
    return QuantizedActivation(q, scale)


def act_token_scale(a: jax.Array) -> jax.Array:
    """Per-token scale for a token-minor (K, N) activation → (N,) f32.

    The single shared definition of the mpGeMM quantizer scale: the fused
    kernels (which quantize tile-by-tile in VMEM), the unfused pipeline, the
    reference oracle and core.vlut all derive from it, so every path rounds
    identically.
    """
    amax = jnp.max(jnp.abs(a.astype(jnp.float32)), axis=0)
    return jnp.maximum(amax, EPS) / Q_MAX


def act_quant_tokens(a: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Materialized per-token int8 quantization of a token-minor (K, N)
    activation → (a_q int8 (K, N), a_scale f32 (N,)). Used by the unfused
    ablation pipeline and the pure-jnp reference paths; the fused kernels
    take only `act_token_scale` and quantize in VMEM."""
    a = a.astype(jnp.float32)
    scale = act_token_scale(a)
    q = jnp.clip(jnp.round(a / scale[None, :]), -Q_MAX, Q_MAX).astype(jnp.int8)
    return q, scale


def fake_act_quant(a: jax.Array, axis: int = -1) -> jax.Array:
    """STE int8 activation fake-quant (training path)."""
    q = act_quant_int8(a, axis)
    deq = (q.values.astype(jnp.float32) * q.scale).astype(a.dtype)
    return a + jax.lax.stop_gradient(deq - a)
