"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2, Mamba+attention 1:7 interleave (1 attention layer
per 8-layer period), MoE every other layer. [arXiv:2403.19887; hf]

Deviation noted in DESIGN.md: Mamba layers use the Mamba2/SSD formulation
(chunk-parallel, memory-feasible at 500k ctx) with Jamba's d_state=16."""
from .base import LayerSpec, MoEConfig, ModelConfig, SSMConfig

def _period():
    out = []
    for i in range(8):
        mixer = "attn" if i == 3 else "ssm"
        ffn = "moe" if i % 2 == 1 else "dense"
        out.append(LayerSpec(mixer=mixer, ffn=ffn))
    return tuple(out)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24_576, vocab=65_536,
    layers=_period() * 9,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24_576),
    ssm=SSMConfig(d_inner=16_384, d_state=16, n_heads=256, head_dim=64,
                  n_groups=1, chunk=64),
    tie_embeddings=False,
)

def _smoke_period():
    out = []
    for i in range(4):
        mixer = "attn" if i == 3 else "ssm"
        ffn = "moe" if i % 2 == 1 else "dense"
        out.append(LayerSpec(mixer=mixer, ffn=ffn))
    return tuple(out)

SMOKE = ModelConfig(
    name="jamba-1.5-large-398b-smoke",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512,
    layers=_smoke_period(),
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128, capacity_factor=4.0),
    ssm=SSMConfig(d_inner=128, d_state=16, n_heads=8, head_dim=16,
                  n_groups=1, chunk=16),
    tie_embeddings=False, attn_dense_max=8192, loss_chunk=64,
)
