"""Model/system configuration dataclasses.

Every assigned architecture is a `ModelConfig` built from per-layer
`LayerSpec`s. Heterogeneous stacks (jamba's 1:7 attn:mamba interleave,
gemma3's 5:1 local:global, deepseek's first-3-dense) compress into scanned
"stages" of repeated layer patterns (see models/decoder.py), so the lowered
HLO stays small even for 72-layer models.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0            # 0 → d_ff_expert
    capacity_factor: float = 1.25
    router_aux_free: bool = False   # DeepSeek aux-loss-free bias routing
    aux_loss_weight: float = 0.01
    router_z_weight: float = 1e-3


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_dim: int
    qk_rope_dim: int
    v_dim: int


@dataclass(frozen=True)
class SSMConfig:
    d_inner: int
    d_state: int
    n_heads: int
    head_dim: int
    n_groups: int = 1
    d_conv: int = 4
    chunk: int = 64
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class LayerSpec:
    """Static per-layer structure. Equal specs at a fixed period compress
    into one scanned stage."""
    mixer: str = "attn"             # 'attn' | 'mla' | 'ssm'
    window: int = 0                 # 0 = full/global attention
    rope_theta: float = 10_000.0
    ffn: str = "dense"              # 'dense' | 'moe' | 'none'
    d_ff: int = 0                   # 0 → cfg.d_ff (deepseek dense-layer size)
    cross_attn: bool = False        # decoder cross-attention (enc-dec)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    layers: tuple[LayerSpec, ...] = ()
    family: str = "lm"              # 'lm' | 'encdec'
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # encoder (enc-dec only)
    enc_layers: int = 0
    enc_frame_ratio: int = 4        # stub frontend downsampling (whisper conv)
    # attention details
    qk_norm: bool = False
    attn_bias: bool = False
    attn_logit_softcap: float = 0.0
    # embeddings / head
    tie_embeddings: bool = True
    emb_scale_by_dim: bool = False  # gemma-style sqrt(d) embedding scale
    # quantization (the paper's technique)
    quant: str = "ternary"          # 'ternary' | 'none'
    pack_mode: str = "auto"         # 'i1' | 'i2' | 'auto'
    # numerics / memory policy
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    attn_chunk: int = 512           # online-softmax kv-chunk for long seqs
    attn_dense_max: int = 2048      # use dense attention below this seq len
    attn_impl: str = "auto"         # 'auto' | 'flash' (Pallas kernel on TPU)
    loss_chunk: int = 2048          # sequence chunking for the CE loss
    remat: bool = True
    remat_policy: str = "full"      # 'full' | 'dots' (save dot outputs) 
    # serving
    max_cache_len: int = 0          # set per-shape by the launcher
    cache_in_carry: bool = False    # scan-carry KV cache (in-place update;
                                    # halves decode HBM traffic — see §Perf)
    moe_shard_capacity: bool = False  # REFUTED variant kept for the §Perf log
    moe_block_dispatch: bool = False  # block-local dispatch positions (§Perf
                                      # 4.2: keeps scatter/gather data-local)

    # -- derived -----------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def layer_specs(self) -> tuple[LayerSpec, ...]:
        if self.layers:
            assert len(self.layers) == self.n_layers
            return self.layers
        return tuple(LayerSpec() for _ in range(self.n_layers))


def uniform_layers(
    n: int, mixer: str = "attn", ffn: str = "dense", **kw
) -> tuple[LayerSpec, ...]:
    return tuple(LayerSpec(mixer=mixer, ffn=ffn, **kw) for _ in range(n))


@dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell from the assignment matrix."""
    name: str                       # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                       # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
