"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 routed top-1 + 1 shared expert, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from .base import LayerSpec, MoEConfig, ModelConfig, uniform_layers

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=202_048,
    layers=uniform_layers(48, mixer="attn", ffn="moe", rope_theta=500_000.0),
    moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192, n_shared=1),
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="llama4-scout-17b-a16e-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512,
    layers=uniform_layers(2, mixer="attn", ffn="moe", rope_theta=500_000.0),
    moe=MoEConfig(n_experts=4, top_k=1, d_ff_expert=128, n_shared=1, capacity_factor=4.0),
    tie_embeddings=False, attn_dense_max=8192, loss_chunk=64,
)
