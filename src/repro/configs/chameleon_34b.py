"""chameleon-34b [vlm]: 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 — early fusion, VQ image tokens share the text vocab (frontend
stub: inputs are token ids), QK-norm. [arXiv:2405.09818; unverified]"""
from .base import ModelConfig, uniform_layers

CONFIG = ModelConfig(
    name="chameleon-34b",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22_016, vocab=65_536,
    layers=uniform_layers(48),
    qk_norm=True, tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="chameleon-34b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512,
    layers=uniform_layers(2),
    qk_norm=True, tie_embeddings=False, attn_dense_max=8192, loss_chunk=64,
)
