"""mamba2-1.3b [ssm]: 48L d_model=2048 attn-free, ssm_state=128 — SSD
(state-space duality), d_inner=4096, 64 heads x headdim 64, no FFN blocks.
[arXiv:2405.21060; unverified]"""
from .base import ModelConfig, SSMConfig, uniform_layers

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    n_layers=48, d_model=2048, n_heads=1, n_kv_heads=1, head_dim=64,
    d_ff=0, vocab=50_280,
    layers=uniform_layers(48, mixer="ssm", ffn="none"),
    ssm=SSMConfig(d_inner=4096, d_state=128, n_heads=64, head_dim=64,
                  n_groups=1, chunk=64),
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-1.3b-smoke",
    n_layers=2, d_model=64, n_heads=1, n_kv_heads=1, head_dim=16,
    d_ff=0, vocab=512,
    layers=uniform_layers(2, mixer="ssm", ffn="none"),
    ssm=SSMConfig(d_inner=128, d_state=32, n_heads=8, head_dim=16,
                  n_groups=1, chunk=16),
    tie_embeddings=True, attn_dense_max=8192, loss_chunk=64,
)
