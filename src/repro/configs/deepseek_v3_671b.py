"""deepseek-v3-671b [moe]: 61L d_model=7168 128H MLA d_ff=2048(routed)
vocab=129280, 1 shared + 256 routed top-8, aux-loss-free routing, first 3
layers dense (d_ff 18432). MTP head available via `with_mtp`.
[arXiv:2412.19437; hf]"""
from .base import LayerSpec, MLAConfig, MoEConfig, ModelConfig

_DENSE = LayerSpec(mixer="mla", ffn="dense", d_ff=18_432)
_MOE = LayerSpec(mixer="mla", ffn="moe")

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=2048, vocab=129_280,
    layers=(_DENSE,) * 3 + (_MOE,) * 58,
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048, n_shared=1,
                  router_aux_free=True),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
                  qk_rope_dim=64, v_dim=128),
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="deepseek-v3-671b-smoke",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=96, vocab=512,
    layers=(LayerSpec(mixer="mla", ffn="dense", d_ff=160),)
    + (LayerSpec(mixer="mla", ffn="moe"),) * 2,
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=96, n_shared=1,
                  router_aux_free=True, capacity_factor=4.0),
    mla=MLAConfig(q_lora_rank=48, kv_lora_rank=32, qk_nope_dim=16,
                  qk_rope_dim=8, v_dim=16),
    tie_embeddings=False, attn_dense_max=8192, loss_chunk=64,
)
