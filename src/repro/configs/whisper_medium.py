"""whisper-medium [audio]: 24L(enc)+24L(dec) d_model=1024 16H d_ff=4096
vocab=51865 — enc-dec; the conv frontend is a STUB (input_specs feeds
precomputed frame embeddings at ratio 4). [arXiv:2212.04356; unverified]

Deviation noted in DESIGN.md: decoder self-attn uses RoPE instead of
Whisper's learned absolute positions (frontend+positions are stubbed)."""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab=51_865,
    layers=tuple(LayerSpec(cross_attn=True) for _ in range(24)),
    family="encdec", enc_layers=24, enc_frame_ratio=4,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper-medium-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=512,
    layers=tuple(LayerSpec(cross_attn=True) for _ in range(2)),
    family="encdec", enc_layers=2, enc_frame_ratio=4,
    tie_embeddings=True, attn_dense_max=8192, loss_chunk=64,
)
