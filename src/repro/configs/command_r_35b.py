"""command-r-35b [dense]: 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000 — GQA, no-bias. [hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from .base import ModelConfig, uniform_layers

CONFIG = ModelConfig(
    name="command-r-35b",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22_528, vocab=256_000,
    layers=uniform_layers(40, rope_theta=8_000_000.0),
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="command-r-35b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=160, vocab=512,
    layers=uniform_layers(2, rope_theta=8_000_000.0),
    tie_embeddings=True, attn_dense_max=8192, loss_chunk=64,
)
