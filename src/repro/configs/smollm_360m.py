"""smollm-360m [dense]: 32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152
— llama-arch small. [hf:HuggingFaceTB/SmolLM-360M; hf]"""
from .base import ModelConfig, uniform_layers

CONFIG = ModelConfig(
    name="smollm-360m",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, head_dim=64,
    d_ff=2560, vocab=49_152,
    layers=uniform_layers(32),
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="smollm-360m-smoke",
    n_layers=2, d_model=60, n_heads=3, n_kv_heads=1, head_dim=20,
    d_ff=160, vocab=512,
    layers=uniform_layers(2),
    tie_embeddings=True, attn_dense_max=8192, loss_chunk=64,
)
