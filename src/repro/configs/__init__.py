"""repro.configs — assigned-architecture registry (--arch <id>).

Each module exposes CONFIG (the exact published dims) and SMOKE (a reduced
same-family config for CPU smoke tests). The FULL configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation)."""
from __future__ import annotations

import importlib

from .base import SHAPES, LayerSpec, MLAConfig, MoEConfig, ModelConfig, ShapeConfig, SSMConfig

_MODULES = {
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "smollm-360m": "smollm_360m",
    "command-r-35b": "command_r_35b",
    "internlm2-1.8b": "internlm2_1_8b",
    "gemma3-1b": "gemma3_1b",
    "whisper-medium": "whisper_medium",
    "chameleon-34b": "chameleon_34b",
    "mamba2-1.3b": "mamba2_1_3b",
}

#: archs whose attention is fully quadratic → long_500k is N/A (DESIGN.md §4)
FULL_ATTENTION_ARCHS = frozenset({
    "llama4-scout-17b-a16e", "deepseek-v3-671b", "smollm-360m",
    "command-r-35b", "internlm2-1.8b", "whisper-medium", "chameleon-34b",
})


def list_archs() -> list[str]:
    return list(_MODULES)


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.SMOKE if smoke else mod.CONFIG


def cell_is_applicable(arch: str, shape_name: str) -> bool:
    if shape_name == "long_500k" and arch in FULL_ATTENTION_ARCHS:
        return False
    return True


__all__ = [
    "SHAPES", "LayerSpec", "MLAConfig", "MoEConfig", "ModelConfig",
    "ShapeConfig", "SSMConfig", "FULL_ATTENTION_ARCHS",
    "list_archs", "get_config", "cell_is_applicable",
]
