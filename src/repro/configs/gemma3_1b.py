"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144 —
5:1 local:global attention (window 512, global every 6th layer), dual RoPE
bases (10k local / 1M global), 128k-class context.
[hf:google/gemma-3-1b-pt; unverified]"""
from .base import LayerSpec, ModelConfig

def _layers(n, window=512):
    out = []
    for i in range(n):
        if (i + 1) % 6 == 0:
            out.append(LayerSpec(window=0, rope_theta=1_000_000.0))
        else:
            out.append(LayerSpec(window=window, rope_theta=10_000.0))
    return tuple(out)

CONFIG = ModelConfig(
    name="gemma3-1b",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab=262_144,
    layers=_layers(26),
    qk_norm=True, emb_scale_by_dim=True, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma3-1b-smoke",
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab=512,
    layers=_layers(6, window=16),
    qk_norm=True, emb_scale_by_dim=True, tie_embeddings=True,
    attn_dense_max=8192, loss_chunk=64,
)
