"""internlm2-1.8b [dense]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92544. [arXiv:2403.17297; hf]"""
from .base import ModelConfig, uniform_layers

CONFIG = ModelConfig(
    name="internlm2-1.8b",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=92_544,
    layers=uniform_layers(24, rope_theta=1_000_000.0),
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="internlm2-1.8b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512,
    layers=uniform_layers(2, rope_theta=1_000_000.0),
    tie_embeddings=False, attn_dense_max=8192, loss_chunk=64,
)
