"""repro.serve — slot-based continuous-batching serving engine."""
from .engine import Engine, Request
from .sampling import sample
from .scheduler import ContinuousBatchingScheduler, ServeStats

__all__ = ["Engine", "Request", "sample", "ContinuousBatchingScheduler", "ServeStats"]
