"""repro.serve — slot-based continuous-batching serving engine (optionally
speculative: `Engine(spec=repro.spec.SpecConfig(...))`, optionally paged:
`Engine(paged_kv=PagedKVConfig(...))` for block-table KV with radix prefix
sharing and a host-RAM offload tier)."""
from .engine import Engine, Request
from .paging import OutOfPages, PagedKVConfig, Pager
from .sampling import accept_speculative, accept_tree, greedy_accept, sample
from .scheduler import ContinuousBatchingScheduler, ServeStats

__all__ = [
    "Engine", "Request", "sample", "greedy_accept", "accept_speculative",
    "accept_tree", "ContinuousBatchingScheduler", "ServeStats",
    "PagedKVConfig", "Pager", "OutOfPages",
]
