"""repro.serve — slot-based continuous-batching serving engine (optionally
speculative: `Engine(spec=repro.spec.SpecConfig(...))`)."""
from .engine import Engine, Request
from .sampling import accept_speculative, accept_tree, greedy_accept, sample
from .scheduler import ContinuousBatchingScheduler, ServeStats

__all__ = [
    "Engine", "Request", "sample", "greedy_accept", "accept_speculative",
    "accept_tree", "ContinuousBatchingScheduler", "ServeStats",
]
