"""Serving engine: slot-based continuous batching (paper §5.3.2).

The engine owns a batched KV cache with `max_slots` request slots. Each
scheduler tick performs at most one prefill (a single request's prompt, B=1,
scattered into its slot) followed by one batched decode step over all active
slots — llama.cpp's mixed prefill/decode policy, the workload on which the
paper reports 273.5 tok/s. All shapes are static (JAX-compile-once): requests
of different lengths coexist through per-slot `idx` positions and position-
masked attention.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import ops as kernel_ops
from repro.models import decode_step as model_decode
from repro.models import init_cache, prefill as model_prefill
from .sampling import sample


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    # filled by the engine
    slot: int = -1
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0


class Engine:
    def __init__(
        self,
        params,
        cfg: ModelConfig,
        *,
        max_slots: int = 8,
        max_len: int = 512,
        mode: str = "serve",
        enc_len: int = 0,
        temperature: float = 0.0,
        seed: int = 0,
        mpgemm_impl: str | None = None,
        mpgemm_fusion: str | None = None,
        mpgemm_interpret: bool | None = None,
    ):
        self.params = params
        self.cfg = cfg
        self.mode = mode
        # mpGeMM routing for every BitLinear this engine traces: by default
        # the fused single-pass kernel on TPU / streamed XLA elsewhere; the
        # knobs force e.g. the interpreted fused path for CPU validation.
        self._mpgemm = dict(
            impl=mpgemm_impl, fusion=mpgemm_fusion, interpret=mpgemm_interpret
        )
        self.max_slots = max_slots
        self.max_len = max_len
        self.temperature = temperature
        self.rng = jax.random.PRNGKey(seed)
        self.cache = init_cache(cfg, max_slots, max_len, enc_len=enc_len)
        self.slot_free = [True] * max_slots
        self.slot_req: dict[int, Request] = {}
        self.last_token = jnp.zeros((max_slots, 1), jnp.int32)
        self.active = np.zeros(max_slots, bool)

        self._prefill1 = jax.jit(
            lambda p, c, t: model_prefill(p, t, c, cfg, mode=mode)
        )
        self._decode = jax.jit(
            lambda p, c, t: model_decode(p, t, c, cfg, mode=mode),
            donate_argnums=(1,),
        )
        # stats
        self.prefill_tokens = 0
        self.decode_tokens = 0

    # ------------------------------------------------------------------
    def _slot_cache(self, slot: int, single_cache):
        """Scatter a B=1 cache into batched slot `slot` (pure tree op)."""
        def scat(full, one):
            return jax.lax.dynamic_update_slice_in_dim(full, one.astype(full.dtype), slot, axis=1)

        self.cache = jax.tree.map(scat, self.cache, single_cache)

    @staticmethod
    def _bucket(n: int) -> int:
        """Pad prompts to 16-multiples → one jit cache entry per bucket."""
        return max(16, (n + 15) // 16 * 16)

    def add(self, req: Request) -> bool:
        """Prefill a request into a free slot. False if no slot free."""
        try:
            slot = self.slot_free.index(True)
        except ValueError:
            return False
        req.slot = slot
        req.t_submit = req.t_submit or time.perf_counter()
        single = init_cache(self.cfg, 1, self.max_len)
        # left-pad to the bucket: pad tokens get negative positions, which
        # every attention mask drops (kv_pos >= 0) — no recompile per length.
        # SSM/hybrid archs can't mask pads inside the scan → exact lengths.
        n = len(req.prompt)
        has_ssm = any(s.mixer == "ssm" for s in self.cfg.layer_specs())
        bucket = n if has_ssm else self._bucket(n)
        tok = np.zeros((1, bucket), np.int32)
        tok[0, bucket - n:] = req.prompt
        if bucket != n:
            single = jax.tree_util.tree_map_with_path(
                lambda p, l: (jnp.full_like(l, n - bucket)
                              if getattr(p[-1], "key", None) == "idx" else l),
                single,
            )
        tok = jnp.asarray(tok)
        with kernel_ops.dispatch_override(**self._mpgemm):
            logits, single = self._prefill1(self.params, single, tok)
        self.prefill_tokens += int(tok.shape[1])
        self._slot_cache(slot, single)
        nxt = self._sample(logits)
        req.generated.append(int(nxt[0]))
        req.t_first_token = time.perf_counter()
        self.last_token = self.last_token.at[slot, 0].set(nxt[0])
        self.slot_free[slot] = False
        self.slot_req[slot] = req
        self.active[slot] = True
        return True

    def _sample(self, logits):
        self.rng, k = jax.random.split(self.rng)
        return sample(logits, k, temperature=self.temperature)

    def decode_once(self):
        """One batched decode step over every active slot."""
        if not self.active.any():
            return
        with kernel_ops.dispatch_override(**self._mpgemm):
            logits, self.cache = self._decode(self.params, self.cache, self.last_token)
        nxt = np.asarray(self._sample(logits))                       # (B,)
        self.last_token = jnp.asarray(nxt)[:, None]
        now = time.perf_counter()
        for slot, req in list(self.slot_req.items()):
            if not self.active[slot]:
                continue
            self.decode_tokens += 1
            req.generated.append(int(nxt[slot]))
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
                req.t_done = now
                self.active[slot] = False
                self.slot_free[slot] = True
                del self.slot_req[slot]

    @property
    def n_active(self) -> int:
        return int(self.active.sum())
