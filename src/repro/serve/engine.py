"""Serving engine: slot-based continuous batching (paper §5.3.2).

The engine owns a batched KV cache with `max_slots` request slots. All
shapes are static (JAX-compile-once): requests of different lengths coexist
through per-slot `idx` positions and position-masked attention.

Two prefill policies:

  * Whole-prompt (`prefill_chunk=0`, the legacy path): admission runs the
    request's entire prompt as one blocking B=1 bucketed prefill scattered
    into its slot, then every tick runs one batched decode step — llama.cpp's
    mixed prefill/decode policy, the workload on which the paper reports
    273.5 tok/s. Under load the Vec-LUT kernels see their big-M win only at
    admission; every active decode slot stalls behind each whole prompt.

  * Chunked (`prefill_chunk=N`): admission only *claims* a slot
    (PREFILLING); the prompt is consumed N tokens per tick by a single
    batched (max_slots, N) multi-token step (`models.verify_step` — the same
    machinery as speculative verification, so GQA and MLA are exact) that
    carries every scheduled prefill chunk AND, when speculation is off, the
    last-token decode rows of all DECODING slots. The mpGeMM kernels see
    M ≈ chunk x (prefilling slots) + (decode rows) parallel tokens *every*
    tick, not just at admission — serving itself becomes the parallel-token
    workload of the paper's thesis. A left-over chunk is mask-padded: the
    pad tail's positions exceed every real query position (causal position
    mask) and its cache writes are rolled back before the next step.
    `token_budget` caps the real tokens scheduled per tick (decode rows
    first, then FCFS prefill chunks; at least one chunk always advances).
    TTFT is measured when the *last* chunk completes and the first token is
    sampled. Greedy chunked output is token-identical to the whole-prompt
    path. Chunked mode needs rollbackable caches (full-buffer attention/MLA;
    ssm and windowed ring caches are refused, exactly like speculation).

With speculation enabled, PREFILLING slots are excluded from draft/verify
rows until their last chunk lands (the drafter's `on_admit` fires at the
PREFILLING→DECODING transition, so a ModelDrafter's mirrored cache syncs to
the full prompt exactly once); each tick then runs the chunk step over
prefilling slots followed by the usual spec step over decoding slots —
chain, adaptive-K, and tree modes all compose with chunked prefill.

With `spec=SpecConfig(...)` the decode step becomes speculative: a drafter
proposes K tokens per slot, one batched `models.verify_step` runs the target
over (B, K+1) candidates — the Vec-LUT mpGeMM kernels see M=K+1 parallel
tokens instead of M=1 — and `sampling.accept_speculative` keeps the longest
valid prefix, rolling the KV cache back past the first rejection. Greedy
outputs are token-for-token identical to plain decoding.

With `SpecConfig(adaptive_k=True)` the engine additionally tracks a per-slot
acceptance-rate EWMA and drafts only `k_eff = spec.k_policy(ewma)` real
tokens per slot each step (0 for cold slots — their verify row degenerates to
a plain last-token decode), padding the rest so the one compiled (B, K+1)
verify step serves every mixture of slot speeds; `accept_speculative` is
handed the matching `draft_mask` and never accepts past a slot's k_eff.
`SpecConfig(stochastic=True)` makes a ModelDrafter sample its proposals at
the serving temperature and threads the per-position draft distributions
into acceptance (`draft_probs`), so temperature>0 serving emits exact
target-model samples with real draft probability mass credited.

`SpecConfig(tree=(b1, b2, ...))` switches the step to tree-structured
multi-candidate verification: the drafter proposes a token *tree* of depth k
(top-b_d candidates at each of the first depths, one chain continuation per
leaf after), flattened in DraftTree node order into a single (B, n_nodes)
verify pass — each slot's verify row carries n_nodes > k+1 candidates
through the Vec-LUT kernels. Inside the step, node i occupies cache slot
idx+i with position idx+depth(i) and attends the cached prefix plus its tree
ancestors only, so its logits are exactly sequential decode's after the
root-to-i path; `accept_tree` keeps the longest accepted root-to-leaf path,
`compact_tree_cache` gathers the winners onto contiguous slots (and stamps
slot_pos = -1 on the losers, preserving the rollback stale-entry safety
argument: every surviving entry's recorded position is either live-correct
or unreachable), and the idx rolls back to the accepted depth. Greedy tree
output stays token-for-token identical to plain decode; chain mode
(tree=None) is bit-identical to pre-tree behavior.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as obs_mod
from repro.configs.base import ModelConfig
from repro.kernels import ops as kernel_ops
from repro.models import compact_tree_cache, decode_step as model_decode
from repro.models import gather_page, init_cache, prefill as model_prefill
from repro.models import prefill_bucket, prefill_into_slot, reset_slot_idx
from repro.models import restore_page, rollback_cache, scrub_pages
from repro.models import set_block_tables
from repro.models import verify_step as model_verify
from repro.spec import SpecConfig
from .paging import OutOfPages, PagedKVConfig, Pager
from .sampling import accept_speculative, accept_tree, sample


# single definitions of the speculative metrics, shared by Engine (live
# counters) and ServeStats (per-run snapshot) so the two can never diverge.
# The third consumer — the repro.obs metrics registry — is synced *from* the
# engine's live counters at tick boundaries (obs.Obs.on_tick), so enabling
# observability adds an export surface without a parallel set of counters.
def spec_acceptance_rate(accepted_tokens: int, drafted_tokens: int) -> float:
    """Fraction of drafted tokens the target model accepted."""
    return accepted_tokens / drafted_tokens if drafted_tokens else 0.0


def spec_tokens_per_step(decode_tokens: int, spec_slot_steps: int) -> float:
    """Mean tokens a slot emits per verify step (1..k+1; 1.0 unspeculated)."""
    return decode_tokens / spec_slot_steps if spec_slot_steps else 1.0


def spec_skip_rate(spec_skipped_steps: int, spec_slot_steps: int) -> float:
    """Fraction of slot verify steps that skipped drafting (k_eff=0)."""
    return spec_skipped_steps / spec_slot_steps if spec_slot_steps else 0.0


def spec_mean_k(
    drafted_tokens: int, spec_slot_steps: int, spec_skipped_steps: int
) -> float:
    """Mean effective draft length over the slot steps that did draft."""
    drafting = spec_slot_steps - spec_skipped_steps
    return drafted_tokens / drafting if drafting else 0.0


def spec_nodes_per_step(verified_nodes: int, spec_slot_steps: int) -> float:
    """Mean candidate tokens one slot's verify row carries per step — k+1 in
    chain mode, the tree's node count under tree verification (1.0 when
    unspeculated). This is the M the Vec-LUT mpGeMM kernels see per slot."""
    return verified_nodes / spec_slot_steps if spec_slot_steps else 1.0


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    # filled by the engine
    slot: int = -1
    prefill_pos: int = 0          # prompt tokens already in cache (chunked)
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False
    error: str = ""               # admission rejection reason (done, no output)
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0


class Engine:
    """Slot-based continuous-batching engine over a static (max_slots,
    max_len) KV cache.

    `spec=SpecConfig(...)` turns decode into draft→verify→accept;
    `SpecConfig(adaptive_k=True)` additionally adapts each slot's draft
    length to its acceptance EWMA (see `_choose_k_eff` / `SpecConfig.
    k_policy`; live per-slot state in `slot_accept` / `slot_k_eff`), and
    `SpecConfig(stochastic=True)` samples ModelDrafter proposals at the
    serving `temperature`, threading their distributions into rejection
    sampling. Admission budgets `len(prompt) + max_new_tokens - 1` cache
    positions (+ the k-token draft window under speculation): the final
    generated token is sampled but never written back."""

    def __init__(
        self,
        params,
        cfg: ModelConfig,
        *,
        max_slots: int = 8,
        max_len: int = 512,
        mode: str = "serve",
        enc_len: int = 0,
        temperature: float = 0.0,
        seed: int = 0,
        mpgemm_impl: str | None = None,
        mpgemm_fusion: str | None = None,
        mpgemm_interpret: bool | None = None,
        spec: SpecConfig | None = None,
        prefill_chunk: int = 0,
        token_budget: int = 0,
        paged_kv: PagedKVConfig | None = None,
        obs: "obs_mod.ObsConfig | obs_mod.Obs | None" = None,
    ):
        self.params = params
        self.cfg = cfg
        self.mode = mode
        # observability: the null instance is free (every method early-
        # returns); an enabled Obs also installs itself for the kernel-side
        # dispatch hooks (ops.ternary_matmul / autotune.tune)
        if obs is None:
            self.obs = obs_mod.NULL_OBS
        elif isinstance(obs, obs_mod.Obs):
            self.obs = obs
        else:
            self.obs = obs_mod.Obs(obs)
        if self.obs.enabled:
            obs_mod.install(self.obs)
        # mpGeMM routing for every BitLinear this engine traces: by default
        # the fused single-pass kernel on TPU / streamed XLA elsewhere; the
        # knobs force e.g. the interpreted fused path for CPU validation.
        self._mpgemm = dict(
            impl=mpgemm_impl, fusion=mpgemm_fusion, interpret=mpgemm_interpret
        )
        self.max_slots = max_slots
        self.max_len = max_len
        self.temperature = temperature
        self.rng = jax.random.PRNGKey(seed)
        # paged KV: a physical page pool + per-slot block tables replace the
        # dense (max_slots, max_len) slabs. The host-side Pager owns
        # allocation, radix prefix sharing, and host-RAM offload
        # (serve.paging); the device side is pure data movement
        # (models.paged). Admission reserves the full worst-case page budget
        # up front, so pool exhaustion surfaces exactly once — at add(),
        # where the scheduler queues the request for pages.
        self.pager: Pager | None = None
        self._set_tab = self._scrub = None
        if paged_kv is not None:
            if any(s.mixer == "ssm" for s in cfg.layer_specs()):
                raise ValueError(
                    "paged KV needs per-position cache entries a block table "
                    f"can own; {cfg.name} has ssm layer(s), whose recurrent "
                    "state is neither rollbackable nor pageable"
                )
            if any(s.window for s in cfg.layer_specs()):
                raise ValueError(
                    "paged KV is exact only for full-buffer caches; "
                    f"{cfg.name} has windowed (ring-cache) layers — a ring "
                    "buffer overwrites itself in place, so its pages can "
                    "never be remapped or shared"
                )
            if enc_len:
                raise ValueError(
                    "paged KV does not cover cross-attention caches "
                    "(enc_len > 0): encoder K/V is per-request dense state, "
                    "not positionally growing history"
                )
            ps = paged_kv.page_size
            n_pages = paged_kv.n_pages or max_slots * (max_len // ps) + 1
            self.cache = init_cache(
                cfg, max_slots, max_len, page_size=ps, n_pages=n_pages
            )
            self.pager = Pager(
                paged_kv, max_slots=max_slots, max_len=max_len,
                n_pages=n_pages, page_out=self._page_out,
                page_in=self._page_in,
            )
            self._set_tab = jax.jit(set_block_tables, donate_argnums=(0,))
            self._scrub = jax.jit(scrub_pages, donate_argnums=(0,))
        else:
            self.cache = init_cache(cfg, max_slots, max_len, enc_len=enc_len)
        self.slot_free = [True] * max_slots
        self.slot_req: dict[int, Request] = {}
        self.last_token = jnp.zeros((max_slots, 1), jnp.int32)
        self.active = np.zeros(max_slots, bool)

        self._prefill1 = jax.jit(
            lambda p, c, t: model_prefill(p, t, c, cfg, mode=mode)
        )
        self._decode = jax.jit(
            lambda p, c, t: model_decode(p, t, c, cfg, mode=mode),
            donate_argnums=(1,),
        )
        # chunked prefill: admission claims a slot (PREFILLING); the prompt
        # is consumed prefill_chunk tokens per step() by one batched
        # multi-token pass shared with the decode rows (see _chunk_step)
        if prefill_chunk < 0:
            raise ValueError(f"prefill_chunk must be >= 0, got {prefill_chunk}")
        if token_budget < 0:
            raise ValueError(f"token_budget must be >= 0, got {token_budget}")
        if prefill_chunk:
            if prefill_chunk > max_len:
                raise ValueError(
                    f"prefill_chunk ({prefill_chunk}) exceeds max_len "
                    f"({max_len}); the chunk step cannot outgrow the cache"
                )
            if any(s.mixer == "ssm" for s in cfg.layer_specs()):
                raise ValueError(
                    "chunked prefill needs rollbackable KV caches (the "
                    "mask-padded chunk tail is rolled back); "
                    f"{cfg.name} has ssm layer(s), whose recurrent state is "
                    "neither rollbackable nor pageable"
                )
            if any(s.window for s in cfg.layer_specs()):
                raise ValueError(
                    "chunked prefill is exact only for full-buffer or paged "
                    f"KV caches; {cfg.name} has windowed (ring-cache) "
                    "layers, whose in-window history the padded-tail "
                    "rollback would clobber (the ring overwrites in place, "
                    "so it is genuinely non-pageable too)"
                )
        self.prefill_chunk = prefill_chunk
        self.token_budget = token_budget
        self.prefilling: dict[int, Request] = {}    # slot → mid-prefill req
        # decode rows ride the chunk step only when their logits come off
        # the very path plain decode uses: MLA decode is absorbed while the
        # chunk step reads via prefill_resume (naive expansion, quantized
        # like whole-prompt prefill) — those slots decode in their own
        # absorbed step each tick instead, exactly like spec engines
        self._decode_rides = spec is None and not any(
            s.mixer == "mla" for s in cfg.layer_specs()
        )
        # logit_cols: each slot only ever needs the distribution after ONE
        # chunk position (its last real token), so the head matmul runs on
        # (B, 1, d) gathered hidden states, never (B, chunk, V) — non-final
        # chunks skip the full-vocab projection entirely. Paged engines need
        # this entry even in whole-prompt mode: their admission prefill is a
        # wide in-place verify pass (the B=1 scatter-a-fresh-cache path has
        # no block tables to write through)
        self._chunk_verify = (
            jax.jit(
                lambda p, c, t, col: model_verify(
                    p, t, c, cfg, mode=mode, prefill_resume=True,
                    logit_cols=col,
                ),
                donate_argnums=(1,),
            )
            if (prefill_chunk or paged_kv is not None) else None
        )
        # speculative decoding (draft → verify → accept)
        self.spec = spec
        self.drafter = None
        self._tree = None
        if spec is not None:
            bad = [s.mixer for s in cfg.layer_specs() if s.mixer == "ssm"]
            if bad:
                raise ValueError(
                    "speculative decoding needs rollbackable KV caches; "
                    f"{cfg.name} has {len(bad)} ssm layer(s), whose "
                    "recurrent state is neither rollbackable nor pageable"
                )
            if any(s.window for s in cfg.layer_specs()):
                raise ValueError(
                    "speculative decoding is exact only for full-buffer or "
                    f"paged KV caches; {cfg.name} has windowed (ring-cache) "
                    "layers, whose in-window history a rollback would "
                    "clobber (the ring overwrites in place, so it is "
                    "genuinely non-pageable too)"
                )
            self.drafter = spec.build(max_slots=max_slots, max_len=max_len, mode=mode)
            # tree mode: the static DraftTree layout is baked into the
            # verify trace (per-node depths/positions + ancestor mask) and
            # into the post-acceptance window compaction
            self._tree = spec.tree_struct()
            self._verify = jax.jit(
                lambda p, c, t: model_verify(
                    p, t, c, cfg, mode=mode, tree=self._tree
                ),
                donate_argnums=(1,),
            )
            if self._tree is not None:
                self._compact = jax.jit(compact_tree_cache, donate_argnums=(0,))
                if temperature > 0.0:
                    import warnings

                    warnings.warn(
                        "tree verification at temperature>0 greedy-matches "
                        "the draft nodes and only *samples* the correction "
                        "token — output is greedy-filtered, not an exact "
                        "target-temperature sample (chain mode is exact; "
                        "see sampling.accept_tree's TODO)",
                        stacklevel=3,
                    )
        # per-slot adaptive-K state: acceptance EWMA (slots start optimistic
        # at 1.0 on admission), the consecutive-skip streak that triggers a
        # cold slot's k_min probe, and the last k_eff the policy chose
        self.slot_accept = np.ones(max_slots, np.float64)
        self.slot_skip_streak = np.zeros(max_slots, np.int64)
        self.slot_k_eff = np.full(max_slots, self._draft_k, np.int64)
        # stats
        self.prefill_tokens = 0     # real prompt tokens prefilled
        self.prefill_pad_tokens = 0  # bucket/chunk padding (not real work)
        self.decode_tokens = 0
        self.decode_steps = 0       # batched decode/verify step invocations
        self.chunk_steps = 0        # batched mixed chunk-step invocations
        self.spec_steps = 0         # batched verify steps (engine ticks)
        self.spec_slot_steps = 0    # per-slot verify steps (Σ active slots)
        self.spec_skipped_steps = 0  # slot steps that skipped drafting (k_eff=0)
        self.drafted_tokens = 0
        self.accepted_tokens = 0
        self.verified_nodes = 0     # candidate tokens verified (Σ per slot)

    # ------------------------------------------------------------------
    @property
    def _draft_k(self) -> int:
        return self.spec.k if self.spec is not None else 0

    @property
    def _draft_window(self) -> int:
        """Cache slots one verify step writes past the root's position: k in
        chain mode, the tree's draft-node count under tree verification
        (every flattened node gets its own slot)."""
        if self._tree is not None:
            return self._tree.n_draft
        return self._draft_k

    def _validate(self, req: Request) -> None:
        """Reject requests that would overflow the slot KV cache: the prompt
        plus every decode position (and, speculatively, the draft window
        past the last kept token) must fit in max_len. The final generated
        token is sampled but never written back, so it needs no cache
        position: prompt + max_new_tokens - 1 (+ draft window) is the exact
        budget."""
        need = len(req.prompt) + req.max_new_tokens - 1 + self._draft_window
        if need > self.max_len:
            extra = (
                f" + draft window ({self._draft_window})"
                if self._draft_window else ""
            )
            raise ValueError(
                f"request {req.rid}: prompt ({len(req.prompt)}) + "
                f"max_new_tokens - 1 ({req.max_new_tokens - 1}){extra} = {need} "
                f"exceeds the model context (max_len={self.max_len}); "
                f"truncate the prompt, lower max_new_tokens, or grow the "
                f"engine's max_len — this can never succeed, unlike a "
                f"transient out-of-pages deferral"
            )
        if self.pager is not None:
            # a reservation larger than the ENTIRE pool is equally permanent:
            # no amount of waiting (or prefix sharing — shared pages are pool
            # pages too) can ever map that many pages to one slot
            ps = self.pager.cfg.page_size
            need_pages = -(-need // ps)
            if need_pages > self.pager.total_pages:
                raise ValueError(
                    f"request {req.rid}: needs {need_pages} KV pages "
                    f"({need} positions at page_size={ps}) but the pool "
                    f"only has {self.pager.total_pages} allocatable pages; "
                    f"grow n_pages or shrink the request — this can never "
                    f"succeed, unlike a transient out-of-pages deferral"
                )

    def add(self, req: Request) -> bool:
        """Admit a request into a free slot. False if no slot free; raises
        ValueError if the request cannot fit in max_len at all.

        Whole-prompt mode (prefill_chunk=0) runs the full B=1 bucketed
        prefill here and samples the first token. Chunked mode only claims
        the slot (PREFILLING): the prompt is consumed chunk by chunk by
        subsequent `step()` calls and the first token is sampled when the
        last chunk lands."""
        self._validate(req)
        try:
            slot = self.slot_free.index(True)
        except ValueError:
            return False
        req.slot = slot
        req.t_submit = req.t_submit or time.perf_counter()
        matched = 0
        if self.pager is not None:
            # reserve the request's full worst-case page budget (the same
            # bound _validate just checked against max_len), reusing shared
            # prefix pages where the radix index matches. OutOfPages is a
            # TRANSIENT condition — decoding slots will finish and free
            # pages — so the request stays queued (return False), in
            # contrast to the permanent exceeds-model-context ValueError.
            need = len(req.prompt) + req.max_new_tokens - 1 + self._draft_window
            try:
                matched = self.pager.admit(slot, np.asarray(req.prompt), need)
            except OutOfPages as e:
                req.error = f"queued: waiting for free KV pages ({e})"
                return False
            req.error = ""
            self._flush_pager()
            # matched prefix pages already hold their KV: the slot's write
            # position starts at the matched frontier and only the prompt
            # suffix runs through the model
            self.cache = reset_slot_idx(self.cache, slot, value=matched)
        if self.prefill_chunk:
            self.slot_free[slot] = False
            req.prefill_pos = matched
            self.prefilling[slot] = req
            if self.pager is None:
                # the slot's write position restarts at 0; stale K/V needs
                # no clearing (see models.reset_slot_idx) — contiguous
                # chunk writes re-cover every position before a query sees it
                self.cache = reset_slot_idx(self.cache, slot)
            return True
        if self.pager is not None:
            self._paged_prefill(slot, req, matched)
            return True
        # SSM/hybrid archs can't mask pads inside the scan → exact lengths.
        has_ssm = any(s.mixer == "ssm" for s in self.cfg.layer_specs())
        with kernel_ops.dispatch_override(**self._mpgemm):
            logits, self.cache, padded = prefill_into_slot(
                self.params, self.cache, slot, req.prompt, self.cfg,
                max_len=self.max_len, prefill_fn=self._prefill1,
                exact_len=has_ssm,
            )
        # only real prompt tokens are prefill work; bucket padding is
        # accounted separately so tok/s can't be inflated by left-pads
        self.prefill_tokens += len(req.prompt)
        self.prefill_pad_tokens += padded - len(req.prompt)
        nxt = int(self._sample(logits)[0])
        self._start_decoding(slot, req, nxt, time.perf_counter())
        return True

    def _paged_prefill(self, slot: int, req: Request, matched: int) -> None:
        """Whole-prompt admission for a paged engine: one wide in-place
        verify pass over the unmatched prompt suffix, writing K/V through
        the slot's freshly flushed block table. The dense path's B=1
        scatter-a-fresh-cache trick has no analogue here (a fresh cache has
        no pages), so paged admission reuses the chunked-prefill machinery
        with chunk = the whole suffix: other slots' rows are mask-padding
        whose frontier scribbles are rolled back exactly like a chunk
        step's. A prefix hit shrinks the pass to the suffix alone — the
        shared pages' KV is already resident."""
        rem = req.prompt[matched:]
        bucket = prefill_bucket(len(rem), self.max_len)
        tokens = np.zeros((self.max_slots, bucket), np.int32)
        tokens[slot, :len(rem)] = rem
        col = np.zeros(self.max_slots, np.int64)
        col[slot] = len(rem) - 1
        new_idx = self._idx_vector()
        new_idx[slot] = len(req.prompt)
        with kernel_ops.dispatch_override(**self._mpgemm):
            rows, cache = self._chunk_verify(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(col, np.int32),
            )                                                    # rows: (B, V)
        self.cache = rollback_cache(cache, jnp.asarray(new_idx))
        self.prefill_tokens += len(rem)
        self.prefill_pad_tokens += bucket - len(rem)
        nxt = int(self._sample(rows[slot][None])[0])
        self._start_decoding(slot, req, nxt, time.perf_counter())

    def _start_decoding(self, slot: int, req: Request, first_tok: int,
                        now: float) -> None:
        """Prefill complete (whole-prompt or final chunk): record the first
        generated token and transition the slot to DECODING — or finish it
        outright when max_new_tokens=1 asked for nothing more."""
        req.generated.append(first_tok)
        req.t_first_token = now
        if req.t_submit:
            self.obs.observe_ttft(now - req.t_submit)
        self.last_token = self.last_token.at[slot, 0].set(first_tok, mode="drop")
        if len(req.generated) >= req.max_new_tokens:
            # prefill already produced everything asked for (max_new_tokens=1)
            req.done = True
            req.t_done = req.t_first_token
            self.slot_free[slot] = True
            if self.pager is not None:
                self.pager.release(slot, np.asarray(req.prompt))
            return
        self.slot_free[slot] = False
        self.slot_req[slot] = req
        self.active[slot] = True
        if self.drafter is not None:
            # chunked mode defers this to the PREFILLING→DECODING
            # transition: the drafter syncs the full prompt exactly once
            self.drafter.on_admit(slot, req.prompt)
        # fresh request → optimistic acceptance state (starts at full k)
        self.slot_accept[slot] = 1.0
        self.slot_skip_streak[slot] = 0
        self.slot_k_eff[slot] = self._draft_k

    def _sample(self, logits):
        self.rng, k = jax.random.split(self.rng)
        return sample(logits, k, temperature=self.temperature)

    # -- paged-KV device sync ------------------------------------------
    def _page_out(self, page: int):
        """Pager offload callback: copy one physical page to host numpy."""
        return gather_page(self.cache, page)

    def _page_in(self, page: int, data) -> None:
        """Pager page-in callback: restore a host copy into `page`. The
        restored slot_pos rides along with the K/V, so paged-in pages are
        deliberately NOT scrubbed (a scrub would erase the positions that
        make the restored prefix attendable)."""
        self.cache = restore_page(self.cache, page, data)

    def _flush_pager(self) -> None:
        """Push the pager's host state to the device before the next jitted
        step: scrub slot_pos = -1 on freshly allocated pages (fixed-width
        batches padded with the out-of-range n_pages sentinel, so the jitted
        scrub never recompiles and pads are mode="drop"ped) and broadcast
        the new block tables into every layer's tab. Called at admission
        (before the prefill pass) and at tick start (after releases)."""
        if self.pager is None or not self.pager.dirty:
            return
        tab, fresh = self.pager.take_flush()
        if fresh:
            w = self.pager.cfg.scrub_batch
            fresh = fresh + [self.pager.n_pages] * ((-len(fresh)) % w)
            for i in range(0, len(fresh), w):
                self.cache = self._scrub(
                    self.cache, jnp.asarray(fresh[i:i + w], jnp.int32)
                )
        self.cache = self._set_tab(self.cache, jnp.asarray(tab, jnp.int32))

    def _slot_exhausted(self, req: Request) -> bool:
        """True when the slot has no room for another decode (or verify)
        step: the next write position (+ draft window) would pass max_len.
        Admission bounds this (so this never fires for admitted requests —
        it is a safety re-check against buffer scribbles), but it must use
        the same exact bound: the last generated token is never written, so
        the next step writes slots next_pos .. next_pos + draft_window where
        next_pos is the cache slot last_token will occupy."""
        next_pos = len(req.prompt) + len(req.generated) - 1  # last_token's slot
        return next_pos + self._draft_window >= self.max_len

    def _finish_slot(self, slot: int, req: Request, now: float):
        req.done = True
        req.t_done = now
        # TPOT = mean inter-token gap after the first token (undefined for
        # single-token requests, which finish in _start_decoding anyway)
        if len(req.generated) > 1 and req.t_first_token:
            self.obs.observe_tpot(
                (now - req.t_first_token) / (len(req.generated) - 1)
            )
        self.active[slot] = False
        self.slot_free[slot] = True
        del self.slot_req[slot]
        if self.pager is not None:
            # prefix pages return to the radix index (the next request with
            # this prompt prefix admits at near-zero prefill cost), the rest
            # to the free pool; the block-table flush is deferred to the
            # next admission or tick (no jitted step runs before either)
            self.pager.release(slot, np.asarray(req.prompt))
        if self.drafter is not None:
            self.drafter.on_release(slot)

    @property
    def has_work(self) -> bool:
        """True when a step() would do anything: slots mid-prefill or
        actively decoding. The scheduler skips the tick's batched step
        entirely when this is False (e.g. every admission was satisfied by
        prefill alone) instead of burning a dispatch on an empty batch."""
        return bool(self.prefilling) or bool(self.active.any())

    def _idx_vector(self) -> np.ndarray:
        """Host mirror of every slot's true cache write position: a DECODING
        slot's idx is its last sampled token's cache position (that token is
        never written until the next step), a PREFILLING slot's is its
        consumed-prompt prefix, and free slots sit at 0 (chunked admission
        resets them; whole-prompt admission rescatters a fresh cache).
        Every batched rollback starts from this vector so a step over one
        subset of slots can never scribble the idx of another."""
        idx = np.zeros(self.max_slots, np.int64)
        for slot, req in self.prefilling.items():
            idx[slot] = req.prefill_pos
        for slot, req in self.slot_req.items():
            if self.active[slot]:
                idx[slot] = len(req.prompt) + len(req.generated) - 1
        return idx

    def step(self):
        """One engine tick: the chunked-prefill mixed step (when any slot is
        PREFILLING), then/or the batched decode step. The scheduler's tick
        entry point; whole-prompt engines fall straight through to
        decode_once()."""
        self._flush_pager()    # released slots' tab rows → null before any step
        if self.prefilling:
            self._chunk_step()
            if not self._decode_rides:
                # spec engines (draft→verify→accept) and MLA archs (absorbed
                # decode vs the chunk step's prefill_resume read) exclude
                # decode rows from the chunk step — their own decode step
                # runs in the same tick
                self.decode_once()
        else:
            self.decode_once()

    def _chunk_step(self):
        """One batched mixed prefill/decode step over the (max_slots,
        prefill_chunk) token grid — the tentpole of chunked prefill.

        Row contents: a scheduled PREFILLING slot carries its next c =
        min(chunk, remaining) prompt tokens (left-over chunk mask-padded —
        pad positions exceed every real query position and are rolled back
        below); when speculation is off, every DECODING slot rides along as
        a last-token row (column 0 is exactly a plain decode — verify
        semantics — so mixed ticks keep emitting); all other rows are
        padding. One `models.verify_step` pass appends everything at
        per-slot positions, so the Vec-LUT mpGeMM kernels see
        M ≈ chunk x (scheduled prefills) + (decode rows) real parallel
        tokens in a single launch.

        `token_budget` caps the real tokens scheduled per step: decode rows
        are mandatory and count first, then prefill chunks are granted FCFS
        (admission order); at least one chunk always advances so prefill
        can never starve."""
        _t0 = time.perf_counter() if self.obs.enabled else 0.0
        chunk = self.prefill_chunk
        include_decode = self._decode_rides and bool(self.active.any())
        used = int(self.active.sum()) if include_decode else 0
        budget = self.token_budget
        chosen: list[tuple[int, int]] = []
        for slot, req in self.prefilling.items():
            c = min(chunk, len(req.prompt) - req.prefill_pos)
            if chosen and budget and used + c > budget:
                break
            chosen.append((slot, c))
            used += c
        tokens = np.zeros((self.max_slots, chunk), np.int32)
        col = np.zeros(self.max_slots, np.int64)     # logits column per slot
        new_idx = self._idx_vector()
        for slot, c in chosen:
            req = self.prefilling[slot]
            tokens[slot, :c] = req.prompt[req.prefill_pos:req.prefill_pos + c]
            col[slot] = c - 1
            new_idx[slot] = req.prefill_pos + c
        decode_slots: list[int] = []
        if include_decode:
            last = np.asarray(self.last_token)[:, 0]
            for slot, req in self.slot_req.items():
                if not self.active[slot]:
                    continue
                tokens[slot, 0] = last[slot]
                new_idx[slot] += 1          # idx_vector holds last_token's pos
                decode_slots.append(slot)
        with kernel_ops.dispatch_override(**self._mpgemm):
            rows, cache = self._chunk_verify(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(col, np.int32),
            )                                                    # rows: (B, V)
        nxt = np.asarray(self._sample(rows))
        now = time.perf_counter()
        self.chunk_steps += 1
        for slot, c in chosen:
            req = self.prefilling[slot]
            req.prefill_pos += c
            self.prefill_tokens += c
            self.prefill_pad_tokens += chunk - c
            if req.prefill_pos < len(req.prompt):
                continue
            # final chunk landed: first token, PREFILLING → DECODING
            del self.prefilling[slot]
            self._start_decoding(slot, req, int(nxt[slot]), now)
        for slot in decode_slots:
            req = self.slot_req[slot]
            self.decode_tokens += 1
            req.generated.append(int(nxt[slot]))
            self.last_token = self.last_token.at[slot, 0].set(
                nxt[slot], mode="drop"
            )
            if len(req.generated) >= req.max_new_tokens or self._slot_exhausted(req):
                self._finish_slot(slot, req, now)
        self.cache = rollback_cache(cache, jnp.asarray(new_idx))
        if self.obs.enabled:
            # used = real tokens this step carried (chunk tokens + decode
            # rows) — the effective M the batched mpGeMM dispatch saw
            self.obs.step_event(
                "chunk", _t0, m_real=used, m_padded=self.max_slots * chunk,
                prefills=len(chosen), decodes=len(decode_slots),
            )

    def decode_once(self):
        """One batched decode step over every active slot. With spec enabled
        this is draft → verify → accept (1..k+1 tokens per slot)."""
        self._flush_pager()    # bench loops call decode_once without step()
        if not self.active.any():
            return
        if self._tree is not None:
            return self._decode_spec_tree()
        if self.spec is not None:
            return self._decode_spec()
        self.decode_steps += 1
        _t0 = time.perf_counter() if self.obs.enabled else 0.0
        _m_active = int(self.active.sum())   # rows finishing mid-loop still counted
        # the jit'd decode step advances EVERY slot's idx by 1 and scatters
        # a (garbage) token at every slot's frontier; with slots mid-chunked-
        # prefill that drift must be undone — the restored frontier index is
        # rewritten by the slot's next chunk before it can be attended
        restore = bool(self.prefilling)
        if restore:
            new_idx = self._idx_vector()
            new_idx[np.asarray(self.active)] += 1    # decode wrote last_token
        with kernel_ops.dispatch_override(**self._mpgemm):
            logits, self.cache = self._decode(self.params, self.cache, self.last_token)
        nxt = np.asarray(self._sample(logits))                       # (B,)
        self.last_token = jnp.asarray(nxt)[:, None]
        now = time.perf_counter()
        for slot, req in list(self.slot_req.items()):
            if not self.active[slot]:
                continue
            self.decode_tokens += 1
            req.generated.append(int(nxt[slot]))
            if len(req.generated) >= req.max_new_tokens or self._slot_exhausted(req):
                self._finish_slot(slot, req, now)
        if restore:
            self.cache = rollback_cache(self.cache, jnp.asarray(new_idx))
        if self.obs.enabled:
            self.obs.step_event(
                "decode", _t0, m_real=_m_active, m_padded=self.max_slots,
            )

    def _choose_k_eff(self) -> np.ndarray:
        """Per-slot effective draft length for this step: spec.k everywhere
        unless adaptive_k, in which case each active slot gets
        spec.k_policy(acceptance EWMA, skip streak) ∈ [0, k]."""
        k_eff = np.full(self.max_slots, self.spec.k, np.int64)
        if not self.spec.adaptive_k:
            return k_eff
        for slot in range(self.max_slots):
            if self.active[slot]:
                k_eff[slot] = self.spec.k_policy(
                    float(self.slot_accept[slot]),  # lint: disable=R3 -- slot_accept is a host np.ndarray EWMA
                    int(self.slot_skip_streak[slot]),  # lint: disable=R3 -- slot_skip_streak is host np.ndarray state
                )
        return k_eff

    def _update_slot_accept(self, slot: int, k_eff: int, n_acc: int) -> None:
        """Fold one verify step's verdict into the slot's acceptance EWMA;
        skipped (k_eff=0) steps only advance the probe streak."""
        if k_eff == 0:
            self.slot_skip_streak[slot] += 1
            self.spec_skipped_steps += 1
            return
        self.slot_skip_streak[slot] = 0
        a = self.spec.accept_ewma
        self.slot_accept[slot] = a * self.slot_accept[slot] + (1 - a) * (
            n_acc / k_eff
        )

    def _gather_contexts(self):
        """Per-slot drafting inputs: the full token context (prompt +
        generated; None for free slots) and the cache idx of the last
        sampled token. → (contexts, pos)."""
        contexts: list = [None] * self.max_slots
        pos = np.zeros(self.max_slots, np.int64)     # per-slot cache idx
        for slot, req in self.slot_req.items():
            if self.active[slot]:
                contexts[slot] = np.concatenate(
                    # lint: disable=R3 -- prompt/generated are host python lists
                    [np.asarray(req.prompt, np.int64), np.asarray(req.generated, np.int64)]
                )
                pos[slot] = len(req.prompt) + len(req.generated) - 1
        return contexts, pos

    def _decode_spec(self):
        """One speculative decode step: drafter proposal, a single batched
        (B, K+1) verify pass through the Vec-LUT kernels, longest-accepted-
        prefix emission, and KV rollback to the last kept position.

        Shapes are static for every mixture of per-slot draft lengths: a slot
        drafting k_eff < k real tokens pads the rest of its row, and the
        draft_mask handed to accept_speculative stops acceptance at k_eff
        (a k_eff=0 row is a plain last-token decode)."""
        _t0 = time.perf_counter() if self.obs.enabled else 0.0
        active0 = self.active.copy()         # slots finishing mid-loop flip it
        k = self.spec.k
        contexts, pos = self._gather_contexts()
        k_eff = self._choose_k_eff()
        self.slot_k_eff = k_eff.copy()
        stochastic = self.spec.stochastic and self.temperature > 0.0
        draft_probs = None
        if stochastic:
            self.rng, draft_key = jax.random.split(self.rng)
            draft, probs = self.drafter.propose(
                contexts, k, slot_k=k_eff, rng=draft_key,
                temperature=self.temperature, return_probs=True,
            )
            if probs is not None:
                draft_probs = jnp.asarray(probs)
        else:
            draft = self.drafter.propose(contexts, k, slot_k=k_eff)
        draft = np.asarray(draft, np.int32)
        mask = np.arange(k)[None, :] < k_eff[:, None]            # (B, K)
        tokens = jnp.concatenate([self.last_token, jnp.asarray(draft)], axis=1)
        with kernel_ops.dispatch_override(**self._mpgemm):
            logits, cache = self._verify(self.params, self.cache, tokens)
        self.rng, key = jax.random.split(self.rng)
        n_acc, out = accept_speculative(
            jnp.asarray(draft), logits, key, temperature=self.temperature,
            draft_probs=draft_probs, draft_mask=jnp.asarray(mask),
        )
        n_acc, out = np.asarray(n_acc), np.asarray(out)
        # inactive slots keep their true idx (free: 0, PREFILLING: the
        # consumed-prompt prefix) — the batched rollback must never scribble
        # a mid-prefill slot's write position
        new_idx = self._idx_vector()
        new_last = np.asarray(self.last_token).copy()
        now = time.perf_counter()
        for slot, req in list(self.slot_req.items()):
            if not self.active[slot]:
                continue
            remaining = req.max_new_tokens - len(req.generated)
            take = min(int(n_acc[slot]) + 1, remaining)
            req.generated.extend(int(t) for t in out[slot, :take])
            new_last[slot, 0] = out[slot, take - 1]
            new_idx[slot] = pos[slot] + take
            self.decode_tokens += take
            self.spec_slot_steps += 1
            self.drafted_tokens += int(k_eff[slot])  # lint: disable=R3 -- _choose_k_eff returns host np.ndarray
            self.verified_nodes += k + 1
            # acceptance counts the verifier's verdict, not the emission cap:
            # a request finishing mid-step still accepted n_acc draft tokens.
            self.accepted_tokens += int(n_acc[slot])
            self._update_slot_accept(slot, int(k_eff[slot]), int(n_acc[slot]))  # lint: disable=R3 -- k_eff is host np from _choose_k_eff
            if len(req.generated) >= req.max_new_tokens or self._slot_exhausted(req):
                self._finish_slot(slot, req, now)
        self.spec_steps += 1
        self.decode_steps += 1
        self.last_token = jnp.asarray(new_last)
        self.cache = rollback_cache(cache, jnp.asarray(new_idx))
        if self.obs.enabled:
            # every verify row carries k_eff + 1 real candidate tokens
            self.obs.step_event(
                "verify", _t0, m_real=int(np.sum(k_eff[active0] + 1)),
                m_padded=self.max_slots * (k + 1), k=k,
            )

    def _decode_spec_tree(self):
        """One tree-speculative decode step: the drafter proposes a token
        *tree* per slot (spec.tree.DraftTree, n_nodes flattened nodes), one
        batched (B, n_nodes) verify pass runs the target over every node —
        the Vec-LUT kernels see M = n_nodes parallel tokens per slot —
        `accept_tree` keeps the longest accepted root-to-leaf path, the
        winning path's cache entries are compacted back onto contiguous
        slots (compact_tree_cache), and the idx rolls back to the accepted
        depth. Greedy output is token-for-token plain decode."""
        _t0 = time.perf_counter() if self.obs.enabled else 0.0
        _m_active = int(self.active.sum())
        tree = self._tree
        n_nodes = tree.n_nodes
        contexts, pos = self._gather_contexts()
        draft = np.asarray(
            self.drafter.propose(contexts, self.spec.k, tree=tree), np.int32
        )                                            # (B, n_nodes-1)
        tokens = jnp.concatenate([self.last_token, jnp.asarray(draft)], axis=1)
        with kernel_ops.dispatch_override(**self._mpgemm):
            logits, cache = self._verify(self.params, self.cache, tokens)
        self.rng, key = jax.random.split(self.rng)
        n_acc, out, path = accept_tree(
            tokens, logits, tree, key, temperature=self.temperature
        )
        n_acc, out, path = np.asarray(n_acc), np.asarray(out), np.asarray(path)
        new_idx = self._idx_vector()    # inactive slots keep their true idx
        # slots outside this verify step (free or PREFILLING) pass take =
        # n_nodes with an identity sel: compact_tree_cache leaves their
        # window byte-for-byte unchanged instead of stamping slot_pos = -1
        # over a mid-prefill slot's live prefix
        take_arr = np.full(self.max_slots, n_nodes, np.int64)
        new_last = np.asarray(self.last_token).copy()
        now = time.perf_counter()
        for slot, req in list(self.slot_req.items()):
            if not self.active[slot]:
                continue
            remaining = req.max_new_tokens - len(req.generated)
            take = min(int(n_acc[slot]) + 1, remaining)
            req.generated.extend(int(t) for t in out[slot, :take])
            new_last[slot, 0] = out[slot, take - 1]
            new_idx[slot] = pos[slot] + take
            take_arr[slot] = take
            self.decode_tokens += take
            self.spec_slot_steps += 1
            # drafted counts the per-PATH budget (depth k, the most any
            # step can accept), keeping acceptance_rate/mean_draft_k
            # comparable with chain mode; the tree's node-level width is
            # reported separately via verified_nodes / nodes_per_step
            self.drafted_tokens += tree.k
            # as in chain mode: acceptance counts the verifier's verdict,
            # not the emission cap of a request finishing mid-step
            self.accepted_tokens += int(n_acc[slot])
            self.verified_nodes += n_nodes
            if len(req.generated) >= req.max_new_tokens or self._slot_exhausted(req):
                self._finish_slot(slot, req, now)
        self.spec_steps += 1
        self.decode_steps += 1
        self.last_token = jnp.asarray(new_last)
        # window compaction: gather the winning path's nodes onto contiguous
        # slots (depth d → slot pos+d) and invalidate the losers, so the
        # rolled-back cache is indistinguishable from one that decoded the
        # accepted tokens sequentially
        sel = np.tile(np.arange(n_nodes, dtype=np.int64), (self.max_slots, 1))
        sel[:, 1 : tree.k + 1] = np.where(
            (np.arange(1, tree.k + 1)[None, :] <= n_acc[:, None]),
            path[:, 1:],
            sel[:, 1 : tree.k + 1],
        )
        self.cache = self._compact(
            cache, jnp.asarray(pos), jnp.asarray(sel), jnp.asarray(take_arr)
        )
        self.cache = rollback_cache(self.cache, jnp.asarray(new_idx))
        if self.obs.enabled:
            self.obs.step_event(
                "tree_verify", _t0, m_real=_m_active * n_nodes,
                m_padded=self.max_slots * n_nodes, n_nodes=n_nodes,
            )

    def jit_entries(self) -> dict:
        """Every jitted entry point this engine dispatches through, by name —
        the surface `repro.lint.CompileGuard` watches to assert steady-state
        ticks stop compiling after warmup (the dynamic R2 check). The
        drafter's own entries ride along prefixed `drafter.`."""
        entries = {"prefill1": self._prefill1, "decode": self._decode}
        if self._chunk_verify is not None:
            entries["chunk_verify"] = self._chunk_verify
        if self.pager is not None:
            entries["set_tab"] = self._set_tab
            entries["scrub"] = self._scrub
        if self.spec is not None:
            entries["verify"] = self._verify
        if self._tree is not None:
            entries["compact"] = self._compact
        if self.drafter is not None:
            probe = getattr(self.drafter, "jit_entries", None)
            if callable(probe):
                entries.update(
                    {f"drafter.{k}": v for k, v in probe().items()}
                )
        return entries

    def reset_stats(self):
        """Zero the token/acceptance counters (e.g. after a warmup run, so a
        timed run's stats exclude it). Slot/cache state is untouched."""
        self.prefill_tokens = self.prefill_pad_tokens = self.decode_tokens = 0
        self.decode_steps = self.chunk_steps = 0
        self.spec_steps = self.spec_slot_steps = self.spec_skipped_steps = 0
        self.drafted_tokens = self.accepted_tokens = self.verified_nodes = 0
        if self.pager is not None:
            self.pager.prefix_hit_tokens = self.pager.prefix_hit_requests = 0

    @property
    def prefix_hit_tokens(self) -> int:
        """Prompt tokens admitted straight off shared radix-prefix pages
        (their prefill was skipped entirely). 0 on unpaged engines."""
        return self.pager.prefix_hit_tokens if self.pager is not None else 0

    @property
    def prefix_hit_requests(self) -> int:
        """Admissions that matched at least one shared prefix page."""
        return self.pager.prefix_hit_requests if self.pager is not None else 0

    @property
    def n_active(self) -> int:
        return int(self.active.sum())

    @property
    def acceptance_rate(self) -> float:
        return spec_acceptance_rate(self.accepted_tokens, self.drafted_tokens)

    @property
    def decode_tokens_per_step(self) -> float:
        return spec_tokens_per_step(self.decode_tokens, self.spec_slot_steps)

    @property
    def skip_rate(self) -> float:
        return spec_skip_rate(self.spec_skipped_steps, self.spec_slot_steps)

    @property
    def mean_draft_k(self) -> float:
        return spec_mean_k(
            self.drafted_tokens, self.spec_slot_steps, self.spec_skipped_steps
        )

    @property
    def nodes_per_step(self) -> float:
        return spec_nodes_per_step(self.verified_nodes, self.spec_slot_steps)
