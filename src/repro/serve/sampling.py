"""Token sampling: greedy / temperature / top-k, plus the speculative-decoding
acceptance rules (exact greedy matching and Leviathan-style rejection
sampling over a verify step's (B, K+1, V) logits).

Both acceptance rules take an optional ``draft_mask`` so a batch can mix
per-slot effective draft lengths: position j of row b is a *real* proposal
only where ``draft_mask[b, j]`` — acceptance can never run past the first
masked (padded) position, and the correction token emitted there is a full
target sample rather than a residual resample (nothing was proposed, so
nothing was rejected). One compiled (B, K+1) verify thereby serves every
mixture of per-slot draft lengths, including k_eff=0 plain-decode rows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(
    logits: jax.Array,
    rng: jax.Array,
    *,
    temperature: float = 0.0,
    top_k: int = 0,
) -> jax.Array:
    """logits: (B, V) → (B,) int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    # top_k >= V keeps every token (and must not index out of bounds)
    top_k = min(top_k, logits.shape[-1])
    if top_k:
        kth = jnp.sort(logits, axis=-1)[:, -top_k][:, None]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)


# --------------------------------------------------------------------------
# Speculative acceptance
# --------------------------------------------------------------------------
def greedy_accept(
    draft: jax.Array,
    target_tokens: jax.Array,
    draft_mask: jax.Array | None = None,
) -> jax.Array:
    """Longest accepted draft prefix under exact greedy matching.

    draft: (B, K) proposed tokens; target_tokens: (B, K+1) the target's
    greedy picks at each verified position. Draft token j is accepted iff it
    equals the target's pick after the j-1 previously accepted tokens —
    and, when draft_mask (B, K) bool is given, iff position j holds a real
    proposal (padding past a slot's k_eff is never accepted).
    → (B,) int32 in [0, K]."""
    matches = draft == target_tokens[:, :-1]
    if draft_mask is not None:
        matches = matches & draft_mask
    return jnp.sum(jnp.cumprod(matches.astype(jnp.int32), axis=1), axis=1)


def accept_speculative(
    draft: jax.Array,
    target_logits: jax.Array,
    rng: jax.Array,
    *,
    temperature: float = 0.0,
    draft_probs: jax.Array | None = None,
    draft_mask: jax.Array | None = None,
):
    """Acceptance rule over one verify step. → (n_accepted (B,), out (B, K+1)).

    draft: (B, K) proposed tokens; target_logits: (B, K+1, V) from
    models.verify_step (position j conditions on the last sampled token plus
    draft[:, :j]). The caller emits out[:, :n_accepted+1]: the accepted
    draft prefix followed by one bonus/correction token — every speculative
    step advances at least one token.

    draft_mask: (B, K) bool, True where the draft position is a real
    proposal. Rows with fewer than K real drafts (per-slot adaptive k_eff,
    down to 0 = an unspeculated plain-decode row) pad the tail; acceptance
    stops at the first padded position and the token emitted there is a
    *full* target sample/argmax for that position — exact, because position
    k_eff's logits condition only on the k_eff accepted real drafts.

    temperature<=0: exact greedy matching — emitted tokens are token-for-token
    what sequential greedy decode would produce.

    temperature>0: Leviathan et al. rejection sampling. Accept draft token x
    with prob min(1, p(x)/q(x)); on first rejection resample from the
    normalized residual (p-q)+, after full acceptance sample the bonus from
    the last position. q defaults to the one-hot proposal of a deterministic
    (greedy/n-gram) drafter, in which case acceptance prob is p(x) and the
    residual is p with x removed; pass draft_probs (B, K, V) — e.g. a
    stochastic ModelDrafter's per-position sampling distributions — for a
    stochastic drafter. Either way emitted tokens are exact target-model
    samples. When the residual vanishes (p ≤ q everywhere, possible only
    through float round-off or an inconsistent q) the fallback resamples
    from p with the rejected token explicitly zeroed, so a rejected token
    can never be re-emitted at its own position."""
    b, kp1, v = target_logits.shape
    k = kp1 - 1
    mask = None if draft_mask is None else jnp.asarray(draft_mask, bool)
    if temperature <= 0.0:
        tgt = jnp.argmax(target_logits, axis=-1).astype(jnp.int32)   # (B, K+1)
        return greedy_accept(draft, tgt, mask), tgt

    p = jax.nn.softmax(target_logits / temperature, axis=-1)         # (B,K+1,V)
    p_k = p[:, :k]
    p_draft = jnp.take_along_axis(p_k, draft[..., None], axis=-1)[..., 0]
    if draft_probs is None:                       # deterministic proposal
        q = jax.nn.one_hot(draft, v, dtype=p.dtype)
        q_draft = jnp.ones_like(p_draft)
    else:
        q = draft_probs
        q_draft = jnp.take_along_axis(q, draft[..., None], axis=-1)[..., 0]
    rng_u, rng_r, rng_f, rng_b = jax.random.split(rng, 4)
    u = jax.random.uniform(rng_u, (b, k))
    accept = u < p_draft / jnp.maximum(q_draft, 1e-20)
    if mask is not None:
        accept = accept & mask
    n_acc = jnp.sum(jnp.cumprod(accept.astype(jnp.int32), axis=1), axis=1)
    # Rejection can only fire where p(x) <= q(x), so the residual (p-q)+ is
    # already zero at the rejected token; the vanishing-residual fallback must
    # preserve that — resample from p with the rejected token removed, never
    # from full p (which could re-emit the token just rejected).
    not_drafted = 1.0 - jax.nn.one_hot(draft, v, dtype=p.dtype)
    residual = jnp.maximum(p_k - q, 0.0)
    rsum = jnp.sum(residual, axis=-1, keepdims=True)
    fallback = p_k * not_drafted
    fallback = fallback / jnp.maximum(
        jnp.sum(fallback, axis=-1, keepdims=True), 1e-30
    )
    residual = jnp.where(rsum > 0, residual / jnp.maximum(rsum, 1e-30), fallback)
    resample = jax.random.categorical(
        rng_r, jnp.log(jnp.maximum(residual, 1e-30)), axis=-1
    )                                                                 # (B, K)
    if mask is not None:
        # padded positions proposed nothing → correction is a full target
        # sample for that position, not a residual resample
        full = jax.random.categorical(
            rng_f, target_logits[:, :k] / temperature, axis=-1
        )
        resample = jnp.where(mask, resample, full)
    bonus = jax.random.categorical(rng_b, target_logits[:, -1] / temperature, axis=-1)
    j = jnp.arange(k, dtype=n_acc.dtype)[None, :]
    mid = jnp.where(j < n_acc[:, None], draft, resample).astype(jnp.int32)
    out = jnp.concatenate([mid, bonus[:, None].astype(jnp.int32)], axis=1)
    return n_acc, out
