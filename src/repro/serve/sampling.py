"""Token sampling: greedy / temperature / top-k, plus the speculative-decoding
acceptance rules (exact greedy matching and Leviathan-style rejection
sampling over a chain verify step's (B, K+1, V) logits, and `accept_tree` —
longest accepted root-to-leaf path — over a tree verify step's flattened
(B, N_nodes, V) logits).

Both acceptance rules take an optional ``draft_mask`` so a batch can mix
per-slot effective draft lengths: position j of row b is a *real* proposal
only where ``draft_mask[b, j]`` — acceptance can never run past the first
masked (padded) position, and the correction token emitted there is a full
target sample rather than a residual resample (nothing was proposed, so
nothing was rejected). One compiled (B, K+1) verify thereby serves every
mixture of per-slot draft lengths, including k_eff=0 plain-decode rows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(
    logits: jax.Array,
    rng: jax.Array,
    *,
    temperature: float = 0.0,
    top_k: int = 0,
) -> jax.Array:
    """logits: (B, V) → (B,) int32.

    top_k keeps *exactly* top_k candidates (0 = unrestricted): ties at the
    k-th logit are broken toward lower token ids (lax.top_k order), never
    silently widening the kept set. top_k > V is clamped to V; top_k < 0 is
    rejected."""
    if top_k < 0:
        raise ValueError(f"top_k must be >= 0, got {top_k}")
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    # top_k >= V keeps every token (and must not index out of bounds)
    top_k = min(top_k, logits.shape[-1])
    if top_k:
        _, idx = jax.lax.top_k(logits, top_k)
        rows = jnp.arange(logits.shape[0])[:, None]
        keep = jnp.zeros(logits.shape, bool).at[rows, idx].set(True)
        logits = jnp.where(keep, logits, -1e30)
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)


# --------------------------------------------------------------------------
# Speculative acceptance
# --------------------------------------------------------------------------
def greedy_accept(
    draft: jax.Array,
    target_tokens: jax.Array,
    draft_mask: jax.Array | None = None,
) -> jax.Array:
    """Longest accepted draft prefix under exact greedy matching.

    draft: (B, K) proposed tokens; target_tokens: (B, K+1) the target's
    greedy picks at each verified position. Draft token j is accepted iff it
    equals the target's pick after the j-1 previously accepted tokens —
    and, when draft_mask (B, K) bool is given, iff position j holds a real
    proposal (padding past a slot's k_eff is never accepted).
    → (B,) int32 in [0, K]."""
    matches = draft == target_tokens[:, :-1]
    if draft_mask is not None:
        matches = matches & draft_mask
    return jnp.sum(jnp.cumprod(matches.astype(jnp.int32), axis=1), axis=1)


def accept_speculative(
    draft: jax.Array,
    target_logits: jax.Array,
    rng: jax.Array,
    *,
    temperature: float = 0.0,
    draft_probs: jax.Array | None = None,
    draft_mask: jax.Array | None = None,
):
    """Acceptance rule over one verify step. → (n_accepted (B,), out (B, K+1)).

    draft: (B, K) proposed tokens; target_logits: (B, K+1, V) from
    models.verify_step (position j conditions on the last sampled token plus
    draft[:, :j]). The caller emits out[:, :n_accepted+1]: the accepted
    draft prefix followed by one bonus/correction token — every speculative
    step advances at least one token.

    draft_mask: (B, K) bool, True where the draft position is a real
    proposal. Rows with fewer than K real drafts (per-slot adaptive k_eff,
    down to 0 = an unspeculated plain-decode row) pad the tail; acceptance
    stops at the first padded position and the token emitted there is a
    *full* target sample/argmax for that position — exact, because position
    k_eff's logits condition only on the k_eff accepted real drafts.

    temperature<=0: exact greedy matching — emitted tokens are token-for-token
    what sequential greedy decode would produce.

    temperature>0: Leviathan et al. rejection sampling. Accept draft token x
    with prob min(1, p(x)/q(x)); on first rejection resample from the
    normalized residual (p-q)+, after full acceptance sample the bonus from
    the last position. q defaults to the one-hot proposal of a deterministic
    (greedy/n-gram) drafter, in which case acceptance prob is p(x) and the
    residual is p with x removed; pass draft_probs (B, K, V) — e.g. a
    stochastic ModelDrafter's per-position sampling distributions — for a
    stochastic drafter. Either way emitted tokens are exact target-model
    samples. When the residual vanishes (p ≤ q everywhere, possible only
    through float round-off or an inconsistent q) the fallback resamples
    from p with the rejected token explicitly zeroed, so a rejected token
    can never be re-emitted at its own position."""
    b, kp1, v = target_logits.shape
    k = kp1 - 1
    mask = None if draft_mask is None else jnp.asarray(draft_mask, bool)
    if temperature <= 0.0:
        tgt = jnp.argmax(target_logits, axis=-1).astype(jnp.int32)   # (B, K+1)
        return greedy_accept(draft, tgt, mask), tgt

    p = jax.nn.softmax(target_logits / temperature, axis=-1)         # (B,K+1,V)
    p_k = p[:, :k]
    p_draft = jnp.take_along_axis(p_k, draft[..., None], axis=-1)[..., 0]
    if draft_probs is None:                       # deterministic proposal
        q = jax.nn.one_hot(draft, v, dtype=p.dtype)
        q_draft = jnp.ones_like(p_draft)
    else:
        q = draft_probs
        q_draft = jnp.take_along_axis(q, draft[..., None], axis=-1)[..., 0]
    rng_u, rng_r, rng_f, rng_b = jax.random.split(rng, 4)
    u = jax.random.uniform(rng_u, (b, k))
    accept = u < p_draft / jnp.maximum(q_draft, 1e-20)
    if mask is not None:
        accept = accept & mask
    n_acc = jnp.sum(jnp.cumprod(accept.astype(jnp.int32), axis=1), axis=1)
    # Rejection can only fire where p(x) <= q(x), so the residual (p-q)+ is
    # already zero at the rejected token; the vanishing-residual fallback must
    # preserve that — resample from p with the rejected token removed, never
    # from full p (which could re-emit the token just rejected).
    not_drafted = 1.0 - jax.nn.one_hot(draft, v, dtype=p.dtype)
    residual = jnp.maximum(p_k - q, 0.0)
    rsum = jnp.sum(residual, axis=-1, keepdims=True)
    fallback = p_k * not_drafted
    fallback = fallback / jnp.maximum(
        jnp.sum(fallback, axis=-1, keepdims=True), 1e-30
    )
    residual = jnp.where(rsum > 0, residual / jnp.maximum(rsum, 1e-30), fallback)
    resample = jax.random.categorical(
        rng_r, jnp.log(jnp.maximum(residual, 1e-30)), axis=-1
    )                                                                 # (B, K)
    if mask is not None:
        # padded positions proposed nothing → correction is a full target
        # sample for that position, not a residual resample
        full = jax.random.categorical(
            rng_f, target_logits[:, :k] / temperature, axis=-1
        )
        resample = jnp.where(mask, resample, full)
    bonus = jax.random.categorical(rng_b, target_logits[:, -1] / temperature, axis=-1)
    j = jnp.arange(k, dtype=n_acc.dtype)[None, :]
    mid = jnp.where(j < n_acc[:, None], draft, resample).astype(jnp.int32)
    out = jnp.concatenate([mid, bonus[:, None].astype(jnp.int32)], axis=1)
    return n_acc, out


def accept_tree(
    tokens: jax.Array,
    target_logits: jax.Array,
    tree,
    rng: jax.Array,
    *,
    temperature: float = 0.0,
):
    """Acceptance rule over one *tree* verify step (multi-candidate drafts).

    tokens: (B, N) node tokens in DraftTree flattening order (column 0 is
    the root — the last sampled token); target_logits: (B, N, V) from
    verify_step(..., tree=...), so position j conditions on exactly the
    root-to-j path. → (n_acc (B,), out (B, K+1), path (B, K+1)):

      n_acc  accepted draft nodes along the winning root-to-leaf path, in
             [0, K] (K = tree depth).
      out    emitted tokens: the winning path's accepted tokens in columns
             0..n_acc-1, one correction/bonus token at column n_acc (the
             caller emits out[:, :n_acc+1]); later columns repeat the
             correction and carry no meaning.
      path   the winning leaf's node index per depth (column 0 = root, i.e.
             0) — the engine's cache-compaction gather map.

    Greedy (temperature<=0): node j is accepted iff its token equals the
    target argmax at its parent AND its whole ancestor chain is accepted;
    the winner is the deepest accepted leaf path (ties resolve to the
    lowest-rank — chain-proposal — branch). Since at most one token value
    can match each parent's argmax, the emitted tokens are token-for-token
    what sequential greedy decode would produce.

    temperature>0 uses the same exact greedy path matching with the
    correction token *sampled* at `temperature` from the last accepted
    node's next-token distribution — every emitted token is a valid target
    sample but the joint distribution is greedy-filtered, not the target's.
    TODO(spec-tree): exact multi-candidate rejection sampling (SpecTr /
    SpecInfer-style recursive residual transport across sibling candidates);
    until it lands, SpecConfig refuses tree + stochastic and temperature>0
    tree serving documents this approximation."""
    b, n, v = target_logits.shape
    parents = jnp.asarray(tree.parents, jnp.int32)                # (N,)
    paths = jnp.asarray(tree.leaf_paths, jnp.int32)               # (L, K+1)
    k = paths.shape[1] - 1
    tgt = jnp.argmax(target_logits, axis=-1).astype(jnp.int32)    # (B, N)
    # node-level greedy match: token j == the target's pick at j's parent
    match = tokens == jnp.take_along_axis(
        tgt, jnp.broadcast_to(parents[None, :], (b, n)), axis=1
    )
    match = match.at[:, 0].set(True)                              # root given
    pm = match[:, paths]                                          # (B, L, K+1)
    acc_len = (
        jnp.sum(jnp.cumprod(pm.astype(jnp.int32), axis=-1), axis=-1) - 1
    )                                                             # (B, L)
    best = jnp.argmax(acc_len, axis=-1)                           # (B,)
    n_acc = jnp.take_along_axis(acc_len, best[:, None], axis=1)[:, 0]
    path = paths[best]                                            # (B, K+1)
    path_tok = jnp.take_along_axis(tokens, path, axis=1)          # (B, K+1)
    path_tgt = jnp.take_along_axis(tgt, path, axis=1)             # (B, K+1)
    last = jnp.take_along_axis(path, n_acc[:, None], axis=1)      # (B, 1)
    if temperature > 0.0:
        corr_logits = jnp.take_along_axis(
            target_logits, last[..., None], axis=1
        )[:, 0]                                                   # (B, V)
        corr = jax.random.categorical(rng, corr_logits / temperature, axis=-1)
        corr = corr[:, None].astype(jnp.int32)
    else:
        corr = jnp.take_along_axis(path_tgt, n_acc[:, None], axis=1)
    d = jnp.arange(k + 1, dtype=n_acc.dtype)[None, :]
    nxt = jnp.concatenate([path_tok[:, 1:], path_tgt[:, -1:]], axis=1)
    out = jnp.where(d < n_acc[:, None], nxt, corr).astype(jnp.int32)
    return n_acc, out, path
