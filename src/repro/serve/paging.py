"""Host-side paged-KV management: page pool, radix prefix index, offload.

The device side (models.paged) is pure data movement — pools, block tables,
tab-mapped scatters. Everything stateful lives here, on the host, in the
`Pager` the engine consults at admission/release time:

  * `PagePool` — the physical free list + per-page refcounts. Page 0 is the
    reserved null page and is never allocated.
  * `RadixPrefixIndex` — a page-granular radix trie over prompt prefixes:
    each edge is one page_size-token chunk, each node owns (one refcount of)
    the physical page holding that chunk's KV. `Engine.add` walks it so a
    request sharing a prompt prefix is admitted at near-zero prefill cost —
    its block table simply points at the shared pages (full-page-only
    sharing, the vLLM copy-on-write discipline: a divergence below page
    granularity recomputes the partial page into a private fresh page, so
    no literal KV copy is ever needed).
  * Host-RAM offload — when the pool runs dry, cold index pages (LRU,
    refcount 1 = held only by the index) are paged out to host numpy
    storage instead of being dropped, and paged back in on the next prefix
    hit. `host_offload_pages` bounds the tier; 0 disables it (cold pages
    are then dropped outright, childless-first so the trie stays rooted).

Allocation policy: admission reserves the request's full worst-case page
budget up front (prompt + max_new - 1 + draft window, minus shared prefix
pages). That makes mid-decode exhaustion impossible by construction — the
out-of-pages condition surfaces exactly once, at admission, where the
scheduler can queue the request (`OutOfPages` → `Engine.add` returns False)
instead of deadlocking a half-decoded slot.

Safety: freshly allocated pages still hold their previous owner's content.
GQA pools scrub `slot_pos = -1` on allocation (`pending_scrub`, flushed by
the engine before the next jitted step); MLA pools need no scrub — see
models.paged. Shared prefix pages are never scrubbed and never written:
every cache write targets logical positions >= the slot's admission idx,
which is >= the matched prefix length.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np


class OutOfPages(Exception):
    """Admission-time pool exhaustion: no free page and nothing evictable.
    The engine turns this into a queue-for-pages admission deferral (a
    transient condition), never a hard rejection."""


@dataclasses.dataclass
class PagedKVConfig:
    """Engine-facing paged-KV knobs (Engine(paged_kv=PagedKVConfig(...)))."""

    page_size: int = 16          # tokens per KV page (must divide max_len)
    n_pages: int = 0             # pool size incl. null page; 0 = auto:
                                 #   max_slots * (max_len/page_size) + 1
    prefix_sharing: bool = True  # radix prompt-prefix index + CoW refcounts
    host_offload_pages: int = 0  # host-RAM tier capacity in pages (0 = off)
    scrub_batch: int = 32        # fixed width of the jitted slot_pos scrub


class PagePool:
    """Free list + refcounts over physical pages 1..n_pages-1 (0 = null)."""

    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        self.free: list[int] = list(range(n_pages - 1, 0, -1))
        self.refs = np.zeros(n_pages, np.int64)

    @property
    def free_pages(self) -> int:
        return len(self.free)

    def alloc(self) -> int | None:
        if not self.free:
            return None
        page = self.free.pop()
        self.refs[page] = 1
        return page

    def retain(self, page: int) -> None:
        self.refs[page] += 1

    def release(self, page: int) -> bool:
        """Drop one reference; True if the page returned to the free list."""
        self.refs[page] -= 1
        if self.refs[page] == 0:
            self.free.append(page)
            return True
        return False


class _RadixNode:
    __slots__ = ("children", "parent", "key", "page", "host_data", "last_used")

    def __init__(self, parent: "_RadixNode | None", key: tuple | None):
        self.children: dict[tuple, _RadixNode] = {}
        self.parent = parent
        self.key = key
        self.page = -1           # live physical page, or -1 (offloaded/root)
        self.host_data: Any = None
        self.last_used = 0


class RadixPrefixIndex:
    """Page-granular radix trie over prompt prefixes. Each node below the
    root represents one page_size-token chunk and holds one refcount of the
    page with that chunk's KV (or its host copy when offloaded)."""

    def __init__(self, page_size: int):
        self.page_size = page_size
        self.root = _RadixNode(None, None)
        self.live_nodes = 0       # nodes with a device-resident page
        self.offloaded_nodes = 0  # nodes whose page lives in host RAM

    def walk(self, prompt: np.ndarray, limit_tokens: int):
        """Yield the trie nodes matching `prompt`'s leading full-page chunks,
        stopping at `limit_tokens` or the first miss."""
        ps = self.page_size
        node = self.root
        off = 0
        while off + ps <= limit_tokens:
            child = node.children.get(tuple(int(t) for t in prompt[off:off + ps]))
            if child is None:
                return
            yield child
            node = child
            off += ps

    def child_for(self, node: _RadixNode, chunk: tuple) -> "_RadixNode | None":
        return node.children.get(chunk)

    def insert(self, node: _RadixNode, chunk: tuple) -> _RadixNode:
        child = _RadixNode(node, chunk)
        node.children[chunk] = child
        return child

    def remove(self, node: _RadixNode) -> None:
        node.parent.children.pop(node.key, None)

    def evictable(self, refs: np.ndarray, *, droppable_only: bool):
        """LRU-ordered nodes whose page only the index holds (refcount 1).
        droppable_only restricts to childless nodes — dropping an interior
        node would orphan its (still reachable only through it) subtree."""
        best = None
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if n.page < 0 or refs[n.page] != 1:
                continue
            if droppable_only and n.children:
                continue
            if best is None or n.last_used < best.last_used:
                best = n
        return best


class Pager:
    """The engine's paged-KV authority: block-table bookkeeping, prefix
    matching, reservation-based admission, and eviction/offload.

    page_out(page) -> host data and page_in(page, data) are engine-provided
    device callbacks (models.paged.gather_page / restore_page)."""

    def __init__(
        self,
        cfg: PagedKVConfig,
        *,
        max_slots: int,
        max_len: int,
        n_pages: int,
        page_out: Callable[[int], Any] | None = None,
        page_in: Callable[[int, Any], None] | None = None,
    ):
        if max_len % cfg.page_size:
            raise ValueError(
                f"max_len ({max_len}) must be a multiple of page_size "
                f"({cfg.page_size})"
            )
        self.cfg = cfg
        self.page_size = cfg.page_size
        self.max_slots = max_slots
        self.max_len = max_len
        self.cap = max_len // cfg.page_size     # block-table row width
        self.pool = PagePool(n_pages)
        self.index = RadixPrefixIndex(cfg.page_size)
        self._page_out = page_out
        self._page_in = page_in
        self.slot_pages: list[list[int]] = [[] for _ in range(max_slots)]
        self.slot_shared = [0] * max_slots      # leading shared-page count
        self.pending_scrub: list[int] = []      # fresh pages awaiting scrub
        self.dirty = False                      # block tables need a flush
        self._clock = 0
        # counters (the obs layer and ServeStats read these)
        self.prefix_hit_tokens = 0
        self.prefix_hit_requests = 0
        self.pages_paged_out = 0
        self.pages_paged_in = 0
        self.pages_dropped = 0

    # -- stats ---------------------------------------------------------
    @property
    def n_pages(self) -> int:
        return self.pool.n_pages

    @property
    def free_pages(self) -> int:
        return self.pool.free_pages

    @property
    def total_pages(self) -> int:
        """Allocatable pages (the reserved null page excluded)."""
        return self.pool.n_pages - 1

    @property
    def shared_pages(self) -> int:
        """Device-resident pages held by the prefix index."""
        return self.index.live_nodes

    @property
    def offloaded_pages(self) -> int:
        return self.index.offloaded_nodes

    # -- internals -----------------------------------------------------
    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _evict_one(self) -> bool:
        """Free one cold index page: offload it to host RAM when the tier
        has room (any refcount-1 node, LRU), otherwise drop a childless
        refcount-1 node outright. False when nothing is evictable."""
        can_offload = (
            self._page_out is not None
            and self.index.offloaded_nodes < self.cfg.host_offload_pages
        )
        if can_offload:
            victim = self.index.evictable(self.pool.refs, droppable_only=False)
            if victim is not None:
                victim.host_data = self._page_out(victim.page)
                self.pool.release(victim.page)
                victim.page = -1
                self.index.live_nodes -= 1
                self.index.offloaded_nodes += 1
                self.pages_paged_out += 1
                return True
        victim = self.index.evictable(self.pool.refs, droppable_only=True)
        if victim is None:
            return False
        self.pool.release(victim.page)
        self.index.remove(victim)
        self.index.live_nodes -= 1
        self.pages_dropped += 1
        return True

    def _alloc(self) -> int | None:
        page = self.pool.alloc()
        if page is None and self._evict_one():
            page = self.pool.alloc()
        return page

    def _release_page(self, page: int) -> None:
        if self.pool.release(page):
            # the page may still be queued for a scrub it no longer needs —
            # harmless (scrubbing a free page invalidates garbage), keep it.
            pass

    # -- admission / release -------------------------------------------
    def admit(self, slot: int, prompt: np.ndarray, need_tokens: int) -> int:
        """Reserve slot `slot`'s full page budget for a request needing
        `need_tokens` cache positions, reusing shared prefix pages where the
        radix index matches (paging offloaded ones back in). Returns the
        matched prefix length in tokens. Raises OutOfPages (with every
        reservation rolled back) when the pool cannot cover the remainder.
        """
        ps = self.page_size
        need_pages = -(-need_tokens // ps)
        matched_pages: list[int] = []
        if self.cfg.prefix_sharing:
            # cap the match below the full prompt: at least one prompt token
            # must still run through the model to produce first-token logits
            limit = min(len(prompt) - 1, need_tokens)
            for node in self.index.walk(prompt, limit):
                if node.page < 0:
                    if self._page_in is None:
                        break
                    page = self._alloc()
                    if page is None:
                        break               # partial prefix is still a win
                    self._page_in(page, node.host_data)
                    node.host_data = None
                    node.page = page
                    # _alloc gave the page one ref — that is the index's
                    self.index.live_nodes += 1
                    self.index.offloaded_nodes -= 1
                    self.pages_paged_in += 1
                self.pool.retain(node.page)     # the slot's reference
                matched_pages.append(node.page)
                node.last_used = self._tick()
        fresh: list[int] = []
        for _ in range(need_pages - len(matched_pages)):
            page = self._alloc()
            if page is None:
                for p in fresh:
                    self._release_page(p)
                for p in matched_pages:
                    self._release_page(p)
                raise OutOfPages(
                    f"KV page pool exhausted: need {need_pages} pages "
                    f"({need_tokens} positions), "
                    f"{len(matched_pages)} shared + {self.free_pages} free"
                )
            fresh.append(page)
        self.pending_scrub.extend(fresh)
        self.slot_pages[slot] = matched_pages + fresh
        self.slot_shared[slot] = len(matched_pages)
        matched = len(matched_pages) * ps
        self.prefix_hit_tokens += matched
        if matched:
            self.prefix_hit_requests += 1
        self.dirty = True
        return matched

    def release(self, slot: int, prompt: np.ndarray) -> None:
        """Return slot `slot`'s pages: full-page prompt-prefix pages are
        inserted into (or merged with) the radix index so the next request
        with this prefix admits at near-zero prefill cost; the rest
        (partial prompt tail + generated tokens) go back to the free list.
        """
        pages = self.slot_pages[slot]
        if not pages:
            return
        ps = self.page_size
        n_prefix = min(len(prompt) // ps, len(pages)) if self.cfg.prefix_sharing else 0
        node = self.index.root
        for i in range(n_prefix):
            chunk = tuple(int(t) for t in prompt[i * ps:(i + 1) * ps])
            child = self.index.child_for(node, chunk)
            if child is None:
                # transfer the slot's reference to the new index node
                child = self.index.insert(node, chunk)
                child.page = pages[i]
                self.index.live_nodes += 1
            elif child.page < 0:
                # offloaded node: adopt the slot's live page (it holds the
                # exact same chunk KV) and drop the stale host copy
                child.page = pages[i]
                child.host_data = None
                self.index.live_nodes += 1
                self.index.offloaded_nodes -= 1
            else:
                # index already holds this chunk (shared admission, or a
                # concurrent duplicate) — drop the slot's reference
                self._release_page(pages[i])
            child.last_used = self._tick()
            node = child
        for page in pages[n_prefix:]:
            self._release_page(page)
        self.slot_pages[slot] = []
        self.slot_shared[slot] = 0
        self.dirty = True

    # -- device sync ----------------------------------------------------
    def tables(self) -> np.ndarray:
        """(max_slots, cap) int32 block tables; 0 = unmapped (null page)."""
        tab = np.zeros((self.max_slots, self.cap), np.int32)
        for slot, pages in enumerate(self.slot_pages):
            tab[slot, :len(pages)] = pages
        return tab

    def take_flush(self) -> tuple[np.ndarray, list[int]]:
        """→ (block tables, fresh pages to scrub); clears the dirty state.
        The engine pushes both to the device before its next jitted step."""
        scrub, self.pending_scrub = self.pending_scrub, []
        self.dirty = False
        return self.tables(), scrub
