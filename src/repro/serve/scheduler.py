"""Continuous-batching scheduler: FCFS admission + one batched engine step
per tick (paper §5.3.2's mixed prefill/decode workload).

Whole-prompt engines (prefill_chunk=0) admit at most one request per tick
(each admission is a blocking B=1 prefill) before the batched decode step.
Chunked engines admit every queued request that gets a slot — admission only
claims the slot — and the engine's token budget paces the prefill chunks
across the subsequent mixed steps; TTFT is then measured when a request's
*last* chunk completes and its first token is sampled. Ticks with no work
(no slot prefilling or decoding) skip the batched step entirely.

Pure-python control around the jit'd engine steps; per-request latency and
throughput accounting built in (used by benchmarks/decode_bench.py to
reproduce the paper's continuous-batching table).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Iterable

import jax

from .engine import (
    Engine,
    Request,
    spec_acceptance_rate,
    spec_mean_k,
    spec_nodes_per_step,
    spec_skip_rate,
    spec_tokens_per_step,
)

#: engine counters ServeStats mirrors; run_to_completion snapshots them so a
#: scheduler reused across runs reports per-run deltas, not lifetime totals
_ENGINE_COUNTERS = (
    "prefill_tokens", "prefill_pad_tokens", "decode_tokens", "decode_steps",
    "chunk_steps", "spec_steps", "spec_slot_steps",
    "spec_skipped_steps", "drafted_tokens", "accepted_tokens",
    "verified_nodes", "prefix_hit_tokens", "prefix_hit_requests",
)


@dataclasses.dataclass
class ServeStats:
    wall_s: float = 0.0
    prefill_tokens: int = 0         # real prompt tokens (padding excluded)
    prefill_pad_tokens: int = 0     # bucket/chunk padding, reported separately
    decode_tokens: int = 0
    decode_steps: int = 0           # batched decode/verify step invocations
    chunk_steps: int = 0            # batched mixed chunk-step invocations
    completed: int = 0
    rejected: int = 0               # failed admission (Request.error set)
    ttft_s: list = dataclasses.field(default_factory=list)
    # speculative decoding (zero when the engine runs without spec=)
    spec_steps: int = 0         # batched verify steps
    spec_slot_steps: int = 0    # per-slot verify steps (Σ active slots)
    spec_skipped_steps: int = 0  # slot steps that skipped drafting (k_eff=0)
    drafted_tokens: int = 0
    accepted_tokens: int = 0
    verified_nodes: int = 0     # candidate tokens verified (Σ per slot)
    # paged KV + radix prefix sharing (zero when the engine runs unpaged)
    prefix_hit_tokens: int = 0   # prompt tokens served off shared pages
    prefix_hit_requests: int = 0  # admissions that hit the prefix index

    @property
    def total_tokens(self) -> int:
        return self.prefill_tokens + self.decode_tokens

    @property
    def acceptance_rate(self) -> float:
        return spec_acceptance_rate(self.accepted_tokens, self.drafted_tokens)

    @property
    def decode_tokens_per_step(self) -> float:
        return spec_tokens_per_step(self.decode_tokens, self.spec_slot_steps)

    @property
    def skip_rate(self) -> float:
        """Fraction of slot verify steps the adaptive policy left undrafted."""
        return spec_skip_rate(self.spec_skipped_steps, self.spec_slot_steps)

    @property
    def mean_draft_k(self) -> float:
        """Mean k_eff over the slot steps that did draft (k when fixed)."""
        return spec_mean_k(
            self.drafted_tokens, self.spec_slot_steps, self.spec_skipped_steps
        )

    @property
    def nodes_per_step(self) -> float:
        """Mean candidate tokens per slot verify row — the per-slot M the
        Vec-LUT kernels see (k+1 chain, the tree node count under trees)."""
        return spec_nodes_per_step(self.verified_nodes, self.spec_slot_steps)

    @property
    def throughput_tok_s(self) -> float:
        return self.total_tokens / self.wall_s if self.wall_s else 0.0

    @property
    def decode_tok_s(self) -> float:
        return self.decode_tokens / self.wall_s if self.wall_s else 0.0

    @property
    def prefill_tok_s(self) -> float:
        return self.prefill_tokens / self.wall_s if self.wall_s else 0.0


class ContinuousBatchingScheduler:
    def __init__(self, engine: Engine):
        self.engine = engine
        self.queue: deque[Request] = deque()
        self.completed: list[Request] = []  # finished requests, in finish order
        self.rejected: list[Request] = []   # failed admission (req.error set)
        # high-water marks of what earlier run_to_completion calls already
        # reported, so each run's ServeStats covers exactly the work since
        # the last report (manual ticks included) and never re-counts it
        self._reported = {k: getattr(engine, k) for k in _ENGINE_COUNTERS}
        self._reported_done = 0
        self._reported_rejected = 0
        self._reported_ttft = 0

    def submit(self, reqs: Iterable[Request]):
        for r in reqs:
            r.t_submit = time.perf_counter()
            self.queue.append(r)

    def tick(self):
        """One scheduler iteration: admissions + 1 batched engine step.

        Whole-prompt engines admit ≤1 request (each admission is a blocking
        B=1 prefill); chunked engines admit every queued request that gets a
        slot — claims are free, and the engine's token budget paces the
        prefill chunks across subsequent mixed steps.

        A request the engine can never fit (prompt + budget > max_len) is
        rejected in place — `error` set, `done` stays False, no output; see
        `self.rejected` — so one bad request aborts itself, not the batch.
        A rejection does not consume the tick's admission: the scheduler
        keeps trying subsequent queued requests until one admits, the engine
        reports no free slot, or the queue drains. A tick with nothing
        prefilling or decoding (every admission satisfied by prefill alone)
        skips the batched step instead of burning a dispatch on an empty
        batch."""
        obs = self.engine.obs
        _t0 = time.perf_counter() if obs.enabled else 0.0
        multi = bool(self.engine.prefill_chunk)
        while self.queue:
            head = self.queue[0]
            try:
                if not self.engine.add(head):
                    break              # no free slot — head stays queued
                self.queue.popleft()
                if head.done:          # satisfied by prefill alone
                    self.completed.append(head)
                if not multi:
                    break              # one blocking admission per tick
            except ValueError as e:
                head.error = str(e)
                self.rejected.append(head)
                self.queue.popleft()   # rejected in place; try the next
        before = list(self.engine.slot_req.values()) + list(
            self.engine.prefilling.values()
        )
        if self.engine.has_work:
            self.engine.step()
        for r in before:
            if r.done:                 # finished this step (decode or final
                self.completed.append(r)  # chunk with max_new_tokens=1)
        if obs.enabled:
            # end-of-tick state sync: queue depth + slot occupancy gauges,
            # counter mirrors — the registry reads engine state, never
            # double-counts it
            obs.on_tick(
                self.engine, queue_depth=len(self.queue),
                completed=len(self.completed), rejected=len(self.rejected),
            )
            obs.tracer.complete(
                "scheduler_tick", _t0,
                args=dict(queue=len(self.queue),
                          running=int(self.engine.active.sum()),
                          prefilling=len(self.engine.prefilling)),
            )

    def run_to_completion(self, max_ticks: int = 100_000) -> ServeStats:
        """Drain the queue (≤ max_ticks); → ServeStats for this run.

        Stats are per-run deltas against what earlier calls already
        reported: tokens/completions/rejections/TTFTs from manual ticks
        since the last report are included, but a reused scheduler/engine
        can never re-count an earlier run's work against the new run's
        wall clock (which used to inflate throughput and acceptance)."""
        t0 = time.perf_counter()
        # tolerate an external engine.reset_stats() between runs: count
        # from the reset point rather than going negative
        base = {
            k: min(self._reported[k], getattr(self.engine, k))
            for k in _ENGINE_COUNTERS
        }
        pending = lambda: self.queue or self.engine.has_work
        ticks = 0
        while pending() and ticks < max_ticks:
            self.tick()
            ticks += 1
        # drain async dispatch before stopping the clock: per-tick host
        # syncs (np.asarray on logits) cover most of it, but donated cache
        # updates can still be in flight and would under-report wall time
        jax.block_until_ready(self.engine.cache)
        wall = time.perf_counter() - t0
        # every request this scheduler has seen: finished (incl. by earlier
        # manual ticks), still in flight, and never admitted
        all_reqs: list[Request] = (
            self.completed
            + list(self.engine.slot_req.values())
            + list(self.engine.prefilling.values())
            + list(self.queue)
        )
        self._reported = {
            k: getattr(self.engine, k) for k in _ENGINE_COUNTERS
        }
        done = sum(r.done for r in all_reqs)
        # first-token latencies in event order, minus the already-reported
        # prefix (the event times are monotone across ticks)
        ttft_events = sorted(
            (r.t_first_token, r.t_first_token - r.t_submit)
            for r in all_reqs
            if r.t_first_token
        )
        stats = ServeStats(
            wall_s=wall,
            completed=done - self._reported_done,
            rejected=len(self.rejected) - self._reported_rejected,
            ttft_s=[d for _, d in ttft_events[self._reported_ttft:]],
            **{k: self._reported[k] - base[k] for k in _ENGINE_COUNTERS},
        )
        self._reported_done = done
        self._reported_rejected = len(self.rejected)
        self._reported_ttft = len(ttft_events)
        return stats
