"""Continuous-batching scheduler: FCFS admission, one prefill per tick, then
a batched decode step (paper §5.3.2's mixed prefill/decode workload).

Pure-python control around the jit'd engine steps; per-request latency and
throughput accounting built in (used by benchmarks/decode_bench.py to
reproduce the paper's continuous-batching table).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Iterable

from .engine import Engine, Request


@dataclasses.dataclass
class ServeStats:
    wall_s: float = 0.0
    prefill_tokens: int = 0
    decode_tokens: int = 0
    completed: int = 0
    ttft_s: list = dataclasses.field(default_factory=list)

    @property
    def total_tokens(self) -> int:
        return self.prefill_tokens + self.decode_tokens

    @property
    def throughput_tok_s(self) -> float:
        return self.total_tokens / self.wall_s if self.wall_s else 0.0

    @property
    def decode_tok_s(self) -> float:
        return self.decode_tokens / self.wall_s if self.wall_s else 0.0

    @property
    def prefill_tok_s(self) -> float:
        return self.prefill_tokens / self.wall_s if self.wall_s else 0.0


class ContinuousBatchingScheduler:
    def __init__(self, engine: Engine):
        self.engine = engine
        self.queue: deque[Request] = deque()
        self.completed: list[Request] = []

    def submit(self, reqs: Iterable[Request]):
        for r in reqs:
            r.t_submit = time.perf_counter()
            self.queue.append(r)

    def tick(self):
        """One scheduler iteration: ≤1 prefill admission + 1 decode step."""
        if self.queue and self.engine.add(self.queue[0]):
            self.queue.popleft()
        before = set(self.engine.slot_req)
        self.engine.decode_once()
        after = set(self.engine.slot_req)
        for slot in before - after:
            pass  # finished requests already detached by the engine

    def run_to_completion(self, max_ticks: int = 100_000) -> ServeStats:
        t0 = time.perf_counter()
        n_submitted = len(self.queue)
        finished: list[Request] = []
        pending = lambda: self.queue or self.engine.n_active
        ticks = 0
        all_reqs: list[Request] = list(self.queue)
        while pending() and ticks < max_ticks:
            self.tick()
            ticks += 1
        wall = time.perf_counter() - t0
        stats = ServeStats(
            wall_s=wall,
            prefill_tokens=self.engine.prefill_tokens,
            decode_tokens=self.engine.decode_tokens,
            completed=sum(r.done for r in all_reqs),
            ttft_s=[
                r.t_first_token - r.t_submit for r in all_reqs if r.t_first_token
            ],
        )
        return stats
