"""Fault-tolerant training loop.

Features (all unit-tested):
  * jit'd train_step with donated state (params+opt updated in place);
  * microbatch gradient accumulation (optionally int8+error-feedback
    compressed at the accumulation boundary);
  * periodic + preemption-triggered atomic checkpoints (async writer),
    including the data-pipeline state → exact replay on restart;
  * auto-resume from the latest complete checkpoint (elastic: restore onto a
    different mesh);
  * straggler monitor fed by per-step timings;
  * bounded-restart supervision via dist.fault_tolerance.run_with_restarts.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.dist.compression import compress_tree, decompress_tree, ef_init
from repro.dist.fault_tolerance import PreemptionGuard, StragglerMonitor
from repro.dist.sharding import use_sharding_ctx
from repro.models import encdec_init, encdec_loss, init_lm, lm_loss
from repro.optim import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass
class TrainConfig:
    total_steps: int = 100
    microbatches: int = 1
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    log_every: int = 10
    grad_compression: bool = False
    seed: int = 0


def make_loss_fn(cfg: ModelConfig):
    if cfg.family == "encdec":
        def loss_fn(params, batch):
            return encdec_loss(
                params, batch["frames"], batch["tokens"], batch["labels"], cfg,
                mode="train",
            )
    else:
        def loss_fn(params, batch):
            return lm_loss(params, batch["tokens"], batch["labels"], cfg, mode="train")
    return loss_fn


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, tc: TrainConfig):
    loss_fn = make_loss_fn(cfg)

    def train_step(state, batch):
        if tc.microbatches > 1:
            def micro(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state["params"], mb
                )
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"]
            )
            mbs = jax.tree.map(
                lambda x: x.reshape(tc.microbatches, -1, *x.shape[1:]), batch
            )
            (grads, loss), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / tc.microbatches, grads)
            loss = loss / tc.microbatches
            metrics = {"ce": loss}
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state["params"], batch
            )

        if tc.grad_compression:
            comp, new_ef = compress_tree(grads, state["ef"])
            grads = decompress_tree(comp)
        new_params, new_opt, om = adamw_update(
            state["params"], grads, state["opt"], opt_cfg
        )
        new_state = {"params": new_params, "opt": new_opt}
        if tc.grad_compression:
            new_state["ef"] = new_ef
        return new_state, dict(metrics, loss=loss, **om)

    return train_step


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        opt_cfg: AdamWConfig,
        tc: TrainConfig,
        data_cfg: DataConfig,
        mesh=None,
        install_signals: bool = False,
    ):
        self.cfg, self.opt_cfg, self.tc = cfg, opt_cfg, tc
        self.mesh = mesh
        self.data = SyntheticLM(data_cfg)
        self.ckpt = Checkpointer(tc.checkpoint_dir, keep=tc.keep_checkpoints)
        self.guard = PreemptionGuard(install=install_signals)
        self.monitor = StragglerMonitor(n_hosts=max(jax.process_count(), 1))
        self.metrics_log: list[dict] = []
        self._build_state()
        step_fn = make_train_step(cfg, opt_cfg, tc)
        self._step = jax.jit(step_fn, donate_argnums=(0,))

    # ------------------------------------------------------------------
    def _build_state(self):
        rng = jax.random.PRNGKey(self.tc.seed)
        init = encdec_init if self.cfg.family == "encdec" else init_lm
        params = init(rng, self.cfg)
        state = {"params": params, "opt": adamw_init(params, self.opt_cfg)}
        if self.tc.grad_compression:
            state["ef"] = ef_init(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            )
        self.state = state
        self.step = 0
        # resume if a checkpoint exists
        latest = self.ckpt.latest_step()
        if latest is not None:
            abstract = jax.tree.map(
                lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), self.state
            )
            self.state, extra = self.ckpt.restore(abstract, latest)
            self.step = latest
            self.data.load_state_dict(extra["data"])

    # ------------------------------------------------------------------
    def save(self, blocking=True):
        self.ckpt.save(
            self.step, self.state,
            extra={"data": self.data.state_dict()}, blocking=blocking,
        )

    def run(self) -> list[dict]:
        ctx = (
            use_sharding_ctx(self.mesh, self.cfg)
            if self.mesh is not None else _null_ctx()
        )
        with ctx:
            while self.step < self.tc.total_steps:
                if self.guard.requested:
                    self.save(blocking=True)
                    return self.metrics_log
                self.data.step = self.step
                batch = {k: jnp.asarray(v) for k, v in next(self.data).items()}
                if self.cfg.family == "encdec":
                    b = batch["tokens"].shape[0]
                    s_enc = self.cfg.max_cache_len or batch["tokens"].shape[1]
                    batch["frames"] = _stub_frames(
                        self.cfg, b, batch["tokens"].shape[1], self.tc.seed
                    )
                t0 = time.perf_counter()
                self.state, metrics = self._step(self.state, batch)
                jax.block_until_ready(jax.tree.leaves(metrics)[0])
                dt = time.perf_counter() - t0
                self.step += 1
                self.monitor.record(self.step, [dt])
                if self.step % self.tc.log_every == 0 or self.step == 1:
                    row = {
                        "step": self.step,
                        "loss": float(metrics["loss"]),
                        "step_time_s": dt,
                    }
                    self.metrics_log.append(row)
                    print(f"[train] {row}")
                if self.step % self.tc.checkpoint_every == 0:
                    self.save(blocking=False)
            self.ckpt.wait()
            self.save(blocking=True)
        return self.metrics_log


def _stub_frames(cfg, b, s, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.standard_normal((b, max(s // cfg.enc_frame_ratio, 1), cfg.d_model)),
        jnp.bfloat16,
    )


class _null_ctx:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False
