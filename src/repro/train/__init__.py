"""repro.train — fault-tolerant training loop."""
from .trainer import TrainConfig, Trainer, make_loss_fn, make_train_step

__all__ = ["TrainConfig", "Trainer", "make_loss_fn", "make_train_step"]
