"""repro.optim — AdamW (+ blockwise-int8 states), schedules, grad compression."""
from .adamw import (
    AdamWConfig,
    QTensor,
    adamw_init,
    adamw_update,
    dequantize_blockwise,
    global_norm,
    lr_at,
    quantize_blockwise,
)

__all__ = [
    "AdamWConfig", "QTensor", "adamw_init", "adamw_update",
    "dequantize_blockwise", "global_norm", "lr_at", "quantize_blockwise",
]
