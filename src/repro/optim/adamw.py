"""AdamW with optional int8 moment quantization.

The int8 states (linear absmax quantization per last-axis row — shape-
preserving, so the quantized state inherits the parameter's NamedSharding
without reshapes/resharding) cut optimizer memory from 8 B/param (fp32 m+v)
to ~2+ B/param — the difference between deepseek-v3-671b fitting on a
512×16GiB slice or not (DESIGN.md §6). Small leaves (norms, scales, biases
< 4096 elts) stay fp32; numerics tests bound the induced error per step.

(A flattened bitsandbytes-style block layout was tried first and rejected:
the flat int8 buffer cannot inherit the param sharding, and XLA SPMD falls
back to "involuntary full rematerialization" on every moment reshape —
see EXPERIMENTS.md §Perf.)
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

SMALL = 4096


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    int8_state: bool = True
    # schedule
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class QTensor:
    """Blockwise int8 tensor: q int8 (padded flat), scale f32 per block.
    `shape` (the logical unquantized shape) is static aux data."""
    q: jax.Array      # int8, same shape as the source tensor
    scale: jax.Array  # f32, shape[:-1] (absmax per last-axis row)
    shape: tuple

    def tree_flatten_with_keys(self):
        ga = jax.tree_util.GetAttrKey
        return ((ga("q"), self.q), (ga("scale"), self.scale)), (self.shape,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, shape=aux[0])

    @property
    def dtype(self):
        return jnp.float32


def quantize_blockwise(x: jax.Array) -> QTensor:
    """Shape-preserving int8 quantization, absmax per last-axis row."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127).astype(jnp.int8)
    return QTensor(q, scale, tuple(x.shape))


def dequantize_blockwise(t: QTensor) -> jax.Array:
    return t.q.astype(jnp.float32) * t.scale[..., None]


def _maybe_q(x: jax.Array, enable: bool):
    if enable and x.size >= SMALL:
        return quantize_blockwise(x)
    return x.astype(jnp.float32)


def _maybe_dq(x):
    return dequantize_blockwise(x) if isinstance(x, QTensor) else x


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * frac


def adamw_init(params, cfg: AdamWConfig):
    """m: int8 (first moment tolerates linear quantization), v: bf16 (the
    second moment's dynamic range within a row breaks int8 absmax — verified
    by the divergence study in tests/test_optim.py). ≈3 B/param total."""
    def m_like(x):
        return _maybe_q(jnp.zeros(x.shape, jnp.float32), cfg.int8_state)

    def v_like(x):
        if cfg.int8_state and x.size >= SMALL:
            return jnp.zeros(x.shape, jnp.bfloat16)
        return jnp.zeros(x.shape, jnp.float32)

    return {
        "m": jax.tree.map(m_like, params),
        "v": jax.tree.map(v_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree))
    )


def adamw_update(params, grads, opt_state, cfg: AdamWConfig):
    """→ (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    is_q = lambda n: isinstance(n, QTensor)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        v_dtype = v.dtype
        m = _maybe_dq(m)
        v = v.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, _maybe_q(m, cfg.int8_state), v.astype(v_dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.flatten(opt_state["m"], is_leaf=is_q)[0]
    flat_v = jax.tree.flatten(opt_state["v"], is_leaf=is_q)[0]
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return (
        new_p,
        {"m": new_m, "v": new_v, "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
