"""Offline weight transformation (paper §3.1 stage (i)).

`pack_params` rewrites a trained/QAT parameter tree into the serving tree:
every quantizable linear (key "qw", stored (K, M)) becomes a `PackedWeight`
(ternary absmean quant → trit-code packing at 1.6/2.0 bpw, per-channel
scales). Batched expert weights (leading E dim) pack along their last axis.
The rewrite is a pure pytree transformation — the model code is identical in
both modes (linear_apply dispatches on the key)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.packing import pack_weight
from repro.core.quantize import ternary_quantize


def pack_params(params, cfg):
    def rec(node):
        if isinstance(node, dict):
            if "qw" in node:
                w = jnp.swapaxes(node["qw"].astype(jnp.float32), -1, -2)  # (...,M,K)
                tw = ternary_quantize(w, per_channel=True)
                return {"pw": pack_weight(tw.values, tw.scale, mode=cfg.pack_mode)}
            return {k: rec(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [rec(v) for v in node]
            return type(node)(t) if isinstance(node, tuple) else t
        return node

    return rec(params)


def packed_param_bytes(params) -> int:
    """Total bytes of a (possibly packed) parameter tree."""
    return sum(
        l.size * l.dtype.itemsize for l in jax.tree.leaves(params)
    )


def param_count(params) -> int:
    """Logical parameter count (packed uint8 leaves count as g weights each —
    approximated via PackedWeight geometry during tree traversal)."""
    from repro.core.packing import PackedWeight

    total = 0

    def rec(node):
        nonlocal total
        if isinstance(node, PackedWeight):
            total += node.M * node.K + node.scale.size
            return
        if isinstance(node, dict):
            for v in node.values():
                rec(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                rec(v)
        else:
            total += node.size

    rec(params)
    return total
