"""Grouped-query attention with RoPE, sliding windows, ring KV caches, and an
online-softmax chunked path for long sequences.

Design notes
  * Positions are explicit everywhere: masks derive from absolute positions
    (`q_pos`, `kv_pos`), with `kv_pos == -1` marking invalid cache slots.
    This makes sliding-window *ring* caches trivial (a gemma3 local layer
    serving long_500k keeps only `window` slots) and makes sequence-parallel
    decode work under pjit: the KV cache shards over its length axis and
    XLA inserts the max/sum all-reduces of the distributed softmax.
  * Chunked attention (lax.scan over KV chunks, running max/sum) bounds the
    score tensor for 32k prefill; dense einsum below `attn_dense_max`.
  * All projections are quantizable BitLinears (the paper's mpGeMM targets).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard_act

from .common import (
    Params,
    linear_apply,
    linear_init,
    rmsnorm_apply,
    rmsnorm_init,
    rope,
)

NEG_INF = -1e30


# --------------------------------------------------------------------------
# Scaled dot-product attention over explicit positions
# --------------------------------------------------------------------------
def _mask(q_pos, kv_pos, causal: bool, window: int):
    """(B, Sq, Skv) bool."""
    m = kv_pos[:, None, :] >= 0
    if causal:
        m &= kv_pos[:, None, :] <= q_pos[:, :, None]
    if window:
        m &= q_pos[:, :, None] - kv_pos[:, None, :] < window
    return m


def _scores(q, k, scale, softcap):
    s = jnp.einsum(
        "bqkgd,bskd->bkgqs", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    return s


def sdpa(
    q: jax.Array,          # (B, Sq, H, D)
    k: jax.Array,          # (B, Skv, KV, D)
    v: jax.Array,          # (B, Skv, KV, D)
    q_pos: jax.Array,      # (B, Sq) int32
    kv_pos: jax.Array,     # (B, Skv) int32, -1 = invalid slot
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    chunk: int = 512,
    dense_max: int = 2048,
    extra_mask: jax.Array | None = None,   # (B, Sq, Skv) ANDed into the mask
) -> jax.Array:
    b, sq, h, d = q.shape
    dv = v.shape[-1]
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, d)
    scale = d ** -0.5

    if k.shape[1] <= dense_max or k.shape[1] % chunk:
        s = _scores(qg, k, scale, softcap)                       # (B,KV,G,Sq,Skv)
        m = _mask(q_pos, kv_pos, causal, window)
        if extra_mask is not None:
            m = m & extra_mask
        s = jnp.where(m[:, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
        return out.reshape(b, sq, h, dv)

    # ---- online-softmax over KV chunks ----------------------------------
    nc = k.shape[1] // chunk
    k_c = k.reshape(b, nc, chunk, kv, d).transpose(1, 0, 2, 3, 4)
    v_c = v.reshape(b, nc, chunk, kv, dv).transpose(1, 0, 2, 3, 4)
    p_c = kv_pos.reshape(b, nc, chunk).transpose(1, 0, 2)
    e_c = (
        None if extra_mask is None
        else extra_mask.reshape(b, sq, nc, chunk).transpose(2, 0, 1, 3)
    )

    def step(carry, xs):
        m_run, l_run, acc = carry
        kc, vc, pc = xs[:3]
        s = _scores(qg, kc, scale, softcap)                      # (B,KV,G,Sq,c)
        msk = _mask(q_pos, pc, causal, window)
        if e_c is not None:
            msk = msk & xs[3]
        msk = msk[:, None, None]
        s = jnp.where(msk, s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_run * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p, vc.astype(jnp.float32))
        acc = acc * alpha[..., None] + pv
        return (m_new, l_new, acc), None

    init = (
        jnp.full((b, kv, g, sq), NEG_INF, jnp.float32),
        jnp.zeros((b, kv, g, sq), jnp.float32),
        jnp.zeros((b, kv, g, sq, dv), jnp.float32),
    )
    xs = (k_c, v_c, p_c) if e_c is None else (k_c, v_c, p_c, e_c)
    (m_run, l_run, acc), _ = jax.lax.scan(step, init, xs)
    out = acc / jnp.maximum(l_run, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dv).astype(q.dtype)


def tree_step_gate(tree, start: jax.Array, s: int, length: int) -> jax.Array:
    """(B, S, L) bool gate ANDed into a tree-verify step's attention mask.

    The step's S incoming tokens form a draft tree (spec.tree.DraftTree) and
    occupy one cache slot each — slots start..start+S-1, node i at slot
    start+i — while their *positions* are start+depth(node), shared between
    siblings. Inside that slot window a query node may attend only its tree
    ancestors (itself included); outside it the gate is True and the usual
    position mask (cached prefix: kv_pos <= q_pos; stale slots: invalidated
    or position-masked) stands alone."""
    anc = jnp.asarray(tree.ancestors)                                 # (S, S)
    o = jnp.arange(length, dtype=jnp.int32)[None, :] - start[:, None]  # (B, L)
    in_step = (o >= 0) & (o < s)
    lookup = anc[:, jnp.clip(o, 0, s - 1)]                            # (S, B, L)
    return jnp.where(
        in_step[:, None, :], jnp.transpose(lookup, (1, 0, 2)), True
    )


# --------------------------------------------------------------------------
# GQA layer
# --------------------------------------------------------------------------
def attn_init(rng, cfg, spec) -> Params:
    rngs = jax.random.split(rng, 6)
    h, kv, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    p: Params = {
        "wq": linear_init(rngs[0], d, h * hd, cfg),
        "wk": linear_init(rngs[1], d, kv * hd, cfg),
        "wv": linear_init(rngs[2], d, kv * hd, cfg),
        "wo": linear_init(rngs[3], h * hd, d, cfg),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd)
        p["k_norm"] = rmsnorm_init(hd)
    return p


def attn_cache_init(
    cfg, spec, batch: int, max_len: int, dtype,
    page_size: int = 0, n_pages: int = 0,
) -> Params:
    """Ring-buffer cache for windowed layers, full buffer otherwise.

    page_size > 0 switches to the paged layout (see models.paged): one
    physical (n_pages, page_size, ...) pool shared across slots plus a
    per-slot block table, with page 0 reserved as the read-safe null page.
    Windowed layers keep in-window history a page drop would lose — they
    are genuinely non-pageable and refused here."""
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    if page_size:
        if spec.window:
            raise ValueError(
                "windowed (ring-buffer) attention layers are not pageable: "
                "the ring overwrites in place, so page-granular ownership "
                "cannot represent their in-window history"
            )
        return {
            "k": jnp.zeros((n_pages, page_size, kv, hd), dtype),
            "v": jnp.zeros((n_pages, page_size, kv, hd), dtype),
            "slot_pos": jnp.full((n_pages, page_size), -1, jnp.int32),
            "tab": jnp.zeros((batch, max_len // page_size), jnp.int32),
            "idx": jnp.zeros((batch,), jnp.int32),
        }
    buf = min(spec.window, max_len) if spec.window else max_len
    return {
        "k": jnp.zeros((batch, buf, kv, hd), dtype),
        "v": jnp.zeros((batch, buf, kv, hd), dtype),
        "slot_pos": jnp.full((batch, buf), -1, jnp.int32),
        # per-request write position → continuous batching mixes requests of
        # different lengths in one decode batch.
        "idx": jnp.zeros((batch,), jnp.int32),
    }


def _project_qkv(p, x, cfg, spec, mode, positions):
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = linear_apply(p["wq"], x, cfg, mode).reshape(b, s, h, hd)
    k = linear_apply(p["wk"], x, cfg, mode).reshape(b, s, kv, hd)
    v = linear_apply(p["wv"], x, cfg, mode).reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = rmsnorm_apply(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm_apply(p["k_norm"], k, cfg.norm_eps)
    if spec.rope_theta:
        q = rope(q, positions, spec.rope_theta)
        k = rope(k, positions, spec.rope_theta)
    return q, k, v


def attn_apply(
    p: Params,
    x: jax.Array,
    *,
    cfg,
    spec,
    mode: str,
    cache: Params | None = None,
    causal: bool = True,
    verify: bool = False,
    tree=None,
) -> tuple[jax.Array, Params | None]:
    """Self-attention. cache=None → pure (train/eval). Otherwise prefill
    (S>1: fills cache from position cache.idx) or decode (S==1: appends).

    verify=True is the speculative multi-token decode step: S>1 incoming
    tokens are appended to the cache and attend against the *full* cache
    (prior context + themselves, position-causal) instead of the prefill
    branch's within-sequence attention — see models.verify_step.

    tree (a spec.tree.DraftTree, verify only) marks the S incoming tokens as
    a flattened draft *tree*: node i is written to its own cache slot
    start+i but carries position start+depth(i) (siblings share positions —
    RoPE and the causal mask see depths, so the rollback stale-entry safety
    argument is unchanged), and the in-step attention is restricted to tree
    ancestors via `tree_step_gate`."""
    if verify and spec.window:
        raise ValueError(
            "multi-token verification needs a rollbackable cache; windowed "
            "(ring-buffer) layers would lose in-window history on rollback"
        )
    b, s, _ = x.shape
    start = cache["idx"] if cache is not None else jnp.zeros((b,), jnp.int32)
    if tree is not None:
        # per-node positions = node depth under the slot's next position
        offsets = jnp.asarray(tree.depths, jnp.int32)
    else:
        offsets = jnp.arange(s, dtype=jnp.int32)
    positions = start[:, None] + offsets[None, :]                     # (B,S)
    q, k, v = _project_qkv(p, x, cfg, spec, mode, positions)

    if cache is None:
        if cfg.attn_impl == "flash":
            from repro.kernels.flash_attention import flash_attention_trainable

            out = flash_attention_trainable(
                q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                v.transpose(0, 2, 1, 3), causal, spec.window,
                cfg.attn_logit_softcap, jax.default_backend() != "tpu",
            ).transpose(0, 2, 1, 3)
        else:
            out = sdpa(
                q, k, v, positions, positions,
                causal=causal, window=spec.window,
                softcap=cfg.attn_logit_softcap,
                chunk=cfg.attn_chunk, dense_max=cfg.attn_dense_max,
            )
        new_cache = None
    elif "tab" in cache:
        # ---- paged cache: physical page pool + per-slot block table ------
        # (models.paged) — writes map logical indices through the table
        # (unmapped/out-of-range targets dropped), reads attend the gathered
        # logical view with the SAME position-masked sdpa as the dense path.
        from .paged import page_scatter, page_view

        tab = cache["tab"]
        if tree is not None:
            # one slot per tree node (siblings share positions, not slots)
            slots = start[:, None] + jnp.arange(s, dtype=jnp.int32)
        else:
            slots = positions
        ck_pool = page_scatter(cache["k"], tab, slots, k)
        cv_pool = page_scatter(cache["v"], tab, slots, v)
        sp_pool = page_scatter(cache["slot_pos"], tab, slots, positions)
        new_cache = {
            "k": ck_pool, "v": cv_pool, "slot_pos": sp_pool,
            "tab": tab, "idx": start + s,
        }
        if s == 1 or verify:
            ck = page_view(ck_pool, tab)
            cv = page_view(cv_pool, tab)
            sp = page_view(sp_pool, tab)
            gate = (
                tree_step_gate(tree, start, s, ck.shape[1])
                if tree is not None else None
            )
            out = sdpa(
                q, ck, cv, positions, sp,
                causal=causal, window=spec.window,
                softcap=cfg.attn_logit_softcap,
                chunk=cfg.attn_chunk, dense_max=cfg.attn_dense_max,
                extra_mask=gate,
            )
        else:
            # prefill: attend within the incoming sequence itself.
            out = sdpa(
                q, k, v, positions, positions,
                causal=causal, window=spec.window,
                softcap=cfg.attn_logit_softcap,
                chunk=cfg.attn_chunk, dense_max=cfg.attn_dense_max,
            )
    else:
        buf = cache["k"].shape[1]
        bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
        if s >= buf:
            # prefill longer than the ring: keep the trailing `buf` tokens.
            src = s - buf + jnp.arange(buf, dtype=jnp.int32)
            dst = (start[:, None] + src[None, :]) % buf            # (B, buf)
            ck = cache["k"].at[bidx, dst].set(k[:, src], mode="promise_in_bounds")
            cv = cache["v"].at[bidx, dst].set(v[:, src], mode="promise_in_bounds")
            sp = cache["slot_pos"].at[bidx, dst].set(
                positions[:, src], mode="promise_in_bounds"
            )
        else:
            if tree is not None:
                # one slot per tree node; siblings share a *position* but
                # must not share a slot, or the scatter would clobber them
                slots = (start[:, None] + jnp.arange(s, dtype=jnp.int32)) % buf
            elif verify:
                # full-buffer multi-token write: a column whose position
                # passes the buffer end (a chunked prefill's mask-padded
                # tail, a decode rider's pad columns) must be DROPPED by
                # the scatter, never wrapped onto the slot's own early
                # prompt K/V — rollback is idx-only and cannot undo that
                slots = positions
            else:
                slots = positions % buf                             # (B, S)
            ck = cache["k"].at[bidx, slots].set(k, mode="drop")
            cv = cache["v"].at[bidx, slots].set(v, mode="drop")
            sp = cache["slot_pos"].at[bidx, slots].set(positions, mode="drop")
        new_cache = {
            "k": shard_act(ck, "kv_cache"),
            "v": shard_act(cv, "kv_cache"),
            "slot_pos": sp,
            "idx": start + s,
        }
        if s == 1 or verify:
            # decode / verify: the scatter above already wrote the incoming
            # K/V, so attending (ck, cv) with slot positions covers both the
            # cached prefix and the new tokens; causality comes from the
            # position mask (kv_pos <= q_pos), plus the ancestor gate over
            # this step's slot window when the tokens form a draft tree.
            gate = (
                tree_step_gate(tree, start, s, ck.shape[1])
                if tree is not None else None
            )
            out = sdpa(
                q, ck, cv, positions, sp,
                causal=causal, window=spec.window,
                softcap=cfg.attn_logit_softcap,
                chunk=cfg.attn_chunk, dense_max=cfg.attn_dense_max,
                extra_mask=gate,
            )
        else:
            # prefill: attend within the incoming sequence itself.
            out = sdpa(
                q, k, v, positions, positions,
                causal=causal, window=spec.window,
                softcap=cfg.attn_logit_softcap,
                chunk=cfg.attn_chunk, dense_max=cfg.attn_dense_max,
            )
    b_, s_, h, hd = out.shape
    y = linear_apply(p["wo"], out.reshape(b_, s_, h * hd), cfg, mode)
    return y, new_cache


# --------------------------------------------------------------------------
# Cross-attention (enc-dec decoder layers)
# --------------------------------------------------------------------------
def cross_attn_init(rng, cfg) -> Params:
    rngs = jax.random.split(rng, 4)
    h, kv, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    return {
        "wq": linear_init(rngs[0], d, h * hd, cfg),
        "wk": linear_init(rngs[1], d, kv * hd, cfg),
        "wv": linear_init(rngs[2], d, kv * hd, cfg),
        "wo": linear_init(rngs[3], h * hd, d, cfg),
    }


def cross_attn_kv(p: Params, enc_out: jax.Array, cfg, mode: str):
    b, se, _ = enc_out.shape
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    k = linear_apply(p["wk"], enc_out, cfg, mode).reshape(b, se, kv, hd)
    v = linear_apply(p["wv"], enc_out, cfg, mode).reshape(b, se, kv, hd)
    return k, v


def cross_attn_apply(p: Params, x, k, v, cfg, mode: str):
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = linear_apply(p["wq"], x, cfg, mode).reshape(b, s, h, hd)
    q_pos = jnp.zeros((b, s), jnp.int32)
    kv_pos = jnp.zeros((b, k.shape[1]), jnp.int32)
    out = sdpa(
        q, k, v, q_pos, kv_pos, causal=False, window=0,
        chunk=cfg.attn_chunk, dense_max=cfg.attn_dense_max,
    )
    return linear_apply(p["wo"], out.reshape(b, s, h * hd), cfg, mode)
