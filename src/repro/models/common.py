"""Shared model building blocks: linears (dense / QAT-ternary / packed-serve),
RMSNorm, RoPE, embeddings.

Parameter convention: plain nested dicts of arrays. A *quantizable* linear
(one the paper's mpGeMM kernel serves) stores its dense weight under key
``"qw"`` with shape (K_in, M_out); after `convert.pack_params` it becomes
``{"pw": PackedWeight}`` (M_out, K_in packed). Non-quantized linears use key
``"w"``. This makes train→serve conversion a pure pytree rewrite.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.packing import PackedWeight
from repro.core.quantize import fake_act_quant, fake_ternary_cols
from repro.kernels.ops import ternary_matmul

Params = dict[str, Any]


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------------
# Linear
# --------------------------------------------------------------------------
def linear_init(rng, k_in: int, m_out: int, cfg, quant: bool = True) -> Params:
    scale = 1.0 / (k_in ** 0.5)
    w = jax.random.normal(rng, (k_in, m_out), jnp.float32) * scale
    key = "qw" if (quant and cfg.quant == "ternary") else "w"
    return {key: w.astype(_dtype(cfg))}


def linear_apply(p: Params, x: jax.Array, cfg, mode: str) -> jax.Array:
    """x: (..., K) → (..., M). mode: 'train' | 'eval' | 'serve'."""
    if "pw" in p:  # packed serving path → the paper's kernel
        return ternary_matmul(p["pw"], x)
    if "qw" in p:
        w = p["qw"]
        if mode in ("train", "eval"):
            # QAT: ternary weight fake-quant + per-token int8 activation STE.
            wq = fake_ternary_cols(w).astype(x.dtype)
            xq = fake_act_quant(x)
            return xq @ wq
        # mode == 'serve' but unconverted params: dense ternarized compute.
        wq = fake_ternary_cols(w).astype(x.dtype)
        return x @ wq
    return x @ p["w"].astype(x.dtype)


def linear_batched_apply(p: Params, x: jax.Array, cfg, mode: str) -> jax.Array:
    """Batched expert linear: params have a leading E dim; x: (E, C, K)."""
    if "pw" in p:
        return jax.vmap(lambda pw, xe: ternary_matmul(pw, xe))(p["pw"], x)
    key = "qw" if "qw" in p else "w"
    w = p[key]
    if key == "qw" and mode in ("train", "eval"):
        wq = fake_ternary_cols(w)                       # (E, K, M), no transpose
        return jnp.einsum("eck,ekm->ecm", fake_act_quant(x), wq.astype(x.dtype))
    return jnp.einsum("eck,ekm->ecm", x, w.astype(x.dtype))


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------
def rmsnorm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm_apply(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


def gated_rmsnorm_apply(p: Params, x: jax.Array, gate: jax.Array, eps=1e-5):
    """Mamba2's norm(x * silu(gate))."""
    return rmsnorm_apply(p, x * jax.nn.silu(gate.astype(x.dtype)), eps)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D) with D even; positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, d, 2, dtype=jnp.float32) / d)   # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs           # (B,S,D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Embedding
# --------------------------------------------------------------------------
def embed_init(rng, vocab: int, d: int, cfg) -> Params:
    return {"table": jax.random.normal(rng, (vocab, d), jnp.float32).astype(_dtype(cfg)) * 0.02}


def embed_apply(p: Params, tokens: jax.Array, cfg) -> jax.Array:
    x = jnp.take(p["table"], tokens, axis=0)
    if cfg.emb_scale_by_dim:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def head_apply(embed_params: Params, head_params: Params | None, x, cfg):
    """Final logits; tied to the embedding table unless a head is present."""
    if head_params is not None:
        return linear_apply(head_params, x, cfg, mode="eval")
    table = embed_params["table"]
    return x @ table.T.astype(x.dtype)
