"""Multi-head Latent Attention (DeepSeek-V2/V3).

Train/prefill use the naive expansion (latents → per-head K/V). Decode uses
the *absorbed* formulation: the cache stores only the 512-dim compressed
latent + 64-dim decoupled RoPE key per token (576 dims ≈ 4.5× smaller than
GQA kv=128 would need), and W_UK/W_UV are folded into the query/output
projections — the production trick that makes decode_32k at batch 128 cheap.

All five projections (wq_a, wq_b, wkv_a, wkv_b, wo) are quantizable
BitLinears served by the Vec-LUT packed kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard_act

from .common import (
    Params,
    linear_apply,
    linear_init,
    rmsnorm_apply,
    rmsnorm_init,
    rope,
)
from .attention import sdpa, tree_step_gate


def _dims(cfg):
    m = cfg.mla
    return m.q_lora_rank, m.kv_lora_rank, m.qk_nope_dim, m.qk_rope_dim, m.v_dim


def mla_init(rng, cfg, spec) -> Params:
    ql, kvl, nope, rp, vd = _dims(cfg)
    h, d = cfg.n_heads, cfg.d_model
    r = jax.random.split(rng, 5)
    return {
        "wq_a": linear_init(r[0], d, ql, cfg),
        "q_norm": rmsnorm_init(ql),
        "wq_b": linear_init(r[1], ql, h * (nope + rp), cfg),
        "wkv_a": linear_init(r[2], d, kvl + rp, cfg),
        "kv_norm": rmsnorm_init(kvl),
        "wkv_b": linear_init(r[3], kvl, h * (nope + vd), cfg),
        "wo": linear_init(r[4], h * vd, d, cfg),
    }


def mla_cache_init(
    cfg, spec, batch: int, max_len: int, dtype,
    page_size: int = 0, n_pages: int = 0,
) -> Params:
    _, kvl, _, rp, _ = _dims(cfg)
    if page_size:
        # paged layout (models.paged): shared latent page pool + per-slot
        # block table; page 0 reserved as the null page. No slot_pos leaf —
        # the MLA cache's index-as-position convention survives paging
        # because pages are gathered back into logical order for reads.
        return {
            "ckv": jnp.zeros((n_pages, page_size, kvl), dtype),
            "krope": jnp.zeros((n_pages, page_size, rp), dtype),
            "tab": jnp.zeros((batch, max_len // page_size), jnp.int32),
            "idx": jnp.zeros((batch,), jnp.int32),
        }
    return {
        "ckv": jnp.zeros((batch, max_len, kvl), dtype),
        "krope": jnp.zeros((batch, max_len, rp), dtype),
        "idx": jnp.zeros((batch,), jnp.int32),
    }


def _latents(p, x, cfg, mode, positions):
    """→ (q_nope, q_rope, ckv_normed, k_rope) with RoPE applied."""
    ql, kvl, nope, rp, vd = _dims(cfg)
    b, s, _ = x.shape
    h = cfg.n_heads
    q = linear_apply(p["wq_b"], rmsnorm_apply(p["q_norm"],
        linear_apply(p["wq_a"], x, cfg, mode), cfg.norm_eps), cfg, mode)
    q = q.reshape(b, s, h, nope + rp)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    kv_a = linear_apply(p["wkv_a"], x, cfg, mode)
    ckv = rmsnorm_apply(p["kv_norm"], kv_a[..., :kvl], cfg.norm_eps)
    k_rope = kv_a[..., kvl:][:, :, None, :]                          # (B,S,1,rp)
    q_rope = rope(q_rope, positions, spec_theta(cfg))
    k_rope = rope(k_rope, positions, spec_theta(cfg))[:, :, 0, :]
    return q_nope, q_rope, ckv, k_rope


def spec_theta(cfg):
    return 10_000.0


def _wkv_b_dense(p, cfg, dtype):
    """Dense (kvl, H, nope+vd) view of wkv_b — unpacked transiently for the
    absorbed decode einsums (weight ≪ KV traffic at decode)."""
    ql, kvl, nope, rp, vd = _dims(cfg)
    h = cfg.n_heads
    if "pw" in p["wkv_b"]:
        pw = p["wkv_b"]["pw"]
        w_scale = pw.scale if pw.scale.shape[-1] == pw.M else jnp.broadcast_to(pw.scale, (pw.M,))
        w = (pw.unpack().astype(jnp.float32) * w_scale[:, None]).T   # (kvl, M)
    elif "qw" in p["wkv_b"]:
        # mirror the QAT fake-ternary numerics of the naive (prefill) path
        from repro.core.quantize import fake_ternary_cols

        w = fake_ternary_cols(p["wkv_b"]["qw"]).astype(jnp.float32)  # (kvl, M)
    else:
        w = p["wkv_b"]["w"].astype(jnp.float32)                      # (kvl, M)
    return w.reshape(kvl, h, nope + vd).astype(dtype)


def _expand_kv(p, ckv, cfg, mode):
    ql, kvl, nope, rp, vd = _dims(cfg)
    b, s, _ = ckv.shape
    h = cfg.n_heads
    kv = linear_apply(p["wkv_b"], ckv, cfg, mode).reshape(b, s, h, nope + vd)
    return kv[..., :nope], kv[..., nope:]                            # k_nope, v


def mla_apply(
    p: Params,
    x: jax.Array,
    *,
    cfg,
    spec,
    mode: str,
    cache: Params | None = None,
    verify: bool = False,
    tree=None,
    prefill_resume: bool = False,
) -> tuple[jax.Array, Params | None]:
    """verify=True runs the absorbed-latent decode path for S>1 incoming
    tokens (speculative multi-token verification) with a per-query causal
    position mask; without it S>1+cache means prefill (within-sequence).

    prefill_resume=True (verify only, S>1) is the chunked-prefill read path:
    instead of the absorbed formulation it expands the *cached* latents
    through the same quantized `wkv_b` BitLinear the naive prefill path uses
    (activation quantization is per-token, so every cached latent row
    expands to bit-identical K/V regardless of what else is in the buffer)
    and attends with the position-masked sdpa — a chunk's logits are then
    token-identical to the whole-prompt prefill path, which the absorbed
    f32 einsum (no activation quantization) is not. Costs an O(cache-len)
    expansion per chunk — the chunked-prefill tradeoff, not paid at decode.

    tree (spec.tree.DraftTree, verify only): the S tokens are a flattened
    draft tree — node i is written to its own slot start+i but carries
    position start+depth(i), and the in-step attention is restricted to tree
    ancestors (tree_step_gate). The MLA cache has no slot_pos record (slot
    index doubles as position); tree writes briefly break that equality
    inside the step window, where the ancestor gate is exact, and the engine
    compacts the winning path back to slot==position before the next step —
    stale non-path slots sit at indices ≥ the rolled-back idx + are always
    rewritten by the next (equally wide) verify scatter before being
    attended, so the index-as-position mask never reads them."""
    ql, kvl, nope, rp, vd = _dims(cfg)
    b, s, _ = x.shape
    h = cfg.n_heads
    start = cache["idx"] if cache is not None else jnp.zeros((b,), jnp.int32)
    if tree is not None:
        offsets = jnp.asarray(tree.depths, jnp.int32)
    else:
        offsets = jnp.arange(s, dtype=jnp.int32)
    positions = start[:, None] + offsets[None, :]
    q_nope, q_rope, ckv, k_rope = _latents(p, x, cfg, mode, positions)

    new_cache = None
    ckv_cached = krope_cached = None   # logical (B, L, ·) read views
    if cache is not None:
        if tree is not None:                        # one slot per tree node
            slots = start[:, None] + jnp.arange(s, dtype=jnp.int32)
        else:
            slots = positions                                         # full buffer
        if "tab" in cache:
            # paged cache (models.paged): the latent write maps logical
            # indices through the block table (unmapped / out-of-range
            # targets dropped — same semantics as the dense mode="drop"),
            # and reads gather the logical view so index-as-position holds.
            from .paged import page_scatter, page_view

            tab = cache["tab"]
            new_cache = {
                "ckv": page_scatter(cache["ckv"], tab, slots, ckv),
                "krope": page_scatter(cache["krope"], tab, slots, k_rope),
                "tab": tab,
                "idx": start + s,
            }
            ckv_cached = page_view(new_cache["ckv"], tab)
            krope_cached = page_view(new_cache["krope"], tab)
        else:
            bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
            # mode="drop": a multi-token write whose position passes the
            # buffer end (mask-padded chunk tails, decode-rider pad columns)
            # is discarded — XLA's default clamp would clobber the last
            # cache entry, and rollback (idx-only) could never undo it
            new_cache = {
                "ckv": shard_act(
                    cache["ckv"].at[bidx, slots].set(
                        ckv.astype(cache["ckv"].dtype), mode="drop"
                    ),
                    "kv_cache",
                ),
                "krope": shard_act(
                    cache["krope"].at[bidx, slots].set(
                        k_rope.astype(cache["krope"].dtype), mode="drop"
                    ),
                    "kv_cache",
                ),
                "idx": start + s,
            }
            ckv_cached = new_cache["ckv"]
            krope_cached = new_cache["krope"]

    if cache is not None and verify and prefill_resume and s > 1:
        # ---- chunked-prefill resume: naive expansion over the cache ------
        k_nope, v = _expand_kv(p, ckv_cached, cfg, mode)
        L = ckv_cached.shape[1]
        k_rope_all = krope_cached                                    # (B,L,rp)
        k = jnp.concatenate(
            [k_nope,
             jnp.broadcast_to(k_rope_all[:, :, None, :], (b, L, h, rp))],
            axis=-1,
        )
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        # index-as-position: slot i holds position i (the contiguous chunk
        # writes guarantee every index <= a live query position is real)
        kv_pos = jnp.broadcast_to(
            jnp.arange(L, dtype=jnp.int32)[None, :], (b, L)
        )
        out = sdpa(
            q, k.astype(q.dtype), v.astype(q.dtype), positions, kv_pos,
            causal=True, window=0, chunk=cfg.attn_chunk,
            dense_max=cfg.attn_dense_max,
        )
    elif cache is not None and (s == 1 or verify):
        # ---- absorbed decode over the latent cache -----------------------
        wkv_b = _wkv_b_dense(p, cfg, jnp.float32)                    # (kvl,H,nope+vd)
        w_uk, w_uv = wkv_b[..., :nope], wkv_b[..., nope:]
        ckv_all = ckv_cached.astype(jnp.float32)                     # (B,L,kvl)
        krope_all = krope_cached.astype(jnp.float32)                 # (B,L,rp)
        q_eff = jnp.einsum("bqhd,khd->bqhk", q_nope.astype(jnp.float32), w_uk)
        scale = (nope + rp) ** -0.5
        scores = (
            jnp.einsum("bqhk,bsk->bhqs", q_eff, ckv_all)
            + jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(jnp.float32), krope_all)
        ) * scale
        kv_pos = jnp.arange(ckv_all.shape[1], dtype=jnp.int32)[None, :]
        valid = kv_pos[:, None, :] <= positions[:, :, None]          # (B,Sq,L)
        if tree is not None:
            # inside the step's slot window the index-as-position mask is
            # meaningless (an ancestor's slot index can exceed the query's
            # depth-based position) — the ancestor gate *replaces* it there
            o = kv_pos - start[:, None]                              # (B, L)
            in_step = (o >= 0) & (o < s)
            gate = tree_step_gate(tree, start, s, ckv_all.shape[1])
            valid = jnp.where(in_step[:, None, :], gate, valid)
        scores = jnp.where(valid[:, None, :, :], scores, -1e30)     # (B,H,Sq,L)
        probs = jax.nn.softmax(scores, axis=-1)
        lat = jnp.einsum("bhqs,bsk->bqhk", probs, ckv_all)
        out = jnp.einsum("bqhk,khv->bqhv", lat, w_uv)                # (B,1,H,vd)
    else:
        # ---- naive expansion (train / prefill) ---------------------------
        k_nope, v = _expand_kv(p, ckv, cfg, mode)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, rp))], axis=-1
        )
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = sdpa(
            q, k.astype(q.dtype), v.astype(q.dtype), positions, positions,
            causal=True, window=0, chunk=cfg.attn_chunk,
            dense_max=cfg.attn_dense_max,
        )
    y = linear_apply(p["wo"], out.reshape(b, s, h * vd).astype(x.dtype), cfg, mode)
    return y, new_cache
