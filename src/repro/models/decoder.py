"""Staged decoder-only LM.

Heterogeneous layer stacks compress into *stages*: maximal runs of a repeated
LayerSpec pattern. Each stage lowers to ONE lax.scan over its stacked
parameters (with optional remat), so a 72-layer jamba (period-8 pattern) or a
61-layer deepseek (3 dense + 58 MoE) compiles a handful of layer bodies
instead of n_layers copies — essential to keep the multi-pod dry-run HLO
small and compile times sane.

The final cross-entropy is computed in sequence chunks (never materializing
the full (B, S, V) logits — vocab 202k/262k archs would otherwise OOM), with
the vocab dimension shardable over the `model` mesh axis.
"""
from __future__ import annotations

import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import shard_act

from .blocks import block_apply, block_cache_init, block_init
from .common import (
    Params,
    embed_apply,
    embed_init,
    linear_init,
    rmsnorm_apply,
    rmsnorm_init,
)


# --------------------------------------------------------------------------
# Layout compression
# --------------------------------------------------------------------------
def compress_layout(specs: Sequence, max_period: int = 8) -> list[tuple[tuple, int]]:
    """Greedy factorization of the layer list into (pattern, repeats) runs."""
    stages: list[tuple[tuple, int]] = []
    i, n = 0, len(specs)
    while i < n:
        best_p, best_r = 1, 1
        for p in range(1, min(max_period, n - i) + 1):
            r = 1
            while (
                i + (r + 1) * p <= n
                and tuple(specs[i + r * p : i + (r + 1) * p]) == tuple(specs[i : i + p])
            ):
                r += 1
            if r * p > best_p * best_r or (r * p == best_p * best_r and p < best_p):
                best_p, best_r = p, r
        stages.append((tuple(specs[i : i + best_p]), best_r))
        i += best_p * best_r
    return stages


# --------------------------------------------------------------------------
# Stage init / apply
# --------------------------------------------------------------------------
def _stage_init(rng, cfg, pattern, reps: int) -> Params:
    out: Params = {}
    for pos, spec in enumerate(pattern):
        keys = jax.random.split(jax.random.fold_in(rng, pos), reps)
        out[f"b{pos}"] = jax.vmap(lambda k: block_init(k, cfg, spec))(keys)
    return out


def _stage_cache_init(
    cfg, pattern, reps, batch, max_len, dtype, enc_len,
    page_size=0, n_pages=0,
):
    out = {}
    for pos, spec in enumerate(pattern):
        c1 = block_cache_init(
            cfg, spec, batch, max_len, dtype, enc_len,
            page_size=page_size, n_pages=n_pages,
        )
        out[f"b{pos}"] = jax.tree.map(
            lambda l: jnp.repeat(l[None], reps, axis=0), c1
        )
    return out


def _stage_apply(
    stage_params: Params,
    x: jax.Array,
    aux: jax.Array,
    *,
    cfg,
    pattern,
    mode: str,
    cache: Params | None,
    enc_out: jax.Array | None,
    causal: bool,
    verify: bool = False,
    tree=None,
    prefill_resume: bool = False,
):
    has_cache = cache is not None
    carry_cache = has_cache and cfg.cache_in_carry

    def _ckpt(fn):
        if not cfg.remat:
            return fn
        policy = (
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            if cfg.remat_policy == "dots" else None
        )
        return jax.checkpoint(fn, prevent_cse=False, policy=policy)

    if carry_cache:
        # Cache lives in the scan CARRY and is updated in place per layer
        # (dynamic_update_slice on the stacked buffer). XLA keeps the carry
        # buffer resident → decode touches each cache byte ~once instead of
        # the read-xs/write-ys double traffic (+copies) of the ys form.
        reps = jax.tree.leaves(stage_params)[0].shape[0]

        def body_c(carry, xs):
            x, aux, cache_full = carry
            p_rep, li = xs
            new_cache_rep = {}
            for i, spec in enumerate(pattern):
                c = jax.tree.map(
                    lambda l: jax.lax.dynamic_index_in_dim(l, li, 0, keepdims=False),
                    cache_full[f"b{i}"],
                )
                x, nc, a = block_apply(
                    p_rep[f"b{i}"], x, cfg=cfg, spec=spec, mode=mode,
                    cache=c, enc_out=enc_out, causal=causal, verify=verify,
                    tree=tree, prefill_resume=prefill_resume,
                )
                x = shard_act(x, "btd")
                aux = aux + a
                new_cache_rep[f"b{i}"] = nc
            cache_full = jax.tree.map(
                # lint: disable=R1 -- li scans jnp.arange(reps): in bounds by construction
                lambda full, new: jax.lax.dynamic_update_index_in_dim(
                    full, new.astype(full.dtype), li, 0
                ),
                cache_full, new_cache_rep,
            )
            return (x, aux, cache_full), None

        body_fn = _ckpt(body_c)
        (x, aux, new_cache), _ = jax.lax.scan(
            body_fn, (x, aux, cache), (stage_params, jnp.arange(reps))
        )
        return x, aux, new_cache

    def body(carry, xs):
        x, aux = carry
        p_rep = xs[0]
        cache_rep = xs[1] if has_cache else None
        new_cache_rep = {}
        for i, spec in enumerate(pattern):
            c = cache_rep[f"b{i}"] if has_cache else None
            x, nc, a = block_apply(
                p_rep[f"b{i}"], x, cfg=cfg, spec=spec, mode=mode,
                cache=c, enc_out=enc_out, causal=causal, verify=verify,
                tree=tree, prefill_resume=prefill_resume,
            )
            x = shard_act(x, "btd")
            aux = aux + a
            if has_cache:
                new_cache_rep[f"b{i}"] = nc
        return (x, aux), (new_cache_rep if has_cache else None)

    body = _ckpt(body)
    xs = (stage_params, cache) if has_cache else (stage_params,)
    (x, aux), new_cache = jax.lax.scan(body, (x, aux), xs)
    return x, aux, new_cache


# --------------------------------------------------------------------------
# Full model
# --------------------------------------------------------------------------
def init_lm(rng, cfg) -> Params:
    specs = cfg.layer_specs()
    stages = compress_layout(specs)
    p: Params = {
        "embed": embed_init(jax.random.fold_in(rng, 0), cfg.vocab, cfg.d_model, cfg),
        "stages": [
            _stage_init(jax.random.fold_in(rng, 100 + si), cfg, pat, reps)
            for si, (pat, reps) in enumerate(stages)
        ],
        "final_norm": rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["head"] = linear_init(
            jax.random.fold_in(rng, 1), cfg.d_model, cfg.vocab, cfg, quant=False
        )
    return p


def init_cache(
    cfg, batch: int, max_len: int, dtype=jnp.bfloat16, enc_len: int = 0,
    page_size: int = 0, n_pages: int = 0,
):
    """page_size > 0 builds the paged layout (models.paged): per-layer
    physical page pools of ``n_pages`` pages (page 0 reserved null) shared
    across slots, plus per-slot (batch, max_len // page_size) block tables.
    Every serving entry point (decode_step / verify_step / compact_tree_cache
    / rollback_cache / reset_slot_idx) dispatches on the cache structure, so
    paged and dense engines share the same jitted functions."""
    if page_size:
        if max_len % page_size:
            raise ValueError(
                f"max_len ({max_len}) must be a multiple of page_size "
                f"({page_size}) — partial trailing pages would break the "
                "block-table logical<->physical mapping"
            )
        if n_pages < 2:
            raise ValueError(
                f"n_pages ({n_pages}) must be >= 2: page 0 is the reserved "
                "null page, so at least one allocatable page is needed"
            )
    stages = compress_layout(cfg.layer_specs())
    return [
        _stage_cache_init(
            cfg, pat, reps, batch, max_len, dtype, enc_len,
            page_size=page_size, n_pages=n_pages,
        )
        for (pat, reps) in stages
    ]


def lm_hidden(
    params: Params,
    inputs: jax.Array,
    cfg,
    *,
    mode: str = "train",
    cache: list | None = None,
    enc_out: jax.Array | None = None,
    causal: bool = True,
    verify: bool = False,
    tree=None,
    prefill_resume: bool = False,
):
    """inputs: int32 tokens (B, S) or pre-embedded (B, S, d) (stub frontends).
    → (hidden (B,S,d), new_cache, aux_loss). verify=True: S>1 tokens are a
    speculative decode step appended to the cache (see verify_step); tree
    marks them as a flattened draft tree (verify only)."""
    if tree is not None and not verify:
        raise ValueError("tree attention is only defined for verify steps")
    if prefill_resume and (tree is not None or not verify):
        raise ValueError(
            "prefill_resume is the chunked-prefill verify read path; it is "
            "undefined for trees or non-verify forwards"
        )
    if inputs.dtype in (jnp.int32, jnp.int64):
        x = embed_apply(params["embed"], inputs, cfg)
    else:
        x = inputs.astype(jnp.dtype(cfg.dtype))
    x = shard_act(x, "btd")
    aux = jnp.zeros((), jnp.float32)
    stages = compress_layout(cfg.layer_specs())
    new_cache = []
    for si, (pat, reps) in enumerate(stages):
        c = cache[si] if cache is not None else None
        x, aux, nc = _stage_apply(
            params["stages"][si], x, aux, cfg=cfg, pattern=pat, mode=mode,
            cache=c, enc_out=enc_out, causal=causal, verify=verify, tree=tree,
            prefill_resume=prefill_resume,
        )
        new_cache.append(nc)
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    return x, (new_cache if cache is not None else None), aux


def _head_matmul(params: Params, h: jax.Array, cfg) -> jax.Array:
    if "head" in params:
        return h.astype(jnp.float32) @ params["head"]["w"].astype(jnp.float32)
    return h.astype(jnp.float32) @ params["embed"]["table"].T.astype(jnp.float32)


def lm_logits(params: Params, h: jax.Array, cfg) -> jax.Array:
    """Full logits — use only for small S (serving reads the last position)."""
    return _head_matmul(params, h, cfg)


def lm_loss(
    params: Params,
    tokens: jax.Array,
    labels: jax.Array,
    cfg,
    *,
    mode: str = "train",
    enc_out: jax.Array | None = None,
    loss_mask: jax.Array | None = None,
):
    """Chunked softmax cross-entropy. → (loss, metrics dict)."""
    h, _, aux = lm_hidden(params, tokens, cfg, mode=mode, enc_out=enc_out)
    b, s, d = h.shape
    chunk = min(cfg.loss_chunk, s)
    pad = (-s) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        loss_mask = jnp.pad(
            loss_mask if loss_mask is not None else jnp.ones((b, s), jnp.float32),
            ((0, 0), (0, pad)),
        )
    elif loss_mask is None:
        loss_mask = jnp.ones((b, s), jnp.float32)
    nc = (s + pad) // chunk
    h_c = h.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    y_c = labels.reshape(b, nc, chunk).transpose(1, 0, 2)
    m_c = loss_mask.reshape(b, nc, chunk).transpose(1, 0, 2)

    def step(carry, xs):
        tot, cnt = carry
        hc, yc, mc = xs
        logits = shard_act(_head_matmul(params, hc, cfg), "btv")    # (B,c,V) f32
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        tot = tot + jnp.sum((logz - ll) * mc)
        cnt = cnt + jnp.sum(mc)
        return (tot, cnt), None

    step_fn = jax.checkpoint(step, prevent_cse=False) if cfg.remat else step
    (tot, cnt), _ = jax.lax.scan(
        step_fn, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (h_c, y_c, m_c),
    )
    ce = tot / jnp.maximum(cnt, 1.0)
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux, "tokens": cnt}


# --------------------------------------------------------------------------
# Serving entry points
# --------------------------------------------------------------------------
def prefill(params, tokens, cache, cfg, *, mode="serve", enc_out=None, causal=True):
    """Run the prompt through the model, filling the cache.
    → (last-position logits (B, V), new_cache)."""
    h, new_cache, _ = lm_hidden(
        params, tokens, cfg, mode=mode, cache=cache, enc_out=enc_out, causal=causal
    )
    logits = _head_matmul(params, h[:, -1:, :], cfg)[:, 0]
    return logits, new_cache


def decode_step(params, tokens, cache, cfg, *, mode="serve"):
    """One decode step. tokens: (B, 1) int32 (or (B,1,d) embeds).
    → (logits (B, V), new_cache)."""
    h, new_cache, _ = lm_hidden(params, tokens, cfg, mode=mode, cache=cache)
    logits = _head_matmul(params, h[:, -1:, :], cfg)[:, 0]
    return logits, new_cache


def verify_step(params, tokens, cache, cfg, *, mode="serve", tree=None,
                prefill_resume=False, logit_cols=None):
    """Batched multi-token decode — the speculative-verification step.

    tokens: (B, S) int32 candidate tokens per slot (column 0 is the last
    sampled token, columns 1..S-1 the drafted continuation). Every token is
    appended to the slot KV cache at its per-slot position (cache idx) and
    attends against the full cache, so logits[:, j] is exactly the
    distribution a sequential decode would produce after processing
    tokens[:, :j+1] — one batched M=S pass through the Vec-LUT mpGeMM
    kernels instead of S sequential M=1 passes.

    With tree (a spec.tree.DraftTree, S == tree.n_nodes) the tokens are a
    flattened draft *tree* in the DraftTree node order: node j attends the
    cached prefix plus its tree ancestors only, carries position idx +
    depth(j), and is written to its own cache slot idx + j — so logits[:, j]
    is exactly what sequential decode would produce after the root-to-j path.
    After acceptance the engine compacts the winning path's slots back to
    contiguous positions (compact_tree_cache) before rolling back.

    → (logits (B, S, V), new_cache with idx advanced by S). Rejected suffixes
    are undone with rollback_cache. S is expected small (draft_k + 1, or the
    tree's node count): the full (B, S, V) logits are materialized.

    logit_cols ((B,) int32): read path for chunked prefill, where each slot
    needs the distribution after exactly one position in the chunk (the last
    prompt token, or nothing at all mid-prompt). The head matmul runs on the
    single gathered hidden state per slot — (B, 1, d) @ (d, V) instead of
    (B, S, d) @ (d, V) — and the return is (logits (B, V), new_cache). The
    KV-cache write path is identical either way."""
    h, new_cache, _ = lm_hidden(
        params, tokens, cfg, mode=mode, cache=cache, verify=True, tree=tree,
        prefill_resume=prefill_resume,
    )
    if logit_cols is not None:
        h_sel = jnp.take_along_axis(
            h, logit_cols[:, None, None].astype(jnp.int32), axis=1
        )  # (B, 1, d) — broadcasts over d
        logits = _head_matmul(params, h_sel, cfg)[:, 0]
        return logits, new_cache
    logits = _head_matmul(params, h, cfg)
    return logits, new_cache


def prefill_bucket(n: int, max_len: int | None = None) -> int:
    """Pad prompt lengths to 16-multiples → one prefill jit entry per bucket
    (left-padding gives pad tokens negative positions, masked everywhere).

    The bucket is clamped to `max_len`: a prompt within 15 tokens of max_len
    (legal whenever max_new_tokens=1) must not round up past the cache —
    positions would alias mod max_len and the duplicate-index scatter would
    clobber real prompt K/V nondeterministically."""
    b = max(16, (n + 15) // 16 * 16)
    if max_len is not None:
        b = min(b, max_len)
    return max(b, n)


def prefill_into_slot(
    params, cache, slot: int, prompt, cfg, *, max_len: int, prefill_fn,
    exact_len: bool = False,
):
    """Admit one prompt into batched slot `slot`: B=1 bucketed left-padded
    prefill (pad positions negative → masked; start idx set via
    rollback_cache), scattered into the full cache. Shared by the serving
    engine and the speculative ModelDrafter so their cache positions can
    never drift apart. exact_len skips bucketing (ssm archs can't mask pads
    inside the scan). prefill_fn: jit'd (params, single_cache, tokens) →
    (logits, single_cache). → (logits, new_full_cache, padded_len)."""
    n = len(prompt)
    bucket = n if exact_len else prefill_bucket(n, max_len)
    single = init_cache(cfg, 1, max_len)
    if bucket != n:
        single = rollback_cache(single, jnp.asarray([n - bucket]))
    tok = np.zeros((1, bucket), np.int32)
    tok[0, bucket - n:] = prompt
    logits, single = prefill_fn(params, single, jnp.asarray(tok))
    return logits, scatter_slot_cache(cache, single, slot), bucket


def scatter_slot_cache(full_cache, single_cache, slot: int):
    """Scatter a B=1 cache pytree into batched slot `slot` (axis 1 is the
    batch axis under the stacked layer-repeat axis) — shared by the serving
    engine and the speculative ModelDrafter's mirrored cache."""
    def scat(full, one):
        # lint: disable=R1 -- slot is a host int the engine allocated < max_slots
        return jax.lax.dynamic_update_slice_in_dim(
            full, one.astype(full.dtype), slot, axis=1
        )

    return jax.tree.map(scat, full_cache, single_cache)


def reset_slot_idx(cache, slot: int, value: int = 0):
    """Reset ONE batched slot's cache write position, leaving every other
    slot untouched — chunked-prefill admission claims a slot without
    scattering a fresh B=1 cache (the prompt arrives chunk by chunk).

    Stale K/V from the slot's previous occupant needs no clearing: chunk
    writes re-cover positions contiguously from 0 upward, so every cache
    entry a query position can see was rewritten by this request's own
    chunks first, and entries above the write frontier carry recorded
    positions (or index-as-position values) exceeding every live query."""
    def fix(path, leaf):
        if getattr(path[-1], "key", None) == "idx":
            return leaf.at[..., slot].set(value, mode="drop")
        return leaf

    return jax.tree_util.tree_map_with_path(fix, cache)


def compact_tree_cache(cache, pos, sel, take):
    """Compact a tree verify step's cache window onto the accepted path.

    A tree verify (verify_step(..., tree=...)) writes node j's K/V (or MLA
    latents) to its own slot pos+j while recording position pos+depth(j).
    Acceptance keeps one root-to-leaf path; its depth-d node must end up at
    slot pos+d — the contiguous slot==position layout every later prefill /
    decode / verify assumes — before the idx rollback.

    pos:  (B,) int32 — the step's base idx (the root's slot/position).
    sel:  (B, N) int32 — window gather map: slot pos+d receives the entry of
          node sel[b, d] (the accepted path's depth-d node for d < take,
          identity elsewhere; N = the tree's node count).
    take: (B,) int32 — tokens kept this step (window slots d < take stay
          live; the rest get slot_pos = -1 so a stale sibling's small
          position can never satisfy a future query's position mask — the
          rollback stale-entry safety argument for trees). A slot that took
          no part in the verify step (free, or mid-chunked-prefill) must be
          passed sel=identity and take=N: its window is then a pure no-op —
          slot_pos is *gathered* like k/v, never synthesized, so live
          identity entries keep whatever value (including -1) they had.

    Only the per-length-axis cache leaves (attn k/v/slot_pos, MLA
    ckv/krope) are touched; everything is a (B, N)-window gather/scatter,
    never a full-length pass. idx is left to rollback_cache.

    Paged caches (block dicts carrying a ``tab`` leaf — models.paged) route
    through `paged.compact_paged_block`: the same (B, N)-window gather/
    scatter, with the logical src/dst indices mapped to physical
    (page, offset) pairs through the block table — tree compaction is a
    remap of the winner nodes' page-resident entries, never a page copy."""
    pos = pos.astype(jnp.int32)
    sel = sel.astype(jnp.int32)
    take = take.astype(jnp.int32)
    n = sel.shape[1]
    src = pos[:, None] + sel                                     # (B, N)
    dst = pos[:, None] + jnp.arange(n, dtype=jnp.int32)[None, :]  # (B, N)
    live = jnp.arange(n, dtype=jnp.int32)[None, :] < take[:, None]

    def fix(key, leaf):
        if key not in ("k", "v", "slot_pos", "ckv", "krope"):
            return leaf                  # idx (rollback's job), cross xk/xv
        b = leaf.shape[1]
        bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
        idx = src.reshape((1,) + src.shape + (1,) * (leaf.ndim - 3))
        gathered = jnp.take_along_axis(leaf, idx, axis=2)
        if key == "slot_pos":
            # the accepted path's depth-d node recorded position pos+d ==
            # dst, so gathering is exactly the old synthesized value for
            # live tree entries — but leaves identity (take=N) windows of
            # non-participating slots byte-for-byte unchanged
            gathered = jnp.where(live[None], gathered, -1).astype(leaf.dtype)
        # drop, don't clamp: an identity window at the buffer frontier has
        # dst columns past max_len; clamping would re-aim them at the last
        # valid slot (harmless today only because src clamps identically —
        # see test_spec.py boundary regressions), dropping is exact
        return leaf.at[:, bidx, dst].set(gathered, mode="drop")

    def walk(node):
        if isinstance(node, dict):
            if "tab" in node:
                from .paged import compact_paged_block

                return compact_paged_block(node, src, dst, live)
            return {
                k: walk(v) if isinstance(v, (dict, list, tuple))
                else fix(k, v)
                for k, v in node.items()
            }
        if isinstance(node, (list, tuple)):
            out = [walk(v) for v in node]
            return tuple(out) if isinstance(node, tuple) else out
        return node

    return walk(cache)


def rollback_cache(cache, new_idx):
    """Reset every per-slot cache write position to `new_idx` ((B,) int32) —
    the KV rollback of speculative decoding.

    Exact for full-buffer attention/MLA caches: entries past the restored idx
    keep stale K/V, but their recorded positions exceed every future query
    position until they are overwritten, and each forward scatters its new
    K/V *before* attending — so position-masked attention never reads a stale
    entry. Ring (windowed) caches and SSM state cannot be rolled back this
    way; the serving engine refuses speculative decoding for those archs."""
    new_idx = new_idx.astype(jnp.int32)

    def fix(path, leaf):
        if getattr(path[-1], "key", None) == "idx":
            return jnp.broadcast_to(new_idx, leaf.shape).astype(leaf.dtype)
        return leaf

    return jax.tree_util.tree_map_with_path(fix, cache)
