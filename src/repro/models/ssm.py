"""Mamba2 SSD (state-space duality) block — chunked scan for train/prefill,
O(1)-state recurrence for decode.

Implementation follows the Mamba2 paper's "minimal SSD" formulation with a
sequential lax.scan over chunks (the inter-chunk recurrence is sequential
anyway); per-chunk intra attention-like term is (B, H, Q, Q) with Q=chunk.
All decays are exp of non-positive numbers → numerically safe.

in_proj / out_proj are quantizable BitLinears (the paper's mpGeMM applies to
SSM architectures through these projections — DESIGN.md §4: the technique is
attention-agnostic). Conv and the scan itself stay in bf16/fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard_act

from .common import Params, gated_rmsnorm_apply, linear_apply, linear_init, rmsnorm_init


def _sc(cfg):
    return cfg.ssm


def ssm_init(rng, cfg, spec) -> Params:
    sc = _sc(cfg)
    d = cfg.d_model
    di, n, h, p_, g = sc.d_inner, sc.d_state, sc.n_heads, sc.head_dim, sc.n_groups
    conv_ch = di + 2 * g * n
    r = jax.random.split(rng, 5)
    dt = jnp.exp(
        jax.random.uniform(r[2], (h,), jnp.float32)
        * (jnp.log(sc.dt_max) - jnp.log(sc.dt_min))
        + jnp.log(sc.dt_min)
    )
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        "in_proj": linear_init(r[0], d, 2 * di + 2 * g * n + h, cfg),
        "conv_w": jax.random.normal(r[1], (sc.d_conv, conv_ch), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": dt_bias,
        "norm": rmsnorm_init(di),
        "out_proj": linear_init(r[3], di, d, cfg),
    }


def ssm_cache_init(cfg, spec, batch: int, max_len: int, dtype) -> Params:
    sc = _sc(cfg)
    conv_ch = sc.d_inner + 2 * sc.n_groups * sc.d_state
    return {
        "conv": jnp.zeros((batch, sc.d_conv - 1, conv_ch), dtype),
        "state": jnp.zeros((batch, sc.n_heads, sc.head_dim, sc.d_state), jnp.float32),
        "idx": jnp.zeros((batch,), jnp.int32),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array, hist: jax.Array | None):
    """Depthwise causal conv1d. x: (B,S,ch); w: (K,ch); hist: (B,K-1,ch)."""
    kk = w.shape[0]
    if hist is None:
        hist = jnp.zeros((x.shape[0], kk - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([hist, x], axis=1)
    s = x.shape[1]
    acc = jnp.zeros_like(x, dtype=jnp.float32)
    for k in range(kk):  # d_conv = 4 → static unroll
        acc = acc + xp[:, k : k + s].astype(jnp.float32) * w[k]
    out = jax.nn.silu(acc + b)
    new_hist = xp[:, s:] if s >= kk - 1 else jnp.concatenate([hist[:, s:], x], axis=1)
    return out.astype(x.dtype), new_hist


def _split_zxbcdt(zxbcdt, sc):
    di, g, n, h = sc.d_inner, sc.n_groups, sc.d_state, sc.n_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * g * n]
    dt = zxbcdt[..., 2 * di + 2 * g * n :]
    return z, xbc, dt


def _ssd_chunked(x, dt, a, b_mat, c_mat, chunk, h_init):
    """x: (B,S,H,P); dt: (B,S,H); a: (H,); b_mat/c_mat: (B,S,H,N) (group-
    broadcast). Returns (y (B,S,H,P), h_final (B,H,P,N))."""
    bsz, s, h, p_ = x.shape
    n = b_mat.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (s + pad) // q

    def chunkify(t):
        return t.reshape(bsz, nc, q, *t.shape[2:]).transpose(1, 0, 2, *range(3, t.ndim + 1))

    xc, dtc, bc, cc = map(chunkify, (x, dt, b_mat, c_mat))

    def step(h_prev, xs):
        x_q, dt_q, b_q, c_q = xs                     # (B,Q,H,P), (B,Q,H), (B,Q,H,N)
        da = dt_q * a                                 # (B,Q,H) ≤ 0
        dacs = jnp.cumsum(da, axis=1)
        # inter: contribution of carried state
        y_inter = jnp.einsum(
            "bqhn,bhpn,bqh->bqhp", c_q.astype(jnp.float32), h_prev,
            jnp.exp(dacs),
        )
        # intra: masked attention-like term
        decay = jnp.exp(dacs[:, :, None, :] - dacs[:, None, :, :])   # (B,Qi,Qj,H)
        mask = jnp.tril(jnp.ones((q, q), bool))
        att = (
            jnp.einsum("bihn,bjhn->bijh", c_q.astype(jnp.float32), b_q.astype(jnp.float32))
            * decay
            * dt_q[:, None, :, :]
        )
        att = jnp.where(mask[None, :, :, None], att, 0.0)
        y_intra = jnp.einsum("bijh,bjhp->bihp", att, x_q.astype(jnp.float32))
        # state update
        da_tot = dacs[:, -1, :]                                      # (B,H)
        decay_end = jnp.exp(da_tot[:, None, :] - dacs)               # (B,Q,H)
        h_new = h_prev * jnp.exp(da_tot)[:, :, None, None] + jnp.einsum(
            "bqhn,bqhp,bqh->bhpn",
            b_q.astype(jnp.float32), x_q.astype(jnp.float32), decay_end * dt_q,
        )
        return h_new, (y_inter + y_intra).astype(x_q.dtype)

    h_fin, yc = jax.lax.scan(step, h_init, (xc, dtc, bc, cc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(bsz, s + pad, h, p_)
    return y[:, :s], h_fin


def ssm_apply(
    p: Params,
    u: jax.Array,
    *,
    cfg,
    spec,
    mode: str,
    cache: Params | None = None,
) -> tuple[jax.Array, Params | None]:
    sc = _sc(cfg)
    bsz, s, _ = u.shape
    di, n, h, p_, g = sc.d_inner, sc.d_state, sc.n_heads, sc.head_dim, sc.n_groups
    zxbcdt = linear_apply(p["in_proj"], u, cfg, mode)
    z, xbc, dt_raw = _split_zxbcdt(zxbcdt, sc)
    a = -jnp.exp(p["A_log"])                                          # (H,) < 0

    hist = cache["conv"].astype(xbc.dtype) if cache is not None else None
    xbc, new_hist = _causal_conv(xbc, p["conv_w"], p["conv_b"], hist)
    x = xbc[..., :di].reshape(bsz, s, h, p_)
    b_mat = xbc[..., di : di + g * n].reshape(bsz, s, g, n)
    c_mat = xbc[..., di + g * n :].reshape(bsz, s, g, n)
    rep = h // g
    b_h = jnp.repeat(b_mat, rep, axis=2)                              # (B,S,H,N)
    c_h = jnp.repeat(c_mat, rep, axis=2)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)

    if cache is not None and s == 1:
        # ---- recurrent decode --------------------------------------------
        h_prev = cache["state"]
        da = jnp.exp(dt[:, 0] * a)                                    # (B,H)
        upd = jnp.einsum(
            "bhn,bhp,bh->bhpn",
            b_h[:, 0].astype(jnp.float32), x[:, 0].astype(jnp.float32), dt[:, 0],
        )
        h_new = h_prev * da[:, :, None, None] + upd
        y = jnp.einsum("bhn,bhpn->bhp", c_h[:, 0].astype(jnp.float32), h_new)
        y = y[:, None]                                                # (B,1,H,P)
        h_fin = h_new
    else:
        h_init = (
            cache["state"] if cache is not None
            else jnp.zeros((bsz, h, p_, n), jnp.float32)
        )
        y, h_fin = _ssd_chunked(x, dt, a, b_h, c_h, sc.chunk, h_init)

    y = y.astype(u.dtype) + x * p["D"][:, None].astype(u.dtype)
    y = y.reshape(bsz, s, di)
    y = gated_rmsnorm_apply(p["norm"], y, z, cfg.norm_eps)
    out = linear_apply(p["out_proj"], y, cfg, mode)

    new_cache = None
    if cache is not None:
        new_cache = {
            "conv": new_hist.astype(cache["conv"].dtype),
            "state": shard_act(h_fin, "ssm_state"),
            "idx": cache["idx"] + s,
        }
    return out, new_cache
