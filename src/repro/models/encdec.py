"""Encoder-decoder (Whisper-style) built from the same staged blocks.

The audio conv frontend is a STUB per the assignment: `input_specs()` feeds
precomputed frame embeddings (B, S/enc_frame_ratio, d_model); we add
sinusoidal positions (Whisper uses fixed sinusoids) and run a non-causal
encoder stack. The decoder is a standard causal LM whose layers carry
cross-attention to the encoder output (cross-KV cached at prefill)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec
from .common import Params, rmsnorm_apply, rmsnorm_init
from .decoder import (
    _stage_apply,
    _stage_init,
    compress_layout,
    init_lm,
    lm_loss,
)


def encoder_specs(cfg) -> tuple[LayerSpec, ...]:
    # no RoPE (sinusoidal abs positions), full bidirectional attention
    return tuple(
        LayerSpec(mixer="attn", rope_theta=0.0, ffn="dense")
        for _ in range(cfg.enc_layers)
    )


def sinusoid_positions(s: int, d: int, dtype=jnp.float32) -> jax.Array:
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-dim * (jnp.log(10000.0) / d))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def encdec_init(rng, cfg) -> Params:
    enc_stages = compress_layout(encoder_specs(cfg))
    enc = {
        "stages": [
            _stage_init(jax.random.fold_in(rng, 500 + si), cfg, pat, reps)
            for si, (pat, reps) in enumerate(enc_stages)
        ],
        "final_norm": rmsnorm_init(cfg.d_model),
    }
    dec = init_lm(jax.random.fold_in(rng, 1), cfg)
    return {"encoder": enc, "decoder": dec}


def encode(params: Params, frames: jax.Array, cfg, *, mode: str = "train"):
    """frames: (B, S_enc, d_model) stub embeddings → encoder output."""
    b, s, d = frames.shape
    x = frames.astype(jnp.dtype(cfg.dtype)) + sinusoid_positions(
        s, d, jnp.dtype(cfg.dtype)
    )
    aux = jnp.zeros((), jnp.float32)
    for si, (pat, reps) in enumerate(compress_layout(encoder_specs(cfg))):
        x, aux, _ = _stage_apply(
            params["encoder"]["stages"][si], x, aux, cfg=cfg, pattern=pat,
            mode=mode, cache=None, enc_out=None, causal=False,
        )
    return rmsnorm_apply(params["encoder"]["final_norm"], x, cfg.norm_eps)


def encdec_loss(params, frames, tokens, labels, cfg, *, mode="train", loss_mask=None):
    enc_out = encode(params, frames, cfg, mode=mode)
    return lm_loss(
        params["decoder"], tokens, labels, cfg, mode=mode,
        enc_out=enc_out, loss_mask=loss_mask,
    )
