"""repro.models — composable model zoo (attention/MLA/SSD mixers, dense/MoE
FFNs, enc-dec) with train (QAT) and serve (Vec-LUT packed) modes."""
from .common import linear_apply, linear_init, rmsnorm_apply, rope
from .decoder import (
    compact_tree_cache,
    compress_layout,
    decode_step,
    init_cache,
    init_lm,
    lm_hidden,
    lm_logits,
    lm_loss,
    prefill,
    prefill_bucket,
    prefill_into_slot,
    reset_slot_idx,
    rollback_cache,
    scatter_slot_cache,
    verify_step,
)
from .encdec import encdec_init, encdec_loss, encode
from .convert import pack_params, packed_param_bytes, param_count
from .paged import (
    gather_page,
    restore_page,
    scrub_pages,
    set_block_tables,
)

__all__ = [
    "linear_apply", "linear_init", "rmsnorm_apply", "rope",
    "compact_tree_cache", "compress_layout", "decode_step", "init_cache",
    "init_lm", "lm_hidden",
    "lm_logits", "lm_loss", "prefill", "prefill_bucket", "prefill_into_slot",
    "reset_slot_idx", "rollback_cache", "scatter_slot_cache", "verify_step",
    "encdec_init", "encdec_loss", "encode",
    "pack_params", "packed_param_bytes", "param_count",
    "gather_page", "restore_page", "scrub_pages", "set_block_tables",
]
