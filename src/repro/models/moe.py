"""Mixture-of-Experts FFN: top-k routing, capacity-based dispatch, shared
experts, and DeepSeek-style aux-loss-free bias routing.

Dispatch is scatter-based (no (T, E, C) one-hot einsum): tokens compute their
position-in-expert via a cumsum over the token axis, then scatter into an
(E, C, d) buffer. Under pjit the buffer is sharded expert-parallel over the
`model` ('expert') axis while tokens are batch-sharded — XLA SPMD lowers the
scatter/gather pair into the expert all-to-all. Capacity overflow drops
tokens (standard GShard semantics); the residual stream carries them.

Expert FFNs are quantizable BitLinears (batched over E) — in the paper's
terms, each expert matmul is an mpGeMM served by the Vec-LUT kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard_act

from .common import Params, linear_apply, linear_batched_apply, linear_init


def _expert_ffn_init(rng, d: int, f: int, e: int, cfg) -> Params:
    """e stacked SwiGLU experts: w1/w3 (E, d, f), w2 (E, f, d)."""
    r = jax.random.split(rng, 3)

    def stack(key, k_in, m_out):
        ws = jax.vmap(lambda kk: linear_init(kk, k_in, m_out, cfg))(
            jax.random.split(key, e)
        )
        return ws

    return {"w1": stack(r[0], d, f), "w3": stack(r[1], d, f), "w2": stack(r[2], f, d)}


def moe_init(rng, cfg) -> Params:
    mc = cfg.moe
    d = cfg.d_model
    r = jax.random.split(rng, 3)
    p: Params = {
        # router stays high-precision (small, accuracy-critical)
        "router": {"w": jax.random.normal(r[0], (d, mc.n_experts), jnp.float32) * 0.02},
        "experts": _expert_ffn_init(r[1], d, mc.d_ff_expert, mc.n_experts, cfg),
    }
    if mc.router_aux_free:
        p["router_bias"] = jnp.zeros((mc.n_experts,), jnp.float32)
    if mc.n_shared:
        f_sh = (mc.d_ff_shared or mc.d_ff_expert) * mc.n_shared
        rs = jax.random.split(r[2], 3)
        p["shared"] = {
            "w1": linear_init(rs[0], d, f_sh, cfg),
            "w3": linear_init(rs[1], d, f_sh, cfg),
            "w2": linear_init(rs[2], f_sh, d, cfg),
        }
    return p


def moe_apply(
    p: Params, x: jax.Array, cfg, mode: str, n_blocks: int | None = None
) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) → (out, aux_loss).

    Dispatch is computed in `n_blocks` independent token blocks (default: the
    data-shard count from the sharding context). Positions-in-expert are
    *block-local*, so the scatter into and gather out of the (nb, E, C_b, d)
    buffer never crosses the data axis — the only cross-device movement is
    the expert-parallel exchange over `model`. With global positions (the
    naive form) the backward of the scatter all-reduces the full dispatch
    buffer per layer: measured 3.8× WORSE collectives on jamba train_4k
    (EXPERIMENTS §Perf 4.2).
    """
    from repro.dist.sharding import dispatch_blocks

    mc = cfg.moe
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    e, k = mc.n_experts, mc.top_k
    nb = n_blocks if n_blocks is not None else dispatch_blocks(t)

    logits = (xt.astype(jnp.float32) @ p["router"]["w"])             # (T, E)
    gate_probs = jax.nn.softmax(logits, axis=-1)
    sel_scores = logits + p["router_bias"] if mc.router_aux_free else logits
    _, top_idx = jax.lax.top_k(sel_scores, k)                        # (T, k)
    top_p = jnp.take_along_axis(gate_probs, top_idx, axis=-1)        # (T, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # ---- block-local capacity + position-in-expert ------------------------
    tb = t // nb
    cap = max(
        -(-tb * k * mc.capacity_factor // e).__int__(), min(tb * k, 16), 1
    )
    onehot = jax.nn.one_hot(top_idx, e, dtype=jnp.int32)             # (T, k, E)
    flat = onehot.reshape(nb, tb * k, e)
    pos = jnp.cumsum(flat, axis=1) - 1                               # per-block
    pos = jnp.sum(pos * flat, axis=-1).reshape(t, k)                 # (T, k)
    keep = pos < cap

    # ---- block-local scatter into the (nb, E, C_b, d) buffer --------------
    eidx = top_idx.reshape(nb, tb * k)
    cidx = jnp.clip(pos.reshape(nb, tb * k), 0, cap - 1)
    keep_f = keep.reshape(nb, tb * k, 1).astype(xt.dtype)
    src = jnp.repeat(xt, k, axis=0).reshape(nb, tb * k, d) * keep_f

    def scat(ei, ci, sr):
        return jnp.zeros((e, cap, d), xt.dtype).at[ei, ci].add(sr, mode="drop")

    buf = jax.vmap(scat)(eidx, cidx, src)                            # (nb,E,C,d)
    buf = shard_act(buf, "moe_buf_blocked")
    # EP layout: experts on 'model', blocks on ('pod','data')
    buf = buf.transpose(1, 0, 2, 3).reshape(e, nb * cap, d)
    buf = shard_act(buf, "moe_buf")  # EP all-to-all inserted by SPMD here

    # ---- expert computation (batched BitLinear mpGeMMs) ------------------
    h1 = linear_batched_apply(p["experts"]["w1"], buf, cfg, mode)
    h3 = linear_batched_apply(p["experts"]["w3"], buf, cfg, mode)
    h = jax.nn.silu(h1) * h3
    eo = shard_act(
        linear_batched_apply(p["experts"]["w2"], h, cfg, mode), "moe_buf"
    )                                                                # (E,nb*C,d)

    # ---- block-local gather back + combine --------------------------------
    eo_b = shard_act(
        eo.reshape(e, nb, cap, d).transpose(1, 0, 2, 3), "moe_buf_blocked"
    )
    back = jax.vmap(lambda eb, ei, ci: eb[ei, ci])(eo_b, eidx, cidx)
    back = back * keep_f
    back = back.reshape(t, k, d) * top_p.reshape(t, k)[..., None].astype(eo.dtype)
    out = jnp.sum(back, axis=1)

    if mc.n_shared:
        sh = p["shared"]
        hs = jax.nn.silu(linear_apply(sh["w1"], xt, cfg, mode)) * linear_apply(
            sh["w3"], xt, cfg, mode
        )
        out = out + linear_apply(sh["w2"], hs, cfg, mode)

    # ---- aux losses (train only; 0 when aux-free routing) -----------------
    if mode == "train" and not mc.router_aux_free:
        me = jnp.mean(gate_probs, axis=0)                            # (E,)
        ce = jnp.mean(
            jnp.sum(jax.nn.one_hot(top_idx[:, 0], e, dtype=jnp.float32), axis=0)
        ) / t * e
        frac = jnp.sum(
            jax.nn.one_hot(top_idx, e, dtype=jnp.float32), axis=(0, 1)
        ) / (t * k)
        aux = mc.aux_loss_weight * e * jnp.sum(frac * me)
        zloss = mc.router_z_weight * jnp.mean(
            jnp.square(jax.nn.logsumexp(logits, axis=-1))
        )
        aux = aux + zloss
    else:
        aux = jnp.zeros((), jnp.float32)
    return out.reshape(b, s, d).astype(x.dtype), aux


def dense_ffn_init(rng, cfg, d_ff: int | None = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    r = jax.random.split(rng, 3)
    return {
        "w1": linear_init(r[0], d, f, cfg),
        "w3": linear_init(r[1], d, f, cfg),
        "w2": linear_init(r[2], f, d, cfg),
    }


def dense_ffn_apply(p: Params, x: jax.Array, cfg, mode: str) -> jax.Array:
    h = jax.nn.silu(linear_apply(p["w1"], x, cfg, mode)) * linear_apply(
        p["w3"], x, cfg, mode
    )
    return linear_apply(p["w2"], h, cfg, mode)
