"""Paged KV cache primitives: a physical page pool + per-slot block tables.

Layout (per attention/MLA block, stacked over layer reps by the stage init):

  * pool leaves — ``k``/``v``: ``(n_pages, page_size, kv, hd)``,
    ``slot_pos``: ``(n_pages, page_size)`` (GQA), or ``ckv``/``krope``:
    ``(n_pages, page_size, ·)`` (MLA). One physical pool is shared by every
    slot; ``n_pages`` *includes* the reserved null page 0.
  * ``tab``: ``(batch, max_len // page_size)`` int32 block table — entry
    ``p`` of slot ``b``'s row is the physical page holding that slot's
    logical positions ``[p*page_size, (p+1)*page_size)``; 0 = unmapped.
  * ``idx``: ``(batch,)`` per-slot write position, identical to the dense
    cache's — rollback stays idx-only (``models.rollback_cache`` unchanged).

Null-page discipline: physical page 0 is never allocated. On the READ side
an unmapped table entry gathers page 0, whose ``slot_pos`` is all ``-1``
(GQA position mask) and whose stale MLA content sits at logical positions
beyond every live query (index-as-position + contiguous writes). On the
WRITE side an unmapped or out-of-range target is remapped to ``n_pages``
(one past the pool) so the scatter's ``mode="drop"`` discards it — writing
through a null entry would corrupt the shared page 0.

Stale-entry safety mirrors the dense rollback argument, with one paging
addition: a recycled page keeps its previous owner's content, so the host
pager scrubs ``slot_pos = -1`` on every fresh GQA allocation
(``scrub_pages``). MLA needs no scrub — index-as-position plus
write-from-page-start contiguity keeps stale latents at logical positions
above every live query until overwritten.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

#: per-page cache leaves (everything except tab/idx and cross-attn xk/xv)
POOL_KEYS = ("k", "v", "slot_pos", "ckv", "krope")


def page_phys(tab, slots, page_size: int, n_pages: int, *, for_write: bool):
    """Map logical per-slot cache indices ``slots`` (B, S) to physical
    ``(page, offset)`` pairs under block table ``tab`` (B, cap).

    for_write=True sends unmapped / out-of-range targets to ``n_pages`` so a
    ``mode="drop"`` scatter discards them; for_write=False sends them to the
    null page 0 (read-safe: invalidated slot_pos / beyond-query positions)."""
    cap = tab.shape[1]
    page_l = jnp.floor_divide(slots, page_size)
    off = jnp.mod(slots, page_size)
    in_bounds = (slots >= 0) & (page_l < cap)
    pg = jnp.take_along_axis(tab, jnp.clip(page_l, 0, cap - 1), axis=1)
    if for_write:
        pg = jnp.where(in_bounds & (pg > 0), pg, n_pages)
    else:
        pg = jnp.where(in_bounds & (pg > 0), pg, 0)
    return pg, off


def page_scatter(pool, tab, slots, values):
    """Scatter ``values`` (B, S, ...) into the pool (n_pages, ps, ...) at the
    physical locations of logical indices ``slots`` (B, S) under ``tab``.
    Unmapped / out-of-range targets are dropped (see module docstring)."""
    pg, off = page_phys(
        tab, slots, pool.shape[1], pool.shape[0], for_write=True
    )
    return pool.at[pg, off].set(values.astype(pool.dtype), mode="drop")


def page_view(pool, tab):
    """Gather the per-slot logical view (B, cap*ps, ...) from the pool —
    the paged read path: downstream position-masked attention (sdpa, the
    absorbed MLA einsums, tree gates) runs on this view unchanged."""
    g = pool[tab]                                     # (B, cap, ps, ...)
    return g.reshape((g.shape[0], g.shape[1] * g.shape[2]) + g.shape[3:])


def set_block_tables(cache, tab):
    """Broadcast a fresh (batch, cap) int32 block table into every ``tab``
    leaf of a paged cache pytree (the host pager's flush point)."""
    tab = jnp.asarray(tab, jnp.int32)

    def fix(path, leaf):
        if getattr(path[-1], "key", None) == "tab":
            return jnp.broadcast_to(tab, leaf.shape)
        return leaf

    return jax.tree_util.tree_map_with_path(fix, cache)


def scrub_pages(cache, pages):
    """Invalidate ``slot_pos`` on freshly allocated physical pages
    (``pages``: (K,) int32, padded with >= n_pages sentinels — dropped).

    This is the paging leg of the stale-entry safety argument: a recycled
    page still holds its previous owner's recorded positions, which could
    otherwise unmask garbage K/V for a new owner whose queries pass them.
    MLA pools carry no slot_pos and need no scrub (index-as-position)."""
    pages = jnp.asarray(pages, jnp.int32)

    def fix(path, leaf):
        if getattr(path[-1], "key", None) == "slot_pos" and leaf.ndim == 3:
            # (reps, n_pages, ps) pool leaf — dense slot_pos is 2-D
            return leaf.at[:, pages].set(-1, mode="drop")
        return leaf

    return jax.tree_util.tree_map_with_path(fix, cache)


def compact_paged_block(bd, src, dst, live):
    """Tree-verify window compaction for one paged block dict (stacked over
    reps): gather the accepted path's entries from their node slots and
    scatter them onto contiguous slots, both through the block table.

    src/dst: (B, N) logical indices (models.compact_tree_cache computes
    them); live: (B, N) bool — slots >= take get slot_pos = -1. Unmapped
    sources read the null page (slot_pos -1, never attended); unmapped
    destinations are dropped."""
    tab = bd["tab"][0]                     # (B, cap) — identical across reps
    out = dict(bd)
    for key in POOL_KEYS:
        if key not in bd:
            continue
        leaf = bd[key]                     # (reps, n_pages, ps, ...)
        n_pages, ps = leaf.shape[1], leaf.shape[2]
        pg_s, off_s = page_phys(tab, src, ps, n_pages, for_write=False)
        pg_d, off_d = page_phys(tab, dst, ps, n_pages, for_write=True)
        gathered = leaf[:, pg_s, off_s]    # (reps, B, N, ...)
        if key == "slot_pos":
            gathered = jnp.where(live[None], gathered, -1).astype(leaf.dtype)
        out[key] = leaf.at[:, pg_d, off_d].set(gathered, mode="drop")
    return out


def gather_page(cache, page: int):
    """Copy one physical page's content (every pool leaf, every layer) to
    host numpy — the offload tier's page-out. Returns a nested
    [stage][block][leaf] structure mirroring the cache."""
    out = []
    for stage in cache:
        so = {}
        for bname, bd in stage.items():
            if "tab" in bd:
                so[bname] = {k: bd[k][:, page] for k in POOL_KEYS if k in bd}
        out.append(so)
    return jax.device_get(out)


def restore_page(cache, page: int, data):
    """Write a previously gathered page back into physical page ``page`` —
    the offload tier's page-in (the pager re-points the radix node here)."""
    new = []
    for stage, sdata in zip(cache, data):
        so = {}
        for bname, bd in stage.items():
            if bname in sdata:
                nd = dict(bd)
                for k, arr in sdata[bname].items():
                    # page is a host int the pager allocated < n_pages
                    nd[k] = nd[k].at[:, page].set(
                        jnp.asarray(arr).astype(nd[k].dtype),
                        mode="promise_in_bounds",
                    )
                so[bname] = nd
            else:
                so[bname] = bd
        new.append(so)
    return new
