"""Transformer/SSM block assembly: pre-norm residual blocks whose token mixer
and FFN are chosen by a LayerSpec (attn | mla | ssm × dense | moe | none,
with optional cross-attention for enc-dec decoders)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import (
    attn_apply,
    attn_cache_init,
    attn_init,
    cross_attn_apply,
    cross_attn_init,
    cross_attn_kv,
)
from .common import Params, rmsnorm_apply, rmsnorm_init
from .mla import mla_apply, mla_cache_init, mla_init
from .moe import dense_ffn_apply, dense_ffn_init, moe_apply, moe_init
from .ssm import ssm_apply, ssm_cache_init, ssm_init


def block_init(rng, cfg, spec) -> Params:
    r = jax.random.split(rng, 4)
    mixer_init = {"attn": attn_init, "mla": mla_init, "ssm": ssm_init}[spec.mixer]
    p: Params = {
        "mixer_norm": rmsnorm_init(cfg.d_model),
        "mixer": mixer_init(r[0], cfg, spec),
    }
    if spec.cross_attn:
        p["cross_norm"] = rmsnorm_init(cfg.d_model)
        p["cross"] = cross_attn_init(r[1], cfg)
    if spec.ffn == "dense":
        p["ffn_norm"] = rmsnorm_init(cfg.d_model)
        p["ffn"] = dense_ffn_init(r[2], cfg, spec.d_ff or cfg.d_ff)
    elif spec.ffn == "moe":
        p["ffn_norm"] = rmsnorm_init(cfg.d_model)
        p["ffn"] = moe_init(r[3], cfg)
    return p


def block_cache_init(
    cfg, spec, batch: int, max_len: int, dtype, enc_len: int = 0,
    page_size: int = 0, n_pages: int = 0,
):
    if page_size and spec.mixer == "ssm":
        raise ValueError(
            "ssm layers carry recurrent state, not per-position KV — there "
            "is nothing page-granular to own, so paged caching refuses them"
        )
    cache_init = {
        "attn": attn_cache_init,
        "mla": mla_cache_init,
        "ssm": ssm_cache_init,
    }[spec.mixer]
    if page_size:
        c = cache_init(
            cfg, spec, batch, max_len, dtype,
            page_size=page_size, n_pages=n_pages,
        )
    else:
        c = cache_init(cfg, spec, batch, max_len, dtype)
    if spec.cross_attn:
        kv, hd = cfg.n_kv_heads, cfg.head_dim
        c["xk"] = jnp.zeros((batch, enc_len, kv, hd), dtype)
        c["xv"] = jnp.zeros((batch, enc_len, kv, hd), dtype)
    return c


def block_apply(
    p: Params,
    x: jax.Array,
    *,
    cfg,
    spec,
    mode: str,
    cache: Params | None = None,
    enc_out: jax.Array | None = None,
    causal: bool = True,
    verify: bool = False,
    tree=None,
    prefill_resume: bool = False,
):
    """→ (x, new_cache, aux_loss)."""
    h = rmsnorm_apply(p["mixer_norm"], x, cfg.norm_eps)
    if spec.mixer == "attn":
        y, new_cache = attn_apply(
            p["mixer"], h, cfg=cfg, spec=spec, mode=mode, cache=cache,
            causal=causal, verify=verify, tree=tree,
        )
    elif spec.mixer == "mla":
        y, new_cache = mla_apply(
            p["mixer"], h, cfg=cfg, spec=spec, mode=mode, cache=cache,
            verify=verify, tree=tree, prefill_resume=prefill_resume,
        )
    else:
        if verify:
            raise ValueError(
                "multi-token verification needs a rollbackable cache; "
                "ssm mixers carry recurrent state and cannot be verified"
            )
        y, new_cache = ssm_apply(p["mixer"], h, cfg=cfg, spec=spec, mode=mode, cache=cache)
    x = x + y

    if spec.cross_attn:
        hc = rmsnorm_apply(p["cross_norm"], x, cfg.norm_eps)
        if cache is not None:
            if enc_out is not None:  # prefill: compute + store cross KV
                xk, xv = cross_attn_kv(p["cross"], enc_out, cfg, mode)
                new_cache = dict(new_cache or {})
                new_cache["xk"], new_cache["xv"] = (
                    xk.astype(cache["xk"].dtype), xv.astype(cache["xv"].dtype),
                )
            else:  # decode: reuse cached cross KV
                new_cache = dict(new_cache or {})
                new_cache["xk"], new_cache["xv"] = cache["xk"], cache["xv"]
            xk, xv = new_cache["xk"], new_cache["xv"]
        else:
            xk, xv = cross_attn_kv(p["cross"], enc_out, cfg, mode)
        x = x + cross_attn_apply(p["cross"], hc, xk, xv, cfg, mode)

    aux = jnp.zeros((), jnp.float32)
    if spec.ffn != "none":
        hf = rmsnorm_apply(p["ffn_norm"], x, cfg.norm_eps)
        if spec.ffn == "moe":
            y, aux = moe_apply(p["ffn"], hf, cfg, mode)
        else:
            y = dense_ffn_apply(p["ffn"], hf, cfg, mode)
        x = x + y
    return x, new_cache, aux
