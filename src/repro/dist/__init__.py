"""repro.dist — distribution layer: sharding rules, gradient compression,
fault tolerance.

  sharding         — name-based PartitionSpec rules for params / optimizer
                     states / serving caches / batches, plus the activation
                     constraint helper `shard_act` and the trace-time
                     `use_sharding_ctx` context the models read.
  compression      — int8 + error-feedback gradient compression for the
                     accumulation boundary and a compressed-psum pattern.
  fault_tolerance  — preemption guard, straggler monitor, bounded restarts.
"""
from . import compression, fault_tolerance, sharding

__all__ = ["compression", "fault_tolerance", "sharding"]
