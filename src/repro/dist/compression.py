"""Gradient compression with error feedback (int8 over the wire).

Used at the gradient-accumulation boundary (train/trainer.py): large leaves
are compressed to blockwise-int8 `QTensor`s (the same shape-preserving
absmax-per-row format the optimizer states use, so shardings are inherited),
and the quantization residual is carried in an error-feedback tree so the
signal drains over steps instead of being lost. Small leaves (norms, biases)
pass through uncompressed — their bytes don't matter and their numerics do.

`compressed_psum` is the collective-side pattern: quantize → sum →
dequantize, bounding the per-shard error by rowmax/127.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim import QTensor, dequantize_blockwise, quantize_blockwise

#: leaves smaller than this stay uncompressed (matches optim.adamw.SMALL)
SMALL = 4096


def _is_q(x) -> bool:
    return isinstance(x, QTensor)


def ef_init(grads):
    """Zero error-feedback tree shaped like the gradients (f32)."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_tree(grads, ef):
    """(grads, ef) → (compressed, new_ef).

    Per leaf: x = g + ef; large leaves become QTensor(x) with
    new_ef = x - dequant(QTensor(x)) (exact error accounting), small leaves
    pass through with zero error.
    """
    def one(g, e):
        x = g.astype(jnp.float32) + e
        if x.size >= SMALL and x.ndim >= 1:
            q = quantize_blockwise(x)
            return q, x - dequantize_blockwise(q)
        return x, jnp.zeros_like(x)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    comp = jax.tree.unflatten(treedef, [p[0] for p in pairs])
    new_ef = jax.tree.unflatten(treedef, [p[1] for p in pairs])
    return comp, new_ef


def decompress_tree(comp):
    """Inverse of :func:`compress_tree`'s quantization (f32 tree)."""
    return jax.tree.map(
        lambda l: dequantize_blockwise(l) if _is_q(l) else l,
        comp,
        is_leaf=_is_q,
    )


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-compressed all-reduce: each shard quantizes blockwise before the
    sum, bounding wire precision at 8 bits (error ≤ rowmax/127 per shard)."""
    q = quantize_blockwise(x)
    return jax.lax.psum(dequantize_blockwise(q), axis_name)
