"""Fault tolerance: preemption guard, straggler monitor, bounded restarts."""
from __future__ import annotations

import dataclasses
import itertools
import signal
import statistics
import time
from typing import Callable, Sequence


class PreemptionGuard:
    """Cooperative preemption flag.

    The trainer polls ``.requested`` each step and checkpoints + exits when
    set. With ``install=True`` the guard hooks SIGTERM/SIGINT (the preemption
    notice on most schedulers); tests set ``.requested`` directly.
    """

    def __init__(self, install: bool = False, signals=(signal.SIGTERM, signal.SIGINT)):
        self.requested = False
        if install:
            for s in signals:
                signal.signal(s, self._handler)

    def _handler(self, signum, frame):  # pragma: no cover - signal path
        self.requested = True


@dataclasses.dataclass
class StragglerEvent:
    step: int
    host: int
    ratio: float  # host step time / median step time


class StragglerMonitor:
    """Flags hosts that run persistently slower than the fleet median.

    A host whose step time exceeds ``threshold × median`` for ``patience``
    consecutive steps raises a :class:`StragglerEvent` (appended to
    ``.events`` and passed to ``on_straggler``). Needs ≥ 2 hosts to compare;
    single-host runs record nothing.
    """

    def __init__(
        self,
        n_hosts: int,
        threshold: float = 2.0,
        patience: int = 2,
        on_straggler: Callable[[StragglerEvent], None] | None = None,
    ):
        self.n_hosts = n_hosts
        self.threshold = threshold
        self.patience = patience
        self.on_straggler = on_straggler
        self.events: list[StragglerEvent] = []
        self._strikes = [0] * n_hosts

    def record(self, step: int, times: Sequence[float]) -> None:
        if self.n_hosts < 2 or len(times) != self.n_hosts:
            return
        med = max(statistics.median(times), 1e-12)
        for host, t in enumerate(times):
            ratio = t / med
            if ratio > self.threshold:
                self._strikes[host] += 1
            else:
                self._strikes[host] = 0
            if self._strikes[host] >= self.patience:
                ev = StragglerEvent(step=step, host=host, ratio=ratio)
                self.events.append(ev)
                if self.on_straggler is not None:
                    self.on_straggler(ev)


def run_with_restarts(
    fn: Callable[[int], None],
    max_restarts: int = 3,
    sleep: Callable[[float], None] = time.sleep,
    retryable: tuple[type[BaseException], ...] = (RuntimeError, OSError),
) -> int:
    """Run ``fn(attempt)`` with bounded restart supervision.

    Retries only *fault-shaped* errors (``retryable``; bugs like ValueError
    propagate immediately) with exponential backoff, giving up by re-raising
    once ``max_restarts`` restarts are exhausted. Returns the attempt index
    that succeeded.
    """
    for attempt in itertools.count():
        try:
            fn(attempt)
            return attempt
        except retryable:
            if attempt >= max_restarts:
                raise
            sleep(min(2.0 ** attempt, 60.0))
    raise AssertionError("unreachable")  # pragma: no cover
