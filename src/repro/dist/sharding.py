"""Sharding rules for the production mesh (pod × data × model).

Everything here is *name-based*: a rule looks at the trailing pytree path
names (the param convention from repro/models/common.py) and the trailing
dims of the leaf, so the same rule covers scanned stacks with any number of
leading stage/repeat dims. Every axis assignment is guarded by divisibility —
a dim that doesn't divide evenly over the proposed mesh axes is replicated
rather than unevenly sharded.

Layouts the rules understand:

  dense quantizable linear ``qw``  (..., K, M)
      column-parallel (wq/wk/wv/w1/w3/…): K → (pod, data) FSDP, M → model
      row-parallel    (wo/w2/out_proj):   K → model,        M → (pod, data)
  packed serving weight ``pw.packed{5,4}``  (..., M, K//g)
      column-parallel: M → model, K-groups → (pod, data)
      row-parallel:    K-groups inherit K's ``model`` axis, M replicated
      (the Vec-LUT kernel contracts over K-groups; the packed layout is
      transposed w.r.t. ``qw``, so the K axis keeps its dense assignment)
  expert-stacked linears  (..., E, K, M): E → model (EP), K → (pod, data)
  embedding ``table``  (V, D): V → model, D → (pod, data)
  everything else (norm scales, biases, router) replicated.

Optimizer moments inherit the parameter's spec; blockwise-int8 ``QTensor``
moments are shape-preserving so ``q`` inherits directly and ``scale`` drops
the last dim's axis. Serving caches shard batch over (pod, data), falling
back to sequence-parallel over ``data`` when B = 1 (long-context decode),
heads/SSM-heads over ``model``.

``use_sharding_ctx(mesh, cfg)`` installs the (mesh, cfg) pair that
``shard_act`` / ``dispatch_blocks`` read at trace time; outside the context
both are no-ops, so models run unmodified on a single device.
"""
from __future__ import annotations

import contextlib
from typing import Any, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# --------------------------------------------------------------------------
# mesh helpers (duck-typed: only axis_names + shape, so shape-only fakes work)
# --------------------------------------------------------------------------
_BATCH_AXES = ("pod", "data")
_ROW_PARALLEL = frozenset({"wo", "w2", "out_proj"})
_PACKED_KEYS = frozenset({"packed5", "packed4"})


def _batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in _BATCH_AXES if a in mesh.axis_names)


def _axis_size(mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    out = 1
    for n in names:
        if n in mesh.axis_names:
            out *= mesh.shape[n]
    return out


def _norm(axes: Sequence[str]):
    """() → None, 1-tuple → bare name, else tuple (canonical P entries)."""
    axes = tuple(axes)
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


def _take(mesh, size: int, axes) -> Any:
    """Axes entry if `size` divides evenly over them (and they exist)."""
    if isinstance(axes, str):
        axes = (axes,)
    axes = tuple(a for a in axes if a in mesh.axis_names)
    n = _axis_size(mesh, axes)
    if not axes or n <= 1 or size % n:
        return None
    return _norm(axes)


def _key_name(entry) -> str:
    """Path-entry → name for DictKey/GetAttrKey/SequenceKey/test doubles."""
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "name"):
        return str(entry.name)
    if hasattr(entry, "idx"):
        return f"[{entry.idx}]"
    return str(entry)


def _names(path) -> list[str]:
    return [_key_name(e) for e in path]


# --------------------------------------------------------------------------
# parameter rules
# --------------------------------------------------------------------------
def param_spec(path, leaf, mesh, cfg) -> P:
    names = _names(path)
    shape = tuple(leaf.shape)
    nd = len(shape)
    spec: list = [None] * nd
    if not names or nd == 0:
        return P(*spec)
    term = names[-1]
    batch = _batch_axes(mesh)

    if term == "table" and nd >= 2:  # embedding (V, D)
        spec[-2] = _take(mesh, shape[-2], "model")
        spec[-1] = _take(mesh, shape[-1], batch)
    elif term in ("qw", "w") and nd >= 2:
        owner = names[-2] if len(names) >= 2 else ""
        if owner == "router":
            pass  # small, accuracy-critical: replicated
        elif "experts" in names and nd >= 3:
            spec[-3] = _take(mesh, shape[-3], "model")      # EP over experts
            spec[-2] = _take(mesh, shape[-2], batch)        # FSDP over K
        elif owner in _ROW_PARALLEL:
            spec[-2] = _take(mesh, shape[-2], "model")
            spec[-1] = _take(mesh, shape[-1], batch)
        else:
            spec[-2] = _take(mesh, shape[-2], batch)
            spec[-1] = _take(mesh, shape[-1], "model")
    elif term in _PACKED_KEYS and nd >= 2:
        owner = names[-3] if len(names) >= 3 else ""        # [.., owner, pw, packedX]
        if "experts" in names and nd >= 3:
            spec[-3] = _take(mesh, shape[-3], "model")
            spec[-1] = _take(mesh, shape[-1], batch)
        elif owner in _ROW_PARALLEL:
            spec[-1] = _take(mesh, shape[-1], "model")
        else:
            spec[-2] = _take(mesh, shape[-2], "model")
            spec[-1] = _take(mesh, shape[-1], batch)
    elif term == "scale" and "pw" in names:
        owner = names[-3] if len(names) >= 3 else ""
        if "experts" in names and nd >= 2:
            spec[-2] = _take(mesh, shape[-2], "model")
        elif owner not in _ROW_PARALLEL:
            spec[-1] = _take(mesh, shape[-1], "model")
    # everything else (norms, biases, conv, dt, router_bias): replicated
    return P(*spec)


class _Fake:
    __slots__ = ("shape",)

    def __init__(self, shape):
        self.shape = shape


def opt_spec(path, leaf, mesh, cfg) -> P:
    """Optimizer-state rule: moments inherit the parameter's spec.

    QTensor int8 moments are shape-preserving, so the ``q`` leaf inherits the
    parameter spec verbatim; the per-row ``scale`` (shape[:-1]) drops the
    last dim's axis.
    """
    names = _names(path)
    if not names:
        return P(*([None] * len(leaf.shape)))
    term = names[-1]
    if term == "q":
        return param_spec(path[:-1], leaf, mesh, cfg)
    if (
        term == "scale"
        and len(names) >= 2
        and names[-2] in frozenset({"qw", "w", "table"}) | _PACKED_KEYS
    ):
        # QTensor scale: recompute the param spec with a dummy (always
        # divisible) trailing dim, then drop it.
        dummy = _axis_size(mesh, tuple(mesh.axis_names)) * 128
        full = param_spec(path[:-1], _Fake(tuple(leaf.shape) + (dummy,)), mesh, cfg)
        return P(*tuple(full)[:-1])
    return param_spec(path, leaf, mesh, cfg)


# --------------------------------------------------------------------------
# serving-cache + batch rules
# --------------------------------------------------------------------------
def cache_spec(path, leaf, mesh, cfg) -> P:
    names = _names(path)
    shape = tuple(leaf.shape)
    nd = len(shape)
    spec: list = [None] * nd
    if not names or nd == 0:
        return P(*spec)
    term = names[-1]
    batch = _batch_axes(mesh)

    if term in ("k", "v") and nd >= 4:           # (..., B, S, H, D)
        b = _take(mesh, shape[-4], batch)
        spec[-4] = b
        if b is None:                            # B=1 long context → SP over S
            spec[-3] = _take(mesh, shape[-3], "data")
        spec[-2] = _take(mesh, shape[-2], "model")
    elif term in ("ckv", "krope") and nd >= 3:   # (..., B, S, r) MLA latents
        b = _take(mesh, shape[-3], batch)
        spec[-3] = b
        if b is None:
            spec[-2] = _take(mesh, shape[-2], "data")
    elif term == "state" and nd >= 4:            # (..., B, H, P, N) SSM state
        spec[-4] = _take(mesh, shape[-4], batch)
        spec[-3] = _take(mesh, shape[-3], "model")
    elif term == "conv" and nd >= 3:             # (..., B, hist, d_inner)
        spec[-3] = _take(mesh, shape[-3], batch)
    elif term in ("idx", "slot_pos") and nd >= 1:
        spec[-1] = _take(mesh, shape[-1], batch)
    return P(*spec)


def batch_spec(path, leaf, mesh, cfg) -> P:
    shape = tuple(leaf.shape)
    spec: list = [None] * len(shape)
    if shape:
        spec[0] = _take(mesh, shape[0], _batch_axes(mesh))
    return P(*spec)


# --------------------------------------------------------------------------
# tree-level builders (NamedSharding trees for jit in/out shardings)
# --------------------------------------------------------------------------
def _shardings(rule, tree, mesh, cfg):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, rule(p, l, mesh, cfg)), tree
    )


def param_shardings(tree, mesh, cfg):
    return _shardings(param_spec, tree, mesh, cfg)


def opt_shardings(tree, mesh, cfg):
    return _shardings(opt_spec, tree, mesh, cfg)


def cache_shardings(tree, mesh, cfg):
    return _shardings(cache_spec, tree, mesh, cfg)


def batch_shardings(tree, mesh, cfg):
    return _shardings(batch_spec, tree, mesh, cfg)


# --------------------------------------------------------------------------
# trace-time context: activation constraints + MoE dispatch blocking
# --------------------------------------------------------------------------
_CTX: list[tuple[Any, Any]] = []


@contextlib.contextmanager
def use_sharding_ctx(mesh, cfg):
    """Install (mesh, cfg) so `shard_act`/`dispatch_blocks` resolve during
    tracing. Re-entrant; no-op helpers outside any context."""
    _CTX.append((mesh, cfg))
    try:
        yield
    finally:
        _CTX.pop()


def _current():
    return _CTX[-1] if _CTX else None


def act_spec(name: str, shape, mesh, cfg) -> P | None:
    """Constraint spec for a named activation; None → leave unconstrained."""
    nd = len(shape)
    spec: list = [None] * nd
    batch = _batch_axes(mesh)
    if name == "tokens" and nd >= 1:             # (B, S)
        spec[0] = _take(mesh, shape[0], batch)
    elif name == "btd" and nd >= 2:              # (B, S, d) residual stream
        b = _take(mesh, shape[0], batch)
        spec[0] = b
        if b is None and nd >= 3 and shape[1] > 1:
            spec[1] = _take(mesh, shape[1], "data")
    elif name == "btv" and nd >= 3:              # (B, c, V) logits
        spec[0] = _take(mesh, shape[0], batch)
        spec[-1] = _take(mesh, shape[-1], "model")
    elif name == "kv_cache" and nd >= 3:         # (B, S, H, D) | (B, S, r)
        b = _take(mesh, shape[0], batch)
        spec[0] = b
        if b is None:
            spec[1] = _take(mesh, shape[1], "data")
        if nd >= 4:
            spec[-2] = _take(mesh, shape[-2], "model")
    elif name == "ssm_state" and nd >= 4:        # (B, H, P, N)
        spec[0] = _take(mesh, shape[0], batch)
        spec[1] = _take(mesh, shape[1], "model")
    elif name == "moe_buf" and nd >= 2:          # (E, nb·C, d) expert-parallel
        spec[0] = _take(mesh, shape[0], "model")
        spec[1] = _take(mesh, shape[1], batch)
    elif name == "moe_buf_blocked" and nd >= 1:  # (nb, E, C, d) block-local
        spec[0] = _take(mesh, shape[0], batch)
    else:
        return None
    return P(*spec)


def shard_act(x: jax.Array, name: str) -> jax.Array:
    """`with_sharding_constraint` by activation name; identity outside a
    sharding context (single-device tests/benchmarks run unconstrained)."""
    ctx = _current()
    if ctx is None:
        return x
    mesh, cfg = ctx
    spec = act_spec(name, x.shape, mesh, cfg)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def dispatch_blocks(t: int) -> int:
    """Number of block-local MoE dispatch blocks for `t` tokens: the batch
    shard count when the config opts in (cfg.moe_block_dispatch) and it
    divides `t`, else 1 (global positions)."""
    ctx = _current()
    if ctx is None:
        return 1
    mesh, cfg = ctx
    if not getattr(cfg, "moe_block_dispatch", False):
        return 1
    nb = _axis_size(mesh, _batch_axes(mesh))
    return nb if nb > 1 and t % nb == 0 else 1
