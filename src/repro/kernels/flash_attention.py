"""Pallas TPU kernel: flash (IO-aware) self-attention.

Motivation (EXPERIMENTS.md §Perf): the XLA-level chunked attention
materializes per-chunk score tensors to HBM — the dominant memory term for
every train/prefill cell (e.g. internlm2 train_4k: ~0.9 of all traffic is
attention interior). This kernel keeps the (bq × bk) score tile, the running
max/sum and the output accumulator in VMEM scratch across the KV grid
dimension, so per-layer attention traffic drops to Q+K+V+O streaming.

Supports causal masking, sliding windows (gemma3), GQA (KV-head sharing via
the BlockSpec index map — no KV replication in HBM), and softcap. Validated
bit-close against models.attention.sdpa in interpret mode (tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: int, softcap: float,
    sq: int, sk: int, bq: int, bk: int, nk: int,
):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qb = q_ref[0, 0].astype(jnp.float32)                   # (bq, D)
    kb = k_ref[0, 0].astype(jnp.float32)                   # (bk, D)
    vb = v_ref[0, 0].astype(jnp.float32)                   # (bk, D)

    s = jax.lax.dot_general(
        qb, kb, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                              # (bq, bk)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    q_pos = pl.program_id(2) * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = (q_pos < sq) & (k_pos < sk)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                    # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                                 # (bq, bk)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    m_scr[...] = m_new
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, vb, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(j == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "bq", "bk", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, KV, Sk, D)
    v: jax.Array,  # (B, KV, Sk, D)
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    bq: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    b, h, sq, d = q.shape
    _, kv, sk, _ = k.shape
    g = h // kv
    scale = d ** -0.5
    bq = min(bq, max(sq, 8))
    bk = min(bk, max(sk, 8))
    pq, pk = (-sq) % bq, (-sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq, nk = (sq + pq) // bq, (sk + pk) // bk

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal, window=window,
            softcap=softcap, sq=sq, sk=sk, bq=bq, bk=bk, nk=nk,
        ),
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            # GQA: query head h reads KV head h // g — no HBM replication
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, i, j, g=g: (b_, h_ // g, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, i, j, g=g: (b_, h_ // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq + pq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq, :]


def flash_attention_bsnd(
    q: jax.Array,  # (B, Sq, H, D) — model layout
    k: jax.Array,  # (B, Sk, KV, D)
    v: jax.Array,
    **kw,
) -> jax.Array:
    out = flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), **kw,
    )
    return out.transpose(0, 2, 1, 3)


# ----------------------------------------------------------------------------
# Differentiable wrapper: Pallas forward, reference backward.
#
# The backward re-derives gradients through the numerically-identical
# reference attention (recompute-style, like flash-attention's own backward
# recomputes p = softmax(qk) — here at XLA level rather than in a second
# kernel; a dedicated backward kernel is the next step and changes traffic,
# not semantics). This makes `attn_impl='flash'` usable in train_step today.
# ----------------------------------------------------------------------------
import functools as _functools


def _ref_attention(q, k, v, causal, window, softcap):
    b, h, sq, d = q.shape
    kv = k.shape[1]
    g = h // kv
    qg = q.reshape(b, kv, g, sq, d).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k.astype(jnp.float32)) * (d ** -0.5)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = jax.lax.broadcasted_iota(jnp.int32, (sq, k.shape[2]), 0)
    k_pos = jax.lax.broadcasted_iota(jnp.int32, (sq, k.shape[2]), 1)
    mask = jnp.ones((sq, k.shape[2]), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return out.reshape(b, h, sq, d).astype(q.dtype)


@_functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention_trainable(q, k, v, causal=True, window=0, softcap=0.0,
                              interpret=False):
    return flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap,
        interpret=interpret,
    )


def _fa_fwd(q, k, v, causal, window, softcap, interpret):
    out = flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap,
        interpret=interpret,
    )
    return out, (q, k, v)


def _fa_bwd(causal, window, softcap, interpret, res, dout):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _ref_attention(q_, k_, v_, causal, window, softcap),
        q, k, v,
    )
    return vjp(dout)


flash_attention_trainable.defvjp(_fa_fwd, _fa_bwd)
