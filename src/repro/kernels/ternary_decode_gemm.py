"""Pallas TPU kernel: streamed ternary-decode mpGeMM (beyond-paper variant).

TPU-native realization of Vec-LUT's memory-system insight (DESIGN.md §2):
weights stay in HBM at 1.6/2.0 bits/weight as trit codes; each grid step
streams a packed tile into VMEM, decodes it to {-1,0,1} int8 *in VMEM* (three
VPU ops per trit position), and feeds the MXU with an int8×int8→int32 dot.
No dequantized weight tensor ever exists in HBM — the analogue of the paper's
"streamed precompute-lookup entirely in cache", with the MXU replacing the
table since TPU matmul is cheaper than cross-sublane gathers.

Layout contract (Vector-LUT-centric, paper §3.3 adapted):
  * activation A is pre-deinterleaved to A_r (g, K//g, N): A_r[j, k, :] =
    A[k*g + j, :] — token dim N minor/lane-contiguous. Done once in ops.py
    ("fused activation transformation").
  * packed weights W (M, K//g) uint8 — tile-contiguous via BlockSpec.
  * output O (M, N) int32, token-contiguous.

Per block (bm, bn, bkg):  O[i,j] += sum_j trit_j(W[i,k]) @ A_r[j,k,n]
— g small matmuls of (bm × bkg) @ (bkg × bn), int32 accumulation in the
revisited output block (grid minor dim = K).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_R = 3


def _decode_gemm_kernel(w_ref, a_ref, o_ref, *, g: int, nk: int):
    """One (bm, bn) output tile, one K-tile step.

    w_ref: (bm, bkg) uint8; a_ref: (g, bkg, bn) int8; o_ref: (bm, bn) int32.
    """
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    codes = w_ref[...].astype(jnp.int32)                   # (bm, bkg)
    acc = jnp.zeros(o_ref.shape, jnp.int32)
    for j in range(g):                                     # static unroll
        trit = (codes // (_R ** j)) % _R - 1               # VPU decode, {-1,0,1}
        acc = acc + jax.lax.dot_general(
            trit.astype(jnp.int8),
            a_ref[j],                                      # (bkg, bn) int8
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
    o_ref[...] += acc


@functools.partial(
    jax.jit, static_argnames=("g", "bm", "bn", "bkg", "interpret")
)
def ternary_decode_gemm(
    packed: jax.Array,
    a_r: jax.Array,
    *,
    g: int,
    bm: int = 128,
    bn: int = 256,
    bkg: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """packed: (M, KG) uint8; a_r: (g, KG, N) int8 → (M, N) int32.

    Block sizes follow the TPU-adapted §4 rules: bn multiple of 128 lanes
    (N_tile rule), bm multiple of 8 sublanes, bkg sized so the A tile
    (g·bkg·bn int8) + W tile stay within the VMEM budget (K_tile rule).
    Shapes not divisible by blocks are padded by Pallas (zero padding is
    exact here: code 0 decodes to all -1 trits but the padded A rows are 0).
    """
    m, kg = packed.shape
    g_, kg_, n = a_r.shape
    assert g_ == g and kg_ == kg, (packed.shape, a_r.shape, g)
    bm = min(bm, m)
    bn = min(bn, n)
    bkg = min(bkg, kg)
    nm, nn, nk = pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(kg, bkg)

    return pl.pallas_call(
        functools.partial(_decode_gemm_kernel, g=g, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bkg), lambda i, j, k: (i, k)),
            pl.BlockSpec((g, bkg, bn), lambda i, j, k: (0, k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(packed, a_r)
