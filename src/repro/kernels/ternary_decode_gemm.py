"""Pallas TPU kernel: streamed ternary-decode mpGeMM (beyond-paper variant).

TPU-native realization of Vec-LUT's memory-system insight (DESIGN.md §2):
weights stay in HBM at 1.6/2.0 bits/weight as trit codes; each grid step
streams a packed tile into VMEM, decodes it to {-1,0,1} int8 *in VMEM* (three
VPU ops per trit position), and feeds the MXU with an int8×int8→int32 dot.
No dequantized weight tensor ever exists in HBM — the analogue of the paper's
"streamed precompute-lookup entirely in cache", with the MXU replacing the
table since TPU matmul is cheaper than cross-sublane gathers.

Two entry points:
  * `ternary_decode_gemm` — integer-only (pre-quantized int8 A_r in, int32
    out); the unfused pipeline, kept for ablation and oracle checks.
  * `ternary_decode_gemm_fused` — single-pass (paper §3.3 adapted): float A
    in the free (KG, g, N) view, per-tile quantization prologue in VMEM,
    int32 VMEM scratch accumulation, and the w_scale × a_scale dequant
    epilogue fused into the last K step → f32/bf16 straight to HBM.

Layout contract (Vector-LUT-centric, paper §3.3 adapted):
  * unfused: activation A pre-deinterleaved to A_r (g, K//g, N) in XLA;
    fused: A passed as the (K//g, g, N) row-major *view* (zero-copy) and
    de-interleaved per tile in VMEM.
  * packed weights W (M, K//g) uint8 — tile-contiguous via BlockSpec.
  * output O (M, N), token-contiguous.

Per block (bm, bn, bkg):  O[i,j] += sum_j trit_j(W[i,k]) @ A_r[j,k,n]
— g small matmuls of (bm × bkg) @ (bkg × bn), int32 accumulation in the
revisited output block (grid minor dim = K).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_R = 3


def _decode_block_int(codes, a_r, *, g: int):
    """codes (bm, bkg) i32, a_r (g, bkg, bn) int8 → (bm, bn) int32."""
    acc = jnp.zeros((codes.shape[0], a_r.shape[2]), jnp.int32)
    for j in range(g):                                     # static unroll
        trit = (codes // (_R ** j)) % _R - 1               # VPU decode, {-1,0,1}
        acc = acc + jax.lax.dot_general(
            trit.astype(jnp.int8),
            a_r[j],                                        # (bkg, bn) int8
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
    return acc


def _decode_gemm_kernel(w_ref, a_ref, o_ref, *, g: int, nk: int):
    """One (bm, bn) output tile, one K-tile step.

    w_ref: (bm, bkg) uint8; a_ref: (g, bkg, bn) int8; o_ref: (bm, bn) int32.
    """
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    codes = w_ref[...].astype(jnp.int32)                   # (bm, bkg)
    o_ref[...] += _decode_block_int(codes, a_ref[...], g=g)


def _decode_gemm_fused_kernel(
    w_ref, a_ref, as_ref, ws_ref, o_ref, acc_ref, *, g: int, nk: int
):
    """Single-pass tile: quantize prologue → decode+dot → dequant epilogue.

    w_ref: (bm, bkg) uint8; a_ref: (bkg, g, bn) float; as_ref: (1, bn) f32;
    ws_ref: (bm, 1) f32; o_ref: (bm, bn) f32/bf16; acc_ref: (bm, bn) int32
    scratch persisting across the sequential K grid.
    """
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32) / as_ref[...][None]          # (bkg, g, bn)
    a_q = jnp.clip(jnp.round(a), -127, 127).astype(jnp.int8)
    a_r = a_q.transpose(1, 0, 2)                                    # (g, bkg, bn)

    codes = w_ref[...].astype(jnp.int32)
    acc_ref[...] += _decode_block_int(codes, a_r, g=g)

    @pl.when(k_step == nk - 1)
    def _finish():
        out = acc_ref[...].astype(jnp.float32) * ws_ref[...] * as_ref[...]
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("g", "bm", "bn", "bkg", "interpret")
)
def ternary_decode_gemm(
    packed: jax.Array,
    a_r: jax.Array,
    *,
    g: int,
    bm: int = 128,
    bn: int = 256,
    bkg: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """packed: (M, KG) uint8; a_r: (g, KG, N) int8 → (M, N) int32.

    Block sizes follow the TPU-adapted §4 rules: bn multiple of 128 lanes
    (N_tile rule), bm multiple of 8 sublanes, bkg sized so the A tile
    (g·bkg·bn int8) + W tile stay within the VMEM budget (K_tile rule) —
    kernels/autotune.py enumerates and times the candidates. Shapes not
    divisible by blocks are padded by Pallas (zero padding is exact here:
    code 0 decodes to all -1 trits but the padded A rows are 0).
    """
    m, kg = packed.shape
    g_, kg_, n = a_r.shape
    assert g_ == g and kg_ == kg, (packed.shape, a_r.shape, g)
    bm = min(bm, m)
    bn = min(bn, n)
    bkg = min(bkg, kg)
    nm, nn, nk = pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(kg, bkg)

    return pl.pallas_call(
        functools.partial(_decode_gemm_kernel, g=g, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bkg), lambda i, j, k: (i, k)),
            pl.BlockSpec((g, bkg, bn), lambda i, j, k: (0, k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(packed, a_r)


@functools.partial(
    jax.jit, static_argnames=("g", "bm", "bn", "bkg", "out_dtype", "interpret")
)
def ternary_decode_gemm_fused(
    packed: jax.Array,
    a: jax.Array,
    a_scale: jax.Array,
    w_scale: jax.Array,
    *,
    g: int,
    bm: int = 128,
    bn: int = 256,
    bkg: int = 128,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Single-pass fused decode mpGeMM.

    packed: (M, KG) uint8; a: (KG, g, N) float (free view of (K, N));
    a_scale: (1, N) f32; w_scale: (M, 1) f32 → (M, N) out_dtype.

    Padded tokens must carry a_scale = 1, padded rows w_scale = 0 (see
    vlut_lookup_gemm_fused).
    """
    m, kg = packed.shape
    kg_, g_, n = a.shape
    assert g_ == g and kg_ == kg, (packed.shape, a.shape, g)
    assert a_scale.shape == (1, n) and w_scale.shape == (m, 1), (
        a_scale.shape, w_scale.shape)
    bm = min(bm, m)
    bn = min(bn, n)
    bkg = min(bkg, kg)
    nm, nn, nk = pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(kg, bkg)

    return pl.pallas_call(
        functools.partial(_decode_gemm_fused_kernel, g=g, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bkg), lambda i, j, k: (i, k)),
            pl.BlockSpec((bkg, g, bn), lambda i, j, k: (k, 0, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(packed, a, a_scale, w_scale)
