"""Pallas TPU kernel: paper-faithful streamed vector-LUT mpGeMM.

Implements the Vec-LUT pipeline (paper Alg. 1 + §3.4) per VMEM tile:

  1. *LUT precompute in VMEM*: the unified sub-table tile
     T (3^g, bkg, bn) int16 = S(3^g, g) ⨯ A_r(g, bkg, bn), computed with one
     MXU contraction against the compile-time sign-enumeration matrix S
     (the TPU replacement for topological precompute — DESIGN.md §2).
     T lives only in this grid step's VMEM: this is the paper's
     "streamed precomputing-lookup execution" with VMEM as the cache.
  2. *1→N vector lookup & accumulate*: every packed byte W[m, k] selects a
     row T[idx, k, :] — a vector of bn token results — accumulated into the
     revisited output block.

Two lookup strategies (both faithful to "one 1→N lookup per index"):
  * 'onehot' (default): the gather is expressed as a one-hot batched matmul
    onehot(W)(bm, bkg, 3^g) ⨯ T(3^g, bkg, bn) on the MXU — TPU has no
    cross-sublane vector gather, and one-hot contraction is the idiomatic
    Mosaic lowering of a row-select.
  * 'serial': literal row gather via a fori_loop of dynamic slices — the
    closest transliteration of the CPU kernel's inner loop; sublane-serial
    on real hardware (kept for fidelity comparison + ablation).

VMEM budget per §4's K_tile rule (adapted): 3^g · bkg · bn · 2B for T —
ops.select_tiles() sizes bkg so this stays ≲ 4 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


_R = 3


def _vlut_kernel(w_ref, a_ref, o_ref, *, g: int, lookup: str):
    """w_ref: (bm, bkg) uint8; a_ref: (g, bkg, bn) int8; o_ref: (bm, bn) i32."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    bm, bkg = w_ref.shape
    bn = o_ref.shape[1]
    n_entries = _R ** g

    # --- 1. streamed LUT precompute (unified across the bn tokens) --------
    # Sign-enumeration matrix S[e, j] = trit_j(e) - 1, built in-kernel from
    # iota (Pallas kernels cannot capture host constants).
    e_iota = jax.lax.broadcasted_iota(jnp.int32, (n_entries, 1), 0)
    s = jnp.concatenate(
        [(e_iota // (_R ** j)) % _R - 1 for j in range(g)], axis=1
    ).astype(jnp.int8)                                              # (3^g, g)
    # T[e, k, n] = sum_j S[e, j] * A_r[j, k, n]
    t = jax.lax.dot_general(
        s, a_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    ).astype(jnp.int16)                                             # (3^g, bkg, bn)

    codes = w_ref[...].astype(jnp.int32)                            # (bm, bkg)

    # --- 2. 1→N vector lookup + accumulate --------------------------------
    if lookup == "onehot":
        # onehot[m, k, e] ⨯ T[e, k, n] → batched over k: (bkg, bm, bn)
        eye = jax.lax.broadcasted_iota(jnp.int32, (bm, bkg, n_entries), 2)
        onehot = (eye == codes[:, :, None]).astype(jnp.int8)
        part = jax.lax.dot_general(
            onehot.transpose(1, 0, 2),                              # (bkg, bm, 3^g)
            t.transpose(1, 0, 2),                                   # (bkg, 3^g, bn)
            dimension_numbers=(((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.int32,
        )                                                           # (bkg, bm, bn)
        o_ref[...] += jnp.sum(part, axis=0)
    else:  # 'serial' — literal per-(m,k) row gather
        def body_k(k, acc):
            t_k = jax.lax.dynamic_slice(t, (0, k, 0), (n_entries, 1, bn))[:, 0, :]
            rows = jnp.take(t_k, codes[:, k], axis=0)               # (bm, bn) 1→N
            return acc + rows.astype(jnp.int32)

        o_ref[...] += jax.lax.fori_loop(
            0, bkg, body_k, jnp.zeros((bm, bn), jnp.int32)
        )


@functools.partial(
    jax.jit, static_argnames=("g", "bm", "bn", "bkg", "lookup", "interpret")
)
def vlut_lookup_gemm(
    packed: jax.Array,
    a_r: jax.Array,
    *,
    g: int,
    bm: int = 128,
    bn: int = 128,
    bkg: int = 32,
    lookup: str = "onehot",
    interpret: bool = False,
) -> jax.Array:
    """packed: (M, KG) uint8; a_r: (g, KG, N) int8 → (M, N) int32.

    Callers (ops.py) must pre-pad M/N/KG to block multiples — padded K-groups
    must carry the all-zero-trit code so they contribute 0.
    """
    m, kg = packed.shape
    g_, kg_, n = a_r.shape
    assert g_ == g and kg_ == kg, (packed.shape, a_r.shape, g)
    bm = min(bm, m)
    bn = min(bn, n)
    bkg = min(bkg, kg)
    nm, nn, nk = pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(kg, bkg)

    return pl.pallas_call(
        functools.partial(_vlut_kernel, g=g, lookup=lookup),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bkg), lambda i, j, k: (i, k)),
            pl.BlockSpec((g, bkg, bn), lambda i, j, k: (0, k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(packed, a_r)
