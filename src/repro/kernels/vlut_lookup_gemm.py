"""Pallas TPU kernel: paper-faithful streamed vector-LUT mpGeMM.

Implements the Vec-LUT pipeline (paper Alg. 1 + §3.4) per VMEM tile:

  1. *LUT precompute in VMEM*: the unified sub-table tile
     T (3^g, bkg, bn) int16 = S(3^g, g) ⨯ A_r(g, bkg, bn), computed with one
     MXU contraction against the compile-time sign-enumeration matrix S
     (the TPU replacement for topological precompute — DESIGN.md §2).
     T lives only in this grid step's VMEM: this is the paper's
     "streamed precomputing-lookup execution" with VMEM as the cache.
  2. *1→N vector lookup & accumulate*: every packed byte W[m, k] selects a
     row T[idx, k, :] — a vector of bn token results — accumulated into the
     revisited output block.

Two entry points share that core:

  * `vlut_lookup_gemm` — the integer-only kernel: pre-quantized int8
    activations in the de-interleaved (g, KG, N) layout → int32 output. The
    *unfused* pipeline (ops.py quantizes / de-interleaves / dequantizes in
    XLA around it, three extra HBM round-trips) — kept for the fusion
    ablation and as the bit-exact integer oracle target.
  * `vlut_lookup_gemm_fused` — the single-pass kernel (paper §3.3 "fused
    activation and output transformation"): activations enter as *float* in
    the free (KG, g, N) row-major view, each grid step quantizes its
    (bkg, g, bn) tile against the per-token scale *in VMEM* (prologue) and
    the final K step applies the w_scale × a_scale dequant epilogue from an
    int32 VMEM scratch accumulator, emitting f32/bf16 directly. No int8
    activation buffer, no de-interleave rematerialization, and no int32
    output ever touch HBM.

Two lookup strategies (both faithful to "one 1→N lookup per index"):
  * 'onehot' (default): the gather is expressed as a one-hot batched matmul
    onehot(W)(bm, bkg, 3^g) ⨯ T(3^g, bkg, bn) on the MXU — TPU has no
    cross-sublane vector gather, and one-hot contraction is the idiomatic
    Mosaic lowering of a row-select.
  * 'serial': literal row gather via a fori_loop of dynamic slices — the
    closest transliteration of the CPU kernel's inner loop; sublane-serial
    on real hardware (kept for fidelity comparison + ablation).

VMEM budget per §4's K_tile rule (adapted): 3^g · bkg · bn · 2B for T —
kernels/autotune.py enumerates (bm, bn, bkg) candidates under this budget
(ops.select_tiles is the cold-cache heuristic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


_R = 3


def _lut_block_int(codes, a_r, *, g: int, lookup: str):
    """Shared LUT core: codes (bm, bkg) i32, a_r (g, bkg, bn) i8 → (bm, bn) i32."""
    bm, bkg = codes.shape
    bn = a_r.shape[2]
    n_entries = _R ** g

    # --- 1. streamed LUT precompute (unified across the bn tokens) --------
    # Sign-enumeration matrix S[e, j] = trit_j(e) - 1, built in-kernel from
    # iota (Pallas kernels cannot capture host constants).
    e_iota = jax.lax.broadcasted_iota(jnp.int32, (n_entries, 1), 0)
    s = jnp.concatenate(
        [(e_iota // (_R ** j)) % _R - 1 for j in range(g)], axis=1
    ).astype(jnp.int8)                                              # (3^g, g)
    # T[e, k, n] = sum_j S[e, j] * A_r[j, k, n]
    t = jax.lax.dot_general(
        s, a_r,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    ).astype(jnp.int16)                                             # (3^g, bkg, bn)

    # --- 2. 1→N vector lookup + accumulate --------------------------------
    if lookup == "onehot":
        # onehot[m, k, e] ⨯ T[e, k, n] → batched over k: (bkg, bm, bn)
        eye = jax.lax.broadcasted_iota(jnp.int32, (bm, bkg, n_entries), 2)
        onehot = (eye == codes[:, :, None]).astype(jnp.int8)
        part = jax.lax.dot_general(
            onehot.transpose(1, 0, 2),                              # (bkg, bm, 3^g)
            t.transpose(1, 0, 2),                                   # (bkg, 3^g, bn)
            dimension_numbers=(((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.int32,
        )                                                           # (bkg, bm, bn)
        return jnp.sum(part, axis=0)
    # 'serial' — literal per-(m,k) row gather
    def body_k(k, acc):
        t_k = jax.lax.dynamic_slice(t, (0, k, 0), (n_entries, 1, bn))[:, 0, :]
        rows = jnp.take(t_k, codes[:, k], axis=0)                   # (bm, bn) 1→N
        return acc + rows.astype(jnp.int32)

    return jax.lax.fori_loop(0, bkg, body_k, jnp.zeros((bm, bn), jnp.int32))


def _vlut_kernel(w_ref, a_ref, o_ref, *, g: int, lookup: str):
    """w_ref: (bm, bkg) uint8; a_ref: (g, bkg, bn) int8; o_ref: (bm, bn) i32."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    codes = w_ref[...].astype(jnp.int32)                            # (bm, bkg)
    o_ref[...] += _lut_block_int(codes, a_ref[...], g=g, lookup=lookup)


def _vlut_fused_kernel(
    w_ref, a_ref, as_ref, ws_ref, o_ref, acc_ref, *, g: int, lookup: str, nk: int
):
    """Single-pass tile: quantize prologue → LUT core → dequant epilogue.

    w_ref: (bm, bkg) uint8; a_ref: (bkg, g, bn) float; as_ref: (1, bn) f32
    per-token scale; ws_ref: (bm, 1) f32 per-channel scale; o_ref: (bm, bn)
    f32/bf16; acc_ref: (bm, bn) int32 VMEM scratch (persists across the
    sequential K grid).
    """
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # --- prologue: fused per-token quantization + de-interleave -----------
    # A arrives as the free row-major view (KG, g, N); the (bkg, g, bn) tile
    # is quantized against the per-token scale and transposed to the
    # token-minor (g, bkg, bn) layout entirely in VMEM (§3.3).
    a = a_ref[...].astype(jnp.float32) / as_ref[...][None]          # (bkg, g, bn)
    a_q = jnp.clip(jnp.round(a), -127, 127).astype(jnp.int8)
    a_r = a_q.transpose(1, 0, 2)                                    # (g, bkg, bn)

    codes = w_ref[...].astype(jnp.int32)
    acc_ref[...] += _lut_block_int(codes, a_r, g=g, lookup=lookup)

    # --- epilogue: fused scale application on the last K step -------------
    @pl.when(k_step == nk - 1)
    def _finish():
        out = acc_ref[...].astype(jnp.float32) * ws_ref[...] * as_ref[...]
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("g", "bm", "bn", "bkg", "lookup", "interpret")
)
def vlut_lookup_gemm(
    packed: jax.Array,
    a_r: jax.Array,
    *,
    g: int,
    bm: int = 128,
    bn: int = 128,
    bkg: int = 32,
    lookup: str = "onehot",
    interpret: bool = False,
) -> jax.Array:
    """packed: (M, KG) uint8; a_r: (g, KG, N) int8 → (M, N) int32.

    Callers (ops.py) must pre-pad M/N/KG to block multiples — padded K-groups
    must carry the all-zero-trit code so they contribute 0.
    """
    m, kg = packed.shape
    g_, kg_, n = a_r.shape
    assert g_ == g and kg_ == kg, (packed.shape, a_r.shape, g)
    bm = min(bm, m)
    bn = min(bn, n)
    bkg = min(bkg, kg)
    nm, nn, nk = pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(kg, bkg)

    return pl.pallas_call(
        functools.partial(_vlut_kernel, g=g, lookup=lookup),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bkg), lambda i, j, k: (i, k)),
            pl.BlockSpec((g, bkg, bn), lambda i, j, k: (0, k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(packed, a_r)


@functools.partial(
    jax.jit,
    static_argnames=("g", "bm", "bn", "bkg", "lookup", "out_dtype", "interpret"),
)
def vlut_lookup_gemm_fused(
    packed: jax.Array,
    a: jax.Array,
    a_scale: jax.Array,
    w_scale: jax.Array,
    *,
    g: int,
    bm: int = 128,
    bn: int = 128,
    bkg: int = 32,
    lookup: str = "onehot",
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Single-pass fused lookup mpGeMM.

    packed: (M, KG) uint8; a: (KG, g, N) float (the free row-major view of
    the (K, N) activation); a_scale: (1, N) f32; w_scale: (M, 1) f32
    → (M, N) out_dtype = (W ⨯ quant(A)) · w_scale · a_scale.

    Same padding contract as the unfused kernel; additionally padded tokens
    must carry a_scale = 1 (their activations are 0 so any nonzero scale is
    exact) and padded rows w_scale = 0.
    """
    m, kg = packed.shape
    kg_, g_, n = a.shape
    assert g_ == g and kg_ == kg, (packed.shape, a.shape, g)
    assert a_scale.shape == (1, n) and w_scale.shape == (m, 1), (
        a_scale.shape, w_scale.shape)
    bm = min(bm, m)
    bn = min(bn, n)
    bkg = min(bkg, kg)
    nm, nn, nk = pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(kg, bkg)

    return pl.pallas_call(
        functools.partial(_vlut_fused_kernel, g=g, lookup=lookup, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bkg), lambda i, j, k: (i, k)),
            pl.BlockSpec((bkg, g, bn), lambda i, j, k: (k, 0, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(packed, a, a_scale, w_scale)
