"""Public jit'd wrappers around the Vec-LUT TPU kernels.

Responsibilities:
  * the fused Vector-LUT-centric layout transformation (paper §3.3): token
    flattening + transpose to token-minor + per-group de-interleave, fused by
    XLA into the activation-quantization epilogue;
  * padding to block multiples (padded K-groups carry the all-zero-trit code
    so they contribute exactly 0);
  * TPU-adapted tile-size selection (paper §4 rules, VMEM instead of L1);
  * backend dispatch: Pallas kernels on TPU (or interpret=True for CPU
    validation), and a shardable pure-XLA streamed-decode path used by the
    multi-device dry-run (pjit-friendly, identical semantics);
  * scale application (per-channel weight scale × per-token activation scale).

The packed-serving path is inference-only by design (training runs the QAT
fake-quant dense path; see repro/models/bitlinear.py), so no custom_vjp here.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.packing import PackedWeight
from .ternary_decode_gemm import ternary_decode_gemm
from .vlut_lookup_gemm import vlut_lookup_gemm

_R = 3

Impl = Literal["decode", "lookup", "xla"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def select_tiles(g: int, impl: Impl, vmem_budget_bytes: int = 4 * 2**20):
    """TPU adaptation of paper §4 tile-size selection.

    N_tile: minimal multiple of the 128-lane vector width that still feeds
    the MXU (paper: minimal multiple of SIMD width) → 128 for lookup, 256 for
    decode (bigger N amortizes the decode).
    K_tile: for 'lookup', the streamed table T (3^g · bkg · bn · 2B) must fit
    the VMEM budget (paper: 3^g · N_tile · K_tile/g < L1); for 'decode' the
    A tile (g · bkg · bn) dominates → bkg 128–256.
    """
    if impl == "lookup":
        bn = 128
        bkg = max(8, vmem_budget_bytes // (_R ** g * bn * 2))
        bkg = min(128, 1 << (bkg.bit_length() - 1))                 # pow2 clamp
        return dict(bm=128, bn=bn, bkg=bkg)
    return dict(bm=128, bn=256, bkg=128)


def _deinterleave(a_q: jax.Array, g: int) -> jax.Array:
    """(K, N) → (g, K//g, N): A_r[j, k, :] = A[k*g+j, :] (§3.3 layout)."""
    K, N = a_q.shape
    return a_q.reshape(K // g, g, N).transpose(1, 0, 2)


def _pad_to(x: jax.Array, axis: int, mult: int, value=0) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _segment_gemm_int(
    packed: jax.Array,
    a_q_seg: jax.Array,
    g: int,
    impl: Impl,
    interpret: bool,
    tiles: dict | None,
) -> jax.Array:
    """One homogeneous-g segment: packed (M, KG) uint8 × a_q_seg (K, N) int8
    → (M, N) int32, dispatched to the chosen kernel."""
    m, kg = packed.shape
    n = a_q_seg.shape[1]
    if impl == "xla":
        # Shardable streamed decode: scan over K-chunks so the transient
        # dense tile stays small (the dry-run / pjit path).
        return _xla_streamed_decode(packed, a_q_seg, g)

    t = dict(select_tiles(g, impl))
    if tiles:
        t.update(tiles)
    zero_code = (_R ** g - 1) // 2
    packed_p = _pad_to(_pad_to(packed, 1, t["bkg"], value=zero_code), 0, 8)
    a_r = _deinterleave(a_q_seg, g)
    a_r = _pad_to(_pad_to(a_r, 1, t["bkg"]), 2, 128)
    fn = ternary_decode_gemm if impl == "decode" else vlut_lookup_gemm
    out = fn(packed_p, a_r, g=g, interpret=interpret, **t)
    return out[:m, :n]


def _xla_streamed_decode(
    packed: jax.Array, a_q_seg: jax.Array, g: int, k_chunk_groups: int = 512
) -> jax.Array:
    """Pure-XLA streamed decode+dot: functionally the Pallas decode kernel,
    expressed as a scan over K-group chunks (keeps the transient decoded tile
    ≤ M×(k_chunk·g) int8). pjit-shardable: M shards freely; K sharding gives
    row-parallel partial sums (psum inserted by SPMD)."""
    m, kg = packed.shape
    n = a_q_seg.shape[1]
    if kg <= k_chunk_groups:
        return _decode_dot(packed, a_q_seg, g)
    zero_code = (_R ** g - 1) // 2
    packed_p = _pad_to(packed, 1, k_chunk_groups, value=zero_code)
    a_p = _pad_to(a_q_seg, 0, k_chunk_groups * g)
    nc = packed_p.shape[1] // k_chunk_groups
    w_c = packed_p.reshape(m, nc, k_chunk_groups).transpose(1, 0, 2)
    a_c = a_p.reshape(nc, k_chunk_groups * g, n)

    def step(acc, xs):
        wc, ac = xs
        return acc + _decode_dot(wc, ac, g), None

    out, _ = jax.lax.scan(step, jnp.zeros((m, n), jnp.int32), (w_c, a_c))
    return out


def _decode_dot(packed: jax.Array, a_q: jax.Array, g: int) -> jax.Array:
    codes = packed.astype(jnp.int32)                                 # (M, KG)
    trits = (codes[..., None] // (_R ** jnp.arange(g, dtype=jnp.int32))) % _R - 1
    w_t = trits.reshape(packed.shape[0], packed.shape[1] * g).astype(jnp.int8)
    return jax.lax.dot_general(
        w_t, a_q, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


@functools.partial(jax.jit, static_argnames=("impl", "interpret", "out_dtype"))
def vlut_mpgemm(
    pw: PackedWeight,
    a: jax.Array,
    *,
    impl: Impl = "decode",
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Kernel-backed mpGeMM. a: (K, N) float, token-contiguous → (M, N)."""
    amax = jnp.max(jnp.abs(a.astype(jnp.float32)), axis=0)
    a_scale = jnp.maximum(amax, 1e-6) / 127.0
    a_q = jnp.clip(jnp.round(a / a_scale[None, :]), -127, 127).astype(jnp.int8)
    out = jnp.zeros((pw.M, a.shape[1]), jnp.int32)
    if pw.packed5.shape[-1]:
        out = out + _segment_gemm_int(pw.packed5, a_q[: pw.k5], 5, impl, interpret, None)
    if pw.packed4.shape[-1]:
        out = out + _segment_gemm_int(pw.packed4, a_q[pw.k5:], 4, impl, interpret, None)
    w_scale = pw.scale if pw.scale.shape[-1] == pw.M else jnp.broadcast_to(pw.scale, (pw.M,))
    return (out.astype(jnp.float32) * w_scale[:, None] * a_scale[None, :]).astype(out_dtype)


def ternary_matmul(pw: PackedWeight, x: jax.Array, impl: Impl | None = None) -> jax.Array:
    """Model-facing packed linear:  y(..., M) = x(..., K) · Wᵀ.

    Fuses the token-first layout transformation (flatten tokens → transpose to
    token-minor) around the kernel, per paper §3.3 "Fused activation and
    output transformation". Chooses the Pallas kernel on TPU and the
    shardable XLA streamed-decode elsewhere (incl. the multi-pod dry-run).
    """
    if impl is None:
        impl = "decode" if on_tpu() else "xla"
    lead = x.shape[:-1]
    a = x.reshape(-1, x.shape[-1]).T                                 # (K, N) token-minor
    out = vlut_mpgemm(pw, a, impl=impl, out_dtype=x.dtype)           # (M, N)
    return out.T.reshape(*lead, pw.M)
