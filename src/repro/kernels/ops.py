"""Public jit'd wrappers around the Vec-LUT TPU kernels.

The hot path is **single-pass** (paper §3.3 "fused activation and output
transformation"): float activations go straight into the Pallas kernel, which
quantizes each (bkg, bn) tile against the per-token scale in VMEM (prologue),
de-interleaves in registers from the free (K//g, g, N) row-major view, and
applies the w_scale × a_scale dequant epilogue on the last K grid step —
emitting f32/bf16 directly. The only HBM tensors are the packed weights, the
float activation, and the float output: no int8 activation buffer, no
de-interleave rematerialization, no int32 output round-trip.

Responsibilities:
  * per-token activation scale (one cheap reduction; shared with the QAT
    path via core.quantize.act_token_scale) + padding to block multiples
    (padded K-groups carry the all-zero-trit code so they contribute 0;
    padded tokens carry a_scale = 1, padded rows w_scale = 0);
  * tile-size selection through kernels/autotune.py (measured, disk-cached;
    the static §4 heuristic `select_tiles` is the cold-cache fallback);
  * backend dispatch: fused Pallas kernels on TPU (or interpret=True for CPU
    validation), and a shardable pure-XLA streamed-decode path used by the
    multi-device dry-run (pjit-friendly, identical semantics);
  * the `fusion="unfused"` ablation path: the original three-pass pipeline
    (XLA quantize → de-interleave/pad → int kernel → dequant), kept for
    benchmarks/gemm_bench.py --fusion and as a parity oracle.

The packed-serving path is inference-only by design (training runs the QAT
fake-quant dense path; see repro/models/common.py), so no custom_vjp here.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro import obs as obs_mod
from repro.core.packing import PackedWeight
from repro.core.quantize import act_quant_tokens, act_token_scale
from . import autotune
from .ternary_decode_gemm import ternary_decode_gemm, ternary_decode_gemm_fused
from .vlut_lookup_gemm import vlut_lookup_gemm, vlut_lookup_gemm_fused

_R = 3

Impl = Literal["decode", "lookup", "xla"]
Fusion = Literal["fused", "unfused"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def select_tiles(g: int, impl: Impl, vmem_budget: int | None = None):
    """Static §4 tile heuristic (delegates to autotune.heuristic_tiles).

    Kept public as the autotuner's cold-cache fallback; measured winners come
    from kernels/autotune.get_tiles / tune. The default budget resolves
    through `autotune.vmem_budget_bytes()` (env-overridable) — the same
    source the R5 lint rule reads, so dispatch and lint can never drift.
    """
    return autotune.heuristic_tiles(g, impl, vmem_budget)


# --------------------------------------------------------------------------
# dispatch configuration (the serve/model-facing routing knobs)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class DispatchConfig:
    """Process-wide defaults for `ternary_matmul` routing. `impl=None` picks
    the backend default (fused Pallas decode on TPU, streamed XLA elsewhere)."""
    impl: Impl | None = None
    fusion: Fusion = "fused"
    interpret: bool = False


_dispatch = DispatchConfig()


def dispatch_config() -> DispatchConfig:
    return _dispatch


_DISPATCH_FIELDS = tuple(f.name for f in dataclasses.fields(DispatchConfig))


def configure_dispatch(**kw) -> DispatchConfig:
    """Set process-wide dispatch defaults (serve entrypoints call this).
    None values are ignored; unknown knobs raise."""
    for k, v in kw.items():
        if k not in _DISPATCH_FIELDS:
            raise TypeError(f"unknown dispatch knob {k!r}; have {_DISPATCH_FIELDS}")
        if v is not None:
            setattr(_dispatch, k, v)
    return _dispatch


@contextlib.contextmanager
def dispatch_override(**kw):
    """Temporarily override dispatch defaults (None values are ignored)."""
    saved = {f: getattr(_dispatch, f) for f in _DISPATCH_FIELDS}
    try:
        configure_dispatch(**kw)
        yield _dispatch
    finally:
        for f, v in saved.items():
            setattr(_dispatch, f, v)


# --------------------------------------------------------------------------
# layout / padding helpers
# --------------------------------------------------------------------------
def _deinterleave(a_q: jax.Array, g: int) -> jax.Array:
    """(K, N) → (g, K//g, N): A_r[j, k, :] = A[k*g+j, :] (§3.3 layout).

    Only the *unfused* ablation path materializes this — the fused kernels
    consume the zero-copy (K//g, g, N) view and transpose in VMEM."""
    K, N = a_q.shape
    return a_q.reshape(K // g, g, N).transpose(1, 0, 2)


def _pad_to(x: jax.Array, axis: int, mult: int, value=0) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _resolve_tiles(
    g: int, impl: Impl, m: int, kg: int, n: int,
    *, fused: bool, interpret: bool, tiles: dict | None,
) -> dict:
    """Per-segment tile resolution: explicit override > autotune cache >
    §4 heuristic (see kernels/autotune.py).

    A fully-specified override skips the autotuner entirely — essential for
    the autotuner's own timing benchmark (segment_mpgemm), which would
    otherwise re-enter tune() for the very key it is measuring."""
    if tiles and all(k in tiles for k in ("bm", "bn", "bkg")):
        return dict(tiles)
    t = autotune.get_tiles(g, impl, m, kg, n, fused=fused, interpret=interpret)
    if tiles:
        t = dict(t)
        t.update(tiles)
    return t


# --------------------------------------------------------------------------
# per-segment kernels (one homogeneous g)
# --------------------------------------------------------------------------
def _segment_gemm_int(
    packed: jax.Array,
    a_q_seg: jax.Array,
    g: int,
    impl: Impl,
    interpret: bool,
    tiles: dict | None,
) -> jax.Array:
    """Unfused integer segment: packed (M, KG) uint8 × a_q_seg (K, N) int8
    → (M, N) int32, dispatched to the chosen kernel."""
    m, kg = packed.shape
    n = a_q_seg.shape[1]
    if impl == "xla":
        # Shardable streamed decode: scan over K-chunks so the transient
        # dense tile stays small (the dry-run / pjit path).
        return _xla_streamed_decode(packed, a_q_seg, g)

    t = _resolve_tiles(g, impl, m, kg, n, fused=False, interpret=interpret, tiles=tiles)
    zero_code = (_R ** g - 1) // 2
    packed_p = _pad_to(_pad_to(packed, 1, t["bkg"], value=zero_code), 0, 8)
    a_r = _deinterleave(a_q_seg, g)
    a_r = _pad_to(_pad_to(a_r, 1, t["bkg"]), 2, 128)
    fn = ternary_decode_gemm if impl == "decode" else vlut_lookup_gemm
    out = fn(packed_p, a_r, g=g, interpret=interpret, **t)
    return out[:m, :n]


def _segment_gemm_fused(
    packed: jax.Array,
    a_seg: jax.Array,
    a_scale: jax.Array,
    w_scale: jax.Array,
    g: int,
    impl: Impl,
    interpret: bool,
    tiles: dict | None,
    out_dtype,
) -> jax.Array:
    """Single-pass fused segment: packed (M, KG) uint8 × a_seg (K, N) float
    → (M, N) out_dtype, with quantization + de-interleave + dequantization
    inside the kernel. a_scale: (N,) f32 per-token; w_scale: (M,) f32."""
    m, kg = packed.shape
    n = a_seg.shape[1]
    t = _resolve_tiles(g, impl, m, kg, n, fused=True, interpret=interpret, tiles=tiles)
    zero_code = (_R ** g - 1) // 2
    packed_p = _pad_to(_pad_to(packed, 1, t["bkg"], value=zero_code), 0, 8)
    a3 = a_seg.reshape(kg, g, n)                   # free row-major view of (K, N)
    a3 = _pad_to(_pad_to(a3, 0, t["bkg"]), 2, 128)
    a_scale_p = _pad_to(a_scale[None, :], 1, 128, value=1.0)
    w_scale_p = _pad_to(w_scale[:, None], 0, 8, value=0.0)
    fn = ternary_decode_gemm_fused if impl == "decode" else vlut_lookup_gemm_fused
    out = fn(
        packed_p, a3, a_scale_p, w_scale_p,
        g=g, out_dtype=out_dtype, interpret=interpret, **t,
    )
    return out[:m, :n]


def _xla_streamed_decode(
    packed: jax.Array, a_q_seg: jax.Array, g: int, k_chunk_groups: int = 512
) -> jax.Array:
    """Pure-XLA streamed decode+dot: functionally the Pallas decode kernel,
    expressed as a scan over K-group chunks (keeps the transient decoded tile
    ≤ M×(k_chunk·g) int8). pjit-shardable: M shards freely; K sharding gives
    row-parallel partial sums (psum inserted by SPMD)."""
    m, kg = packed.shape
    n = a_q_seg.shape[1]
    if kg <= k_chunk_groups:
        return _decode_dot(packed, a_q_seg, g)
    zero_code = (_R ** g - 1) // 2
    packed_p = _pad_to(packed, 1, k_chunk_groups, value=zero_code)
    a_p = _pad_to(a_q_seg, 0, k_chunk_groups * g)
    nc = packed_p.shape[1] // k_chunk_groups
    w_c = packed_p.reshape(m, nc, k_chunk_groups).transpose(1, 0, 2)
    a_c = a_p.reshape(nc, k_chunk_groups * g, n)

    def step(acc, xs):
        wc, ac = xs
        return acc + _decode_dot(wc, ac, g), None

    out, _ = jax.lax.scan(step, jnp.zeros((m, n), jnp.int32), (w_c, a_c))
    return out


def _decode_dot(packed: jax.Array, a_q: jax.Array, g: int) -> jax.Array:
    """Decode to a dense int8 tile, then one dot. A per-trit-position dot
    (the Pallas decode kernel's structure) is ~1.3× faster on pre-quantized
    int8 inputs, but in the *fused* graph its g operand reads make XLA
    re-fuse (recompute) the activation quantization per trit position —
    measured net loss; the single-consumer form keeps quantize computed
    once."""
    codes = packed.astype(jnp.int32)                                 # (M, KG)
    trits = (codes[..., None] // (_R ** jnp.arange(g, dtype=jnp.int32))) % _R - 1
    w_t = trits.reshape(packed.shape[0], packed.shape[1] * g).astype(jnp.int8)
    return jax.lax.dot_general(
        w_t, a_q, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def _segments(pw: PackedWeight):
    """[(packed, col_start, col_stop, g)] for the non-empty segments."""
    segs = []
    if pw.packed5.shape[-1]:
        segs.append((pw.packed5, 0, pw.k5, 5))
    if pw.packed4.shape[-1]:
        segs.append((pw.packed4, pw.k5, pw.k5 + pw.k4, 4))
    return segs


def _w_scale(pw: PackedWeight) -> jax.Array:
    return (
        pw.scale if pw.scale.shape[-1] == pw.M
        else jnp.broadcast_to(pw.scale, (pw.M,))
    )


# --------------------------------------------------------------------------
# public mpGeMM entry points
# --------------------------------------------------------------------------
@functools.partial(
    jax.jit, static_argnames=("impl", "interpret", "out_dtype", "fusion")
)
def vlut_mpgemm(
    pw: PackedWeight,
    a: jax.Array,
    *,
    impl: Impl = "decode",
    interpret: bool = False,
    out_dtype=jnp.float32,
    fusion: Fusion = "fused",
) -> jax.Array:
    """Kernel-backed mpGeMM. a: (K, N) float, token-contiguous → (M, N).

    fusion="fused" (default) runs the single-pass kernel; "unfused" runs the
    original multi-pass pipeline, whose stage boundaries are real HBM
    materializations for the Pallas impls (XLA quantize → pallas_call →
    XLA dequant). The two are numerically identical up to f32 summation
    order when the weight has both a g=5 and a g=4 segment, bit-identical
    otherwise. For impl="xla" there is no Pallas stage and XLA fuses freely
    inside one jit (optimization_barrier is elided on CPU), so both fusion
    arms compile to the same graph here — the benchmark's unfused-xla
    ablation arm stages separate dispatches instead (gemm_bench.py).
    """
    n = a.shape[1]
    segs = _segments(pw)
    if fusion == "fused" and impl != "xla":
        a_f = a if jnp.issubdtype(a.dtype, jnp.floating) else a.astype(jnp.float32)
        a_scale = act_token_scale(a_f)                               # (N,)
        w_scale = _w_scale(pw)
        seg_dtype = out_dtype if len(segs) == 1 else jnp.float32
        parts = [
            _segment_gemm_fused(
                packed, a_f[lo:hi], a_scale, w_scale, g, impl, interpret,
                None, seg_dtype,
            )
            for packed, lo, hi, g in segs
        ]
        if not parts:
            return jnp.zeros((pw.M, n), out_dtype)
        out = parts[0] if len(parts) == 1 else sum(parts).astype(out_dtype)
        return out

    # fusion="unfused" (or impl="xla"): the original three-pass pipeline —
    # materialized int8 activations, de-interleave layout pass (Pallas impls),
    # int32 kernel output, separate dequant. For the Pallas kernels each
    # stage boundary is a real HBM materialization (pallas_call in/out); for
    # impl="xla" inside one jit XLA fuses freely, so the *benchmark* stages
    # the unfused ablation as separate dispatches (see gemm_bench.py).
    a_q, a_scale = act_quant_tokens(a)
    out = jnp.zeros((pw.M, n), jnp.int32)
    for packed, lo, hi, g in segs:
        out = out + _segment_gemm_int(packed, a_q[lo:hi], g, impl, interpret, None)
    w_scale = _w_scale(pw)
    return (
        out.astype(jnp.float32) * w_scale[:, None] * a_scale[None, :]
    ).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("g", "impl", "fused", "interpret", "tiles_t", "out_dtype"),
)
def _segment_mpgemm_jit(
    packed, a, *, g, impl, fused, interpret, tiles_t, out_dtype
):
    tiles = dict(tiles_t) if tiles_t else None
    a_scale = act_token_scale(a)
    m = packed.shape[0]
    if fused and impl != "xla":
        w_scale = jnp.ones((m,), jnp.float32)
        return _segment_gemm_fused(
            packed, a, a_scale, w_scale, g, impl, interpret, tiles, out_dtype
        )
    a_q, a_scale = act_quant_tokens(a)
    out = _segment_gemm_int(packed, a_q, g, impl, interpret, tiles)
    return (out.astype(jnp.float32) * a_scale[None, :]).astype(out_dtype)


def segment_mpgemm(
    packed: jax.Array,
    a: jax.Array,
    g: int,
    impl: Impl,
    *,
    fused: bool = True,
    interpret: bool = False,
    tiles: dict | None = None,
    out_dtype=jnp.float32,
) -> jax.Array:
    """One homogeneous-g mpGeMM with unit weight scale — the autotuner's
    timing target (explicit `tiles` override, fused/unfused selectable)."""
    tiles_t = tuple(sorted(tiles.items())) if tiles else None
    return _segment_mpgemm_jit(
        packed, a, g=g, impl=impl, fused=fused, interpret=interpret,
        tiles_t=tiles_t, out_dtype=out_dtype,
    )


def _peek_tiles(pw: PackedWeight, n_tokens: int, impl: str, fusion: str,
                interpret: bool):
    """Best-effort cached-tile lookup for the dispatch trace annotation (the
    first segment's tiles; 'heuristic' when the autotuner has no measured
    winner). Never tunes — this runs on the dispatch path."""
    if impl == "xla":
        return None
    segs = _segments(pw)
    if not segs:
        return None
    packed, _, _, g = segs[0]
    backend = "interpret" if interpret else jax.default_backend()
    hit = autotune.default_cache().get(autotune.cache_key(
        g, impl, packed.shape[0], packed.shape[1], n_tokens,
        backend=backend, fused=fusion == "fused",
    ))
    return hit if hit is not None else "heuristic"


def ternary_matmul(
    pw: PackedWeight,
    x: jax.Array,
    impl: Impl | None = None,
    fusion: Fusion | None = None,
) -> jax.Array:
    """Model-facing packed linear:  y(..., M) = x(..., K) · Wᵀ.

    Fuses the token-first layout transformation (flatten tokens → transpose
    to token-minor) around the kernel, per paper §3.3. Routing comes from the
    process DispatchConfig (see `configure_dispatch`/`dispatch_override`):
    by default the fused single-pass Pallas kernel on TPU (tiles from the
    autotuner) and the shardable XLA streamed-decode elsewhere (incl. the
    multi-pod dry-run). serve/engine.py prefill and decode land here for
    every BitLinear.
    """
    cfg = _dispatch
    if impl is None:
        impl = cfg.impl if cfg.impl is not None else (
            "decode" if (on_tpu() or cfg.interpret) else "xla"
        )
    fusion = fusion if fusion is not None else cfg.fusion
    lead = x.shape[:-1]
    a = x.reshape(-1, x.shape[-1]).T                                 # (K, N) token-minor
    # observability hook: inside a jit this python body runs at *trace* time
    # only, so the span fires once per compiled shape (duration = host-side
    # dispatch/trace cost) with the (M, N, K, impl, fusion, tile) args that
    # make slow ticks attributable to kernel shape choices. Eager calls get
    # a true per-call span. See repro.obs / docs/observability.md.
    o = obs_mod.current()
    if o is not None:
        span = o.mpgemm_span(
            m_tokens=a.shape[1], k=a.shape[0], n_out=pw.M, impl=impl,
            fusion=fusion,
            tiles=_peek_tiles(pw, a.shape[1], impl, fusion, cfg.interpret),
        )
    else:
        span = contextlib.nullcontext()
    with span:
        out = vlut_mpgemm(
            pw, a, impl=impl, interpret=cfg.interpret, out_dtype=x.dtype,
            fusion=fusion,
        )                                                            # (M, N)
    return out.T.reshape(*lead, pw.M)
