"""Tile-size autotuner for the Vec-LUT mpGeMM kernels (paper §4, measured).

The paper's §4 tile-size rules give a *feasible region* (N_tile a multiple of
the vector width, K_tile bounded so the streamed table fits the cache); the
best point inside it is hardware- and shape-dependent. This module:

  * enumerates legal (bm, bn, bkg) candidates under the VMEM-budget rule
    (`candidate_tiles`) — the TPU adaptation of 3^g · N_tile · K_tile/g < L1,
    extended with the fused kernels' float tile + scratch accumulator;
  * times each candidate on the *actual* kernel for a concrete
    (g, M, K-groups, N, backend, fusion) problem (`tune`);
  * persists winners in an on-disk JSON cache (`TileCache`, default
    ``~/.cache/repro/vlut_tiles.json``, override via
    ``REPRO_VLUT_AUTOTUNE_CACHE``) so a shape is timed once per host;
  * answers dispatch-time queries (`get_tiles`): cache hit → cached tiles,
    miss → the §4 heuristic (`heuristic_tiles`, what ops.select_tiles always
    returned) unless inline tuning is enabled (``REPRO_VLUT_AUTOTUNE=1`` or
    ``tune_if_missing=True``).

ops.py routes every kernel dispatch (and therefore `ternary_matmul`, the
model/serve-facing entry) through `get_tiles`; benchmarks/gemm_bench.py and
an explicit `tune` call are the usual cache writers.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time
from typing import Callable, Iterable

_R = 3
#: default per-kernel VMEM working-set budget (§4 K_tile rule, TPU-adapted).
#: This constant is the SINGLE source of truth for every budget consumer —
#: candidate enumeration here, `ops.select_tiles`, and the R5 lint rule all
#: resolve it through :func:`vmem_budget_bytes` so they can never drift.
VMEM_BUDGET_BYTES = 4 * 2**20

CACHE_ENV = "REPRO_VLUT_AUTOTUNE_CACHE"
TUNE_ENV = "REPRO_VLUT_AUTOTUNE"
#: env override for the VMEM budget (bytes) — hardware generations differ
#: (v4: 16 MiB/core usable, v5e: ~64 MiB shared); the autotuner AND the R5
#: lint rule both read this, so an override re-tunes and re-lints coherently
VMEM_BUDGET_ENV = "REPRO_VLUT_VMEM_BUDGET"


def vmem_budget_bytes() -> int:
    """The per-kernel VMEM working-set budget every consumer must use:
    ``REPRO_VLUT_VMEM_BUDGET`` when set (bytes), else VMEM_BUDGET_BYTES.
    A malformed or non-positive override falls back to the default rather
    than silently disabling the budget rule."""
    raw = os.environ.get(VMEM_BUDGET_ENV)
    if raw:
        try:
            v = int(raw)
        except ValueError:
            return VMEM_BUDGET_BYTES
        if v > 0:
            return v
    return VMEM_BUDGET_BYTES

_BM_CANDIDATES = (64, 128, 256)
_BN_CANDIDATES = (128, 256, 512)
_BKG_CANDIDATES = (8, 16, 32, 64, 128, 256)


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def tile_vmem_bytes(
    g: int, impl: str, bm: int, bn: int, bkg: int, *, fused: bool = True
) -> int:
    """Working-set bytes of one grid step (W + A + table + out + scratch)."""
    w = bm * bkg                                   # uint8 codes
    a = g * bkg * bn * (4 if fused else 1)         # f32 tile (fused) vs int8
    table = (_R ** g) * bkg * bn * 2 if impl == "lookup" else 0
    out = bm * bn * 4
    acc = bm * bn * 4 if fused else 0
    scales = 4 * (bm + bn) if fused else 0
    return w + a + table + out + acc + scales


def heuristic_tiles(
    g: int,
    impl: str,
    vmem_budget: int | None = None,
    *,
    fused: bool = False,
) -> dict:
    """The static §4 rule (the pre-autotune default, and the cold-cache
    fallback): bn = minimal multiple of the 128-lane width that feeds the
    MXU (256 for decode — bigger N amortizes the decode), bkg sized so the
    streamed table fits the budget (lookup) or 128 (decode). With
    ``fused=True`` the working set additionally holds the f32 activation
    tile and the int32 scratch accumulator, so bkg shrinks until the whole
    fused tile fits the same budget. ``vmem_budget=None`` resolves through
    :func:`vmem_budget_bytes` (env-overridable)."""
    budget = vmem_budget if vmem_budget is not None else vmem_budget_bytes()
    if impl == "lookup":
        bn = 128
        bkg = max(8, budget // (_R ** g * bn * 2))
        bkg = min(128, 1 << (bkg.bit_length() - 1))                 # pow2 clamp
        t = dict(bm=128, bn=bn, bkg=bkg)
    else:
        t = dict(bm=128, bn=256, bkg=128)
    while (
        fused
        and t["bkg"] > 8
        and tile_vmem_bytes(g, impl, **t, fused=True) > budget
    ):
        t["bkg"] //= 2
    return t


def candidate_tiles(
    g: int,
    impl: str,
    m: int,
    kg: int,
    n: int,
    *,
    fused: bool = True,
    vmem_budget: int | None = None,
) -> list[dict]:
    """Legal (bm, bn, bkg) candidates for a concrete problem: every
    combination from the standard ladders that (a) stays within the VMEM
    budget and (b) isn't degenerate for the problem shape (tiles larger than
    the padded problem are clamped away as duplicates). Always non-empty —
    the §4 heuristic is appended as a safety net."""
    budget = vmem_budget if vmem_budget is not None else vmem_budget_bytes()
    m_cap = _round_up(max(m, 1), 8)
    n_cap = _round_up(max(n, 1), 128)
    out: list[dict] = []
    seen: set[tuple[int, int, int]] = set()
    for bm in _BM_CANDIDATES:
        bm = min(bm, m_cap)
        for bn in _BN_CANDIDATES:
            bn = min(bn, n_cap)
            for bkg in _BKG_CANDIDATES:
                bkg = min(bkg, max(kg, 1))
                key = (bm, bn, bkg)
                if key in seen:
                    continue
                if tile_vmem_bytes(g, impl, bm, bn, bkg, fused=fused) > budget:
                    continue
                seen.add(key)
                out.append(dict(bm=bm, bn=bn, bkg=bkg))
    if not out:
        out.append(heuristic_tiles(g, impl, budget, fused=fused))
    return out


# --------------------------------------------------------------------------
# persistent cache
# --------------------------------------------------------------------------
def cache_key(
    g: int, impl: str, m: int, kg: int, n: int, *, backend: str, fused: bool
) -> str:
    return f"{backend}|{impl}|{'fused' if fused else 'unfused'}|g{g}|m{m}|kg{kg}|n{n}"


class TileCache:
    """On-disk JSON map: cache_key → {bm, bn, bkg, seconds}."""

    def __init__(self, path: str | None = None):
        self.path = path or os.environ.get(CACHE_ENV) or os.path.join(
            os.path.expanduser("~"), ".cache", "repro", "vlut_tiles.json"
        )
        self._data: dict[str, dict] | None = None

    def _load(self) -> dict[str, dict]:
        if self._data is None:
            try:
                with open(self.path) as f:
                    self._data = json.load(f)
            except (OSError, ValueError):
                self._data = {}
        return self._data

    def get(self, key: str) -> dict | None:
        ent = self._load().get(key)
        if not ent:
            return None
        return {k: int(ent[k]) for k in ("bm", "bn", "bkg")}

    def put(self, key: str, tiles: dict, seconds: float | None = None) -> None:
        data = self._load()
        ent = {k: int(tiles[k]) for k in ("bm", "bn", "bkg")}
        if seconds is not None:
            ent["seconds"] = float(seconds)
        data[key] = ent
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        fd, tmp = tempfile.mkstemp(
            dir=os.path.dirname(self.path) or ".", suffix=".tmp"
        )
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(data, f, indent=0, sort_keys=True)
            os.replace(tmp, self.path)
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass


_default_cache: TileCache | None = None


def default_cache() -> TileCache:
    global _default_cache
    if _default_cache is None:
        _default_cache = TileCache()
    return _default_cache


def reset_default_cache(path: str | None = None) -> TileCache:
    """(Re)point the process-wide cache (tests / benchmark isolation)."""
    global _default_cache
    _default_cache = TileCache(path)
    return _default_cache


# --------------------------------------------------------------------------
# timing
# --------------------------------------------------------------------------
def _default_benchmark(
    g: int, impl: str, m: int, kg: int, n: int, *, fused: bool, interpret: bool
) -> Callable[[dict], float]:
    """Times the actual kernel on random data for one tile candidate."""
    import jax
    import numpy as np

    from . import ops  # local import: ops imports this module

    rng = np.random.default_rng(0)
    zero_code = (_R ** g - 1) // 2
    packed = jax.numpy.asarray(
        rng.integers(0, _R ** g, (m, kg)).astype(np.uint8)
    )
    a = jax.numpy.asarray(rng.standard_normal((kg * g, n)).astype(np.float32))

    def run(tiles: dict, repeats: int = 3) -> float:
        fn = lambda: ops.segment_mpgemm(  # noqa: E731
            packed, a, g, impl,
            fused=fused, interpret=interpret, tiles=tiles,
        )
        out = fn()
        jax.block_until_ready(out)                       # compile + warmup
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            times.append(time.perf_counter() - t0)
        times.sort()
        return times[len(times) // 2]

    return run


@dataclasses.dataclass
class TuneResult:
    tiles: dict
    seconds: float
    trials: list[tuple[dict, float]]


def tune(
    g: int,
    impl: str,
    m: int,
    kg: int,
    n: int,
    *,
    fused: bool = True,
    backend: str | None = None,
    interpret: bool = False,
    cache: TileCache | None = None,
    benchmark: Callable[[dict], float] | None = None,
    candidates: Iterable[dict] | None = None,
    vmem_budget: int | None = None,
) -> TuneResult:
    """Time every legal candidate, persist the winner, return it."""
    import jax

    backend = backend or ("interpret" if interpret else jax.default_backend())
    cache = cache or default_cache()
    budget = vmem_budget if vmem_budget is not None else vmem_budget_bytes()
    cands = list(
        candidates
        if candidates is not None
        else candidate_tiles(
            g, impl, m, kg, n, fused=fused, vmem_budget=budget
        )
    )
    bench = benchmark or _default_benchmark(
        g, impl, m, kg, n, fused=fused, interpret=interpret
    )
    trials: list[tuple[dict, float]] = []
    for t in cands:
        try:
            trials.append((t, float(bench(t))))
        except Exception:  # noqa: BLE001 — an illegal candidate just loses
            continue
    if not trials:
        # Every candidate failed (transient OOM, busy device, …): return the
        # heuristic but do NOT poison the persistent cache — a later run
        # should get another chance to tune this key.
        best = heuristic_tiles(g, impl, budget, fused=fused)
        return TuneResult(tiles=best, seconds=float("inf"), trials=trials)
    best, best_s = min(trials, key=lambda kv: kv[1])
    key = cache_key(g, impl, m, kg, n, backend=backend, fused=fused)
    cache.put(key, best, best_s)
    # observability: feed the measured winner into the installed metrics
    # registry (per-(shape, impl) timing series + achieved GB/s / GFLOP/s
    # gauges) so serve-time tuning shows up in the metrics dump
    from repro import obs as obs_mod

    o = obs_mod.current()
    if o is not None:
        o.record_kernel_sample(
            g=g, impl=impl, m=m, kg=kg, n=n, fused=fused, seconds=best_s
        )
    return TuneResult(tiles=best, seconds=best_s, trials=trials)


def get_tiles(
    g: int,
    impl: str,
    m: int,
    kg: int,
    n: int,
    *,
    fused: bool = True,
    backend: str | None = None,
    interpret: bool = False,
    cache: TileCache | None = None,
    tune_if_missing: bool | None = None,
    benchmark: Callable[[dict], float] | None = None,
) -> dict:
    """Dispatch-time tile query: cached winner if present; otherwise tune
    inline when enabled (REPRO_VLUT_AUTOTUNE=1 / tune_if_missing=True) or
    fall back to the §4 heuristic (cold cache, e.g. first trace on CI)."""
    import jax

    backend = backend or ("interpret" if interpret else jax.default_backend())
    cache = cache or default_cache()
    key = cache_key(g, impl, m, kg, n, backend=backend, fused=fused)
    hit = cache.get(key)
    if hit is not None:
        return hit
    if tune_if_missing is None:
        # Env-triggered inline tuning never targets the interpreter: its
        # timings don't transfer to hardware and a single candidate can take
        # minutes. Explicit tune()/tune_if_missing=True still may.
        tune_if_missing = (
            os.environ.get(TUNE_ENV, "0") == "1" and backend != "interpret"
        )
    if tune_if_missing:
        return tune(
            g, impl, m, kg, n,
            fused=fused, backend=backend, interpret=interpret,
            cache=cache, benchmark=benchmark,
        ).tiles
    return heuristic_tiles(g, impl, fused=fused)
