"""Pure-jnp oracles for the Pallas kernels.

The oracle for every vlut/mpGeMM kernel is the *dense ternary matmul* in
int32: unpack the trit codes, multiply, accumulate exactly. All kernels must
match it bit-exactly on the integer output (the LUT transformation is lossless
— paper §5.1 "our method is lossless for ternary weights").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.packing import PackedWeight, unpack_ternary
from repro.core.quantize import act_quant_tokens


def ref_segment_gemm_int(packed: jax.Array, a_q: jax.Array, g: int) -> jax.Array:
    """Dense int32 reference for one homogeneous-g segment.

    packed: (M, K//g) uint8, a_q: (K, N) int8 → (M, N) int32.
    """
    w_t = unpack_ternary(packed, g)                                  # (M, K) int8
    return jax.lax.dot_general(
        w_t, a_q, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def ref_mpgemm_int(pw: PackedWeight, a_q: jax.Array) -> jax.Array:
    """Dense int32 reference over all segments. a_q: (K, N) int8 → (M, N)."""
    out = jnp.zeros((pw.M, a_q.shape[1]), jnp.int32)
    if pw.packed5.shape[-1]:
        out = out + ref_segment_gemm_int(pw.packed5, a_q[: pw.k5], 5)
    if pw.packed4.shape[-1]:
        out = out + ref_segment_gemm_int(pw.packed4, a_q[pw.k5 :], 4)
    return out


def ref_mpgemm(pw: PackedWeight, a: jax.Array) -> jax.Array:
    """Float end-to-end reference (per-token int8 act quant + dequant)."""
    a_q, a_scale = act_quant_tokens(a)
    out = ref_mpgemm_int(pw, a_q)
    w_scale = (
        pw.scale if pw.scale.shape[-1] == pw.M else jnp.broadcast_to(pw.scale, (pw.M,))
    )
    return out.astype(jnp.float32) * w_scale[:, None] * a_scale[None, :]
