"""repro.kernels — Pallas TPU kernels for the Vec-LUT mpGeMM hot spot.

  vlut_lookup_gemm.py   — paper-faithful streamed vector-LUT (VMEM table +
                          1→N lookup), `pl.pallas_call` + BlockSpec tiling.
  ternary_decode_gemm.py— beyond-paper TPU-native streamed decode + MXU dot
                          (same ≤2-bit HBM format, same layout rules).
  flash_attention.py    — IO-aware attention (VMEM-resident scores) for the
                          train/prefill memory term (EXPERIMENTS §Perf).
  ops.py                — jit wrappers: fused layout transform, padding,
                          tile selection, backend dispatch, scales.
  ref.py                — pure-jnp oracles (dense int32 ternary matmul).
"""
from .flash_attention import flash_attention, flash_attention_bsnd
from .ops import select_tiles, ternary_matmul, vlut_mpgemm
from .ref import ref_mpgemm, ref_mpgemm_int, ref_segment_gemm_int
from .ternary_decode_gemm import ternary_decode_gemm
from .vlut_lookup_gemm import vlut_lookup_gemm

__all__ = [
    "flash_attention", "flash_attention_bsnd",
    "select_tiles", "ternary_matmul", "vlut_mpgemm",
    "ref_mpgemm", "ref_mpgemm_int", "ref_segment_gemm_int",
    "ternary_decode_gemm", "vlut_lookup_gemm",
]
