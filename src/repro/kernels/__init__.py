"""repro.kernels — Pallas TPU kernels for the Vec-LUT mpGeMM hot spot.

The hot path is the **fused single-pass pipeline** (paper §3.3): float
activations stream into the kernel, each grid step quantizes its tile against
the per-token scale in VMEM and de-interleaves in registers, and the
w_scale × a_scale dequant epilogue runs on the last K step — no int8
activation buffer, de-interleave rematerialization, or int32 output ever
round-trips through HBM. Tile sizes come from the measured autotuner with
the static §4 heuristic as the cold-cache fallback.

  vlut_lookup_gemm.py   — paper-faithful streamed vector-LUT (VMEM table +
                          1→N lookup): `vlut_lookup_gemm` (integer/unfused)
                          and `vlut_lookup_gemm_fused` (single-pass).
  ternary_decode_gemm.py— beyond-paper TPU-native streamed decode + MXU dot
                          (same ≤2-bit HBM format, same layout rules):
                          `ternary_decode_gemm` / `ternary_decode_gemm_fused`.
  autotune.py           — §4 tile-size rules made empirical: candidate
                          enumeration under the VMEM budget, per-(g, M, K,
                          N, backend) timing, persistent on-disk cache.
  flash_attention.py    — IO-aware attention (VMEM-resident scores) for the
                          train/prefill memory term (EXPERIMENTS §Perf).
  ops.py                — jit wrappers: fused/unfused dispatch, padding,
                          autotuned tile selection, scales, and the
                          DispatchConfig that serve/engine.py routes through.
  ref.py                — pure-jnp oracles (dense int32 ternary matmul).
"""
from . import autotune
from .flash_attention import flash_attention, flash_attention_bsnd
from .ops import (
    configure_dispatch,
    dispatch_override,
    segment_mpgemm,
    select_tiles,
    ternary_matmul,
    vlut_mpgemm,
)
from .ref import ref_mpgemm, ref_mpgemm_int, ref_segment_gemm_int
from .ternary_decode_gemm import ternary_decode_gemm, ternary_decode_gemm_fused
from .vlut_lookup_gemm import vlut_lookup_gemm, vlut_lookup_gemm_fused

__all__ = [
    "autotune",
    "flash_attention", "flash_attention_bsnd",
    "configure_dispatch", "dispatch_override", "segment_mpgemm",
    "select_tiles", "ternary_matmul", "vlut_mpgemm",
    "ref_mpgemm", "ref_mpgemm_int", "ref_segment_gemm_int",
    "ternary_decode_gemm", "ternary_decode_gemm_fused",
    "vlut_lookup_gemm", "vlut_lookup_gemm_fused",
]
