"""Static analysis of optimized HLO text → roofline inputs.

XLA's `compiled.cost_analysis()` counts ops inside `while` bodies (lax.scan —
i.e. *every layer of every model here*) exactly once, so its flops/bytes are
useless for scanned models. This module parses the post-SPMD HLO text and
computes, with loop-trip-count multipliers propagated through the call graph
(entry → while bodies → nested scans; fusion bodies fold into their call
sites):

  * dot_flops        — 2 · |result| · |contraction| per dot, × multiplier
  * traffic_bytes    — Σ (operand + result bytes) over *materializing* ops
                       (fusions, dots, copies, DUS, converts, collectives…)
                       — a fused-op-level HBM traffic model
  * collective bytes — per collective kind, × multiplier

Trip counts come from the integer constant in each while's condition
computation (lax.scan lowers to `compare(i, c), direction=LT`); dynamic
conditions fall back to ×1 and are reported in `unknown_trip_whiles`.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|\S+?)\s+([\w\-]+)\((.*)$"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.+\s*\{")
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# ops that don't move HBM bytes themselves
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id", "replica-id",
    "iota", "custom-call",  # custom-call operands counted if it materializes
}


def _dims(shape_str: str):
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return None, 0
    dt, dims = m.groups()
    sizes = [int(d) for d in dims.split(",") if d]
    n = 1
    for s in sizes:
        n *= s
    return sizes, n * _DTYPE_BYTES.get(dt, 4)


def _shape_bytes_multi(type_str: str) -> int:
    return sum(_dims(s.group(0))[1] for s in _SHAPE_RE.finditer(type_str))


@dataclass
class Op:
    name: str
    result_type: str
    kind: str
    rest: str  # operands + attrs (raw tail of the line)


@dataclass
class HloStats:
    dot_flops: float = 0.0
    traffic_bytes: float = 0.0
    collectives: dict = field(default_factory=dict)
    unknown_trip_whiles: int = 0
    n_whiles: int = 0

    @property
    def collective_bytes(self) -> float:
        return sum(v["bytes"] for v in self.collectives.values())


def _parse_computations(text: str):
    comps: dict[str, list[Op]] = {}
    entry = None
    cur = None
    for raw in text.splitlines():
        line = raw.rstrip()
        mc = _COMP_RE.match(line.strip())
        if mc and ("=" not in line.split("(")[0]):
            cur = mc.group(1)
            comps[cur] = []
            if line.strip().startswith("ENTRY"):
                entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        mo = _OP_RE.match(line)
        if mo:
            name, rtype, kind, rest = mo.groups()
            comps[cur].append(Op(name, rtype, kind, rest))
    return comps, entry


def _split_operands(rest: str) -> tuple[list[str], str]:
    """Split 'a, %b, ...), attrs' into operand names and the attr tail."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                inner, attrs = rest[:i], rest[i + 1:]
                ops = [o.strip() for o in _top_split(inner)]
                names = [
                    o.split()[-1].lstrip("%") for o in ops if o and "%" in o
                ]
                return names, attrs
    return [], rest


def _top_split(s: str):
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return out


def _fusion_traffic(op, names, attrs, comps, shapes, res_b) -> float:
    """Fusion-op traffic with slice-through-parameter inspection."""
    mcall = re.search(r"calls=%?([\w.\-]+)", attrs)
    body = comps.get(mcall.group(1), []) if mcall else []
    param_idx: dict[str, int] = {}
    defs: dict[str, "Op"] = {}
    for bop in body:
        defs[bop.name] = bop
        if bop.kind == "parameter":
            mi = re.match(r"(\d+)", bop.rest)
            if mi:
                param_idx[bop.name] = int(mi.group(1))
    # params that are only read through a slice/gather/ds charge slice bytes
    sliced_bytes: dict[int, float] = {}
    full_use: set[int] = set()
    root_dus_upd: float | None = None
    for bop in body:
        if bop.kind == "parameter":
            continue
        onames, _ = _split_operands(bop.rest)
        for pos, nm in enumerate(onames):
            tgt = nm
            # resolve through layout/dtype-only chains to the fusion param
            for _hop in range(6):
                if tgt in param_idx or tgt not in defs:
                    break
                if defs[tgt].kind in ("bitcast", "copy", "reshape",
                                      "transpose", "convert"):
                    inner, _ = _split_operands(defs[tgt].rest)
                    if not inner:
                        break
                    tgt = inner[0]
                else:
                    break
            if tgt not in param_idx:
                continue
            pi = param_idx[tgt]
            if bop.kind in ("dynamic-slice", "slice", "gather") and pos == 0:
                sliced_bytes[pi] = sliced_bytes.get(pi, 0.0) + _shape_bytes_multi(
                    bop.result_type
                )
            elif bop.kind == "dynamic-update-slice" and pos == 0:
                upd_names, _ = _split_operands(bop.rest)
                upd_b = (
                    _shape_bytes_multi(shapes.get(upd_names[1], ""))
                    or _shape_bytes_multi(
                        defs[upd_names[1]].result_type
                    ) if len(upd_names) > 1 and upd_names[1] in defs else 0
                )
                sliced_bytes[pi] = sliced_bytes.get(pi, 0.0) + 2 * upd_b
                root_dus_upd = (root_dus_upd or 0.0) + upd_b
            else:
                full_use.add(pi)
    total = 0.0
    for pos, nm in enumerate(names):
        ob = _shape_bytes_multi(shapes.get(nm, ""))
        if pos in sliced_bytes and pos not in full_use:
            total += min(ob, sliced_bytes[pos])
        else:
            total += ob
    # DUS-rooted fusion writes the update region, not the whole buffer
    if root_dus_upd is not None and res_b >= root_dus_upd:
        total += root_dus_upd
    else:
        total += res_b
    return total


def parse_hlo_stats(text: str) -> HloStats:
    comps, entry = _parse_computations(text)
    shapes: dict[str, str] = {}
    for ops in comps.values():
        for op in ops:
            shapes[op.name] = op.result_type

    # ---- call-graph multipliers -------------------------------------------
    mult: dict[str, float] = defaultdict(float)
    if entry is None:
        return HloStats()
    stats = HloStats()

    def trip_count(cond_comp: str) -> float:
        best = None
        for op in comps.get(cond_comp, []):
            if op.kind == "constant":
                m = re.search(r"constant\((-?\d+)", "constant(" + op.rest)
                if m:
                    v = int(m.group(1))
                    if v > 0:
                        best = max(best or 0, v)
        if best is None:
            stats.unknown_trip_whiles += 1
            return 1.0
        return float(best)

    # BFS from entry
    pending = [(entry, 1.0)]
    seen_pairs = []
    fusion_parent_mult: dict[str, float] = defaultdict(float)
    while pending:
        comp, m = pending.pop()
        mult[comp] += m
        for op in comps.get(comp, []):
            attrs = op.rest
            if op.kind == "while":
                mb = re.search(r"body=%?([\w.\-]+)", attrs)
                mc = re.search(r"condition=%?([\w.\-]+)", attrs)
                stats.n_whiles += 1
                if mb:
                    tc = trip_count(mc.group(1)) if mc else 1.0
                    pending.append((mb.group(1), m * tc))
            elif op.kind == "call":
                # plain `call` is real top-level code (e.g. the CPU backend's
                # parallelization wrapper around fusions), not an element-wise
                # body: descend with the caller's multiplier so materializing
                # ops inside still count traffic.
                for mm in re.finditer(r"to_apply=%?([\w.\-]+)", attrs):
                    pending.append((mm.group(1), m))
            elif op.kind in ("fusion", "custom-call", "reduce",
                             "map", "scatter", "select-and-scatter", "sort"):
                for mm in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)", attrs):
                    fusion_parent_mult[mm.group(1)] += m
            elif op.kind == "conditional":
                for mm in re.finditer(
                    r"(?:branch_computations=\{([^}]*)\}|"
                    r"(?:true|false)_computation=%?([\w.\-]+))", attrs,
                ):
                    blob = mm.group(1) or mm.group(2) or ""
                    for b in re.findall(r"%?([\w.\-]+)", blob):
                        pending.append((b, m))

    # dots inside fusion/reduce bodies count at the call-site multiplier
    for comp, m in fusion_parent_mult.items():
        if comp in comps:
            mult[comp] += m

    # ---- accumulate ---------------------------------------------------------
    for comp, ops in comps.items():
        m = mult.get(comp, 0.0)
        if m == 0.0:
            continue
        in_fusion_body = comp in fusion_parent_mult
        for op in ops:
            if op.kind == "dot":
                res_dims, _ = _dims(op.result_type)
                names, attrs = _split_operands(op.rest)
                lhs_shape = shapes.get(names[0], "") if names else ""
                lhs_dims, _ = _dims(lhs_shape)
                mctr = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", attrs)
                ctr = 1
                if lhs_dims and mctr:
                    for d in mctr.group(1).split(","):
                        if d:
                            ctr *= lhs_dims[int(d)]
                nres = 1
                for d in res_dims or []:
                    nres *= d
                stats.dot_flops += m * 2.0 * nres * ctr
            kind = next(
                (k for k in _COLLECTIVES
                 if op.kind == k or op.kind.startswith(k + "-start")
                 or op.kind == k + "-start"),
                None,
            )
            if kind:
                b = _shape_bytes_multi(op.result_type)
                ent = stats.collectives.setdefault(kind, {"bytes": 0.0, "count": 0})
                ent["bytes"] += m * b
                ent["count"] += m
            # traffic model: top-level materializing ops only.
            # Sliced access patterns charge the bytes actually touched, not
            # the whole operand (a dynamic-slice of a 500k-token cache reads
            # one slice, not the buffer). Fusions are inspected: operands
            # that are only sliced/gathered inside the fused body charge the
            # slice bytes; a DUS root charges the update, not the buffer.
            if not in_fusion_body and op.kind not in _FREE_OPS:
                names, attrs = _split_operands(op.rest)
                res_b = _shape_bytes_multi(op.result_type)
                if op.kind in ("dynamic-slice", "slice", "gather"):
                    b = 2 * res_b                      # read slice + write out
                elif op.kind == "dynamic-update-slice":
                    upd = (_shape_bytes_multi(shapes.get(names[1], ""))
                           if len(names) > 1 else res_b)
                    b = 3 * upd                        # read old+new, write
                elif op.kind == "scatter":
                    upd = (_shape_bytes_multi(shapes.get(names[2], ""))
                           if len(names) > 2 else res_b)
                    b = 3 * upd
                elif op.kind == "fusion":
                    b = _fusion_traffic(op, names, attrs, comps, shapes, res_b)
                else:
                    b = res_b
                    for nm in names:
                        b += _shape_bytes_multi(shapes.get(nm, ""))
                stats.traffic_bytes += m * b
    return stats
