"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three-term model per (arch × shape × mesh), TPU v5e constants:
    compute_s    = HLO_FLOPs_per_device / 197e12        (bf16 MXU peak)
    memory_s     = HLO_bytes_per_device / 819e9         (HBM bandwidth)
    collective_s = collective_bytes_per_device / 50e9   (per-link ICI)

`compiled.cost_analysis()` runs on the *post-SPMD per-device* module, so its
flops/bytes are already per-chip. Collective bytes are NOT in cost_analysis —
we parse the optimized HLO text and sum the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(shapes there are per-device too).

MODEL_FLOPS uses 6·N·D (train) or 2·N·D (single forward) with N = active
params, so the ratio MODEL_FLOPS / (HLO_FLOPs × chips) exposes remat/
redundancy waste.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """bytes of one HLO shape like 'f32[8,128]' (scalars: 'f32[]')."""
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    nbytes = _DTYPE_BYTES.get(dt, 4)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


def mpgemm_cost(m: int, k: int, n: int, g: int, *,
                fused: bool = True) -> tuple[float, float]:
    """Analytic (FLOPs, HBM bytes) of one packed mpGeMM dispatch: the
    (m, k) ternary weight (k/g packed uint8 codes per row) against n
    parallel tokens. The fused single-pass kernel touches HBM exactly for
    the packed codes, the float activation, and the float output; the
    unfused pipeline additionally materializes the int8 activation and the
    int32 output between stages (each written once, read once). Used for
    the achieved-bandwidth gauges (repro.obs) and the crossover table's
    intensity column; the HLO-parsed figures (parse_hlo_stats) stay the
    ground truth where a compiled module is at hand."""
    kg = k // g
    flops = 2.0 * m * k * n
    bytes_ = m * kg + 4.0 * k * n + 4.0 * m * n          # packed + A + out
    if not fused:
        bytes_ += 2.0 * k * n + 2.0 * 4.0 * m * n        # int8 A, int32 out
    return flops, bytes_


def collective_bytes(hlo_text: str) -> dict[str, dict[str, float]]:
    """Sum result-shape bytes per collective kind from optimized HLO text."""
    out: dict[str, dict[str, float]] = {
        k: {"bytes": 0, "count": 0} for k in _COLLECTIVES
    }
    for line in hlo_text.splitlines():
        line = line.strip()
        # e.g. %all-reduce.1 = f32[8,16]{1,0} all-reduce(...)
        #      %ag = (bf16[4,8]{1,0}, bf16[4,8]{1,0}) all-gather(...)
        m = re.match(r"%?[\w.\-]+\s*=\s*(\(.*?\)|\S+)\s+([\w-]+)", line)
        if not m:
            continue
        shapes_str, op = m.groups()
        kind = next(
            (k for k in _COLLECTIVES if op == k or op.startswith(k + ".")), None
        )
        if kind is None:
            continue
        if op.endswith("-start"):
            kind = next((k for k in _COLLECTIVES if op.startswith(k)), kind)
        if shapes_str.startswith("("):
            shapes = re.findall(r"(\w+\[[\d,]*\])(?:\{[^}]*\})?", shapes_str)
            total = sum(_shape_bytes(s) for s in shapes)
        else:
            total = _shape_bytes(shapes_str.split("{")[0])
        out[kind]["bytes"] += total
        out[kind]["count"] += 1
    # async pairs (-start/-done) would double count; the regex above only
    # matches ops whose NAME starts with the kind, and -done ops return the
    # same tuple — halve if both forms present is handled by matching `=`
    # result of -start only (the -done result repeats); accept small overcount.
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_detail: dict = field(default_factory=dict)
    model_flops_total: float = 0.0
    min_bytes_per_device: float = 0.0  # irreducible state traffic (params+cache)

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_device / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        hlo_total = self.flops_per_device * self.chips
        return self.model_flops_total / hlo_total if hlo_total else 0.0

    @property
    def memory_efficiency(self) -> float:
        """irreducible state bytes / actual HLO bytes — the score for
        memory-bound (decode) cells where MFU is ~0 by construction."""
        return (
            self.min_bytes_per_device / self.bytes_per_device
            if self.bytes_per_device else 0.0
        )

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / achievable step time (the score)."""
        if self.bound_s == 0:
            return 0.0
        useful_s = self.model_flops_total / (self.chips * PEAK_FLOPS)
        return useful_s / self.bound_s

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops_total,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "memory_efficiency": self.memory_efficiency,
            "coll_detail": self.coll_detail,
        }


def model_flops(cfg, shape, n_params_total: int, n_params_active: int) -> float:
    """6·N·D for train, 2·N·D for a single forward (prefill/decode step)."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_params_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_params_active * tokens
    # decode: one token per sequence
    return 2.0 * n_params_active * shape.global_batch


def count_params(cfg) -> tuple[int, int]:
    """(total, active) parameter counts from the abstract init (no alloc)."""
    import jax

    from repro.launch.steps import _init_params_fn

    params = jax.eval_shape(_init_params_fn(cfg))
    total = active = 0

    def walk(node, in_experts):
        nonlocal total, active
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, in_experts or k == "experts")
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v, in_experts)
        elif hasattr(node, "size"):
            total += node.size
            if in_experts and cfg.moe is not None:
                mc = cfg.moe
                active += int(node.size * mc.top_k / mc.n_experts)
            else:
                active += node.size

    walk(params, False)
    return total, active
