"""Step builders (train / prefill / decode) + abstract state constructors.

These are the functions the launcher jits and the dry-run lowers. All of them
are traced inside `use_sharding_ctx(mesh, cfg)` so activation constraints
resolve; inputs/outputs carry NamedShardings via ShapeDtypeStruct.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist.sharding import (
    batch_shardings,
    cache_shardings,
    opt_shardings,
    param_shardings,
    shard_act,
)
from repro.models import (
    decode_step as model_decode,
    encdec_init,
    encdec_loss,
    encode,
    init_cache,
    init_lm,
    lm_loss,
    pack_params,
    prefill as model_prefill,
)
from repro.optim import AdamWConfig, adamw_init, adamw_update


# --------------------------------------------------------------------------
# step functions
# --------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig):
    is_encdec = cfg.family == "encdec"

    def train_step(state, batch):
        tokens = shard_act(batch["tokens"], "tokens")
        labels = shard_act(batch["labels"], "tokens")

        def loss_fn(params):
            if is_encdec:
                return encdec_loss(
                    params, batch["frames"], tokens, labels, cfg, mode="train"
                )
            return lm_loss(params, tokens, labels, cfg, mode="train")

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"]
        )
        new_params, new_opt, om = adamw_update(
            state["params"], grads, state["opt"], opt_cfg
        )
        metrics = dict(metrics, loss=loss, **om)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    is_encdec = cfg.family == "encdec"

    def prefill_step(params, cache, batch):
        tokens = shard_act(batch["tokens"], "tokens")
        enc_out = None
        if is_encdec:
            enc_out = encode(params, batch["frames"], cfg, mode="serve")
            dec_params = params["decoder"]
        else:
            dec_params = params
        logits, new_cache = model_prefill(
            dec_params, tokens, cache, cfg, mode="serve", enc_out=enc_out
        )
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_cache

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    is_encdec = cfg.family == "encdec"

    def decode_step(params, cache, tokens):
        dec_params = params["decoder"] if is_encdec else params
        logits, new_cache = model_decode(dec_params, tokens, cache, cfg, mode="serve")
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_cache

    return decode_step


# --------------------------------------------------------------------------
# abstract state (eval_shape — no allocation) with shardings attached
# --------------------------------------------------------------------------
def _attach(tree, shardings):
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        tree, shardings,
    )


def _init_params_fn(cfg: ModelConfig):
    rng = jax.random.PRNGKey(0)
    if cfg.family == "encdec":
        return lambda: encdec_init(rng, cfg)
    return lambda: init_lm(rng, cfg)


def abstract_train_state(cfg: ModelConfig, opt_cfg: AdamWConfig, mesh):
    params = jax.eval_shape(_init_params_fn(cfg))
    opt = jax.eval_shape(functools.partial(adamw_init, cfg=opt_cfg), params)
    return {
        "params": _attach(params, param_shardings(params, mesh, cfg)),
        "opt": _attach(opt, opt_shardings(opt, mesh, cfg)),
    }


def abstract_serve_params(cfg: ModelConfig, mesh):
    init = _init_params_fn(cfg)
    packed = jax.eval_shape(lambda: pack_params(init(), cfg))
    return _attach(packed, param_shardings(packed, mesh, cfg))


def abstract_cache(cfg: ModelConfig, mesh, batch: int, max_len: int, enc_len: int = 0):
    cache = jax.eval_shape(
        functools.partial(
            init_cache, cfg, batch, max_len, dtype=jnp.bfloat16, enc_len=enc_len
        )
    )
    return _attach(cache, cache_shardings(cache, mesh, cfg))


# --------------------------------------------------------------------------
# input specs per (arch × shape) — the dry-run's model inputs
# --------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if shape.kind == "train":
        batch = {"tokens": tok, "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct(
                (b, s // cfg.enc_frame_ratio, cfg.d_model), jnp.bfloat16
            )
        return _attach(batch, batch_shardings(batch, mesh, cfg))
    if shape.kind == "prefill":
        batch = {"tokens": tok}
        if cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct(
                (b, s // cfg.enc_frame_ratio, cfg.d_model), jnp.bfloat16
            )
        return _attach(batch, batch_shardings(batch, mesh, cfg))
    # decode: one new token against a seq_len cache
    batch = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    return _attach(batch, batch_shardings(batch, mesh, cfg))
