"""Serving launcher: continuous batching over synthetic requests.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
      --requests 32 --max-new 16

Add --spec-k N for speculative decoding (n-gram drafter, N draft tokens per
batched verify step); the summary line then reports acceptance and tok/step.
--spec-adaptive adapts each slot's draft length to its acceptance EWMA
(cold slots skip drafting entirely), adding mean_k and skip-rate columns.
--spec-tree B1,B2,... verifies a draft *tree* (top-B candidates at each of
the first depths) in one flattened pass, adding a nodes/step column.
--prefill-chunk N switches admission to chunked prefill: each tick runs one
batched mixed step carrying every prefilling slot's next N-token chunk plus
the decode rows, so the Vec-LUT kernels see parallel tokens every tick;
--token-budget caps the real tokens scheduled per tick.
--page-size N switches the KV cache to the paged layout (block tables over a
physical page pool, serve.paging) with radix prompt-prefix sharing; --kv-pages
sizes the pool (out-of-pages requests queue instead of rejecting) and
--offload-pages bounds the host-RAM tier for cold prefix pages.

Observability (repro.obs) is on by default (--no-obs disables): the periodic
stats line (--stats-interval S) and the summary's latency/acceptance columns
read from the metrics registry — the single export surface synced from the
engine's counters — and --metrics-out/--trace-out dump the Prometheus-style
JSON metrics snapshot and a Perfetto-loadable trace on exit.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import encdec_init, init_lm, pack_params
from repro.obs import ObsConfig
from repro.serve import ContinuousBatchingScheduler, Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--no-pack", action="store_true",
                    help="serve the QAT (unpacked) weights for comparison")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding draft length (0 = off; "
                         "n-gram prompt-lookup drafter)")
    ap.add_argument("--spec-adaptive", action="store_true",
                    help="per-slot adaptive draft length from the running "
                         "acceptance rate (cold slots skip drafting)")
    ap.add_argument("--spec-tree", default="",
                    help="comma-separated branching factors (e.g. '2,2') for "
                         "tree-structured multi-candidate verification")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill: consume prompts N tokens per tick "
                         "in one batched mixed prefill/decode step "
                         "(0 = whole-prompt admission prefill)")
    ap.add_argument("--token-budget", type=int, default=0,
                    help="cap on real tokens scheduled per chunked tick "
                         "(0 = unlimited; needs --prefill-chunk)")
    ap.add_argument("--page-size", type=int, default=0,
                    help="paged KV: tokens per page (0 = dense slot cache); "
                         "enables radix prompt-prefix sharing")
    ap.add_argument("--kv-pages", type=int, default=0,
                    help="paged KV pool size incl. the null page "
                         "(0 = auto: slots*max_len/page_size + 1; "
                         "needs --page-size)")
    ap.add_argument("--offload-pages", type=int, default=0,
                    help="host-RAM offload tier capacity in pages for cold "
                         "prefix pages (0 = drop instead; needs --page-size)")
    ap.add_argument("--no-obs", action="store_true",
                    help="disable the observability layer (metrics + trace)")
    ap.add_argument("--stats-interval", type=float, default=0.0,
                    help="print a registry-backed stats line every S seconds "
                         "while serving (0 = off)")
    ap.add_argument("--metrics-out", default="",
                    help="write the JSON metrics snapshot here on exit")
    ap.add_argument("--trace-out", default="",
                    help="write the Perfetto trace_event JSON here on exit")
    args = ap.parse_args()
    if (args.spec_adaptive or args.spec_tree) and not args.spec_k:
        ap.error("--spec-adaptive/--spec-tree require --spec-k N (N >= 1)")
    if args.token_budget and not args.prefill_chunk:
        ap.error("--token-budget requires --prefill-chunk N (N >= 1)")
    if (args.kv_pages or args.offload_pages) and not args.page_size:
        ap.error("--kv-pages/--offload-pages require --page-size N (N >= 1)")
    if args.spec_adaptive and args.spec_tree:
        ap.error("--spec-tree and --spec-adaptive are mutually exclusive")
    if args.no_obs and (args.stats_interval or args.metrics_out
                        or args.trace_out):
        ap.error("--no-obs conflicts with --stats-interval/--metrics-out/"
                 "--trace-out")

    cfg = get_config(args.arch, smoke=args.smoke)
    init = encdec_init if cfg.family == "encdec" else init_lm
    params = init(jax.random.PRNGKey(0), cfg)
    if not args.no_pack:
        params = pack_params(params, cfg)

    spec = None
    if args.spec_k:
        from repro.spec import SpecConfig

        tree = (
            tuple(int(x) for x in args.spec_tree.split(","))
            if args.spec_tree else None
        )
        spec = SpecConfig(k=args.spec_k, adaptive_k=args.spec_adaptive,
                          tree=tree)
    obs_cfg = None if args.no_obs else ObsConfig(
        metrics_out=args.metrics_out or None,
        trace_out=args.trace_out or None,
    )
    paged = None
    if args.page_size:
        from repro.serve import PagedKVConfig

        paged = PagedKVConfig(
            page_size=args.page_size, n_pages=args.kv_pages,
            host_offload_pages=args.offload_pages,
        )
    engine = Engine(
        params, cfg, max_slots=args.slots, max_len=args.max_len,
        temperature=args.temperature, spec=spec,
        prefill_chunk=args.prefill_chunk, token_budget=args.token_budget,
        paged_kv=paged, obs=obs_cfg,
    )
    sched = ContinuousBatchingScheduler(engine)
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(
                0, cfg.vocab, size=rng.integers(4, args.prompt_len + 1)
            ).astype(np.int32),
            max_new_tokens=args.max_new,
        )
        for i in range(args.requests)
    ]
    sched.submit(reqs)
    obs = engine.obs
    t_serve = time.perf_counter()
    if args.stats_interval:
        # registry-backed periodic logging: tick manually, report from the
        # metric objects (the gauges/counters obs.on_tick syncs each tick)
        next_at = time.perf_counter() + args.stats_interval
        while sched.queue or engine.has_work:
            sched.tick()
            if time.perf_counter() >= next_at:
                print(f"[obs] {obs.stats_line()}", flush=True)
                next_at = time.perf_counter() + args.stats_interval
    stats = sched.run_to_completion()
    # the manual tick loop's work lands in this run's token counters, so its
    # wall time must land in the run's clock too or tok/s is inflated
    stats.wall_s = time.perf_counter() - t_serve
    spec_cols = (
        f" accept={stats.acceptance_rate:.2f} "
        f"tok/step={stats.decode_tokens_per_step:.2f}"
        if stats.spec_steps else ""
    )
    if stats.spec_steps and args.spec_adaptive:
        spec_cols += (
            f" mean_k={stats.mean_draft_k:.2f} skip={stats.skip_rate:.2f}"
        )
    if stats.spec_steps and args.spec_tree:
        spec_cols += f" nodes/step={stats.nodes_per_step:.1f}"
    rej_cols = f" rejected={stats.rejected}" if stats.rejected else ""
    paged_cols = (
        f" pages={engine.pager.free_pages}/{engine.pager.total_pages}"
        f" prefix_hit={stats.prefix_hit_tokens}tok"
        f"/{stats.prefix_hit_requests}req"
        if engine.pager is not None else ""
    )
    chunk_cols = (
        f" chunk_steps={stats.chunk_steps} pad={stats.prefill_pad_tokens}"
        if args.prefill_chunk else ""
    )
    # latency columns come from the registry histograms when obs is on (the
    # single latency surface — p50/p95 interpolated from the bucket counts);
    # the --no-obs fallback keeps the ad-hoc median over ServeStats events.
    # Either way: no TTFT events → omit the column, never a fake 0.
    if obs.enabled and obs.h_ttft.count:
        ttft_col = (
            f" ttft_p50={1e3 * obs.h_ttft.percentile(0.5):.1f}ms"
            f" p95={1e3 * obs.h_ttft.percentile(0.95):.1f}ms"
        )
        if obs.h_tpot.count:
            ttft_col += f" tpot_p50={1e3 * obs.h_tpot.percentile(0.5):.1f}ms"
        if obs.s_eff_m.count:
            ttft_col += f" eff_m={obs.s_eff_m.mean:.1f}"
    else:
        ttft_col = (
            f" ttft_median={1e3 * float(np.median(stats.ttft_s)):.1f} ms"
            if stats.ttft_s else ""
        )
    print(
        f"completed={stats.completed}/{args.requests} "
        f"throughput={stats.throughput_tok_s:.1f} tok/s "
        f"(prefill {stats.prefill_tok_s:.1f}, decode {stats.decode_tok_s:.1f})"
        f"{ttft_col}{spec_cols}{chunk_cols}{paged_cols}{rej_cols}"
    )
    for path in obs.finalize():
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
