"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_small_mesh(n_data: int = 4, n_model: int = 2, *, multi_pod: bool = False):
    """Reduced mesh for in-CI sharding tests (8 host devices)."""
    if multi_pod:
        return jax.make_mesh((2, n_data // 2, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def batch_axes(mesh) -> tuple[str, ...]:
    """Mesh axes that jointly shard the batch / FSDP dimension."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    out = 1
    for n in names:
        if n in mesh.axis_names:
            out *= mesh.shape[n]
    return out
