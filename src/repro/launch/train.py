"""Training launcher with bounded-restart supervision.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Production posture: XLA latency-hiding-scheduler flags are preset (compute/
communication overlap on real TPU); the supervisor restarts the trainer from
its last checkpoint on retryable failures; SIGTERM checkpoints and exits.
"""
import os

# compute/comm overlap: async collectives + latency-hiding scheduler.
_PERF_FLAGS = (
    " --xla_tpu_enable_async_collective_fusion=true"
    " --xla_tpu_enable_latency_hiding_scheduler=true"
    " --xla_tpu_overlap_compute_collective_tc=true"
)
if os.environ.get("REPRO_TPU_PERF_FLAGS", "0") == "1":
    os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + _PERF_FLAGS

import argparse

from repro.configs import get_config
from repro.data import DataConfig
from repro.dist.fault_tolerance import run_with_restarts
from repro.optim import AdamWConfig
from repro.train import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--no-int8-state", action="store_true")
    ap.add_argument("--max-restarts", type=int, default=3)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    tc = TrainConfig(
        total_steps=args.steps,
        microbatches=args.microbatches,
        checkpoint_every=args.ckpt_every,
        checkpoint_dir=args.ckpt_dir,
        grad_compression=args.grad_compression,
    )
    opt = AdamWConfig(
        lr=args.lr, warmup_steps=max(args.steps // 20, 5),
        total_steps=args.steps, int8_state=not args.no_int8_state,
    )
    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)

    def attempt(i: int):
        print(f"[supervisor] attempt {i}")
        trainer = Trainer(cfg, opt, tc, dc, install_signals=True)
        trainer.run()

    run_with_restarts(attempt, max_restarts=args.max_restarts)
    print("[supervisor] training complete")


if __name__ == "__main__":
    main()
