import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=512"
    )
# ^ MUST precede every other import (jax locks device count on first init).
# Tests may pre-set a smaller count via XLA_FLAGS; production dry-run gets 512.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. constructs abstract state (eval_shape — ShapeDtypeStruct only, zero
     allocation) with NamedShardings from repro.dist.sharding rules,
  3. jits the right step (train_step for train_4k, prefill_step for
     prefill_32k, serve/decode_step for decode_32k & long_500k),
  4. .lower().compile() — proving the distribution config is coherent,
  5. records memory_analysis / cost_analysis / parsed collective bytes into
     a JSON results file consumed by EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod both --out dryrun.json
"""
import argparse
import json
import time
import traceback

try:
    import zstandard as _zstd
except ImportError:  # pragma: no cover
    _zstd = None

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, cell_is_applicable, get_config, list_archs
from repro.dist.sharding import use_sharding_ctx
from repro.launch.mesh import make_production_mesh, make_small_mesh
from repro.launch.steps import (
    abstract_cache,
    abstract_serve_params,
    abstract_train_state,
    input_specs,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
from repro.optim import AdamWConfig
from repro.roofline.analysis import Roofline, count_params, model_flops
from repro.roofline.hlo_stats import parse_hlo_stats


def _per_device_bytes(tree) -> int:
    total = 0
    for l in jax.tree.leaves(tree):
        if hasattr(l, "sharding") and l.sharding is not None:
            shard_shape = l.sharding.shard_shape(l.shape)
            n = 1
            for d in shard_shape:
                n *= d
        else:
            n = l.size
        total += n * l.dtype.itemsize
    return total


def _mem_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _save_hlo(rec: dict, hlo: str) -> None:
    """Persist compressed HLO so roofline analysis can be re-run offline
    (results/reanalyze.py) without recompiling."""
    if _zstd is None:
        return
    os.makedirs("results/hlo", exist_ok=True)
    tag = rec.get("variant", "baseline")
    if rec.get("overrides"):
        import hashlib

        tag += "-" + hashlib.md5(
            json.dumps(rec["overrides"], sort_keys=True).encode()
        ).hexdigest()[:8]
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}_{tag}.hlo.zst"
    path = os.path.join("results/hlo", name)
    with open(path, "wb") as f:
        f.write(_zstd.ZstdCompressor(level=6).compress(hlo.encode()))
    rec["hlo_path"] = path


def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    small_mesh: bool = False,
    verbose: bool = True,
    variant: str = "baseline",
    overrides: dict | None = None,
) -> dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    if variant == "optimized":
        # beyond-paper engine knobs validated in EXPERIMENTS.md §Perf
        cfg = cfg.with_(cache_in_carry=True, moe_block_dispatch=True)
    if overrides:
        cfg = cfg.with_(**overrides)
    rec: dict = {
        "arch": arch, "shape": shape_name, "variant": variant,
        "overrides": overrides or {},
        "mesh": ("small" if small_mesh else ("2x16x16" if multi_pod else "16x16")),
    }
    if not cell_is_applicable(arch, shape_name):
        rec.update(status="skipped",
                   reason="full-attention arch: long_500k N/A (DESIGN.md §4)")
        return rec

    t0 = time.perf_counter()
    mesh = (
        make_small_mesh(multi_pod=multi_pod) if small_mesh
        else make_production_mesh(multi_pod=multi_pod)
    )
    chips = mesh.devices.size
    # serving caches sized to the cell's sequence length
    cfg = cfg.with_(max_cache_len=shape.seq_len)
    enc_len = (
        shape.seq_len // cfg.enc_frame_ratio if cfg.family == "encdec" else 0
    )

    try:
        with mesh, use_sharding_ctx(mesh, cfg):
            batch = input_specs(cfg, shape, mesh)
            donate = (0,)  # train: donate state (params+opt updated in place)
            if shape.kind == "train":
                opt_cfg = AdamWConfig()
                state = abstract_train_state(cfg, opt_cfg, mesh)
                fn = make_train_step(cfg, opt_cfg)
                args = (state, batch)
                rec["state_bytes_per_device"] = _per_device_bytes(state)
            elif shape.kind == "prefill":
                params = abstract_serve_params(cfg, mesh)
                cache = abstract_cache(
                    cfg, mesh, shape.global_batch, shape.seq_len, enc_len
                )
                fn = make_prefill_step(cfg)
                args = (params, cache, batch)
                donate = (1,)  # serve: donate the cache (updated in place)
                rec["state_bytes_per_device"] = _per_device_bytes(
                    (params, cache)
                )
            else:  # decode
                params = abstract_serve_params(cfg, mesh)
                cache = abstract_cache(
                    cfg, mesh, shape.global_batch, shape.seq_len, enc_len
                )
                fn = make_decode_step(cfg)
                args = (params, cache, batch["tokens"])
                donate = (1,)
                rec["state_bytes_per_device"] = _per_device_bytes(
                    (params, cache)
                )

            lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
            t1 = time.perf_counter()
            compiled = lowered.compile()
            t2 = time.perf_counter()

        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        _save_hlo(rec, hlo)
        # cost_analysis counts while-body (lax.scan) ops ONCE → useless for
        # scanned models; use the trip-count-aware HLO analyzer instead and
        # keep XLA's numbers for reference.
        stats = parse_hlo_stats(hlo)
        rec["xla_cost_analysis"] = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
        }
        rec["n_whiles"] = stats.n_whiles
        rec["unknown_trip_whiles"] = stats.unknown_trip_whiles
        n_total, n_active = count_params(cfg)
        rl = Roofline(
            arch=arch, shape=shape_name, mesh=rec["mesh"], chips=chips,
            flops_per_device=stats.dot_flops,
            bytes_per_device=stats.traffic_bytes,
            coll_bytes_per_device=stats.collective_bytes,
            coll_detail=stats.collectives,
            model_flops_total=model_flops(cfg, shape, n_total, n_active),
            min_bytes_per_device=float(rec.get("state_bytes_per_device", 0)),
        )
        rec.update(
            status="ok",
            chips=chips,
            lower_s=round(t1 - t0, 2),
            compile_s=round(t2 - t1, 2),
            params_total=n_total,
            params_active=n_active,
            memory_analysis=_mem_analysis_dict(compiled),
            roofline=rl.row(),
            hlo_bytes=len(hlo),
        )
        if verbose:
            ma = rec["memory_analysis"]
            print(
                f"[OK] {arch} × {shape_name} × {rec['mesh']}: "
                f"compile={rec['compile_s']}s "
                f"state/dev={rec.get('state_bytes_per_device', 0)/2**30:.2f}GiB "
                f"temp/dev={ma.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
                f"dominant={rl.dominant} "
                f"terms=({rl.compute_s:.4f},{rl.memory_s:.4f},"
                f"{rl.collective_s:.4f})s frac={rl.roofline_fraction:.3f}"
            )
    except Exception as e:  # noqa: BLE001 — recorded, sweep continues
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[FAIL] {arch} × {shape_name} × {rec['mesh']}: {e}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=[*SHAPES, None])
    ap.add_argument("--multi-pod", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--small-mesh", action="store_true",
                    help="8-device mesh (CI sharding test)")
    ap.add_argument("--out", default=None, help="append JSONL results here")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "optimized"])
    args = ap.parse_args()

    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    pods = {"single": [False], "multi": [True], "both": [False, True]}[args.multi_pod]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                rec = run_cell(arch, shape, multi_pod=mp,
                               small_mesh=args.small_mesh, variant=args.variant)
                n_fail += rec["status"] == "error"
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
